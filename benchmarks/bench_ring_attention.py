"""Ring attention (context parallelism) — graph vs kernel backends.

The carry-passing overlap applied to attention itself: K/V chunks ride
the transport while the blockwise online softmax folds them into the
resident (m, l, acc) state. Kernel rows run the executor's ``ring_fold``
protocol (ring) / low-latency gather + host replay (one_shot) on the
emulated DMA engine — a correctness vehicle, benched at the smallest
sequence only. Row names are NEW in this PR (the ``--check`` gate
compares by exact name; existing rows never change names).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as cm
from repro.core import overlap, schedules
from repro.core.ring_attention import ring_attention

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("cp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    b, h, hkv, d = 2, 4, 2, 16
    for s_loc in (8, 32):
        s = s_loc * w
        q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        base_us = None
        for mode in overlap.transports_for("ring_attention",
                                           include_baseline=True):
            for backend in overlap.backends_for("ring_attention"):
                if overlap.resolve_backend("ring_attention", backend,
                                           mode) != backend:
                    continue  # no kernel lowering for this mode
                if backend == "kernel" and s_loc > 8:
                    continue  # emulated host callbacks: smallest shape only
                f = cm.make_sharded(
                    functools.partial(ring_attention, axis="cp", causal=True,
                                      mode=mode, backend=backend),
                    mesh, (P(None, None, "cp", None),) * 3,
                    P(None, None, "cp", None))
                us = time_fn(f, q, k, v)
                if mode == "none" and backend == "graph":
                    base_us = us
                derived = (f"speedup={base_us / us:.2f}x"
                           if base_us else "")
                suffix = "/kernel" if backend == "kernel" else ""
                out.append(row(
                    f"ring_attn/{b}x{h}x{s}x{d}/{mode}{suffix}", us, derived))

    # placement axis: causal load balance at worlds 4 and 8 — zigzag
    # (one early + one late half-chunk per rank) vs contiguous, the same
    # ring transport. The wall-clock gap on CPU is modest (the fold
    # skips fully-masked blocks, so contiguous ranks idle rather than
    # slow the critical path at block granularity); the traced per-PE
    # tile_compute spread is pinned in tests/test_placement_trace.py.
    s_loc = 32
    for wp in (4, 8):
        if wp > jax.device_count():
            continue
        mesh_p = jax.make_mesh((wp,), ("cp",),
                               axis_types=(jax.sharding.AxisType.Auto,))
        s = s_loc * wp
        q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        base_us = None
        for placement in ("contiguous", "zigzag"):
            imb = schedules.causal_imbalance(placement, wp, s_loc)
            f = cm.make_sharded(
                functools.partial(ring_attention, axis="cp", causal=True,
                                  mode="ring", placement=placement),
                mesh_p, (P(None, None, "cp", None),) * 3,
                P(None, None, "cp", None))
            us = time_fn(f, q, k, v)
            if placement == "contiguous":
                base_us = us
                if wp == w:
                    continue  # already emitted by the loop above (w8/s256)
                out.append(row(f"ring_attn/{b}x{h}x{s}x{d}/ring", us,
                               f"imbalance={imb:.2f}"))
            else:
                out.append(row(
                    f"ring_attn/{b}x{h}x{s}x{d}/ring/{placement}", us,
                    f"speedup={base_us / us:.2f}x;imbalance={imb:.2f}"))
    return out
