"""Benchmark utilities: timing + the standard CSV row format.

Under ``benchmarks/run.py --trace`` (``_REPRO_BENCH_TRACE`` set in the
inner process) :func:`time_fn` drains the :mod:`repro.obs` event trace
of its timed iterations into ``LAST_MEASURED`` — measured
``overlap_eff`` / ``stall_frac`` for the row :func:`row` is about to
format — and accumulates every event into ``TRACE_EVENTS`` for the
run-level Chrome-trace artifact. Without the env var both hooks are
inert and rows keep the plain ``name,us,derived`` shape.
"""
from __future__ import annotations

import os
import time

import jax

# measured fields of the most recent time_fn call (row() appends them)
LAST_MEASURED: dict = {}
# every traced event of the bench run (run.py saves the combined trace)
TRACE_EVENTS: list = []


def _tracing() -> bool:
    return bool(os.environ.get("_REPRO_BENCH_TRACE"))


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds."""
    global LAST_MEASURED
    LAST_MEASURED = {}
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    if _tracing():
        from repro import obs

        obs.clear()  # attribute events to the timed iterations only
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    if _tracing():
        from repro import obs

        events = obs.events(clear=True)
        if events:
            s = obs.metrics.summarize(events)
            LAST_MEASURED = {"overlap_eff": round(s.overlap_efficiency, 4),
                             "stall_frac": round(s.stall_frac, 4)}
            TRACE_EVENTS.extend(events)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    for k, v in LAST_MEASURED.items():
        line += f",{k}={v}"
    return line
