"""Fused rs->ag boundary (CoCoNet-style): ``matmul_rs_ag_matmul`` vs
the back-to-back unfused pair at the attention-out -> MLP-in seam.

``unfused_pair`` rows time ``matmul_rs`` + seam fn + ``ag_matmul`` as
two separate declarations — the boundary collective fully exposed
between them. ``fused`` rows time the single chained declaration (graph:
rs_pipeline -> ag_pipeline through the fold API; kernel: the executor's
chained ``push_rs_ring_ag`` protocol with no barrier between the
halves). Under ``run.py --trace`` the kernel rows carry measured
``overlap_eff``: the chain drops the pair's two mid-chain barrier
rendezvous — the rs exit + ag entry flush, an exact event-count fact —
and mid-stream rendezvous count as exposed comm in the obs reduction
(only a PE's first barrier per kernel instance is launch skew), so the
fused row's overlap_eff reads higher than the unfused pair's at the
same shape. Both facts are pinned by tests/test_benchmarks.py.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import ops
from repro.core import collective_matmul as cm

from .common import row, time_fn

SPECS = ((P(None, "tp"), P("tp", None), P(None, "tp"), P("tp", None)),
         P(None, "tp"))

# (m, k, n, f) boundary shapes; module-level so tests can trim the sweep
SHAPES = [(512, 256, 256, 256), (1024, 512, 512, 512)]


def _mid(r, x):
    """The rank-local seam: residual add + nonlinearity (rows stay rows)."""
    return jnp.tanh(r + x)


def _unfused(y, wo, wi, xr, backend):
    r = ops.matmul_rs(y, wo, axis="tp", mode="ring", backend=backend,
                      out_dtype=jnp.float32)
    return ops.ag_matmul(_mid(r, xr), wi, axis="tp", mode="ring",
                         backend=backend, out_dtype=jnp.float32)


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for m, k, n, f in SHAPES:
        y = jnp.asarray(rng.randn(m, k), jnp.float32)
        wo = jnp.asarray(rng.randn(k, n), jnp.float32)
        wi = jnp.asarray(rng.randn(n, f), jnp.float32)
        xr = jnp.asarray(rng.randn(m, n), jnp.float32)
        shape = f"{m}x{k}x{n}x{f}"

        # the registered "none" baseline: composed pair on XLA collectives
        fb = cm.make_sharded(
            functools.partial(ops.matmul_rs_ag_matmul, axis="tp",
                              mode="none", out_dtype=jnp.float32, mid=_mid),
            mesh, *SPECS)
        base_us = time_fn(fb, y, wo, wi, xr)
        out.append(row(f"boundary/{shape}/none", base_us, "xla_baseline"))

        for backend in ("graph", "kernel"):
            if backend == "kernel" and m > 512:
                # emulated-DMA rows: smallest shape only (correctness
                # vehicle on CPU — see bench_ag_gemm)
                continue
            suffix = "/kernel" if backend == "kernel" else ""
            fu = cm.make_sharded(
                functools.partial(_unfused, backend=backend), mesh, *SPECS)
            us_un = time_fn(fu, y, wo, wi, xr)
            out.append(row(f"boundary/{shape}/unfused_pair/ring{suffix}",
                           us_un, f"cpu_speedup={base_us / us_un:.2f}x"))
            ff = cm.make_sharded(
                functools.partial(ops.matmul_rs_ag_matmul, axis="tp",
                                  mode="ring", backend=backend,
                                  out_dtype=jnp.float32, mid=_mid),
                mesh, *SPECS)
            us_f = time_fn(ff, y, wo, wi, xr)
            out.append(row(f"boundary/{shape}/fused/ring{suffix}", us_f,
                           f"vs_unfused_pair={us_un / us_f:.2f}x"))

        # boundary sub-chunking (the chunks knob splits the reduced block)
        f2 = cm.make_sharded(
            functools.partial(ops.matmul_rs_ag_matmul, axis="tp",
                              mode="ring", chunks=2, out_dtype=jnp.float32,
                              mid=_mid),
            mesh, *SPECS)
        us2 = time_fn(f2, y, wo, wi, xr)
        out.append(row(f"boundary/{shape}/fused/ring_sub2", us2,
                       f"cpu_speedup={base_us / us2:.2f}x"))
    return out
