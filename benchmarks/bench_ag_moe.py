"""Paper Table 4 — AllGather + MoE GroupGEMM (TP mode, ring overlap).

Reduced versions of the paper's AG+MoE-1/-5/-13 rows (tokens/rank, hidden
sizes scaled to CPU); derived column reports tokens/s and the paper-shape
v5e analytic overlap win for the token gather.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import moe_overlap as mo
from repro.core import tuner
from repro.kernels import ops

from .common import row, time_fn

# (paper row, tokens/rank, in_hidden, out_hidden(dff), experts, topk)
CASES = [
    ("AG+MoE-1", 64, 128, 96, 15, 4),
    ("AG+MoE-5", 64, 256, 128, 8, 2),
    ("AG+MoE-13", 128, 96, 128, 16, 6),
]


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for name, t_loc, d, dff, e, k in CASES:
        x = jnp.asarray(rng.randn(t_loc * w, d), jnp.float32)
        logits = jnp.asarray(rng.randn(t_loc * w, e), jnp.float32)
        wi = jnp.asarray(rng.randn(e, d, dff) / np.sqrt(d), jnp.float32)
        wo = jnp.asarray(rng.randn(e, dff, d) / np.sqrt(dff), jnp.float32)
        cap = max(8, t_loc * k // e * 2)

        def expert_fn(tok, lg):
            dsp, info = mo.topk_dispatch(tok, lg, k, cap)
            y = ops.grouped_matmul(dsp, wi, out_dtype=tok.dtype)
            y = jax.nn.silu(y)
            y = ops.grouped_matmul(y, wo, out_dtype=tok.dtype)
            return mo.topk_combine(y, info)

        def ag_moe_step(xl, ll, mode):
            return mo.ag_moe(xl, ll, expert_fn, "tp", mode=mode)

        for mode in ("ring", "one_shot"):
            f = jax.jit(jax.shard_map(
                functools.partial(ag_moe_step, mode=mode), mesh=mesh,
                in_specs=(P("tp", None), P("tp", None)),
                out_specs=P(None, None), check_vma=False))
            us = time_fn(f, x, logits)
            toks_per_s = t_loc * w / (us * 1e-6)
            # paper-scale analytic: token gather of 1024 x 14336 over 8 ranks
            choice = tuner.analytic_ag_matmul(1024, 14336, 4096 // 8, 8)
            out.append(row(f"ag_moe/{name}/{mode}", us,
                           f"tokens_per_s={toks_per_s:.0f}"
                           f";v5e_gather_mode={choice.mode}"))
    return out
