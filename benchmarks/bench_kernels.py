"""Single-device kernel throughput (XLA path on CPU; the Pallas TPU path
is validated separately in interpret mode). Derived column: the v5e
roofline time for the same shape (what the Pallas kernel targets)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.kernels import ops

from .common import row, time_fn


def rows():
    rng = np.random.RandomState(0)
    out = []
    spec = hw.TPU_V5E

    m, k, n = 1024, 1024, 1024
    a = jnp.asarray(rng.randn(m, k), jnp.bfloat16)
    b = jnp.asarray(rng.randn(k, n), jnp.bfloat16)
    f = jax.jit(lambda x, y: ops.matmul(x, y))
    us = time_fn(f, a, b)
    v5e = 2 * m * k * n / spec.peak_flops_bf16 * 1e6
    out.append(row(f"kernel_matmul/{m}x{k}x{n}", us, f"v5e_mxu_us={v5e:.1f}"))

    bsz, hq, hkv, s, d = 2, 8, 2, 1024, 64
    q = jnp.asarray(rng.randn(bsz, hq, s, d), jnp.bfloat16)
    kk = jnp.asarray(rng.randn(bsz, hkv, s, d), jnp.bfloat16)
    v = jnp.asarray(rng.randn(bsz, hkv, s, d), jnp.bfloat16)
    f = jax.jit(lambda q_, k_, v_: ops.flash_attention(q_, k_, v_))
    us = time_fn(f, q, kk, v)
    flops = 4 * bsz * hq * s * s * d / 2  # causal
    out.append(row(f"kernel_flash/{bsz}x{hq}x{s}x{d}", us,
                   f"v5e_mxu_us={flops / spec.peak_flops_bf16 * 1e6:.1f}"))

    b2, l, h, p, g, ss = 2, 512, 8, 64, 1, 64
    x = jnp.asarray(rng.randn(b2, l, h, p) * 0.3, jnp.float32)
    dt = jnp.asarray(rng.rand(b2, l, h) * 0.3 + 0.01, jnp.float32)
    aa = jnp.asarray(-np.abs(rng.rand(h)) - 0.1, jnp.float32)
    bm = jnp.asarray(rng.randn(b2, l, g, ss) * 0.3, jnp.float32)
    cm_ = jnp.asarray(rng.randn(b2, l, g, ss) * 0.3, jnp.float32)
    f = jax.jit(lambda *args: ops.ssd_scan(*args)[0])
    us = time_fn(f, x, dt, aa, bm, cm_)
    flops = 2 * b2 * l * 128 * h * (ss + p)  # chunked intra matmuls approx
    out.append(row(f"kernel_ssd/{b2}x{l}x{h}x{p}", us,
                   f"v5e_mxu_us={flops / spec.peak_flops_bf16 * 1e6:.2f}"))

    e, cap, kd, nd = 8, 128, 256, 256
    xg = jnp.asarray(rng.randn(e, cap, kd), jnp.bfloat16)
    wg = jnp.asarray(rng.randn(e, kd, nd), jnp.bfloat16)
    f = jax.jit(lambda x_, w_: ops.grouped_matmul(x_, w_))
    us = time_fn(f, xg, wg)
    flops = 2 * e * cap * kd * nd
    out.append(row(f"kernel_grouped/{e}x{cap}x{kd}x{nd}", us,
                   f"v5e_mxu_us={flops / spec.peak_flops_bf16 * 1e6:.2f}"))
    return out
