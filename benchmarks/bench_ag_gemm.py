"""Paper Fig. 11/13 — AllGather-GEMM: overlapped vs. monolithic baseline.

Measured on 8 virtual CPU devices (reduced shapes); the ``derived`` column
is the analytic v5e estimate for a paper-scale shape (M=4096, K=12288,
N=3072/rank, W=16) from the tuner's roofline model: predicted speedup of
the chosen overlap mode over the serialized baseline.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as cm
from repro.core import overlap, tuner

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for m, k, n in [(512, 256, 256), (1024, 512, 512), (2048, 512, 1024)]:
        a = jnp.asarray(rng.randn(m, k), jnp.float32)
        b = jnp.asarray(rng.randn(k, n), jnp.float32)
        base_us = None
        for mode in overlap.transports_for("ag_matmul", include_baseline=True):
            for backend in overlap.backends_for("ag_matmul"):
                if overlap.resolve_backend("ag_matmul", backend, mode) != backend:
                    continue  # no kernel lowering for this mode
                if backend == "kernel" and m > 512:
                    # CPU runs the emulated-DMA backend (host callbacks):
                    # a correctness vehicle, benched at the small shape
                    # only to keep the suite fast. TPU perf comes from
                    # the pltpu lowering, not from these rows.
                    continue
                f = cm.make_sharded(
                    functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                                      backend=backend, out_dtype=jnp.float32),
                    mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
                us = time_fn(f, a, b)
                if mode == "none":
                    base_us = us
                # derived: v5e analytic prediction at paper scale
                choice = tuner.analytic_ag_matmul(4096 // 16, 12288, 3072, 16)
                none_t = tuner.analytic_ag_matmul(
                    4096 // 16, 12288, 3072, 16, candidates=("none",)).t_total
                derived = (f"v5e_speedup={none_t / choice.t_total:.2f}x"
                           f";cpu_speedup={base_us / us:.2f}x")
                suffix = "/kernel" if backend == "kernel" else ""
                out.append(row(f"ag_gemm/{m}x{k}x{n}/{mode}{suffix}", us,
                               derived))
                if m == 512 and mode == "ring":
                    # wire axis: int8 riding chunks at the smallest shape
                    # (both backends), f32 row above is the reference
                    f8 = cm.make_sharded(
                        functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                                          backend=backend,
                                          out_dtype=jnp.float32, wire="int8"),
                        mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
                    us8 = time_fn(f8, a, b)
                    out.append(row(f"ag_gemm/{m}x{k}x{n}/{mode}{suffix}/int8",
                                   us8, f"vs_f32={us / us8:.2f}x"))
    return out
