"""Paper Fig. 10 — hierarchical (2-level) collective matmuls on a
compound (pod x ring-in-pod) mesh, graph vs kernel backends.

The kernel rows run the executor's two-axis protocols (``two_level_ag``
/ ``two_level_rs``: pod-local one_shot exchange concurrent with the
inter-pod ring) on the emulated DMA engine — a correctness vehicle,
benched at the smallest shape only. Row names are NEW in this PR (the
``--check`` gate compares by exact name; existing rows never change
names).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as cm
from repro.core import overlap

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    wo, wi = 2, max(1, w // 2)
    mesh2 = jax.make_mesh((wo, wi), ("pod", "tp"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.RandomState(0)
    out = []
    for m, k, n in [(256, 128, 128), (1024, 256, 512)]:
        a = jnp.asarray(rng.randn(m, k), jnp.float32)
        b = jnp.asarray(rng.randn(k, n), jnp.float32)
        a2 = jnp.asarray(rng.randn(m, 4 * w), jnp.float32)
        b2 = jnp.asarray(rng.randn(4 * w, n), jnp.float32)
        for op, fn, args, specs in (
            ("ag_gemm_2level", cm.ag_matmul_2level, (a, b),
             ((P(("pod", "tp"), None), P(None, ("pod", "tp"))),
              P(None, ("pod", "tp")))),
            ("gemm_rs_2level", cm.matmul_rs_2level, (a2, b2),
             ((P(None, ("pod", "tp")), P(("pod", "tp"), None)),
              P(("pod", "tp"), None))),
        ):
            reg = op.replace("ag_gemm", "ag_matmul").replace(
                "gemm_rs", "matmul_rs")
            base_us = None
            for mode in overlap.transports_for(reg, include_baseline=True):
                for backend in overlap.backends_for(reg):
                    if overlap.resolve_backend(reg, backend, mode) != backend:
                        continue
                    if backend == "kernel" and m > 256:
                        continue  # emulated: smallest shape only
                    f = cm.make_sharded(
                        functools.partial(fn, inner_axis="tp",
                                          outer_axis="pod", mode=mode,
                                          backend=backend,
                                          out_dtype=jnp.float32),
                        mesh2, *specs)
                    us = time_fn(f, *args)
                    if mode == "none" and backend == "graph":
                        base_us = us
                    derived = (f"speedup={base_us / us:.2f}x"
                               if base_us else "")
                    suffix = "/kernel" if backend == "kernel" else ""
                    out.append(row(f"{op}/{m}x{k}x{n}/{mode}{suffix}", us,
                                   derived))
    return out
