"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the machine-readable
``BENCH_overlap.json`` (one ``{op, mode, world, us_per_call}`` record per
row) so the perf trajectory is tracked across PRs. Multi-device benches
need >1 virtual device, so this driver re-execs itself in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag is
scoped to that subprocess, never set globally).

  Fig. 11/13  bench_ag_gemm        AG+GEMM overlap vs monolithic
  Fig. 12/14  bench_gemm_rs        GEMM+RS overlap vs monolithic
  Table 4     bench_ag_moe         AllGather MoE GroupGEMM
  Table 5     bench_moe_rs         MoE GroupGEMM ReduceScatter
  Fig. 15     bench_flash_decode   distributed flash decoding scaling
  Fig. 16     bench_a2a            EP AllToAll dispatch/combine
  Fig. 19     bench_ll_allgather   low-latency AllGather
  (kernels)   bench_kernels        single-device kernel throughput
"""
import json
import os
import subprocess
import sys

def _mode_vocabulary():
    """Transport + baseline names, from the engine registry (the single
    source of truth): a transport added there parses here automatically."""
    from repro.core import overlap

    vocab = set(overlap.TRANSPORTS)
    for spec in overlap.registry().values():
        vocab.add(spec.baseline)
    return vocab


def parse_row(tag: str, line: str, world: int, modes):
    """'op/shape/mode,us,derived' -> {op, mode, world, us_per_call} or None."""
    parts = line.split(",")
    if len(parts) < 2:
        return None
    name = parts[0]
    try:
        us = float(parts[1])
    except ValueError:
        return None
    segs = name.split("/")
    mode = segs[-1] if segs[-1] in modes else ""
    return {
        "op": segs[0],
        "mode": mode,
        "world": world,
        "us_per_call": us,
        "name": f"{tag}/{name}",
    }


def _inner() -> None:
    import jax

    from . import (
        bench_a2a,
        bench_ag_gemm,
        bench_ag_moe,
        bench_flash_decode,
        bench_gemm_rs,
        bench_kernels,
        bench_ll_allgather,
        bench_moe_rs,
    )

    world = min(8, jax.device_count())  # the mesh size multi-device benches use
    modes = _mode_vocabulary()
    print("name,us_per_call,derived")
    modules = [
        ("fig11_13", bench_ag_gemm, world),
        ("fig12_14", bench_gemm_rs, world),
        ("table4", bench_ag_moe, world),
        ("table5", bench_moe_rs, world),
        ("fig15", bench_flash_decode, world),
        ("fig16", bench_a2a, world),
        ("fig19", bench_ll_allgather, world),
        ("kernels", bench_kernels, 1),  # single-device kernel throughput
    ]
    records = []
    for tag, mod, mod_world in modules:
        try:
            for line in mod.rows():
                print(f"{tag}/{line}")
                rec = parse_row(tag, line, mod_world, modes)
                if rec is not None:
                    records.append(rec)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{tag}/ERROR,,{type(e).__name__}: {e}")
        sys.stdout.flush()
    out_path = os.environ.get("_REPRO_BENCH_JSON", "BENCH_overlap.json")
    with open(out_path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"# wrote {len(records)} records to {out_path}", file=sys.stderr)


def main() -> None:
    if os.environ.get("_REPRO_BENCH_INNER") == "1":
        _inner()
        return
    env = dict(os.environ)
    env["_REPRO_BENCH_INNER"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run([sys.executable, "-m", "benchmarks.run"], env=env,
                          cwd=here)
    sys.exit(proc.returncode)


if __name__ == "__main__":
    main()
