"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the machine-readable
``BENCH_overlap.json`` (one ``{op, mode, backend, world, us_per_call}``
record per row) so the perf trajectory is tracked across PRs.
Multi-device benches need >1 virtual device, so this driver re-execs
itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag is
scoped to that subprocess, never set globally).

  Fig. 11/13  bench_ag_gemm        AG+GEMM overlap vs monolithic
  Fig. 12/14  bench_gemm_rs        GEMM+RS overlap vs monolithic
  Table 4     bench_ag_moe         AllGather MoE GroupGEMM
  Table 5     bench_moe_rs         MoE GroupGEMM ReduceScatter
  Fig. 15     bench_flash_decode   distributed flash decoding scaling
  Fig. 16     bench_a2a            EP AllToAll dispatch/combine
  Fig. 19     bench_ll_allgather   low-latency AllGather
  Fig. 10     bench_two_level      hierarchical (2-level) collective matmuls
  (long ctx)  bench_ring_attention ring attention (context parallelism)
  (boundary)  bench_boundary       fused rs->ag seam vs unfused pair (CoCoNet)
  (serve)     bench_serve          paged+chunked-prefill engine vs tokenwise
  (kernels)   bench_kernels        single-device kernel throughput

Regression gate (CI): ``--check`` reruns the suite into a scratch file
and compares per-record timings against the committed
``BENCH_overlap.json``. Ratios are normalized by the run's median
fresh/baseline ratio (the machine-speed factor), so a uniformly slower
runner passes while a single op regressing relative to the suite fails:
a row whose normalized slowdown exceeds ``1 + tol`` (``--tolerance``,
default 1.0 — CPU timing is noisy) or a disappeared record fails the
run. ``--update`` refreshes the committed baseline instead.
"""
import argparse
import json
import os
import subprocess
import sys

# Rows faster than this are excluded from the regression comparison
# (sub-ms CPU timings are pure scheduling noise); everything above it —
# including the low-latency ll_allgather / flash_decode rows the suite
# exists to track — stays gated, with the retry-and-keep-best pass in
# main() absorbing one-off scheduler stalls on shared runners.
_MIN_CHECK_US = 500.0


def _mode_vocabulary():
    """Transport + baseline names, from the engine registry (the single
    source of truth): a transport added there parses here automatically."""
    from repro.core import overlap

    vocab = set(overlap.TRANSPORTS)
    for spec in overlap.registry().values():
        vocab.add(spec.baseline)
    return vocab


def parse_row(tag: str, line: str, world: int, modes):
    """'op/shape/mode[/backend][/wire][/placement],us,derived[,k=v...]'
    -> a BENCH record or None.

    Each record carries the row's resolved overlap ``policy`` (the
    ``repro.ops.OverlapPolicy`` resolution the row ran under — mode,
    backend, sub-chunk count, wire dtype, chunk placement) rather than
    loose strings. Trailing ``k=v`` fields (the ``--trace`` run's
    measured ``overlap_eff`` / ``stall_frac``) land under ``measured``."""
    parts = line.split(",")
    if len(parts) < 2:
        return None
    name = parts[0]
    try:
        us = float(parts[1])
    except ValueError:
        return None
    measured = {}
    for extra in parts[2:]:
        k, sep, v = extra.partition("=")
        if sep and k in ("overlap_eff", "stall_frac"):
            try:
                measured[k] = float(v)
            except ValueError:
                pass
    segs = name.split("/")
    placement = "contiguous"  # implied, like "f32"; non-default rides last
    if segs[-1] in ("zigzag", "striped"):
        placement = segs[-1]
        segs = segs[:-1]
    wire = "f32"
    if segs[-1] in ("int8", "fp8"):  # trailing wire segment ("f32" is implied)
        wire = segs[-1]
        segs = segs[:-1]
    backend = "graph"
    if segs[-1] in ("graph", "kernel"):
        backend = segs[-1]
        segs = segs[:-1]
    chunks = 1
    base, _, sub = segs[-1].partition("_sub")
    if sub.isdigit() and base in modes:  # e.g. "ring_sub2" = ring, 2 chunks
        segs[-1] = base
        chunks = int(sub)
    mode = segs[-1] if segs[-1] in modes else ""
    rec = {
        "op": segs[0],
        "policy": {"mode": mode, "backend": backend, "chunks": chunks,
                   "wire": wire, "placement": placement},
        "world": world,
        "us_per_call": us,
        "name": f"{tag}/{name}",
    }
    if measured:
        rec["measured"] = measured
    return rec


def _inner() -> None:
    import jax

    trace_path = os.environ.get("_REPRO_BENCH_TRACE")
    if trace_path:
        # enable BEFORE any bench compiles so compute spans are traced
        from repro import obs

        obs.enable()

    from . import (
        bench_a2a,
        bench_ag_gemm,
        bench_ag_moe,
        bench_boundary,
        bench_flash_decode,
        bench_gemm_rs,
        bench_kernels,
        bench_ll_allgather,
        bench_moe_rs,
        bench_ring_attention,
        bench_serve,
        bench_two_level,
    )

    world = min(8, jax.device_count())  # the mesh size multi-device benches use
    modes = _mode_vocabulary()
    print("name,us_per_call,derived")
    modules = [
        ("fig11_13", bench_ag_gemm, world),
        ("fig12_14", bench_gemm_rs, world),
        ("table4", bench_ag_moe, world),
        ("table5", bench_moe_rs, world),
        ("fig15", bench_flash_decode, world),
        ("fig16", bench_a2a, world),
        ("fig19", bench_ll_allgather, world),
        ("fig10", bench_two_level, world),  # hierarchical (2-level) matmuls
        ("long_ctx", bench_ring_attention, world),  # context parallelism
        ("boundary", bench_boundary, world),  # fused rs->ag seam (CoCoNet)
        ("serve", bench_serve, 4),  # paged+chunked-prefill engine vs tokenwise
        ("kernels", bench_kernels, 1),  # single-device kernel throughput
    ]
    records = []
    for tag, mod, mod_world in modules:
        try:
            for line in mod.rows():
                print(f"{tag}/{line}")
                rec = parse_row(tag, line, mod_world, modes)
                if rec is not None:
                    records.append(rec)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{tag}/ERROR,,{type(e).__name__}: {e}")
        sys.stdout.flush()
    out_path = os.environ.get("_REPRO_BENCH_JSON", "BENCH_overlap.json")
    with open(out_path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"# wrote {len(records)} records to {out_path}", file=sys.stderr)
    if trace_path:
        from repro import obs

        from . import common

        n = obs.trace.save(trace_path, common.TRACE_EVENTS + obs.events())
        print(f"# wrote {n} trace events to {trace_path}", file=sys.stderr)


def check_regressions(baseline_path: str, fresh_path: str,
                      tolerance: float) -> int:
    """Compare fresh timings against the committed baseline. Returns the
    number of failures (regressed or disappeared records).

    The baseline was recorded on a different machine, so absolute
    microseconds are not comparable — the check normalizes every row's
    fresh/baseline ratio by the run's MEDIAN ratio (the machine-speed
    factor) and flags rows whose normalized slowdown exceeds
    ``1 + tolerance``. A uniformly slower CI runner passes; a single op
    regressing relative to the rest of the suite fails."""
    with open(baseline_path) as f:
        baseline = {r["name"]: r for r in json.load(f)}
    with open(fresh_path) as f:
        fresh = {r["name"]: r for r in json.load(f)}
    failures = 0
    ratios = {}
    for name, base in sorted(baseline.items()):
        got = fresh.get(name)
        if got is None:
            print(f"REGRESSION: record disappeared: {name}")
            failures += 1
            continue
        if base["us_per_call"] >= _MIN_CHECK_US:
            ratios[name] = got["us_per_call"] / max(1e-9, base["us_per_call"])
    if ratios:
        ordered = sorted(ratios.values())
        machine = ordered[len(ordered) // 2]  # median = machine-speed factor
        print(f"# machine-speed factor vs baseline host: {machine:.2f}x")
        for name, ratio in sorted(ratios.items()):
            if ratio > machine * (1.0 + tolerance):
                print(f"REGRESSION: {name}: {ratio:.2f}x vs baseline "
                      f"(> {machine * (1.0 + tolerance):.2f}x = "
                      f"median {machine:.2f}x * {1.0 + tolerance:.2f})")
                failures += 1
    new = sorted(set(fresh) - set(baseline))
    if new:
        print(f"# {len(new)} new records (not in baseline): first={new[0]}")
    if failures == 0:
        print(f"# bench check OK: {len(ratios)} comparable records within "
              f"{1.0 + tolerance:.2f}x of the machine-speed median")
    return failures


def main() -> None:
    if os.environ.get("_REPRO_BENCH_INNER") == "1":
        _inner()
        return
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_overlap.json; "
                         "nonzero exit on regression")
    ap.add_argument("--update", action="store_true",
                    help="refresh the committed BENCH_overlap.json")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="allowed slowdown fraction for --check "
                         "(1.0 = fail above 2x baseline)")
    ap.add_argument("--trace", nargs="?", const="BENCH_trace.json",
                    default=None, metavar="PATH",
                    help="enable repro.obs tracing: write the run's "
                         "Chrome-trace JSON (default BENCH_trace.json) and "
                         "add measured overlap_eff/stall_frac to rows")
    args = ap.parse_args()
    if args.trace and args.update:
        # instrumented timings carry host-callback overhead — they must
        # never become the committed regression baseline
        ap.error("--trace cannot be combined with --update")

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline = os.path.join(here, "BENCH_overlap.json")
    out_json = baseline
    if args.check and not args.update:
        out_json = os.path.join(here, "BENCH_overlap.fresh.json")
    elif args.trace:
        # a traced run's timings are instrumented — keep them out of the
        # committed baseline too
        out_json = os.path.join(here, "BENCH_overlap.traced.json")

    env = dict(os.environ)
    env["_REPRO_BENCH_INNER"] = "1"
    env["_REPRO_BENCH_JSON"] = out_json
    if args.trace:
        env["_REPRO_BENCH_TRACE"] = os.path.abspath(args.trace)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run([sys.executable, "-m", "benchmarks.run"], env=env,
                          cwd=here)
    if proc.returncode != 0:
        sys.exit(proc.returncode)
    if args.check and not args.update:
        failures = check_regressions(baseline, out_json, args.tolerance)
        if failures:
            # Transient CPU stalls on shared runners flap individual rows
            # (a row can read 3x slower in one pass and nominal in the
            # next). Re-time the whole suite once and keep the per-row
            # best before failing: persistent regressions still fail,
            # one-pass stalls do not.
            print("# re-timing once to separate regressions from stalls")
            with open(out_json) as f:
                fresh1 = {r["name"]: r for r in json.load(f)}
            proc = subprocess.run([sys.executable, "-m", "benchmarks.run"],
                                  env=env, cwd=here)
            if proc.returncode != 0:
                sys.exit(proc.returncode)
            with open(out_json) as f:
                fresh2 = {r["name"]: r for r in json.load(f)}
            merged = []
            for name in sorted(set(fresh1) | set(fresh2)):
                a, b = fresh1.get(name), fresh2.get(name)
                rec = dict(b or a)
                if a and b:
                    rec["us_per_call"] = min(a["us_per_call"],
                                             b["us_per_call"])
                merged.append(rec)
            with open(out_json, "w") as f:
                json.dump(merged, f, indent=1)
            failures = check_regressions(baseline, out_json, args.tolerance)
        os.remove(out_json)
        sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
