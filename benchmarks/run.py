"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Multi-device benches need >1
virtual device, so this driver re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag is
scoped to that subprocess, never set globally).

  Fig. 11/13  bench_ag_gemm        AG+GEMM overlap vs monolithic
  Fig. 12/14  bench_gemm_rs        GEMM+RS overlap vs monolithic
  Table 4     bench_ag_moe         AllGather MoE GroupGEMM
  Table 5     bench_moe_rs         MoE GroupGEMM ReduceScatter
  Fig. 15     bench_flash_decode   distributed flash decoding scaling
  Fig. 16     bench_a2a            EP AllToAll dispatch/combine
  Fig. 19     bench_ll_allgather   low-latency AllGather
  (kernels)   bench_kernels        single-device kernel throughput
"""
import os
import subprocess
import sys


def _inner() -> None:
    from . import (
        bench_a2a,
        bench_ag_gemm,
        bench_ag_moe,
        bench_flash_decode,
        bench_gemm_rs,
        bench_kernels,
        bench_ll_allgather,
        bench_moe_rs,
    )

    print("name,us_per_call,derived")
    modules = [
        ("fig11_13", bench_ag_gemm),
        ("fig12_14", bench_gemm_rs),
        ("table4", bench_ag_moe),
        ("table5", bench_moe_rs),
        ("fig15", bench_flash_decode),
        ("fig16", bench_a2a),
        ("fig19", bench_ll_allgather),
        ("kernels", bench_kernels),
    ]
    for tag, mod in modules:
        try:
            for line in mod.rows():
                print(f"{tag}/{line}")
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{tag}/ERROR,,{type(e).__name__}: {e}")
        sys.stdout.flush()


def main() -> None:
    if os.environ.get("_REPRO_BENCH_INNER") == "1":
        _inner()
        return
    env = dict(os.environ)
    env["_REPRO_BENCH_INNER"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run([sys.executable, "-m", "benchmarks.run"], env=env,
                          cwd=here)
    sys.exit(proc.returncode)


if __name__ == "__main__":
    main()
