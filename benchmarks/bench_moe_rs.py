"""Paper Table 5 — MoE GroupGEMM + ReduceScatter (ring accumulator)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import moe_overlap as mo
from repro.kernels import ops

from .common import row, time_fn

# (paper row, tokens/rank, in_hidden, out_hidden, experts, topk)
CASES = [
    ("MoE-RS-1", 128, 96, 128, 8, 2),
    ("MoE-RS-4", 128, 96, 128, 16, 5),
    ("MoE-RS-6", 128, 128, 256, 8, 2),
]


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for name, t_loc, d, dff, e, k in CASES:
        t = t_loc * w
        x = jnp.asarray(rng.randn(t, d), jnp.float32)
        logits = jnp.asarray(rng.randn(t, e), jnp.float32)
        wi = jnp.asarray(rng.randn(e, d, dff) / np.sqrt(d), jnp.float32)
        wo = jnp.asarray(rng.randn(e, dff, d) / np.sqrt(dff), jnp.float32)
        cap = max(8, t * k // e * 2)

        def expert_fn(tok, lg):
            dsp, info = mo.topk_dispatch(tok, lg, k, cap)
            y = ops.grouped_matmul(dsp, wi, out_dtype=tok.dtype)
            y = jax.nn.silu(y)
            y = ops.grouped_matmul(y, wo, out_dtype=tok.dtype)
            return mo.topk_combine(y, info)

        def step(xf, lf):
            return mo.moe_rs(xf, lf, expert_fn, "tp")

        f = jax.jit(jax.shard_map(step, mesh=mesh,
                                  in_specs=(P(None, None), P(None, None)),
                                  out_specs=P("tp", None), check_vma=False))
        us = time_fn(f, x, logits)
        out.append(row(f"moe_rs/{name}/ring", us,
                       f"tokens_per_s={t / (us * 1e-6):.0f}"))
    return out
