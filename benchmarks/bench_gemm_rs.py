"""Paper Fig. 12/14 — GEMM-ReduceScatter: overlapped transports (engine
registry) vs. the monolithic baseline."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as cm
from repro.core import overlap, tuner

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for m, k, n in [(512, 256, 256), (1024, 512, 512), (2048, 1024, 512)]:
        a = jnp.asarray(rng.randn(m, k), jnp.float32)
        b = jnp.asarray(rng.randn(k, n), jnp.float32)
        base_us = None
        for mode in overlap.transports_for("matmul_rs", include_baseline=True):
            for backend in overlap.backends_for("matmul_rs"):
                if overlap.resolve_backend("matmul_rs", backend, mode) != backend:
                    continue  # no kernel lowering for this mode
                if backend == "kernel" and m > 512:
                    # emulated-DMA rows: small shape only (see bench_ag_gemm)
                    continue
                f = cm.make_sharded(
                    functools.partial(cm.matmul_rs, axis="tp", mode=mode,
                                      backend=backend, out_dtype=jnp.float32),
                    mesh, (P(None, "tp"), P("tp", None)), P("tp", None))
                us = time_fn(f, a, b)
                if mode == "none":
                    base_us = us
                choice = tuner.analytic_matmul_rs(4096, 12288 // 16, 3072, 16)
                serial = choice.t_compute + choice.t_comm
                derived = (f"v5e_speedup={serial / choice.t_total:.2f}x"
                           f";cpu_speedup={base_us / us:.2f}x")
                suffix = "/kernel" if backend == "kernel" else ""
                out.append(row(f"gemm_rs/{m}x{k}x{n}/{mode}{suffix}", us,
                               derived))
                if m == 512 and mode == "ring":
                    # wire axis: int8 riding partials at the smallest shape
                    f8 = cm.make_sharded(
                        functools.partial(cm.matmul_rs, axis="tp", mode=mode,
                                          backend=backend,
                                          out_dtype=jnp.float32, wire="int8"),
                        mesh, (P(None, "tp"), P("tp", None)), P("tp", None))
                    us8 = time_fn(f8, a, b)
                    out.append(row(f"gemm_rs/{m}x{k}x{n}/{mode}{suffix}/int8",
                                   us8, f"vs_f32={us / us8:.2f}x"))
        # the rs_chunks sub-chunking knob (mirrors ag_chunks)
        f = cm.make_sharded(
            functools.partial(cm.matmul_rs, axis="tp", mode="ring",
                              chunks_per_rank=2, out_dtype=jnp.float32),
            mesh, (P(None, "tp"), P("tp", None)), P("tp", None))
        us = time_fn(f, a, b)
        out.append(row(f"gemm_rs/{m}x{k}x{n}/ring_sub2", us,
                       f"cpu_speedup={base_us / us:.2f}x"))
    return out
