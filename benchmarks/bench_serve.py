"""Serving-engine benchmark — paged KV + chunked prefill vs token-by-token
prompt ingestion, same seeded synthetic stream (Poisson arrivals, mixed
128–2048-token prompts, batch 8, world 4: dp=2 x tp=2).

Rows (us, lower is better), for each of {paged, cp_prefill, tokenwise}
(cp_prefill = paged with context-parallel chunked prefill: every chunk
shards over the data axis through the zigzag-placed ring_attention op):
  serve/ttft/<engine>   mean arrival -> first-token latency
  serve/tpot/<engine>   mean per-output-token latency after the 1st
  serve/tok/<engine>    wall us per generated token (derived: tok/s)
  serve/step/<engine>   wall us per engine step (derived: step split,
                        occupancy)

Under ``run.py --trace`` the engine runs drain their repro.obs events
into measured overlap_eff/stall_frac on the ``tok`` rows (inert when the
overlap policy resolves to plain XLA collectives — no shmem events)."""
import os
import time

import jax

from repro.configs import ARCHS, reduced
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_paged_engine, build_tokenwise_engine
from repro.ops.policy import OverlapPolicy
from repro.serve import LoadSpec, ServeConfig, drive, generate

from . import common
from .common import row

N_REQUESTS = 64
PROMPT_LENS = (128, 2048)
BATCH = 8
MAX_NEW = 8
MAX_LEN = PROMPT_LENS[1] + MAX_NEW + 1


def _attach_trace():
    """--trace: summarize the engine run's obs events into the next row."""
    common.LAST_MEASURED = {}
    if not os.environ.get("_REPRO_BENCH_TRACE"):
        return
    from repro import obs

    events = obs.events(clear=True)
    if events:
        s = obs.metrics.summarize(events)
        common.LAST_MEASURED = {"overlap_eff": round(s.overlap_efficiency, 4),
                                "stall_frac": round(s.stall_frac, 4)}
        common.TRACE_EVENTS.extend(events)


def _run(engine, arrivals):
    t0 = time.perf_counter()
    leftover = drive(engine, arrivals, max_steps=500_000, time_scale=0.0)
    wall = time.perf_counter() - t0
    assert not leftover, f"{len(leftover)} requests stranded"
    return engine.metrics(), wall


def rows():
    assert jax.device_count() >= 4, "bench runs on a dp=2 x tp=2 mesh"
    cfg = reduced(ARCHS["granite-3-2b"])
    # dp>1 packs params data-sharded (leaf_pspec) -> fsdp gather required
    pcfg = ParallelConfig(dp=2, tp=2, fsdp=True, param_dtype="float32",
                          compute_dtype="float32",
                          overlap=OverlapPolicy(mode="none"))
    mesh = make_mesh(2, 2)
    spec = LoadSpec(n_requests=N_REQUESTS, rate_rps=1e9,
                    prompt_lens=PROMPT_LENS, max_new_tokens=MAX_NEW, seed=0)

    out = []
    results = {}
    for name in ("paged", "cp_prefill", "tokenwise"):
        if name == "paged":
            scfg = ServeConfig(batch=BATCH, max_len=MAX_LEN, page_size=64,
                               chunk=256, token_budget=512, queue_cap=256)
            eng = build_paged_engine(cfg, pcfg, scfg, mesh)
        elif name == "cp_prefill":
            # context-parallel chunked prefill: every chunk shards over
            # the data axis (zigzag placement) through ring_attention —
            # one whole-mesh stream instead of one stream per dp shard
            eng = build_paged_engine(cfg, pcfg, scfg, mesh, prefill_cp=True)
        else:
            eng = build_tokenwise_engine(cfg, pcfg, BATCH, MAX_LEN, mesh)
        arrivals = generate(spec, cfg.vocab_size)
        m, wall = _run(eng, arrivals)
        results[name] = (m, wall)
        _attach_trace()
        tok_us = wall * 1e6 / max(1, m.tokens_generated)
        out.append(row(f"serve/tok/{name}", tok_us,
                       f"tok_s={m.tokens_generated / wall:.1f}"))
        out.append(row(f"serve/ttft/{name}", m.ttft_mean_s * 1e6,
                       f"ttft_max_us={m.ttft_max_s * 1e6:.0f}"))
        out.append(row(f"serve/tpot/{name}", m.tpot_mean_s * 1e6,
                       f"completed={m.requests_completed}"))
        out.append(row(
            f"serve/step/{name}", wall * 1e6 / max(1, m.steps),
            f"steps={m.steps};prefill={m.steps_prefill};"
            f"decode={m.steps_decode};occ={m.slot_occupancy_mean:.2f};"
            f"queue_max={m.queue_depth_max}"))
    # the acceptance comparison, recorded in-row: paged must beat
    # tokenwise on TTFT and match-or-beat it on token throughput
    (mp, wp), (mt, wt) = results["paged"], results["tokenwise"]
    ttft_x = mt.ttft_mean_s / max(1e-9, mp.ttft_mean_s)
    tok_x = (mp.tokens_generated / wp) / max(1e-9, mt.tokens_generated / wt)
    out.append(row("serve/speedup/paged_vs_tokenwise", 0.0,
                   f"ttft_x={ttft_x:.2f};tok_s_x={tok_x:.2f}"))
    (mc, wc) = results["cp_prefill"]
    cp_ttft_x = mp.ttft_mean_s / max(1e-9, mc.ttft_mean_s)
    cp_tok_x = (mc.tokens_generated / wc) / max(1e-9,
                                                mp.tokens_generated / wp)
    out.append(row("serve/speedup/cp_vs_paged", 0.0,
                   f"ttft_x={cp_ttft_x:.2f};tok_s_x={cp_tok_x:.2f}"))
    return out
