"""Paper Fig. 16 — expert-parallel AllToAll dispatch/combine: the one-shot
decomposed a2a (low-latency structure) vs. XLA's monolithic all_to_all,
on both lowering backends (graph = engine pipeline; kernel = the shmem
executor's one_shot_a2a push protocol — emulated DMA on CPU, so kernel
rows run at the smallest shape only, as a correctness-tracking row)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import moe_overlap as mo
from repro.core import overlap

from .common import row, time_fn

# kernel rows only at this shape: the emulated-DMA backend is a
# correctness vehicle (host callbacks), not a CPU fast path
_KERNEL_SHAPE = (16, 32, 128)


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("ep",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for e_glob, cap, d in [(16, 32, 128), (32, 64, 256), (64, 32, 512)]:
        if e_glob % w:
            continue
        x = jnp.asarray(rng.randn(w * e_glob, cap, d), jnp.float32)
        for mode in ("xla", "one_shot"):
            for backend in overlap.backends_for("a2a_ep"):
                if overlap.resolve_backend("a2a_ep", backend, mode) != backend:
                    continue  # no kernel lowering for this mode
                if backend == "kernel" and (e_glob, cap, d) != _KERNEL_SHAPE:
                    continue
                suffix = "/kernel" if backend == "kernel" else ""
                f = jax.jit(jax.shard_map(
                    functools.partial(mo.a2a_ep, axis="ep", mode=mode,
                                      backend=backend),
                    mesh=mesh, in_specs=P("ep", None, None),
                    out_specs=P("ep", None, None), check_vma=False))
                us = time_fn(f, x)
                bytes_dev = e_glob * cap * d * 4 * (w - 1) / w
                out.append(row(f"a2a_dispatch/E{e_glob}c{cap}d{d}/{mode}{suffix}",
                               us, f"bytes_per_dev={bytes_dev:.0f}"))
                # time the combine (inverse) path directly on a DISPATCHED
                # tensor — correct capacity-grouped (E_local, W*cap, d)
                # shards; a difference of two noisy medians (roundtrip -
                # dispatch) can even go negative on loaded CPU hosts
                y = jax.block_until_ready(f(x))
                g = jax.jit(jax.shard_map(
                    lambda yy: mo.a2a_ep_inverse(yy, "ep", mode=mode,
                                                 backend=backend),
                    mesh=mesh, in_specs=P("ep", None, None),
                    out_specs=P("ep", None, None), check_vma=False))
                us2 = time_fn(g, y)
                out.append(row(f"a2a_combine/E{e_glob}c{cap}d{d}/{mode}{suffix}",
                               us2, f"dispatch_us={us:.1f}"))
                if mode == "one_shot" and (e_glob, cap, d) == _KERNEL_SHAPE:
                    # wire axis: int8 token slabs at the smallest shape
                    f8 = jax.jit(jax.shard_map(
                        functools.partial(mo.a2a_ep, axis="ep", mode=mode,
                                          backend=backend, wire="int8"),
                        mesh=mesh, in_specs=P("ep", None, None),
                        out_specs=P("ep", None, None), check_vma=False))
                    us8 = time_fn(f8, x)
                    out.append(row(
                        f"a2a_dispatch/E{e_glob}c{cap}d{d}/{mode}{suffix}/int8",
                        us8, f"vs_f32={us / us8:.2f}x"))
    return out
