"""Paper Fig. 16 — expert-parallel AllToAll dispatch/combine: the one-shot
decomposed a2a (low-latency structure) vs. XLA's monolithic all_to_all."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import moe_overlap as mo

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("ep",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for e_glob, cap, d in [(16, 32, 128), (32, 64, 256), (64, 32, 512)]:
        if e_glob % w:
            continue
        x = jnp.asarray(rng.randn(w * e_glob, cap, d), jnp.float32)
        for mode in ("xla", "one_shot"):
            f = jax.jit(jax.shard_map(
                functools.partial(mo.a2a_ep, axis="ep", mode=mode),
                mesh=mesh, in_specs=P("ep", None, None),
                out_specs=P("ep", None, None), check_vma=False))
            us = time_fn(f, x)
            bytes_dev = e_glob * cap * d * 4 * (w - 1) / w
            out.append(row(f"a2a_dispatch/E{e_glob}c{cap}d{d}/{mode}", us,
                           f"bytes_per_dev={bytes_dev:.0f}"))
            # time the combine (inverse) path directly on a DISPATCHED
            # tensor — a difference of two noisy medians (roundtrip -
            # dispatch) can even go negative on loaded CPU hosts
            y = jax.block_until_ready(f(x))
            g = jax.jit(jax.shard_map(
                lambda yy: mo.a2a_ep_inverse(yy, "ep", mode=mode),
                mesh=mesh, in_specs=P("ep", None, None),
                out_specs=P("ep", None, None), check_vma=False))
            us2 = time_fn(g, y)
            out.append(row(f"a2a_combine/E{e_glob}c{cap}d{d}/{mode}", us2,
                           f"dispatch_us={us:.1f}"))
    return out
