"""Paper Fig. 15 — distributed flash decoding: weak & strong scaling over
sequence-parallel KV shards; derived = per-device HBM-bytes fraction on
v5e (the paper's achieved-bandwidth metric, computed analytically)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import hw
from repro.core import flash_decode as fdm

from .common import row, time_fn


def rows():
    wmax = min(8, jax.device_count())
    rng = np.random.RandomState(0)
    b, hq, hkv, d = 1, 8, 2, 64
    out = []

    def step(q, ks, vs, *, backend="graph"):
        ll = jnp.full((q.shape[0],), ks.shape[2], jnp.int32)
        return fdm.distributed_flash_decode(q, ks, vs, ll, "sp",
                                            mode="one_shot", backend=backend)

    # weak scaling: KV per shard fixed. The combine's backend axis rides
    # along: kernel = the executor's one_shot_ag with the LSE-stacking
    # tile (emulated DMA on CPU — a correctness-tracking row, not a CPU
    # fast path; graph rows keep their historical names).
    per_shard = 2048
    for w in (1, 2, 4, 8):
        if w > wmax:
            break
        mesh = jax.make_mesh((w,), ("sp",), axis_types=(jax.sharding.AxisType.Auto,))
        s = per_shard * w
        q = jnp.asarray(rng.randn(b, hq, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        kv_bytes_dev = 2 * b * hkv * per_shard * d * 4
        t_hbm = kv_bytes_dev / hw.TPU_V5E.hbm_bandwidth
        for backend in ("graph", "kernel"):
            if backend == "kernel" and w != 2:
                # one kernel row, at the smallest COMMUNICATING world —
                # the emulated backend is a correctness-tracking row, not
                # a CPU fast path (matches bench_a2a's _KERNEL_SHAPE rule)
                continue
            f = jax.jit(jax.shard_map(functools.partial(step, backend=backend),
                mesh=mesh,
                in_specs=(P(None,), P(None, None, "sp", None), P(None, None, "sp", None)),
                out_specs=P(None,), check_vma=False))
            us = time_fn(f, q, k, v)
            suffix = "/one_shot/kernel" if backend == "kernel" else ""
            out.append(row(f"flash_decode/weak/kv{per_shard}x{w}{suffix}", us,
                           f"v5e_hbm_bound_us={t_hbm*1e6:.2f}"))
    # strong scaling: global KV fixed
    total = 2048 * wmax
    for w in (1, 2, 4, 8):
        if w > wmax:
            break
        mesh = jax.make_mesh((w,), ("sp",), axis_types=(jax.sharding.AxisType.Auto,))
        q = jnp.asarray(rng.randn(b, hq, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, total, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, total, d), jnp.float32)
        f = jax.jit(jax.shard_map(step, mesh=mesh,
            in_specs=(P(None,), P(None, None, "sp", None), P(None, None, "sp", None)),
            out_specs=P(None,), check_vma=False))
        us = time_fn(f, q, k, v)
        kv_bytes_dev = 2 * b * hkv * (total // w) * d * 4
        t_hbm = kv_bytes_dev / hw.TPU_V5E.hbm_bandwidth
        out.append(row(f"flash_decode/strong/kv{total}w{w}", us,
                       f"v5e_hbm_bound_us={t_hbm*1e6:.2f}"))
    return out
