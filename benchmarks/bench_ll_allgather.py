"""Paper Fig. 19 — low-latency AllGather on small messages: one-shot
(Alg. 4 structure) vs. serial ring vs. XLA's built-in all_gather."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as cm

from .common import row, time_fn


def rows():
    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    out = []
    for rows_, cols in [(8, 32), (64, 128), (512, 256)]:
        x = jnp.asarray(rng.randn(rows_ * w, cols), jnp.float32)
        msg_bytes = rows_ * cols * 4
        variants = {
            "xla": lambda xl: lax.all_gather(xl, "x", tiled=True),
            "ring": functools.partial(cm.all_gather_chunked, axis="x", mode="ring"),
            "one_shot": functools.partial(cm.all_gather_chunked, axis="x",
                                          mode="one_shot"),
        }
        if msg_bytes <= 64 * 1024:
            # the fused LL AllGather shmem kernel (emulated DMA on CPU:
            # correctness vehicle, benched on small messages only)
            variants["one_shot/kernel"] = functools.partial(
                cm.all_gather_chunked, axis="x", mode="one_shot",
                backend="kernel")
        for name, fn in variants.items():
            f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("x", None),
                                      out_specs=P(None, None), check_vma=False))
            us = time_fn(f, x)
            # derived: v5e latency floor — ring pays (W-1) hops, one-shot 1
            hop_us = 1.0  # ~1us ICI hop latency
            hops = (w - 1) if name == "ring" else 1
            out.append(row(f"ll_allgather/{msg_bytes}B/{name}", us,
                           f"v5e_latency_floor_us={hops * hop_us:.0f}"))
    return out
