"""Serve a small LM with batched requests through the decode engine.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_lm.py
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    import jax

    ndev = jax.device_count()
    ns = argparse.Namespace(
        arch="granite-3-2b", reduced=True,
        dp=2 if ndev >= 4 else 1, tp=2 if ndev >= 4 else 1,
        batch=4, max_len=64, requests=8, new_tokens=8, temperature=0.7,
        dtype="float32", no_fsdp=False)
    eng = serve_mod.run(ns)
    print(f"\nKV cache fill after run: {eng.cache_len}/{ns.max_len}")


if __name__ == "__main__":
    main()
