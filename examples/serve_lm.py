"""Serve a small LM with batched requests through the decode engine.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_lm.py
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    import jax

    ndev = jax.device_count()
    ns = argparse.Namespace(
        arch="granite-3-2b", reduced=True,
        dp=2 if ndev >= 4 else 1, tp=2 if ndev >= 4 else 1,
        batch=4, max_len=64, requests=8, new_tokens=8, temperature=0.7,
        dtype="float32", no_fsdp=False)
    eng = serve_mod.run(ns)
    print(f"\nKV cache fill after run: {eng.cache_len}/{ns.max_len}")
    m = eng.metrics()
    print(f"TTFT {m.ttft_mean_s * 1e3:.1f}ms mean / "
          f"{m.ttft_max_s * 1e3:.1f}ms max; "
          f"TPOT {m.tpot_mean_s * 1e3:.2f}ms; "
          f"queue depth {m.queue_depth_mean:.2f} mean "
          f"(max {m.queue_depth_max}); "
          f"slot occupancy {m.slot_occupancy_mean:.0%}")


if __name__ == "__main__":
    main()
