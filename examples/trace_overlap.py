"""Trace one overlapped ag_matmul step and export the per-PE timeline.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/trace_overlap.py

Writes ``trace_overlap.json`` — open it in ui.perfetto.dev (or
chrome://tracing) to see each PE's ``tile_compute`` spans interleaved
with ``credit_wait`` / ``arrival_wait`` stalls and the DMA ``put``
events: the overlap schedule, made visible. Also prints the
overlap-efficiency reduction (``repro.obs.metrics``).
"""
import functools
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import obs  # noqa: E402
from repro.core.collective_matmul import make_sharded  # noqa: E402
from repro.ops import ag_matmul  # noqa: E402


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "trace_overlap.json"
    world = jax.device_count()
    # enable BEFORE the first jit-compilation: the executor's compute
    # spans are decided at trace time
    obs.enable()

    mesh = jax.make_mesh((world,), ("tp",))
    m, k, n = 32 * world, 64, 8 * world
    x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
    step = make_sharded(
        functools.partial(ag_matmul, axis="tp", mode="ring",
                          backend="kernel", out_dtype=jnp.float32),
        mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))

    y = step(x, w)
    y.block_until_ready()

    events = obs.events(clear=True)
    summary = obs.metrics.summarize(
        events, op="ag_matmul", mode="ring", backend="kernel", wire="f32")
    n_events = obs.trace.save(out_path, events)
    print(summary)
    print(f"wrote {n_events} events to {out_path} "
          f"(open in ui.perfetto.dev)")
    assert 0.0 < summary.overlap_efficiency <= 1.0, summary
    return 0


if __name__ == "__main__":
    sys.exit(main())
