"""The paper's FlashDecode+AG: KV cache sequence-sharded across devices,
per-shard flash decode, low-latency AllGather combine.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/distributed_flash_decode.py
"""
import functools
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import flash_decode as fdm  # noqa: E402
from repro.kernels import ref  # noqa: E402

W = jax.device_count()
mesh = jax.make_mesh((W,), ("sp",), axis_types=(jax.sharding.AxisType.Auto,))
B, HQ, HKV, S, D = 2, 8, 2, 1024 * W, 64
rng = np.random.RandomState(0)
q = jnp.asarray(rng.randn(B, HQ, D), jnp.float32)
k = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
v = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)


def step(q, ks, vs, mode):
    lens = jnp.full((q.shape[0],), ks.shape[2], jnp.int32)
    return fdm.distributed_flash_decode(q, ks, vs, lens, "sp", mode=mode)


want, _ = ref.flash_decode(q, k, v)
print(f"distributed flash decode: KV {S} tokens sharded {W}-way "
      f"({S // W}/device)")
for mode in ("xla", "one_shot"):
    f = jax.jit(jax.shard_map(
        functools.partial(step, mode=mode), mesh=mesh,
        in_specs=(P(None,), P(None, None, "sp", None), P(None, None, "sp", None)),
        out_specs=P(None,), check_vma=False))
    got = f(q, k, v)
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    print(f"  combine={mode:9s} max|err| vs single-device oracle = {err:.2e}")
print("ok")
