"""Quickstart: the paper's overlapped kernels through `repro.ops`.

One typed op object per overlapped collective, one `OverlapPolicy` that
answers "how should op X overlap?", and the analytic tuner that produces
a policy for your shapes.

Run (8 virtual CPU devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import ops
from repro.core import overlap, tuner
from repro.core.collective_matmul import make_sharded

W = jax.device_count()
mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))

rng = np.random.RandomState(0)
M, K, N = 512, 256, 256
A = jnp.asarray(rng.randn(M, K), jnp.float32)  # sharded on M (SP tokens)
B = jnp.asarray(rng.randn(K, N), jnp.float32)  # sharded on N (TP weight)

print(f"AllGather-GEMM on {W} devices: C[{M},{N}] = AG(A) @ B\n")
want = np.asarray(A @ B)
for mode in ("none", "ring", "bidir", "one_shot"):
    f = make_sharded(
        functools.partial(ops.ag_matmul, axis="tp", mode=mode,
                          out_dtype=jnp.float32),
        mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
    got = np.asarray(f(A, B))
    err = np.abs(got - want).max()
    print(f"  mode={mode:9s} max|err| vs oracle = {err:.2e}")

print("\nOne OverlapPolicy drives every op (mode/backend/chunks, resolved "
      "against the registry):")
policy = tuner.recommend_overlap_modes(M, K, N, world=W)
for op in ("ag_matmul", "matmul_rs", "all_gather", "a2a_ep"):
    print(f"  {op:12s} -> {policy.describe(op)}")

f = make_sharded(
    functools.partial(ops.ag_matmul, axis="tp", policy=policy,
                      out_dtype=jnp.float32),
    mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
err = np.abs(np.asarray(f(A, B)) - want).max()
print(f"  policy-driven ag_matmul max|err| = {err:.2e}")

print("\nAnalytic tuner (paper §3.8, TPU v5e): which overlap for this op?")
for m_loc, k, n_loc in [(256, 12288, 3072), (8, 512, 64)]:
    c = tuner.analytic_ag_matmul(m_loc, k, n_loc, world=16)
    print(f"  m_loc={m_loc:5d} k={k:6d} n_loc={n_loc:5d} -> {c.mode:9s} "
          f"(compute {c.t_compute*1e6:7.1f}us, comm {c.t_comm*1e6:7.1f}us, "
          f"total {c.t_total*1e6:7.1f}us)")

print("\nGEMM-ReduceScatter (ring accumulator):")
A2 = jnp.asarray(rng.randn(M, 2 * K), jnp.float32)
B2 = jnp.asarray(rng.randn(2 * K, N), jnp.float32)
f = make_sharded(
    functools.partial(ops.matmul_rs, axis="tp", mode="ring",
                      out_dtype=jnp.float32),
    mesh, (P(None, "tp"), P("tp", None)), P("tp", None))
err = np.abs(np.asarray(f(A2, B2)) - np.asarray(A2 @ B2)).max()
print(f"  ring GEMM+RS max|err| = {err:.2e}")

print("\nAuthor a NEW overlapped op in one declaration (graph + kernel "
      "lowerings + backward all derived):")
scaled = ops.declare(ops.OverlapOp(
    name="qs_scaled_ag_matmul",
    kind="ag",
    tile=lambda a, b: 2.0 * jnp.dot(a, b, preferred_element_type=jnp.float32),
    transports=("ring", "one_shot"),
    kernel_protocols=(("ring", "ring_ag"),),
    transpose="matmul_rs",
    rowwise=True,
))
f = make_sharded(
    functools.partial(scaled, axis="tp", mode="ring", out_dtype=jnp.float32),
    mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
err = np.abs(np.asarray(f(A, B)) - 2.0 * want).max()
print(f"  declared op registered: "
      f"{'qs_scaled_ag_matmul' in overlap.registry()}; max|err| = {err:.2e}")
print("\nok")
