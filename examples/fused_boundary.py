"""Fuse across the op boundary: the attention-out GEMM+RS chained into
the MLP-in AG+GEMM as ONE declaration (``ops.fuse`` ->
``ops.matmul_rs_ag_matmul``), vs the back-to-back unfused pair.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/fused_boundary.py

Walks the whole PR-9 surface:
  1. numerics — the fused op equals ``ag_matmul(mid(matmul_rs(...)))``
     on the XLA baseline, the graph pipeline, and the emulated-kernel
     chained ``push_rs_ring_ag`` protocol;
  2. the traced timeline — the chain drops the pair's TWO mid-chain
     barrier rendezvous per call (the rs-exit + ag-entry flush), with
     the overlap summaries printed side by side;
  3. shape-keyed search — ``tuner.search`` times the registry grid for
     one layer shape, emits a ``with_layer`` rule, and a second search
     over the same key does ZERO new timings; the policy JSON
     round-trips.
"""
import functools
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import obs, ops  # noqa: E402
from repro.core import tuner  # noqa: E402
from repro.core.collective_matmul import make_sharded  # noqa: E402

SPECS = ((P(None, "tp"), P("tp", None), P(None, "tp"), P("tp", None)),
         P(None, "tp"))


def mid(r, x):
    """The rank-local seam between the halves: residual + nonlinearity."""
    return jnp.tanh(r + x)


def main():
    world = jax.device_count()
    obs.enable()  # before the first jit-compile: spans are trace-gated
    mesh = jax.make_mesh((world,), ("tp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    m, k, n, f = 16 * world, 8 * world, 48, 8 * world
    rng = np.random.RandomState(0)
    y = jnp.asarray(rng.randn(m, k), jnp.float32)
    wo = jnp.asarray(rng.randn(k, n), jnp.float32)
    wi = jnp.asarray(rng.randn(n, f), jnp.float32)
    xr = jnp.asarray(rng.randn(m, n), jnp.float32)
    want = np.tanh(np.asarray(y) @ np.asarray(wo) + np.asarray(xr)) \
        @ np.asarray(wi)

    # 1. numerics: one declaration, three lowerings, one oracle
    def fused(mode, backend="graph"):
        return make_sharded(
            functools.partial(ops.matmul_rs_ag_matmul, axis="tp", mode=mode,
                              backend=backend, out_dtype=jnp.float32,
                              mid=mid),
            mesh, *SPECS)

    def unfused(y, wo, wi, xr, backend="graph"):
        r = ops.matmul_rs(y, wo, axis="tp", mode="ring", backend=backend,
                          out_dtype=jnp.float32)
        return ops.ag_matmul(mid(r, xr), wi, axis="tp", mode="ring",
                             backend=backend, out_dtype=jnp.float32)

    for name, fn in (("none (xla baseline)", fused("none")),
                     ("ring/graph", fused("ring")),
                     ("ring/kernel", fused("ring", "kernel"))):
        got = np.asarray(fn(y, wo, wi, xr))
        err = np.abs(got - want).max() / max(1.0, np.abs(want).max())
        print(f"fused {name:20s} rel_err={err:.2e}")
        assert err < 1e-5, (name, err)

    # 2. the chained protocol drops the mid-chain barriers the pair pays
    def run_traced(fn):
        jax.block_until_ready(fn(y, wo, wi, xr))  # warmup
        obs.clear()
        jax.block_until_ready(fn(y, wo, wi, xr))
        ev = obs.events(clear=True)
        barriers = sum(1 for e in ev if e.kind == "barrier")
        return barriers, obs.metrics.summarize(ev)

    fk = fused("ring", "kernel")
    fu = make_sharded(functools.partial(unfused, backend="kernel"),
                      mesh, *SPECS)
    nb_u, s_u = run_traced(fu)
    nb_f, s_f = run_traced(fk)
    print(f"unfused pair: {nb_u} barrier events  {s_u}")
    print(f"fused chain:  {nb_f} barrier events  {s_f}")
    assert nb_f == nb_u - 2 * world, (nb_u, nb_f)

    # 3. shape-keyed search: fill a per-layer rule from the registry grid
    def make_step(shape, resolved):
        mm, kk, nn, ff = shape
        step = make_sharded(
            functools.partial(ops.matmul_rs_ag_matmul, axis="tp",
                              mode=resolved.mode, backend=resolved.backend,
                              chunks=resolved.chunks,
                              out_dtype=jnp.float32, mid=mid),
            mesh, *SPECS)
        return lambda: step(y, wo, wi, xr)

    shape = (m, k, n, f)
    tuner.clear_search_cache()
    pol = tuner.search(make_step, "matmul_rs_ag_matmul", [shape],
                       world=world, chunks=(1, 2))
    timed = tuner.SEARCH_TIMINGS
    winner = pol.resolve("matmul_rs_ag_matmul", shape=shape)
    print(f"search winner at {shape}: {winner} ({timed} timings)")
    pol2 = tuner.search(make_step, "matmul_rs_ag_matmul", [shape],
                        world=world, chunks=(1, 2), base=pol)
    assert tuner.SEARCH_TIMINGS == timed, "cache miss on identical search"
    assert pol2 == pol
    assert ops.OverlapPolicy.from_json(pol.to_json()) == pol
    print("second search: 0 new timings; policy JSON round-trips")
    return 0


if __name__ == "__main__":
    sys.exit(main())
