"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data with the full production stack (FSDP + TP overlap,
checkpointing, deterministic data).

Default is a quick CPU demo; pass --full for the ~100M/300-step run.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    import jax

    ndev = jax.device_count()
    # NOTE: on a single-CORE host, multi-virtual-device collectives can
    # trip XLA:CPU's 40s rendezvous abort under load; the --full run is
    # long, so it stays single-device there (parallel paths are covered
    # by the test suite and the quick mode).
    if args.full and os.cpu_count() == 1:
        dp = tp = 1
    else:
        dp = 2 if ndev >= 4 else 1
        tp = 2 if ndev >= 4 else 1

    base = get_config("granite-3-2b")
    if args.full:
        # ~100M params: 12L x 512 x 8H, d_ff 2048, vocab 32k
        cfg_over = dict(num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
                        head_dim=64, d_ff=2048, vocab_size=32000)
        steps = args.steps or 300
        batch, seq = 8, 256
        lr = 1e-3  # 3e-3 diverges for this width around step ~80
    else:
        cfg_over = dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
                        head_dim=32, d_ff=512, vocab_size=2048)
        steps = args.steps or 60
        batch, seq = 8, 64
        lr = 3e-3
    cfg = dataclasses.replace(base, name="demo-lm", **cfg_over)
    print(f"training {cfg.param_count()/1e6:.1f}M params for {steps} steps "
          f"on dp={dp} tp={tp}")

    import repro.configs as C

    C.ARCHS["demo-lm"] = cfg  # register for the driver
    ns = argparse.Namespace(
        arch="demo-lm", reduced=False, dp=dp, tp=tp, pods=1, steps=steps,
        batch=batch, seq=seq, lr=lr, overlap="ring", remat="block",
        dtype="float32", no_fsdp=False, fresh=True,
        ckpt_dir="/tmp/repro_example_ckpt", ckpt_every=max(50, steps // 4),
        log_every=10)
    losses = train_mod.run(ns)
    import numpy as np

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
