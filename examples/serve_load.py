"""Continuous batching under synthetic load: paged KV cache + chunked
prefill, with per-phase overlap policies (prefill throughput-bound,
decode latency-bound).

A seeded Poisson stream of requests with mixed prompt lengths flows
through the scheduler; prefill chunks and decode tokens share steps
under a token budget. The run prints the serving metrics split the
benchmark rows are built from (TTFT / TPOT / queue depth / occupancy).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_load.py
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    import jax

    ndev = jax.device_count()
    ns = argparse.Namespace(
        arch="granite-3-2b", reduced=True,
        dp=2 if ndev >= 4 else 1, tp=2 if ndev >= 4 else 1,
        batch=4, max_len=64, requests=10, new_tokens=6, temperature=0.0,
        dtype="float32", no_fsdp=False,
        # serve v2 knobs: paged pool geometry + chunked prefill budget
        page_size=8, num_pages=0, chunk=8, token_budget=32,
        # per-phase overlap: prefill rides ag_matmul/matmul_rs, decode
        # keeps the latency-bound default
        overlap="none", prefill_overlap="bidir",
        # seeded Poisson arrivals, mixed prompt lengths
        rate=64.0, prompt_min=4, prompt_max=24, time_scale=0.0, seed=0)
    eng = serve_mod.run(ns)
    m = eng.metrics()
    assert m.requests_completed == ns.requests, m
    assert m.steps_prefill > 0 and m.steps_decode > 0, m
    print(f"\nTTFT {m.ttft_mean_s * 1e3:.1f}ms mean / "
          f"{m.ttft_max_s * 1e3:.1f}ms max; "
          f"TPOT {m.tpot_mean_s * 1e3:.2f}ms; "
          f"queue depth {m.queue_depth_mean:.2f} mean "
          f"(max {m.queue_depth_max}); "
          f"slot occupancy {m.slot_occupancy_mean:.0%}; "
          f"truncated {m.requests_truncated}")
    print("serve_load OK")


if __name__ == "__main__":
    main()
