"""Expert-parallel MoE layer with the paper's AllToAll dispatch/combine:
8 experts sharded over 4 devices, one-shot (low-latency) a2a.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/moe_expert_parallel.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import moe_overlap as mo  # noqa: E402
from repro.kernels import ops  # noqa: E402

W = jax.device_count()
E, CAP, D, DFF, K = 2 * W, 16, 64, 128, 2
mesh = jax.make_mesh((W,), ("ep",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)

T = 64  # tokens per rank
x = jnp.asarray(rng.randn(W * T, D), jnp.float32)
router = jnp.asarray(rng.randn(D, E) * 0.1, jnp.float32)
wi = jnp.asarray(rng.randn(W * (E // W), D, DFF) / np.sqrt(D), jnp.float32)
wo = jnp.asarray(rng.randn(W * (E // W), DFF, D) / np.sqrt(DFF), jnp.float32)


def moe_layer(x_loc, wi_loc, wo_loc):
    logits = x_loc @ router
    disp, info = mo.topk_dispatch(x_loc, logits, K, CAP)  # local dispatch
    x_ep = mo.a2a_ep(disp, "ep", mode="one_shot")  # tokens -> their experts
    y = ops.grouped_matmul(x_ep, wi_loc, out_dtype=x_loc.dtype)
    y = jax.nn.silu(y)
    y = ops.grouped_matmul(y, wo_loc, out_dtype=x_loc.dtype)
    back = mo.a2a_ep_inverse(y, "ep", mode="one_shot")  # results come home
    return mo.topk_combine(back, info)


f = jax.jit(jax.shard_map(
    moe_layer, mesh=mesh,
    in_specs=(P("ep", None), P("ep", None, None), P("ep", None, None)),
    out_specs=P("ep", None), check_vma=False))
y = f(x, wi, wo)
print(f"EP MoE on {W} devices: {E} experts ({E//W}/device), top-{K}, "
      f"capacity {CAP}")
print(f"in {x.shape} -> out {y.shape}; finite={bool(jnp.all(jnp.isfinite(y)))}")

# oracle: same math on one device (experts unsharded)
logits = x @ router
disp, info = mo.topk_dispatch(x, logits, K, CAP * W)
yy = ops.grouped_matmul(disp, wi, out_dtype=x.dtype)
yy = jax.nn.silu(yy)
yy = ops.grouped_matmul(yy, wo, out_dtype=x.dtype)
print("note: EP capacity per (rank, expert) differs from the single-device "
      "oracle's — outputs agree for tokens kept by both (spot check):")
want = mo.topk_combine(yy, info)
err = np.abs(np.asarray(y[:8]) - np.asarray(want[:8])).max()
print(f"first-8-token max|diff| = {err:.2e}")
print("ok")
