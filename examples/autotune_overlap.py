"""The paper's §3.8 distributed autotuner, live: tune the overlap mode +
sub-chunking of an AllGather-GEMM with the whole-step protocol (one
execution per iteration, state reset between configs), then compare with
the analytic v5e recommendation.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/autotune_overlap.py
"""
import functools
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import collective_matmul as cm  # noqa: E402
from repro.core import tuner  # noqa: E402

W = jax.device_count()
mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
M, K, N = 1024, 512, 512
A = jnp.asarray(rng.randn(M, K), jnp.float32)
B = jnp.asarray(rng.randn(K, N), jnp.float32)

CONFIGS = [("none", 1), ("ring", 1), ("ring", 2), ("bidir", 1), ("one_shot", 1)]


def make_step(cfg):
    mode, chunks = cfg
    f = cm.make_sharded(
        functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                          chunks_per_rank=chunks, out_dtype=jnp.float32),
        mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))

    def step():
        return f(A, B)

    return step


resets = {"n": 0}


def reset():
    # overlapped kernels synchronize through signals; the paper's tuner
    # resets them between profiled executions (here: a trivial sync)
    resets["n"] += 1


res = tuner.tune(make_step, CONFIGS, reset=reset, warmup=1, iters=3)
print(f"measured on {W} CPU devices (timings are emulation-only):")
for k, v in sorted(res.all_timings.items(), key=lambda kv: kv[1]):
    print(f"  {k:20s} {v*1e6:9.1f} us")
print(f"chosen: {res.config}   (signal resets performed: {resets['n']})")

# the analytic tuner hands back a whole OverlapPolicy — consumable as-is
# (drop onto ParallelConfig.overlap or pass to any repro.ops call)
policy = tuner.recommend_overlap_modes(M, K, N, W)
print(f"\nanalytic v5e recommendation, as one OverlapPolicy:")
print(f"  ag_matmul -> {policy.describe('ag_matmul')}   "
      f"matmul_rs -> {policy.describe('matmul_rs')}")
r = policy.resolve("ag_matmul")
print(f"  resolve('ag_matmul') = mode={r.mode} backend={r.backend} "
      f"chunks={r.chunks}")
print("ok")
