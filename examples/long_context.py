"""Load-balanced causal ring attention — the placement axis, end to end.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/long_context.py

Causal attention under a contiguous chunk->rank placement is badly
imbalanced: rank 0 owns the earliest rows (attends ~nothing beyond its
own chunk) while the last rank owns the latest (attends everything), and
the ring is lockstep — the slowest rank IS the step time. The `zigzag`
placement gives every rank one early + one late half-chunk, equalizing
causal work EXACTLY; `striped` interleaves rows round-robin (near-equal).
This example walks the whole surface:

  1. `core.schedules` — the owner->row maps and their causal imbalance;
  2. the analytic tuner picking a placement per world size;
  3. numerics — zigzag ring attention vs a dense oracle (values equal,
     grads too);
  4. the policy knob (`OverlapPolicy(placement=...)`) and its bench/log
     row spelling.

The serving-side continuation (context-parallel chunked prefill through
the same placed op: `--prefill-cp` on `repro.launch.serve`) is pinned in
tests/test_serve_cp.py.
"""
import functools
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import schedules, tuner  # noqa: E402
from repro.core.ring_attention import ring_attention  # noqa: E402
from repro.ops import OverlapPolicy  # noqa: E402


def main():
    w = jax.device_count()
    assert w >= 8, "run with 8 virtual devices (see module docstring)"
    w = 8

    # -- 1. the owner->row maps and the causal work they imply ---------
    s_loc = 4
    print(f"placements at world={w}, {s_loc} rows per rank:")
    for placement in schedules.PLACEMENTS:
        rows0 = schedules.placement_rows(placement, w, 0, s_loc)
        last = schedules.placement_rows(placement, w, w - 1, s_loc)
        imb = schedules.causal_imbalance(placement, w, s_loc)
        print(f"  {placement:10s} rank0 rows={list(rows0)} "
              f"rank{w - 1} rows={list(last)}  causal imbalance={imb:.2f}")
    assert schedules.causal_imbalance("zigzag", w, s_loc) == 1.0

    # -- 2. the analytic model picks zigzag for causal rings -----------
    pick = tuner.analytic_ring_attention(1024, 128, w, causal=True, heads=8)
    print(f"\ntuner (causal, world {w}): mode={pick.mode} "
          f"wire={pick.wire} placement={pick.placement}")
    assert pick.placement == "zigzag"
    flat = tuner.analytic_ring_attention(1024, 128, w, causal=False, heads=8)
    assert flat.placement == "contiguous"  # non-causal: placements tie

    # -- 3. numerics: placed ring attention == dense oracle ------------
    mesh = jax.make_mesh((w,), ("cp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    b, h, hkv, s_loc, d = 2, 4, 2, 16, 16
    s = s_loc * w
    # the zigzag layout permutes global rows into rank-major shard order
    perm = np.concatenate(
        [schedules.placement_rows("zigzag", w, r, s_loc) for r in range(w)])
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)

    ring = jax.jit(jax.shard_map(
        functools.partial(ring_attention, axis="cp", causal=True,
                          mode="ring", placement="zigzag"),
        mesh=mesh, in_specs=(P(None, None, "cp", None),) * 3,
        out_specs=P(None, None, "cp", None), check_vma=False))

    def dense(q, k, v):
        group = h // hkv
        kk = jnp.repeat(k, group, 1).astype(jnp.float32)
        vv = jnp.repeat(v, group, 1).astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk",
                            q.astype(jnp.float32) / np.sqrt(d), kk)
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        p = jax.nn.softmax(jnp.where(mask[None, None], logits, -1e30), -1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)

    out = np.asarray(ring(q[:, :, perm], k[:, :, perm], v[:, :, perm]))
    want = np.asarray(dense(q, k, v))[:, :, perm]
    err = np.abs(out - want).max()
    print(f"zigzag ring vs dense oracle: max err {err:.2e}")
    assert err < 2e-5

    g_ring = jax.grad(lambda a: jnp.sum(jnp.sin(ring(a, k[:, :, perm],
                                                     v[:, :, perm]))))(
        q[:, :, perm])
    g_dense = jax.grad(lambda a: jnp.sum(jnp.sin(dense(a, k, v))))(q)
    gerr = np.abs(np.asarray(g_ring)
                  - np.asarray(g_dense)[:, :, perm]).max()
    print(f"grad vs dense oracle:        max err {gerr:.2e}")
    assert gerr < 2e-3

    # -- 4. the policy knob and its row spelling -----------------------
    pol = OverlapPolicy(mode="ring", placements={"ring_attention": "zigzag"})
    r = pol.resolve("ring_attention")
    print(f"\npolicy resolve: mode={r.mode} placement={r.placement}")
    print(f"bench/log row:  ring_attention -> "
          f"{pol.describe('ring_attention')}")
    assert pol.describe("ring_attention").endswith("/zigzag")
    print("\nOK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
