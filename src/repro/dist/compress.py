"""Compressed gradient all-reduce for the pod axis (the slow links).

Cross-pod gradient sync moves full parameter-sized tensors over the
lowest-bandwidth links in the system, so it is the natural place for
lossy compression. ``pod_allreduce_int8`` implements the standard
production recipe:

  1. error feedback: add the residual carried from the previous step to
     the fresh gradient (so quantization error is compensated over time
     instead of accumulating as bias);
  2. per-row symmetric int8 quantization: scale = max|row| / 127 — one
     f32 scale per row, 4x fewer wire bytes than f32 gradients;
  3. ring all-reduce of the (int8 payload, scale) pairs over the pod
     axis via the overlap engine's AG pipeline: W-1 hops, each hop
     carrying quantized bytes, each arrival dequantized and accumulated
     in f32;
  4. record the new local residual (bounded by half an LSB of the local
     scale) as the next step's error-feedback state.

Every pod ends with the same (approximate) sum; the approximation error
is one quantization step per contributor, which the error feedback
re-injects next step.

The codec itself (quantize/dequantize/error feedback) is the shared wire
format in :mod:`repro.ops.wire` — the same per-row scaled-block code the
overlap executor's wire-dtype axis uses for riding chunks. This module
keeps only the pod-axis reduction recipe on top of it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core import overlap as ov
from ..ops import wire

Array = jax.Array


def quantize_int8(g: Array) -> Tuple[Array, Array]:
    """Per-row symmetric int8 quantization along the last axis.

    Returns (q int8, scale f32 with keepdims); g ≈ q * scale.
    Alias for ``ops.wire.encode(g, "int8")`` — kept as the public name.
    """
    return wire.encode(g, "int8")


def dequantize_int8(q: Array, scale: Array) -> Array:
    return wire.decode(q, scale)


def pod_allreduce_int8(g: Array, ef: Array, axis: str) -> Tuple[Array, Array]:
    """Int8 ring all-reduce over ``axis`` with error feedback.

    g:  this pod's local gradient (any float dtype).
    ef: carried error-feedback state (f32, same shape as g).
    Returns (summed gradient in g.dtype, new error-feedback state).
    Call inside shard_map with ``axis`` mapped to the pod mesh axis.
    """
    q, scale, new_ef = wire.ef_encode(g, ef, "int8")  # |new_ef| <= scale / 2

    def fold(acc, bufs, s, owner):
        del s, owner
        qq, ss = bufs
        return acc + wire.decode(qq, ss)

    # (q, scale) ride the ring together: W-1 hops of int8 payload (+ one
    # f32 scale per row), dequantize-and-add on arrival — the engine's AG
    # pipeline with an accumulator carry.
    total = ov.ag_pipeline(
        (q, scale), fold, jnp.zeros(g.shape, jnp.float32), axis, transport="ring"
    )
    return total.astype(g.dtype), new_ef
