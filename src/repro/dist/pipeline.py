"""GPipe pipeline parallelism over a mesh axis, SPMD-style.

Each rank on ``axis`` is one pipeline stage holding its own stage
parameters; microbatches stream through the ring of stages. Because the
program is SPMD (all ranks run the same trace), the schedule is a single
loop of M + S - 1 ticks: at each tick every rank applies its stage to
its current input and forwards the result one hop (``ring_permute`` —
the same transport the overlap engine uses, so the hop of tick t
overlaps the compute of tick t+1 under XLA's latency-hiding scheduler).
Stage 0 injects a fresh microbatch per tick; ranks inside the fill/drain
bubble compute on placeholder values that never reach a used output slot
(SPMD uniformity — the cost is the standard GPipe bubble).

Gradients flow through the ppermute transposes, so ``jax.grad`` of a
pipelined loss differentiates stage-locally with no extra machinery.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.primitives import ring_permute

Array = jax.Array


def gpipe(stage_fn, params, micro: Array, axis: str) -> Array:
    """Run ``stage_fn(params, x)`` as a GPipe pipeline over ``axis``.

    micro: (M, ...) microbatches, replicated across stages.
    Returns (M, ...) — the last stage's outputs in microbatch order
    (meaningful on the last rank; see ``gpipe_last_stage_value``).
    """
    s = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m = micro.shape[0]
    carry = jnp.zeros_like(micro[0])
    outs = []
    for t in range(m + s - 1):
        if t < m:
            # stage 0 injects microbatch t; downstream stages keep the
            # value that arrived over the ring
            carry = jnp.where(me == 0, micro[t], carry)
        y = stage_fn(params, carry)
        outs.append(y)
        if t != m + s - 2:
            # stage s's activation rides to stage s+1 while the next
            # tick's compute proceeds
            carry = ring_permute(y, axis)
    # rank s processes microbatch mb at tick mb + s: the last stage's
    # useful outputs occupy ticks S-1 .. S-1+M-1
    return jnp.stack(outs[s - 1 :], axis=0)


def gpipe_last_stage_value(outs: Array, axis: str) -> Array:
    """Broadcast the last stage's pipeline outputs to every rank."""
    s = lax.axis_size(axis)
    me = lax.axis_index(axis)
    keep = (me == s - 1).astype(outs.dtype)
    return lax.psum(outs * keep, axis)
