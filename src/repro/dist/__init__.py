"""repro.dist — cross-pod distributed utilities (slow-link regime).

- compress: quantized gradient all-reduce over the pod axis (int8 +
  error feedback), built on the ring-pipeline engine.
- pipeline: SPMD GPipe pipeline parallelism over a mesh axis.
"""
from . import compress, pipeline

__all__ = ["compress", "pipeline"]
