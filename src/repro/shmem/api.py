"""Backend-independent pieces of the shmem API.

The primitive set (paper Table 1, OpenSHMEM names):

  my_pe / n_pes            rank identity (this module — pure mesh-axis
                           arithmetic, valid inside kernels and graphs)
  putmem_signal_nbi        non-blocking one-sided put whose arrival
                           signal and data transfer are ONE operation
  putmem_signal            blocking variant (returns after send drains)
  signal_op / notify       increment a remote signal without data
  signal_wait_until / wait spin until a local signal reaches a value,
                           then consume it
  barrier_all              all-ranks rendezvous
  broadcast_put            multimem_st analogue (put to every peer)
  quiet                    drain outstanding one-sided ops
  consume_token            data-dependency fence (source fidelity)
  symmetric allocation     pltpu: extra kernel outputs in ``pl.ANY``
                           (stable cross-device addresses);
                           emulated: ``emulated.symmetric_alloc``

Each backend module (``tpu_backend``, ``emulated``) implements the set
against its own memory model; this module holds what is common.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
from jax import lax

Axis = Union[str, Sequence[str]]


def my_pe(axis: Axis) -> jax.Array:
    """Linearized rank along one or more mesh axes (row-major).

    OpenSHMEM ``shmem_my_pe``: valid both at graph level (inside
    shard_map) and at kernel level (inside a Pallas kernel body), since
    mesh axis indices are available in both.
    """
    if isinstance(axis, str):
        return lax.axis_index(axis)
    idx = lax.axis_index(axis[0])
    for a in axis[1:]:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def n_pes(axis: Axis) -> int:
    """OpenSHMEM ``shmem_n_pes``: world size along the axis (static)."""
    if isinstance(axis, str):
        return lax.axis_size(axis)
    n = 1
    for a in axis:
        n *= lax.axis_size(a)
    return n


def consume_token(x, token=None):
    """Paper: consume_token — creates a data dependency between a wait
    and a following load. Pallas refs are effect-ordered and the
    emulated backend's ordered callbacks are sequenced per device, so
    loads issued after a wait are already ordered; kept for source
    fidelity with the paper's primitive list."""
    del token
    return x
