"""The emulated-DMA backend: shmem on host-side symmetric heaps.

This jax's Pallas interpreter cannot emulate cross-device remote DMAs or
semaphore signals, which used to gate every fused distributed kernel
behind a graph-level fallback on CPU. This module removes that gate by
emulating the DMA engine itself:

  symmetric heap   one host-side store per traced kernel instance
                   (namespaced by collective_id), holding one numpy
                   buffer per (name, pe, slot) — the analogue of
                   NVSHMEM's symmetric heap (same name on every PE, PE-
                   indexed storage).
  signal slots     per-(name, pe) counting semaphores guarded by one
                   condition variable per instance — the analogue of
                   the chip's DMA-completion semaphores.

Every primitive is an ``io_callback`` issued from inside ``shard_map``:
jax's CPU client runs each virtual device's SPMD program on its own
thread, so a blocking ``signal_wait_until`` on PE i really does sleep
until PE j's ``putmem_signal_nbi`` lands — puts, arrival signals,
credit flow-control and barriers all execute with their true
concurrency semantics.

Ordering: this jax crashes on ordered effects in multi-parameter jitted
programs (XLA sharding-propagation CHECK), so per-device program order
is enforced with an explicit **token chain** instead — every callback
consumes the previous callback's token and emits a new one, giving a
hard data-dependency order. :class:`ShmemCtx` threads the token so
kernel bodies read like their Pallas counterparts (the token chain is
the emulated analogue of Pallas ref effect-ordering).

Protocol rules for kernels built on this backend:

  1. Open and close every kernel with ``barrier_all`` (the paper's
     barrier-after-allocation, plus: the trailing barrier makes
     back-to-back executions of the same traced kernel — which share
     one state instance — unable to interleave their signal state).
  2. A correct kernel consumes every signal it causes — semaphores must
     return to zero at the trailing barrier. :func:`reset` exists for
     the tuner's between-candidates cleanup of *aborted* runs, matching
     the paper's "overlapped kernels cannot be replayed without
     resetting signals".

Packetization: XLA's CPU runtime moves callback operands/results above
~100KB through an asynchronous transfer path that can starve (and
deadlock) on small hosts while other device threads sit in blocking
waits. The emulated engine therefore moves data like a real DMA engine
moves it — in bounded packets: a put larger than ``_PACKET_BYTES``
issues one callback per packet into the destination buffer and raises
the arrival signal only with the LAST packet (signal-on-completion,
putmem_signal semantics); reads mirror this. Payloads per callback stay
small enough for the synchronous transfer path regardless of transfer
size.

All waits time out (``REPRO_SHMEM_TIMEOUT`` seconds, default 60 —
resolved at WAIT time, so tests and the tuner can tighten or relax it
per run without reimporting) and raise the **stall watchdog report**: a
per-PE waiter table (who waits on which signal at what value, against
the live semaphore counts) plus each PE's last trace events, instead of
deadlocking the test harness with a one-line message.

Observability: when :mod:`repro.obs` tracing is enabled, every host op
appends a timestamped per-PE :class:`repro.obs.TraceEvent` into this
world's bounded ring buffer (``_World.trace``), and
:meth:`ShmemCtx.span` lets the tile executor bracket traced computes
with begin/end marks (data-dependency ordered through the token chain).
Disabled, the only cost is one boolean check per callback — the traced
program is unchanged, so outputs are bit-identical.
"""
from __future__ import annotations

import collections
import functools
import itertools
import os
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from .. import obs
from .api import my_pe


def _timeout() -> float:
    """Wait timeout in seconds — resolved per wait, not at import."""
    return float(os.environ.get("REPRO_SHMEM_TIMEOUT", "60"))

# Max bytes per callback operand/result: keep under XLA CPU's ~100KB
# synchronous host-transfer cutoff (larger transfers take an async path
# that can starve against blocked device threads).
_PACKET_BYTES = int(os.environ.get("REPRO_SHMEM_PACKET_BYTES", str(64 * 1024)))


class _World:
    """Shared state for one kernel instance: heap + signals + barrier,
    plus the observability side — a bounded trace ring buffer, pending
    span-begin timestamps, and the live waiter table the stall watchdog
    dumps on timeout."""

    def __init__(self) -> None:
        self.cond = threading.Condition()
        self.heap: Dict[Tuple[str, int, int], np.ndarray] = {}
        self.sems: Dict[Tuple[str, int], int] = {}
        self.bar_count = 0
        self.bar_gen = 0
        # trace ring (repro.obs events; appended only while tracing is on)
        self.trace: collections.deque = collections.deque(
            maxlen=obs.capacity())
        # (pe, kind, name) -> t0 of an open ShmemCtx.span
        self.pending: Dict[Tuple[int, str, str], float] = {}
        # pe -> (kind, sig, want) while that PE blocks in a wait/barrier
        self.waiters: Dict[int, Tuple[str, str, int]] = {}


# State is keyed by (collective_id, trace-time instance number): every
# traced ShmemCtx gets a PRIVATE world. Under shard_map the kernel is
# traced once for all devices, so each device's callbacks agree on the
# instance number — but two kernels in the same program (e.g. two
# ag_linear layers) can never touch each other's heap/signals even when
# they share a collective_id, and io_callback(ordered=False)'s freedom
# to reorder data-independent callbacks across the two kernels becomes
# harmless. Re-executions of the same traced program DO share the
# instance (that is the replay path, protected by the trailing barrier
# + per-device launch FIFO).
_worlds: Dict[Tuple[int, int], _World] = {}
_worlds_lock = threading.Lock()


def _world(key: Tuple[int, int]) -> _World:
    with _worlds_lock:
        w = _worlds.get(key)
        if w is None:
            w = _worlds[key] = _World()
        return w


def reset(cid: Optional[int] = None) -> None:
    """Drop heap + signal state (every instance of one collective_id, or
    everything). Trace ring buffers die with their worlds — drain
    ``repro.obs.events()`` first if you want the timeline.

    Only call between executions (the empirical tuner's ``reset``
    callback after an aborted/partial candidate). If a wait is still in
    flight — a PE blocked inside ``signal_wait_until`` / ``barrier_all``
    — resetting would silently drop the signal state that PE is waiting
    on, so this raises with the live waiter table instead.
    """
    with _worlds_lock:
        keys = [k for k in _worlds if cid is None or k[0] == cid]
        reports = []
        for key in keys:
            w = _worlds[key]
            with w.cond:
                if w.waiters:
                    reports.append(_watchdog_report(w, key))
        if reports:
            raise RuntimeError(
                "shmem.emulated.reset(): wait in flight — resetting now "
                "would drop signal state under a blocked PE. Let the "
                "program drain (or time out) first.\n" + "\n".join(reports))
        for key in keys:
            _worlds.pop(key, None)


def _signal_state(w: _World) -> str:
    live = {k: v for k, v in w.sems.items() if v}
    return f"live signals: {live or '{}'}; heap keys: {len(w.heap)}"


def _watchdog_report(w: _World, key: Tuple[int, int], last: int = 8) -> str:
    """The stall watchdog's dump: per-PE waiter table + live signal state
    + each PE's last ``last`` trace events. Call with ``w.cond`` held."""
    lines = [f"--- shmem watchdog (cid={key[0]}, instance={key[1]}) ---"]
    if w.waiters:
        lines.append("waiter table:")
        for pe in sorted(w.waiters):
            kind, sig, want = w.waiters[pe]
            if kind == "barrier":
                have = w.bar_count
            else:
                have = w.sems.get((sig, pe), 0)
            lines.append(f"  pe {pe}: {kind} on {sig!r} "
                         f"want={want} have={have}")
    else:
        lines.append("waiter table: (no PE currently blocked)")
    lines.append(_signal_state(w))
    by_pe: Dict[int, list] = {}
    for ev in w.trace:
        by_pe.setdefault(ev.pe, []).append(ev)
    if by_pe:
        t_base = min(ev.t0 for evs in by_pe.values() for ev in evs)
        lines.append(f"last {last} trace events per PE "
                     f"(+seconds since trace start):")
        for pe in sorted(by_pe):
            for ev in by_pe[pe][-last:]:
                size = f" {ev.bytes}B" if ev.bytes else ""
                lines.append(
                    f"  pe {pe}: +{ev.t0 - t_base:.6f}s "
                    f"{ev.kind}:{ev.name}{size} "
                    f"dur={(ev.t1 - ev.t0) * 1e6:.0f}us")
    else:
        lines.append("no trace events recorded — enable repro.obs tracing "
                     "before the run for per-PE timelines")
    return "\n".join(lines)


def _trace(w: _World, key: Tuple[int, int], pe: int, kind: str, name: str,
           nbytes: int, t0: float, t1: float) -> None:
    """Append one obs event (tracing gate checked by the caller)."""
    w.trace.append(obs.TraceEvent(pe, key[0], kind, name, nbytes, t0, t1))


# ---------------------------------------------------------------------------
# Host side (runs on each virtual device's execution thread)
# ---------------------------------------------------------------------------


def _host_put_packet(cid, buf, sig, total, dtype, off, last, tok, peer, slot,
                     me, pkt):
    """One DMA packet of a put: copy into [off, off+len) of the (flat)
    destination buffer; the LAST packet raises the arrival signal."""
    t0 = time.perf_counter()
    w = _world(cid)
    pkt = np.asarray(pkt)
    with w.cond:
        key = (buf, int(peer), int(slot))
        arr = w.heap.get(key)
        if arr is None or arr.size != total or arr.dtype != np.dtype(dtype):
            arr = w.heap[key] = np.empty(total, dtype)
        arr[off:off + pkt.size] = pkt
        if last and sig:
            skey = (sig, int(peer))
            w.sems[skey] = w.sems.get(skey, 0) + 1
            w.cond.notify_all()
        if last and obs.enabled():
            # one event per logical put (not per packet): bytes = payload
            _trace(w, cid, int(me), "put", f"{buf}->pe{int(peer)}",
                   int(total) * np.dtype(dtype).itemsize,
                   t0, time.perf_counter())
    return np.int32(tok) + 1


def _host_signal(cid, sig, tok, peer, inc, me):
    t0 = time.perf_counter()
    w = _world(cid)
    with w.cond:
        key = (sig, int(peer))
        w.sems[key] = w.sems.get(key, 0) + int(inc)
        w.cond.notify_all()
        if obs.enabled():
            _trace(w, cid, int(me), "signal", f"{sig}->pe{int(peer)}", 0,
                   t0, time.perf_counter())
    return np.int32(tok) + 1


def _host_wait(cid, sig, tok, me, value):
    t0 = time.perf_counter()
    w = _world(cid)
    pe = int(me)
    key = (sig, pe)
    # Credit waits (cap* signals: flow control — waiting to SEND) vs
    # arrival waits (recv-style signals: data deps — waiting to RECEIVE).
    kind = "credit_wait" if sig.startswith("cap") else "arrival_wait"
    with w.cond:
        w.waiters[pe] = ("wait", sig, int(value))
        try:
            ok = w.cond.wait_for(
                lambda: w.sems.get(key, 0) >= int(value), timeout=_timeout()
            )
            if not ok:
                raise RuntimeError(
                    f"shmem.emulated: signal_wait_until timed out (cid={cid}, "
                    f"sig={sig!r}, pe={pe}, want={int(value)}, "
                    f"have={w.sems.get(key, 0)})\n" + _watchdog_report(w, cid)
                )
        finally:
            w.waiters.pop(pe, None)
        w.sems[key] -= int(value)
        if obs.enabled():
            _trace(w, cid, pe, kind, sig, 0, t0, time.perf_counter())
    return np.int32(tok) + 1


def _host_read_packet(cid, buf, off, n, tok, me, slot):
    """One DMA packet of a read: [off, off+n) of the (flat) local buffer."""
    t0 = time.perf_counter()
    w = _world(cid)
    with w.cond:
        key = (buf, int(me), int(slot))
        if key not in w.heap:
            raise RuntimeError(
                f"shmem.emulated: read of unwritten symmetric buffer "
                f"{key} (cid={cid}); {_signal_state(w)}"
            )
        out = w.heap[key][off:off + n].copy()
        if obs.enabled():
            _trace(w, cid, int(me), "read", buf, out.nbytes,
                   t0, time.perf_counter())
        return out, np.int32(tok) + 1


def _host_alloc(cid, buf, world, total, dtype, tok, me):
    # Symmetric allocation: the same named buffer exists on every PE.
    # First caller materializes all PE copies; idempotent thereafter.
    t0 = time.perf_counter()
    w = _world(cid)
    with w.cond:
        for pe in range(int(world)):
            key = (buf, pe, 0)
            if key not in w.heap:
                w.heap[key] = np.zeros(total, dtype)
        if obs.enabled():
            _trace(w, cid, int(me), "alloc", buf,
                   int(total) * np.dtype(dtype).itemsize,
                   t0, time.perf_counter())
    return np.int32(tok) + 1


def _host_barrier(cid, world, tok, me):
    t0 = time.perf_counter()
    w = _world(cid)
    pe = int(me)
    with w.cond:
        gen = w.bar_gen
        w.bar_count += 1
        if w.bar_count >= int(world):
            w.bar_count = 0
            w.bar_gen += 1
            w.cond.notify_all()
        else:
            w.waiters[pe] = ("barrier", "barrier_all", int(world))
            try:
                ok = w.cond.wait_for(lambda: w.bar_gen != gen,
                                     timeout=_timeout())
                if not ok:
                    raise RuntimeError(
                        f"shmem.emulated: barrier_all timed out (cid={cid}, "
                        f"pe={pe}, arrived={w.bar_count}/{int(world)})\n"
                        + _watchdog_report(w, cid)
                    )
            finally:
                w.waiters.pop(pe, None)
        if obs.enabled():
            _trace(w, cid, pe, "barrier", "barrier_all", 0,
                   t0, time.perf_counter())
    return np.int32(tok) + 1


def _host_span(cid, kind, name, end, tok, me, *dep):
    """Begin/end mark of a traced-compute span (:meth:`ShmemCtx.span`).
    The begin mark parks t0 in ``_World.pending``; the end mark pops it
    and records the completed event. ``dep`` is an optional value
    operand (``span(sync=True)``) that makes the end mark's EXECUTION
    wait for the compute — its value is ignored."""
    t = time.perf_counter()
    w = _world(cid)
    pe = int(me)
    with w.cond:
        if not end:
            w.pending[(pe, kind, name)] = t
        else:
            t0 = w.pending.pop((pe, kind, name), t)
            if obs.enabled():
                _trace(w, cid, pe, kind, name, 0, t0, t)
    return np.int32(tok) + 1


# ---------------------------------------------------------------------------
# Traced side: ShmemCtx threads the ordering token through the callbacks
# ---------------------------------------------------------------------------

_TOKEN = jax.ShapeDtypeStruct((), jnp.int32)

# Trace-time instance numbers: each traced ShmemCtx owns a private world
# (see _worlds). Doubles as a distinct initial-token constant so no two
# contexts present identical leading callbacks.
_instances = itertools.count(1)


class ShmemCtx:
    """One kernel's handle to the emulated DMA engine.

    Construct inside the kernel body (under shard_map), use the paper's
    primitive names as methods, and let the context thread the ordering
    token. Peer ids and slot ids may be traced values. Each construction
    (= each traced kernel call) gets private heap/signal/barrier state;
    ``collective_id`` namespaces it for diagnostics and targeted
    :func:`reset`.
    """

    def __init__(self, axis: str, world: int, cid: int):
        self.axis = axis
        self.world = world
        self.cid = cid
        inst = next(_instances)
        self._key = (cid, inst)
        self._me = jnp.asarray(my_pe(axis), jnp.int32)
        self._tok = jnp.asarray(inst, jnp.int32)

    # -- internal -----------------------------------------------------
    def _io(self, host_fn, result, *operands):
        return io_callback(host_fn, result, self._tok, *operands,
                           ordered=False)

    @staticmethod
    def _packets(shape, dtype):
        """(total_elems, [(off, n), ...]) DMA packets for a buffer."""
        total = 1
        for d in shape:
            total *= int(d)
        per = max(1, _PACKET_BYTES // max(1, jnp.dtype(dtype).itemsize))
        if total == 0:
            return 0, [(0, 0)]
        return total, [(off, min(per, total - off))
                       for off in range(0, total, per)]

    # -- primitive set ------------------------------------------------
    def barrier_all(self):
        """All-ranks rendezvous for this collective_id (paper: barrier_all)."""
        self._tok = self._io(
            functools.partial(_host_barrier, self._key, self.world),
            _TOKEN, self._me,
        )

    def putmem_signal_nbi(self, x, peer, *, buf: str = "ws", slot=0,
                          sig: str = "recv"):
        """One-sided put of value ``x`` into ``peer``'s symmetric buffer
        ``(buf, slot)`` + arrival signal ``sig`` on the peer. Large
        values move as bounded DMA packets; the signal rides the last
        packet, so — as in NVSHMEM's putmem_signal — it fires only once
        the full payload has landed. (The emulated copy completes inside
        the callbacks, so there is no separate ``quiet``; ordering comes
        from the token chain.)"""
        total, packets = self._packets(x.shape, x.dtype)
        xf = jnp.ravel(x)
        peer = jnp.asarray(peer, jnp.int32)
        slot = jnp.asarray(slot, jnp.int32)
        dtype = jnp.dtype(x.dtype).name
        for off, n in packets:
            pkt = jax.lax.slice(xf, (off,), (off + n,))
            last = off + n >= total
            self._tok = self._io(
                functools.partial(_host_put_packet, self._key, buf,
                                  sig if last else "", total, dtype, off, last),
                _TOKEN, peer, slot, self._me, pkt,
            )

    putmem_signal = putmem_signal_nbi  # emulated sends complete synchronously

    def signal_op(self, peer, *, sig: str, inc: int = 1):
        """Increment signal ``sig`` on ``peer`` (paper: signal_op / notify)."""
        self._tok = self._io(
            functools.partial(_host_signal, self._key, sig),
            _TOKEN,
            jnp.asarray(peer, jnp.int32),
            jnp.asarray(inc, jnp.int32),
            self._me,
        )

    notify = signal_op

    def signal_wait_until(self, *, sig: str, value: int = 1):
        """Block this PE until its ``sig`` count reaches ``value``; consume."""
        self._tok = self._io(
            functools.partial(_host_wait, self._key, sig),
            _TOKEN,
            self._me,
            jnp.asarray(value, jnp.int32),
        )

    wait = signal_wait_until

    def read_symmetric(self, shape, dtype, *, buf: str = "ws", slot=0):
        """Read this PE's copy of symmetric buffer ``(buf, slot)``
        (packetized like puts; reassembled and reshaped to ``shape``)."""
        total, packets = self._packets(shape, dtype)
        slot = jnp.asarray(slot, jnp.int32)
        parts = []
        for off, n in packets:
            part, self._tok = self._io(
                functools.partial(_host_read_packet, self._key, buf, off, n),
                (jax.ShapeDtypeStruct((n,), dtype), _TOKEN),
                self._me, slot,
            )
            parts.append(part)
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return flat.reshape(shape)

    def wait_read(self, shape, dtype, *, buf: str = "ws", slot=0,
                  sig: str = "recv", value: int = 1):
        """signal_wait_until + read: the common consumer idiom — wait for
        the arrival signal (which rides the put's last packet), then load
        the chunk."""
        self.signal_wait_until(sig=sig, value=value)
        return self.read_symmetric(shape, dtype, buf=buf, slot=slot)

    def symmetric_alloc(self, shape, dtype, *, buf: str):
        """shmem_malloc analogue: ensure ``buf`` slot 0 exists (zeroed) on
        every PE. Follow with :meth:`barrier_all` before any one-sided
        access, as OpenSHMEM requires."""
        total, _ = self._packets(shape, dtype)
        self._tok = self._io(
            functools.partial(_host_alloc, self._key, buf, self.world,
                              total, jnp.dtype(dtype).name),
            _TOKEN,
            self._me,
        )

    def _span_tok(self, tok, kind, name, sync, fn, args):
        """Functional core of :meth:`span`: explicit token in/out, so it
        can be traced inside ``lax.cond`` branches (``span(when=...)``)."""
        tok = io_callback(
            functools.partial(_host_span, self._key, kind, name, False),
            _TOKEN, tok, self._me, ordered=False)
        if args:
            flat, treedef = jax.tree_util.tree_flatten(tuple(args))
            tied = jax.lax.optimization_barrier(tuple(flat) + (tok,))
            args = jax.tree_util.tree_unflatten(treedef, tied[:-1])
            tok = tied[-1]
        out = fn(*args)
        leaves, treedef = jax.tree_util.tree_flatten(out)
        if leaves:
            tied = jax.lax.optimization_barrier(tuple(leaves) + (tok,))
            tok = tied[-1]
            out = jax.tree_util.tree_unflatten(treedef, list(tied[:-1]))
        dep = (tuple(jnp.ravel(lf)[0] for lf in leaves)
               if sync and leaves else ())
        tok = io_callback(
            functools.partial(_host_span, self._key, kind, name, True),
            _TOKEN, tok, self._me, *dep, ordered=False)
        return out, tok

    def span(self, kind: str, fn, *args, name: str = "", sync: bool = False,
             when=None):
        """Run ``fn(*args)`` bracketed by begin/end trace marks so the
        host timeline carries a ``kind`` span (``tile_compute``,
        ``decode``, ...) for this PE.

        With tracing disabled this IS ``fn(*args)`` — the traced program
        is unchanged, so outputs stay bit-identical. Enabled, the marks
        are host callbacks tied around the compute via
        ``optimization_barrier``, which pins the COMPILE-TIME schedule
        but creates no runtime cross-element dependency: XLA's thunk
        runtime may still retire the end mark while the compute is in
        flight, so default spans time dispatch, not execution.
        ``sync=True`` additionally feeds one element of each output leaf
        to the end mark as a value operand — a true data dependency, so
        the end timestamp waits for the compute. Use it only where the
        PE's NEXT token-chained op already consumes the result (e.g. a
        carry-passing fold), or the sync point serializes work the
        schedule meant to overlap. ``when`` (a traced bool) emits the
        marks only when true — ``fn`` ALWAYS runs; pass the predicate of
        a compute that no-ops dynamically (e.g. a fully-masked causal
        block) so the timeline shows its real work, not a phantom span.
        Decided at TRACE time — enable tracing before the first
        jit-compilation of the program you want span-annotated.
        """
        if not obs.enabled():
            return fn(*args)
        with obs.phase(kind, name):
            if when is None:
                out, self._tok = self._span_tok(self._tok, kind, name, sync,
                                                fn, args)
                return out
            flat, treedef = jax.tree_util.tree_flatten(tuple(args))

            def _marked(tok, *leaves):
                a = jax.tree_util.tree_unflatten(treedef, leaves)
                return self._span_tok(tok, kind, name, sync, fn, a)

            def _plain(tok, *leaves):
                return fn(*jax.tree_util.tree_unflatten(treedef, leaves)), tok

            out, self._tok = jax.lax.cond(when, _marked, _plain,
                                          self._tok, *flat)
            return out

    def broadcast_put(self, x, *, buf: str = "ws", sig: str = "recv"):
        """multimem_st analogue: put ``x`` into every peer's ``(buf, my_pe)``
        slot (peer loop of one-sided puts, matching the pltpu backend's
        hardware adaptation). Also stores locally so all W slots exist
        symmetrically; signals ``sig`` once per delivery (W total per PE)."""
        for off in range(self.world):
            peer = jax.lax.rem(self._me + off, self.world)
            self.putmem_signal_nbi(x, peer, buf=buf, slot=self._me, sig=sig)
