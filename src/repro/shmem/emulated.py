"""The emulated-DMA backend: shmem on host-side symmetric heaps.

This jax's Pallas interpreter cannot emulate cross-device remote DMAs or
semaphore signals, which used to gate every fused distributed kernel
behind a graph-level fallback on CPU. This module removes that gate by
emulating the DMA engine itself:

  symmetric heap   one host-side store per traced kernel instance
                   (namespaced by collective_id), holding one numpy
                   buffer per (name, pe, slot) — the analogue of
                   NVSHMEM's symmetric heap (same name on every PE, PE-
                   indexed storage).
  signal slots     per-(name, pe) counting semaphores guarded by one
                   condition variable per instance — the analogue of
                   the chip's DMA-completion semaphores.

Every primitive is an ``io_callback`` issued from inside ``shard_map``:
jax's CPU client runs each virtual device's SPMD program on its own
thread, so a blocking ``signal_wait_until`` on PE i really does sleep
until PE j's ``putmem_signal_nbi`` lands — puts, arrival signals,
credit flow-control and barriers all execute with their true
concurrency semantics.

Ordering: this jax crashes on ordered effects in multi-parameter jitted
programs (XLA sharding-propagation CHECK), so per-device program order
is enforced with an explicit **token chain** instead — every callback
consumes the previous callback's token and emits a new one, giving a
hard data-dependency order. :class:`ShmemCtx` threads the token so
kernel bodies read like their Pallas counterparts (the token chain is
the emulated analogue of Pallas ref effect-ordering).

Protocol rules for kernels built on this backend:

  1. Open and close every kernel with ``barrier_all`` (the paper's
     barrier-after-allocation, plus: the trailing barrier makes
     back-to-back executions of the same traced kernel — which share
     one state instance — unable to interleave their signal state).
  2. A correct kernel consumes every signal it causes — semaphores must
     return to zero at the trailing barrier. :func:`reset` exists for
     the tuner's between-candidates cleanup of *aborted* runs, matching
     the paper's "overlapped kernels cannot be replayed without
     resetting signals".

Packetization: XLA's CPU runtime moves callback operands/results above
~100KB through an asynchronous transfer path that can starve (and
deadlock) on small hosts while other device threads sit in blocking
waits. The emulated engine therefore moves data like a real DMA engine
moves it — in bounded packets: a put larger than ``_PACKET_BYTES``
issues one callback per packet into the destination buffer and raises
the arrival signal only with the LAST packet (signal-on-completion,
putmem_signal semantics); reads mirror this. Payloads per callback stay
small enough for the synchronous transfer path regardless of transfer
size.

All waits time out (``REPRO_SHMEM_TIMEOUT`` seconds, default 60) and
raise with a dump of the live signal state instead of deadlocking the
test harness.
"""
from __future__ import annotations

import functools
import itertools
import os
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from .api import my_pe

_TIMEOUT = float(os.environ.get("REPRO_SHMEM_TIMEOUT", "60"))

# Max bytes per callback operand/result: keep under XLA CPU's ~100KB
# synchronous host-transfer cutoff (larger transfers take an async path
# that can starve against blocked device threads).
_PACKET_BYTES = int(os.environ.get("REPRO_SHMEM_PACKET_BYTES", str(64 * 1024)))


class _World:
    """Shared state for one kernel instance: heap + signals + barrier."""

    def __init__(self) -> None:
        self.cond = threading.Condition()
        self.heap: Dict[Tuple[str, int, int], np.ndarray] = {}
        self.sems: Dict[Tuple[str, int], int] = {}
        self.bar_count = 0
        self.bar_gen = 0


# State is keyed by (collective_id, trace-time instance number): every
# traced ShmemCtx gets a PRIVATE world. Under shard_map the kernel is
# traced once for all devices, so each device's callbacks agree on the
# instance number — but two kernels in the same program (e.g. two
# ag_linear layers) can never touch each other's heap/signals even when
# they share a collective_id, and io_callback(ordered=False)'s freedom
# to reorder data-independent callbacks across the two kernels becomes
# harmless. Re-executions of the same traced program DO share the
# instance (that is the replay path, protected by the trailing barrier
# + per-device launch FIFO).
_worlds: Dict[Tuple[int, int], _World] = {}
_worlds_lock = threading.Lock()


def _world(key: Tuple[int, int]) -> _World:
    with _worlds_lock:
        w = _worlds.get(key)
        if w is None:
            w = _worlds[key] = _World()
        return w


def reset(cid: Optional[int] = None) -> None:
    """Drop heap + signal state (every instance of one collective_id, or
    everything).

    Only call between executions (the empirical tuner's ``reset``
    callback after an aborted/partial candidate) — never while an SPMD
    program using the state is in flight.
    """
    with _worlds_lock:
        if cid is None:
            _worlds.clear()
        else:
            for key in [k for k in _worlds if k[0] == cid]:
                _worlds.pop(key, None)


def _signal_state(w: _World) -> str:
    live = {k: v for k, v in w.sems.items() if v}
    return f"live signals: {live or '{}'}; heap keys: {len(w.heap)}"


# ---------------------------------------------------------------------------
# Host side (runs on each virtual device's execution thread)
# ---------------------------------------------------------------------------


def _host_put_packet(cid, buf, sig, total, dtype, off, last, tok, peer, slot, pkt):
    """One DMA packet of a put: copy into [off, off+len) of the (flat)
    destination buffer; the LAST packet raises the arrival signal."""
    w = _world(cid)
    pkt = np.asarray(pkt)
    with w.cond:
        key = (buf, int(peer), int(slot))
        arr = w.heap.get(key)
        if arr is None or arr.size != total or arr.dtype != np.dtype(dtype):
            arr = w.heap[key] = np.empty(total, dtype)
        arr[off:off + pkt.size] = pkt
        if last and sig:
            skey = (sig, int(peer))
            w.sems[skey] = w.sems.get(skey, 0) + 1
            w.cond.notify_all()
    return np.int32(tok) + 1


def _host_signal(cid, sig, tok, peer, inc):
    w = _world(cid)
    with w.cond:
        key = (sig, int(peer))
        w.sems[key] = w.sems.get(key, 0) + int(inc)
        w.cond.notify_all()
    return np.int32(tok) + 1


def _host_wait(cid, sig, tok, me, value):
    w = _world(cid)
    key = (sig, int(me))
    with w.cond:
        ok = w.cond.wait_for(
            lambda: w.sems.get(key, 0) >= int(value), timeout=_TIMEOUT
        )
        if not ok:
            raise RuntimeError(
                f"shmem.emulated: signal_wait_until timed out (cid={cid}, "
                f"sig={sig!r}, pe={int(me)}, want={int(value)}, "
                f"have={w.sems.get(key, 0)}); {_signal_state(w)}"
            )
        w.sems[key] -= int(value)
    return np.int32(tok) + 1


def _host_read_packet(cid, buf, off, n, tok, me, slot):
    """One DMA packet of a read: [off, off+n) of the (flat) local buffer."""
    w = _world(cid)
    with w.cond:
        key = (buf, int(me), int(slot))
        if key not in w.heap:
            raise RuntimeError(
                f"shmem.emulated: read of unwritten symmetric buffer "
                f"{key} (cid={cid}); {_signal_state(w)}"
            )
        return w.heap[key][off:off + n].copy(), np.int32(tok) + 1


def _host_alloc(cid, buf, world, total, dtype, tok, me):
    # Symmetric allocation: the same named buffer exists on every PE.
    # First caller materializes all PE copies; idempotent thereafter.
    w = _world(cid)
    with w.cond:
        for pe in range(int(world)):
            key = (buf, pe, 0)
            if key not in w.heap:
                w.heap[key] = np.zeros(total, dtype)
    return np.int32(tok) + 1


def _host_barrier(cid, world, tok, me):
    w = _world(cid)
    with w.cond:
        gen = w.bar_gen
        w.bar_count += 1
        if w.bar_count >= int(world):
            w.bar_count = 0
            w.bar_gen += 1
            w.cond.notify_all()
        else:
            ok = w.cond.wait_for(lambda: w.bar_gen != gen, timeout=_TIMEOUT)
            if not ok:
                raise RuntimeError(
                    f"shmem.emulated: barrier_all timed out (cid={cid}, "
                    f"pe={int(me)}, arrived={w.bar_count}/{int(world)}); "
                    f"{_signal_state(w)}"
                )
    return np.int32(tok) + 1


# ---------------------------------------------------------------------------
# Traced side: ShmemCtx threads the ordering token through the callbacks
# ---------------------------------------------------------------------------

_TOKEN = jax.ShapeDtypeStruct((), jnp.int32)

# Trace-time instance numbers: each traced ShmemCtx owns a private world
# (see _worlds). Doubles as a distinct initial-token constant so no two
# contexts present identical leading callbacks.
_instances = itertools.count(1)


class ShmemCtx:
    """One kernel's handle to the emulated DMA engine.

    Construct inside the kernel body (under shard_map), use the paper's
    primitive names as methods, and let the context thread the ordering
    token. Peer ids and slot ids may be traced values. Each construction
    (= each traced kernel call) gets private heap/signal/barrier state;
    ``collective_id`` namespaces it for diagnostics and targeted
    :func:`reset`.
    """

    def __init__(self, axis: str, world: int, cid: int):
        self.axis = axis
        self.world = world
        self.cid = cid
        inst = next(_instances)
        self._key = (cid, inst)
        self._me = jnp.asarray(my_pe(axis), jnp.int32)
        self._tok = jnp.asarray(inst, jnp.int32)

    # -- internal -----------------------------------------------------
    def _io(self, host_fn, result, *operands):
        return io_callback(host_fn, result, self._tok, *operands,
                           ordered=False)

    @staticmethod
    def _packets(shape, dtype):
        """(total_elems, [(off, n), ...]) DMA packets for a buffer."""
        total = 1
        for d in shape:
            total *= int(d)
        per = max(1, _PACKET_BYTES // max(1, jnp.dtype(dtype).itemsize))
        if total == 0:
            return 0, [(0, 0)]
        return total, [(off, min(per, total - off))
                       for off in range(0, total, per)]

    # -- primitive set ------------------------------------------------
    def barrier_all(self):
        """All-ranks rendezvous for this collective_id (paper: barrier_all)."""
        self._tok = self._io(
            functools.partial(_host_barrier, self._key, self.world),
            _TOKEN, self._me,
        )

    def putmem_signal_nbi(self, x, peer, *, buf: str = "ws", slot=0,
                          sig: str = "recv"):
        """One-sided put of value ``x`` into ``peer``'s symmetric buffer
        ``(buf, slot)`` + arrival signal ``sig`` on the peer. Large
        values move as bounded DMA packets; the signal rides the last
        packet, so — as in NVSHMEM's putmem_signal — it fires only once
        the full payload has landed. (The emulated copy completes inside
        the callbacks, so there is no separate ``quiet``; ordering comes
        from the token chain.)"""
        total, packets = self._packets(x.shape, x.dtype)
        xf = jnp.ravel(x)
        peer = jnp.asarray(peer, jnp.int32)
        slot = jnp.asarray(slot, jnp.int32)
        dtype = jnp.dtype(x.dtype).name
        for off, n in packets:
            pkt = jax.lax.slice(xf, (off,), (off + n,))
            last = off + n >= total
            self._tok = self._io(
                functools.partial(_host_put_packet, self._key, buf,
                                  sig if last else "", total, dtype, off, last),
                _TOKEN, peer, slot, pkt,
            )

    putmem_signal = putmem_signal_nbi  # emulated sends complete synchronously

    def signal_op(self, peer, *, sig: str, inc: int = 1):
        """Increment signal ``sig`` on ``peer`` (paper: signal_op / notify)."""
        self._tok = self._io(
            functools.partial(_host_signal, self._key, sig),
            _TOKEN,
            jnp.asarray(peer, jnp.int32),
            jnp.asarray(inc, jnp.int32),
        )

    notify = signal_op

    def signal_wait_until(self, *, sig: str, value: int = 1):
        """Block this PE until its ``sig`` count reaches ``value``; consume."""
        self._tok = self._io(
            functools.partial(_host_wait, self._key, sig),
            _TOKEN,
            self._me,
            jnp.asarray(value, jnp.int32),
        )

    wait = signal_wait_until

    def read_symmetric(self, shape, dtype, *, buf: str = "ws", slot=0):
        """Read this PE's copy of symmetric buffer ``(buf, slot)``
        (packetized like puts; reassembled and reshaped to ``shape``)."""
        total, packets = self._packets(shape, dtype)
        slot = jnp.asarray(slot, jnp.int32)
        parts = []
        for off, n in packets:
            part, self._tok = self._io(
                functools.partial(_host_read_packet, self._key, buf, off, n),
                (jax.ShapeDtypeStruct((n,), dtype), _TOKEN),
                self._me, slot,
            )
            parts.append(part)
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return flat.reshape(shape)

    def wait_read(self, shape, dtype, *, buf: str = "ws", slot=0,
                  sig: str = "recv", value: int = 1):
        """signal_wait_until + read: the common consumer idiom — wait for
        the arrival signal (which rides the put's last packet), then load
        the chunk."""
        self.signal_wait_until(sig=sig, value=value)
        return self.read_symmetric(shape, dtype, buf=buf, slot=slot)

    def symmetric_alloc(self, shape, dtype, *, buf: str):
        """shmem_malloc analogue: ensure ``buf`` slot 0 exists (zeroed) on
        every PE. Follow with :meth:`barrier_all` before any one-sided
        access, as OpenSHMEM requires."""
        total, _ = self._packets(shape, dtype)
        self._tok = self._io(
            functools.partial(_host_alloc, self._key, buf, self.world,
                              total, jnp.dtype(dtype).name),
            _TOKEN,
            self._me,
        )

    def broadcast_put(self, x, *, buf: str = "ws", sig: str = "recv"):
        """multimem_st analogue: put ``x`` into every peer's ``(buf, my_pe)``
        slot (peer loop of one-sided puts, matching the pltpu backend's
        hardware adaptation). Also stores locally so all W slots exist
        symmetrically; signals ``sig`` once per delivery (W total per PE)."""
        for off in range(self.world):
            peer = jax.lax.rem(self._me + off, self.world)
            self.putmem_signal_nbi(x, peer, buf=buf, slot=self._me, sig=sig)
