"""The shmem **tile executor**: every fused-kernel communication protocol,
written once, generic over a per-tile compute function.

The fused kernels used to hand-roll their put/signal step loops (ring +
credit flow control in ``ag_gemm``, the Alg. 3 push in ``rs_gemm``, the
Alg. 4 all-puts-up-front structure in ``ll_allgather``). Those protocols
are op-independent: what varies per op is only the *tile compute* — the
pure function applied to a chunk when it arrives (or before it is
pushed). This module factors the protocols out, so an overlapped kernel
is now a DECLARATION: ``executor.run(protocol, tile, operand, statics)``.

Protocols
---------
  ring_ag      Fig. 4 producer/consumer ring with credit flow control:
               the operand chunk rides rank -> rank+1 through a double-
               buffered symmetric workspace; a credit semaphore grants
               the left neighbor permission to overwrite a slot only
               after BOTH readers (local stage + outgoing remote DMA)
               are done. ``tile(chunk, *statics)`` consumes the chunk of
               step s (= rank (me - s) % W's data, the Fig. 7 swizzle);
               the result lands in that owner's output strip. The DMA of
               chunk s+1 is in flight while tile s computes.
  one_shot_ag  Alg. 4 low-latency structure: every rank one-sided-puts
               its chunk into every peer's slot ``me`` up-front (no
               serial ring dependency), waits for W arrivals, then runs
               ``tile`` per landed chunk. ``tile=None`` is the plain
               low-latency AllGather.
  push_rs      Alg. 3 push-mode GEMM+ReduceScatter: per step s the rank
               computes the partial tile for output block
               (me - s - 1) % W (peers first, own block last) and
               one-sided-pushes it to the owner's slot ``me``; each rank
               then waits for its W arrivals and locally reduces in f32.
               Compute of step s+1 overlaps the DMA of step s.
  one_shot_rs  the low-latency RS variant (ROADMAP): ALL W partials are
               computed first and the W puts issued up-front with
               distinct ring offsets — no compute/DMA interleaving
               dependency, latency-optimal for small blocks.
  one_shot_a2a the low-latency AllToAll (EP dispatch/combine): the
               operand's leading dim holds one block per destination PE;
               every PE pushes all its per-destination blocks up-front
               into the destination's slot ``me`` (signal-on-arrival),
               waits for its W arrivals, then runs ``tile`` per landed
               block — out[src] = tile(block PE src sent here). The
               inverse direction is the SAME protocol with the caller
               transposing block placement.
  bidir_ring_ag the executor-level form of the engine's bidir schedule:
               the chunk is split in half along dim 0; the top half
               rides the forward ring (me -> me+1), the bottom half the
               reverse ring (me -> me-1), each direction with its own
               double-buffered workspace + credit flow control, so each
               link direction carries half the bytes. Degrades to
               ring_ag when W < 3 or the chunk has odd rows (mirroring
               the graph lowering's degrade).
  ring_fold    carry-passing ring: the same double-buffered workspace +
               credit flow as ring_ag, but each arriving chunk is FOLDED
               into resident (f32) state instead of written to an output
               strip — the protocol behind ring attention's online
               softmax (m, l, acc) and any chunk-centric reduction that
               carries state across chunks. ``tile`` is a
               :class:`FoldTile` (init / fold / finalize), not a pure
               per-chunk function.
  two_level_ag two-axis (pod x ring) AllGather (Fig. 10): at each outer
               step the current region chunk is pushed over the slow
               inter-pod ring (double-buffered + credit flow) WHILE a
               pod-local one_shot exchange distributes it to every pod
               peer (per-source arrival signals); ``tile`` consumes all
               Wi chunks of the region per outer step. Takes
               ``axis=(inner, outer)`` and ``world=(Wi, Wo)``.
  two_level_rs two-axis GEMM+ReduceScatter (Fig. 10 / Alg. 5): per outer
               step the Wi partials for the scheduled pod region are
               computed and pushed up-front pod-locally (one_shot RS
               structure), reduced in f32, then the pod-reduced
               accumulator rides the inter-pod ring (peers' regions
               first, own pod last). Same two-axis calling convention.
  push_rs_ring_ag the chained boundary protocol (CoCoNet-style rs->ag
               fusion): an Alg. 3 push half reduces this rank's boundary
               block, a rank-local ``mid`` transforms it, and a Fig. 4
               ring half gathers the result — in ONE kernel with NO
               barrier between the halves. The ag ring's initial credit
               is granted before the rs half even starts, so a fast
               rank's first ag hop lands while slow ranks are still
               pushing/reducing rs partials: the boundary collective's
               exposed latency hides behind the rs tail. Each half owns
               its workspace/signals ("ws_rs"/"recv_rs" vs
               "ws_ag"/"recv_ag"/"cap_ag") so the overlapping halves
               never alias. ``tile`` is a :class:`ChainTile`.

Backends (``repro.shmem.default_backend``)
------------------------------------------
  pltpu     real TPU: a generic Pallas kernel per protocol (below);
            statics are staged to VMEM once, ``tile`` runs on VMEM
            values, communication is remote DMA + hardware semaphores.
  emulated  CPU / virtual devices: the SAME protocols against the
            host-side symmetric heaps of ``shmem.emulated`` — every
            put, arrival signal, credit and barrier runs with true
            concurrency semantics, validating the protocol logic
            without hardware.

Contract for ``tile``
---------------------
``tile(chunk, *statics) -> tile_value`` must be a pure jax function of
its inputs (it is traced inside the kernel). For the AG protocols the
output's leading dim defines the per-owner strip written into the
gathered output; for the RS protocols the output is the partial for one
output block (accumulated across ranks in f32).

``ring_fold`` instead takes a :class:`FoldTile` — three pure functions:
``init(chunk, *statics) -> state`` builds the resident (f32) state
pytree from shapes, ``fold(state, chunk, owner, *statics) -> state``
folds one arriving chunk (``owner`` is the traced global rank whose data
the chunk is), and ``finalize(state, *statics) -> out`` produces the
output once all W chunks have been folded.

Scale note (pltpu): refs are whole-shard (VMEM-resident per step). For
production shapes, wrap ``tile`` in ``pltpu.emit_pipeline`` tiling; the
signal protocols are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import default_backend, tpu_backend
from . import emulated as em

Array = jax.Array

PROTOCOLS = ("ring_ag", "one_shot_ag", "push_rs", "one_shot_rs",
             "one_shot_a2a", "bidir_ring_ag", "ring_fold",
             "two_level_ag", "two_level_rs", "push_rs_ring_ag")

# Protocols that compose TWO mesh axes (pod x ring): axis=(inner, outer),
# world=(Wi, Wo); the linearized PE id is outer * Wi + inner.
TWO_LEVEL_PROTOCOLS = ("two_level_ag", "two_level_rs")


@dataclasses.dataclass(frozen=True)
class FoldTile:
    """A stateful fold tile for the carry-passing protocols.

    init      ``init(chunk, *statics) -> state`` — the resident (f32)
              state pytree, built from the chunk/static shapes (the
              chunk VALUE must not contribute: every chunk, own one
              included, is folded through ``fold``).
    fold      ``fold(state, chunk, owner, *statics) -> state`` — fold
              one arriving chunk; ``owner`` is the traced global rank
              whose data the chunk is (causal masks and swizzles key on
              it).
    finalize  ``finalize(state, *statics) -> out`` — the output once
              all W chunks are folded.
    live      optional ``live(owner, *statics) -> traced bool`` (or
              ``None`` for always-live): true iff folding ``owner``'s
              chunk does real work. A fold whose predicate is false must
              be a value no-op (the executor still calls it); protocols
              use the predicate to suppress the ``tile_compute`` span,
              so per-PE timelines show actual compute — the causal
              whole-block skip is the motivating case.
    """

    init: Callable
    fold: Callable
    finalize: Callable
    live: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class ChainTile:
    """The compound tile of the chained boundary protocol
    (``push_rs_ring_ag``): an RS-side tile, a rank-local boundary
    function, and an AG-side tile. The protocol's single ``statics``
    tuple is split positionally — ``statics[:n_rs]`` feed ``rs``,
    ``statics[n_rs:n_rs + n_ag]`` feed ``ag``, the rest feed ``mid``.

    rs    ``rs(block, *rs_statics) -> partial`` — the producer GEMM's
          partial for one output block (reduced across ranks in f32).
    ag    ``ag(h_chunk, *ag_statics) -> strip`` — the consumer GEMM on
          one arriving boundary chunk; the result lands in the chunk
          owner's output strip.
    mid   ``mid(reduced, *mid_statics) -> h`` — rank-local ROW-WISE
          boundary function (residual add / norm / activation) applied
          to the owner's reduced block between the halves; ``None`` is
          the identity.
    """

    rs: Callable
    ag: Callable
    mid: Optional[Callable] = None
    n_rs: int = 0
    n_ag: int = 0


def _identity(x):
    return x


def _tile_struct(tile, chunk_struct, statics) -> jax.ShapeDtypeStruct:
    return jax.eval_shape(tile, chunk_struct, *statics)


def update_rows(out: Array, t: Array, row: int | Array) -> Array:
    """Write ``t`` into ``out`` at row offset ``row`` (all other dims full)."""
    return lax.dynamic_update_slice(out, t, (row,) + (0,) * (t.ndim - 1))


def slice_rows(x: Array, row, n: int) -> Array:
    """Slice ``n`` rows of ``x`` starting at ``row`` (all other dims full)."""
    return lax.dynamic_slice(x, (row,) + (0,) * (x.ndim - 1),
                             (n,) + x.shape[1:])


# ---------------------------------------------------------------------------
# Emulated backend: the protocols on host-side symmetric heaps
# ---------------------------------------------------------------------------


def _ring_ag_emulated(tile, chunk, statics, *, axis, world, out_dtype, cid):
    """Ring + credit protocol (Fig. 4): slot parity, 1 initial credit,
    grant-after-consume, and the skip of the final grants — the former
    ``_ag_gemm_emulated`` body, now op-independent."""
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)
    ts = _tile_struct(tile, chunk, statics)
    tile_m = ts.shape[0]

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    ctx.signal_op(left, sig="cap")

    cur = chunk
    out = jnp.zeros((tile_m * world,) + ts.shape[1:], out_dtype)
    for s in range(world):
        if s != world - 1:
            # producer: wait for a free slot at the right neighbor, then
            # putmem_signal my current chunk into their next slot.
            ctx.signal_wait_until(sig="cap", value=1)
            ctx.putmem_signal_nbi(cur, right, buf="ws", slot=(s + 1) % 2,
                                  sig="recv")
        # consumer: chunk of step s is rank (me - s)'s data.
        t = ctx.span("tile_compute", lambda c: tile(c, *statics), cur,
                     name=f"s{s}").astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        out = update_rows(out, t, owner * tile_m)
        if s != world - 1:
            cur = ctx.wait_read(chunk.shape, chunk.dtype, buf="ws",
                                slot=(s + 1) % 2, sig="recv")
            # Slot fully consumed — only now may the left neighbor
            # overwrite it. Skip grants beyond the W-1 sends it makes.
            if s < world - 2:
                ctx.signal_op(left, sig="cap")
    ctx.barrier_all()
    return out


def _one_shot_ag_emulated(tile, chunk, statics, *, axis, world, out_dtype, cid):
    """Alg. 4 structure: broadcast_put my chunk into every PE's slot
    ``me`` (self included, so all W slots exist symmetrically), one
    signal_wait for all W arrivals, then tile each landed chunk."""
    ts = _tile_struct(tile, chunk, statics)
    tile_m = ts.shape[0]

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    ctx.broadcast_put(chunk, buf="ws", sig="recv")
    ctx.signal_wait_until(sig="recv", value=world)
    out = jnp.zeros((tile_m * world,) + ts.shape[1:], out_dtype)
    for r in range(world):
        shard = ctx.read_symmetric(chunk.shape, chunk.dtype, buf="ws", slot=r)
        t = ctx.span("tile_compute", lambda c: tile(c, *statics), shard,
                     name=f"r{r}").astype(out_dtype)
        out = update_rows(out, t, r * tile_m)
    ctx.barrier_all()
    return out


def _bidir_ring_ag_emulated(tile, chunk, statics, *, axis, world, out_dtype,
                            cid):
    """Bidirectional ring + credit protocol: two independent ring_ag
    instances (disjoint buffers/signals/credits in ONE context), the top
    chunk half riding me -> me+1 and the bottom half me -> me-1. The
    fold of step s overlaps BOTH directions' in-flight DMAs; each link
    direction carries half the bytes (the engine's bidir schedule,
    executor-level)."""
    m = chunk.shape[0]
    if world < 3 or m % 2:
        # mirror the graph lowering: bidir degenerates to ring
        return _ring_ag_emulated(tile, chunk, statics, axis=axis, world=world,
                                 out_dtype=out_dtype, cid=cid)
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)
    half = m // 2
    cur_f, cur_b = chunk[:half], chunk[half:]
    ts = _tile_struct(tile, cur_f, statics)
    tile_h = ts.shape[0]
    tile_m = 2 * tile_h

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    # one initial credit per direction: fwd receives from the left ring
    # neighbor, bwd from the right (grant = "your next slot here is free")
    ctx.signal_op(left, sig="cap_f")
    ctx.signal_op(right, sig="cap_b")

    out = jnp.zeros((tile_m * world,) + ts.shape[1:], out_dtype)
    for s in range(world):
        if s != world - 1:
            ctx.signal_wait_until(sig="cap_f", value=1)
            ctx.putmem_signal_nbi(cur_f, right, buf="wsf", slot=(s + 1) % 2,
                                  sig="recv_f")
            ctx.signal_wait_until(sig="cap_b", value=1)
            ctx.putmem_signal_nbi(cur_b, left, buf="wsb", slot=(s + 1) % 2,
                                  sig="recv_b")
        # forward half: owner (me - s); backward half: owner (me + s)
        t_f = ctx.span("tile_compute", lambda c: tile(c, *statics), cur_f,
                       name=f"s{s}f").astype(out_dtype)
        out = update_rows(out, t_f, lax.rem(me - s + world, world) * tile_m)
        t_b = ctx.span("tile_compute", lambda c: tile(c, *statics), cur_b,
                       name=f"s{s}b").astype(out_dtype)
        out = update_rows(out, t_b,
                          lax.rem(me + s, world) * tile_m + tile_h)
        if s != world - 1:
            cur_f = ctx.wait_read(cur_f.shape, chunk.dtype, buf="wsf",
                                  slot=(s + 1) % 2, sig="recv_f")
            cur_b = ctx.wait_read(cur_b.shape, chunk.dtype, buf="wsb",
                                  slot=(s + 1) % 2, sig="recv_b")
            if s < world - 2:
                ctx.signal_op(left, sig="cap_f")
                ctx.signal_op(right, sig="cap_b")
    ctx.barrier_all()
    return out


def _one_shot_a2a_emulated(tile, xs, statics, *, axis, world, out_dtype, cid):
    """Low-latency AllToAll: all W per-destination blocks pushed up-front
    (self included, so every slot lands symmetrically) into slot ``me``
    of each destination, one signal_wait for the W arrivals, then tile
    each landed block into out[src]."""
    assert xs.shape[0] == world, (xs.shape, world)
    me = lax.axis_index(axis)
    blk_struct = jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype)
    ts = _tile_struct(tile, blk_struct, statics)

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    for off in range(world):  # all puts up-front, no waits between
        tgt = lax.rem(me + off, world)
        block = lax.dynamic_index_in_dim(xs, tgt, 0, keepdims=False)
        ctx.putmem_signal_nbi(block, tgt, buf="ws", slot=me, sig="recv")
    ctx.signal_wait_until(sig="recv", value=world)
    out = jnp.zeros((world,) + ts.shape, out_dtype)
    for src in range(world):
        block = ctx.read_symmetric(xs.shape[1:], xs.dtype, buf="ws", slot=src)
        t = ctx.span("tile_compute", lambda b: tile(b, *statics), block,
                     name=f"src{src}").astype(out_dtype)
        out = lax.dynamic_update_slice(out, t[None],
                                       (src,) + (0,) * len(ts.shape))
    ctx.barrier_all()
    return out


def _block(operand, blk, m_blk):
    return slice_rows(operand, blk * m_blk, m_blk)


def _rs_reduce(ctx, ts, world, out_dtype, decode=None):
    """signal_wait for all W partials, then the local f32 reduction.

    With a wire ``decode`` hook the landed partials are packed wire
    buffers (``ts`` describes the packed uint8 layout); each is decoded
    to f32 before accumulation."""
    ctx.signal_wait_until(sig="recv", value=world)
    acc_shape = ts.shape if decode is None else jax.eval_shape(decode, ts).shape
    acc = jnp.zeros(acc_shape, jnp.float32)
    for r in range(world):
        read_dtype = out_dtype if decode is None else ts.dtype
        part = ctx.read_symmetric(ts.shape, read_dtype, buf="ws", slot=r)
        if decode is None:
            acc = acc + part.astype(jnp.float32)
        else:
            acc = acc + ctx.span("decode", decode, part, name=f"r{r}")
    ctx.barrier_all()
    return acc.astype(out_dtype)


def _push_rs_emulated(tile, operand, statics, *, axis, world, out_dtype, cid,
                      decode=None):
    """Alg. 3 push protocol: per-step put of the partial into the owner's
    slot ``me`` (own block pushed to self at the last step, so all W
    slots land symmetrically), then one signal_wait + f32 reduction.

    Under a wire dtype the tile already returns the packed wire buffer
    (pushed verbatim — no out_dtype cast, which would corrupt the bytes)
    and ``decode`` unpacks each landed partial for the f32 reduction."""
    me = lax.axis_index(axis)
    m_blk = operand.shape[0] // world
    ts = _tile_struct(tile, _block(operand, 0, m_blk), statics)

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    for s in range(world):
        # Alg. 3 swizzle: peers' blocks first, own block last (blk == me)
        blk = lax.rem(me - s - 1 + 2 * world, world)
        partial = ctx.span("tile_compute", lambda b: tile(b, *statics),
                           _block(operand, blk, m_blk), name=f"s{s}")
        if decode is None:
            partial = partial.astype(out_dtype)
        ctx.putmem_signal_nbi(partial, blk, buf="ws", slot=me, sig="recv")
    return _rs_reduce(ctx, ts, world, out_dtype, decode)


def _one_shot_rs_emulated(tile, operand, statics, *, axis, world, out_dtype, cid,
                          decode=None):
    """Low-latency RS: ALL W partials computed first, then the W puts
    issued up-front at distinct ring offsets (own block first) — no
    serial compute/DMA dependency chain."""
    me = lax.axis_index(axis)
    m_blk = operand.shape[0] // world
    ts = _tile_struct(tile, _block(operand, 0, m_blk), statics)

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    partials = []
    for off in range(world):
        tgt = lax.rem(me + off, world)
        partial = ctx.span("tile_compute", lambda b: tile(b, *statics),
                           _block(operand, tgt, m_blk), name=f"off{off}")
        if decode is None:
            partial = partial.astype(out_dtype)
        partials.append((tgt, partial))
    for tgt, partial in partials:  # all puts up-front, no waits between
        ctx.putmem_signal_nbi(partial, tgt, buf="ws", slot=me, sig="recv")
    return _rs_reduce(ctx, ts, world, out_dtype, decode)


def _ring_fold_emulated(fold, chunk, statics, *, axis, world, out_dtype, cid):
    """Carry-passing ring: ring_ag's slot parity / 1 initial credit /
    grant-after-consume communication, but each arriving chunk is folded
    into resident f32 state instead of written to an output strip."""
    assert isinstance(fold, FoldTile), fold
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    if world > 1:
        ctx.signal_op(left, sig="cap")

    cur = chunk
    state = fold.init(chunk, *statics)
    for s in range(world):
        if s != world - 1:
            # producer: wait for a free slot at the right neighbor, then
            # putmem_signal my current chunk into their next slot.
            ctx.signal_wait_until(sig="cap", value=1)
            ctx.putmem_signal_nbi(cur, right, buf="ws", slot=(s + 1) % 2,
                                  sig="recv")
        # consumer: chunk of step s is rank (me - s)'s data — fold it
        # into the resident state while the next chunk's DMA is in flight.
        owner = lax.rem(me - s + world, world)
        # sync=True: the carry means step s+1 consumes this state anyway,
        # so the true-dependency end mark costs no overlap — and per-PE
        # tile_compute spans become honest compute time (the causal
        # load-balance pin in tests/test_placement_trace.py reads them)
        # when=live: a dynamically no-op fold (fully-masked causal
        # block) leaves no span, instead of a phantom one.
        alive = None if fold.live is None else fold.live(owner, *statics)
        state = ctx.span(
            "tile_compute", lambda st, c: fold.fold(st, c, owner, *statics),
            state, cur, name=f"s{s}", sync=True, when=alive)
        if s != world - 1:
            cur = ctx.wait_read(chunk.shape, chunk.dtype, buf="ws",
                                slot=(s + 1) % 2, sig="recv")
            if s < world - 2:
                ctx.signal_op(left, sig="cap")
    ctx.barrier_all()
    return ctx.span("tile_compute",
                    lambda st: fold.finalize(st, *statics),
                    state, name="finalize", sync=True).astype(out_dtype)


def _two_level_pe(axis, world):
    """((inner, outer), (Wi, Wo)) -> pod/ring coords + outer-ring peers
    (linearized pe = oid * Wi + iid; the outer ring preserves iid)."""
    inner, outer = axis
    wi, wo = world
    iid = lax.axis_index(inner)
    oid = lax.axis_index(outer)
    left = lax.rem(oid + wo - 1, wo) * wi + iid
    right = lax.rem(oid + 1, wo) * wi + iid
    return iid, oid, left, right


def _two_level_ag_emulated(tile, chunk, statics, *, axis, world, out_dtype,
                           cid):
    """Two-axis AG (Fig. 10): the current region chunk rides the slow
    inter-pod ring (double-buffered "ows" workspace + credit flow,
    exactly ring_ag's protocol over pods) while a pod-local one_shot
    exchange ("pws", per-source arrival signals, slot parity) hands it
    to every pod peer; the tile consumes all Wi region chunks per outer
    step. The inter-pod hop of region so+1 overlaps region so's pod
    exchange + compute."""
    wi, wo = world
    w_all = wi * wo
    iid, oid, left, right = _two_level_pe(axis, world)
    ts = _tile_struct(tile, chunk, statics)
    tile_m = ts.shape[0]

    ctx = em.ShmemCtx((axis[1], axis[0]), w_all, cid)  # pe = oid * wi + iid
    ctx.barrier_all()
    # outer ring: my left-pod peer's first send may land immediately
    if wo > 1:
        ctx.signal_op(left, sig="cap")

    cur = chunk
    out = jnp.zeros((tile_m * w_all,) + ts.shape[1:], out_dtype)
    for so in range(wo):
        region = lax.rem(oid - so + wo, wo)
        if so != wo - 1:
            # slow-link hop of the NEXT region overlaps this region's
            # pod-local exchange and compute (ring_ag credits over pods)
            ctx.signal_wait_until(sig="cap", value=1)
            ctx.putmem_signal_nbi(cur, right, buf="ows", slot=(so + 1) % 2,
                                  sig="orecv")
        # pod-local one_shot: all Wi puts up-front (self included, so the
        # slots land symmetrically). The arrival signal carries the
        # sender's ring OFFSET from the destination — a per-source
        # signal, so a pod peer racing one step ahead can never satisfy
        # this step's wait for a straggler's chunk (slot parity keeps
        # the two in-flight steps' data apart).
        for off in range(wi):
            tgt = oid * wi + lax.rem(iid + off, wi)
            ctx.putmem_signal_nbi(cur, tgt, buf="pws",
                                  slot=(so % 2) * wi + iid,
                                  sig=f"prcv{off}")
        for d in range(wi):
            ctx.signal_wait_until(sig=f"prcv{d}", value=1)
            src = lax.rem(iid - d + wi, wi)
            shard = ctx.read_symmetric(chunk.shape, chunk.dtype, buf="pws",
                                       slot=(so % 2) * wi + src)
            owner = region * wi + src
            t = ctx.span("tile_compute", lambda c: tile(c, *statics),
                         shard, name=f"o{so}d{d}").astype(out_dtype)
            out = update_rows(out, t, owner * tile_m)
        if so != wo - 1:
            cur = ctx.wait_read(chunk.shape, chunk.dtype, buf="ows",
                                slot=(so + 1) % 2, sig="orecv")
            if so < wo - 2:
                ctx.signal_op(left, sig="cap")
    ctx.barrier_all()
    return out


def _two_level_rs_emulated(tile, operand, statics, *, axis, world, out_dtype,
                           cid):
    """Two-axis RS (Fig. 10 / Alg. 5): per outer step (pod regions
    peers-first, own pod last) the Wi partials for the region's blocks
    are computed and pushed up-front pod-locally (one_shot RS structure,
    per-source signals), reduced in f32, then the pod-reduced
    accumulator rides the inter-pod ring — the slow-link transfer
    overlaps the next region's Wi computes."""
    wi, wo = world
    w_all = wi * wo
    iid, oid, left, right = _two_level_pe(axis, world)
    m_blk = operand.shape[0] // w_all
    ts = _tile_struct(tile, _block(operand, 0, m_blk), statics)

    ctx = em.ShmemCtx((axis[1], axis[0]), w_all, cid)  # pe = oid * wi + iid
    ctx.barrier_all()
    if wo > 1:
        ctx.signal_op(left, sig="cap")

    acc = None
    for so in range(wo):
        region = lax.rem(oid - so - 1 + 2 * wo, wo)
        # pod-local one_shot RS: all Wi partials computed and pushed
        # up-front (own inner block included, so slots land symmetrically)
        for off in range(wi):
            tgt_i = lax.rem(iid + off, wi)
            blk = region * wi + tgt_i
            partial = ctx.span(
                "tile_compute", lambda b: tile(b, *statics),
                _block(operand, blk, m_blk),
                name=f"o{so}off{off}").astype(jnp.float32)
            ctx.putmem_signal_nbi(partial, oid * wi + tgt_i, buf="pws",
                                  slot=(so % 2) * wi + iid,
                                  sig=f"prcv{off}")
        pod = jnp.zeros(ts.shape, jnp.float32)
        for d in range(wi):
            ctx.signal_wait_until(sig=f"prcv{d}", value=1)
            src = lax.rem(iid - d + wi, wi)
            part = ctx.read_symmetric(ts.shape, jnp.float32, buf="pws",
                                      slot=(so % 2) * wi + src)
            pod = pod + part
        if so > 0:
            # the inter-pod accumulator of this region arrives from the
            # left pod (its step so-1 covered the same region)
            prev = ctx.wait_read(ts.shape, jnp.float32, buf="ows",
                                 slot=so % 2, sig="orecv")
            pod = pod + prev
            if so < wo - 1:
                ctx.signal_op(left, sig="cap")
        acc = pod
        if so != wo - 1:
            ctx.signal_wait_until(sig="cap", value=1)
            ctx.putmem_signal_nbi(acc, right, buf="ows", slot=(so + 1) % 2,
                                  sig="orecv")
    ctx.barrier_all()
    return acc.astype(out_dtype)


def _push_rs_ring_ag_emulated(chain, operand, statics, *, axis, world,
                              out_dtype, cid):
    """Chained boundary protocol: Alg. 3 push (rs half) -> rank-local
    ``mid`` -> Fig. 4 ring (ag half), in ONE context with NO barrier
    between the halves. The ag ring's initial credit is granted before
    the rs half starts, so a fast rank's first ag hop lands while slow
    ranks are still pushing/reducing rs partials — the boundary
    collective's exposed latency hides behind the rs tail. Per-half
    workspaces/signals ("ws_rs"/"recv_rs" vs "ws_ag"/"recv_ag"/"cap_ag")
    keep the overlapping halves from aliasing; span labels ``rs_s{s}`` /
    ``mid`` / ``ag_s{s}`` keep the halves apart in traces."""
    assert isinstance(chain, ChainTile), chain
    n_rs, n_ag = chain.n_rs, chain.n_ag
    rs_statics = statics[:n_rs]
    ag_statics = statics[n_rs:n_rs + n_ag]
    mid_statics = statics[n_rs + n_ag:]
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)
    m_blk = operand.shape[0] // world
    rs_ts = _tile_struct(chain.rs, _block(operand, 0, m_blk), rs_statics)

    ctx = em.ShmemCtx(axis, world, cid)
    ctx.barrier_all()
    # the ag ring's initial credit is granted BEFORE the rs half runs:
    # nothing separates the halves, so the first boundary hop can land
    # behind a neighbor still reducing (the fusion).
    ctx.signal_op(left, sig="cap_ag")

    # rs half — Alg. 3 push (peers' blocks first, own last), f32 partials
    for s in range(world):
        blk = lax.rem(me - s - 1 + 2 * world, world)
        partial = ctx.span("tile_compute",
                           lambda b: chain.rs(b, *rs_statics),
                           _block(operand, blk, m_blk),
                           name=f"rs_s{s}").astype(jnp.float32)
        ctx.putmem_signal_nbi(partial, blk, buf="ws_rs", slot=me,
                              sig="recv_rs")
    ctx.signal_wait_until(sig="recv_rs", value=world)
    acc = jnp.zeros(rs_ts.shape, jnp.float32)
    for r in range(world):
        acc = acc + ctx.read_symmetric(rs_ts.shape, jnp.float32,
                                       buf="ws_rs", slot=r)

    # boundary — rank-local mid on the owner's reduced block
    def _mid(a, *ms):
        reduced = a.astype(out_dtype)
        return chain.mid(reduced, *ms) if chain.mid is not None else reduced

    h = ctx.span("tile_compute", _mid, acc, *mid_statics, name="mid")

    # ag half — Fig. 4 ring + credit over the boundary activation
    ag_ts = _tile_struct(chain.ag, h, ag_statics)
    tile_m = ag_ts.shape[0]
    cur = h
    out = jnp.zeros((tile_m * world,) + ag_ts.shape[1:], out_dtype)
    for s in range(world):
        if s != world - 1:
            ctx.signal_wait_until(sig="cap_ag", value=1)
            ctx.putmem_signal_nbi(cur, right, buf="ws_ag", slot=(s + 1) % 2,
                                  sig="recv_ag")
        t = ctx.span("tile_compute", lambda c: chain.ag(c, *ag_statics), cur,
                     name=f"ag_s{s}").astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        out = update_rows(out, t, owner * tile_m)
        if s != world - 1:
            cur = ctx.wait_read(h.shape, h.dtype, buf="ws_ag",
                                slot=(s + 1) % 2, sig="recv_ag")
            if s < world - 2:
                ctx.signal_op(left, sig="cap_ag")
    ctx.barrier_all()
    return out


# ---------------------------------------------------------------------------
# pltpu backend: one generic Pallas kernel per protocol
# ---------------------------------------------------------------------------


def _stage(refs, vmems, sem):
    copies = [pltpu.make_async_copy(r, v, sem) for r, v in zip(refs, vmems)]
    for c in copies:
        c.start()
    for c in copies:
        c.wait()


def _ring_ag_body(*refs, tile, axis, world, n_static, tile_m, out_dtype):
    (chunk_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, ws_ref = rest[n_static], rest[n_static + 1]
    chunk_vmem = rest[n_static + 2]
    static_vmems = rest[n_static + 3:2 * n_static + 3]
    o_vmem = rest[2 * n_static + 3]
    local_sem, send_sem, recv_sem, cap_sem = rest[2 * n_static + 4:]

    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    # Symmetric-memory handshake: every rank's workspace must exist before
    # any one-sided put lands in it (paper: barrier_all after allocation).
    tpu_backend.barrier_all(axis, world)

    # Stage the statics into VMEM once; copy my chunk into ring slot 0.
    _stage((chunk_ref,) + tuple(static_refs),
           (ws_ref.at[0],) + tuple(static_vmems), local_sem)

    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    tpu_backend.signal_op(cap_sem, left, axis=axis)

    for s in range(world):
        slot = s % 2
        send = None
        if s != world - 1:
            # producer: wait for a free slot at the right neighbor, then
            # putmem_signal my current chunk into their next slot.
            tpu_backend.signal_wait_until(cap_sem, 1)
            send = tpu_backend.putmem_signal_nbi(
                ws_ref.at[slot], ws_ref.at[(s + 1) % 2],
                send_sem, recv_sem, right, axis=axis)

        # consumer: chunk of step s is rank (me - s)'s data; its arrival
        # is ordered by recv_sem via the previous step's wait.
        _stage((ws_ref.at[slot],), (chunk_vmem,), local_sem)

        # the tile compute overlaps the in-flight remote DMA of chunk s+1
        with tpu_backend.annotate("tile_compute", f"s{s}"):
            o_vmem[...] = tile(
                chunk_vmem[...], *[v[...] for v in static_vmems]
            ).astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        _stage((o_vmem,), (o_ref.at[pl.ds(owner * tile_m, tile_m)],), local_sem)

        if send is not None:
            # wait: my send drained + my incoming chunk has landed.
            send.wait()
        # Slot fully consumed — BOTH readers done (VMEM stage AND the
        # outgoing remote DMA). Only now may the left neighbor overwrite
        # it. Skip grants beyond the W-1 sends the neighbor makes.
        if s < world - 2:
            tpu_backend.signal_op(cap_sem, left, axis=axis)


def _ring_ag_pltpu(tile, chunk, statics, *, axis, world, out_dtype, cid):
    ts = _tile_struct(tile, chunk, statics)
    body = functools.partial(
        _ring_ag_body, tile=tile, axis=axis, world=world,
        n_static=len(statics), tile_m=ts.shape[0], out_dtype=out_dtype)
    out, _ws = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((ts.shape[0] * world,) + ts.shape[1:], out_dtype),
            jax.ShapeDtypeStruct((2,) + chunk.shape, chunk.dtype),  # ring ws
        ],
        scratch_shapes=[pltpu.VMEM(chunk.shape, chunk.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(ts.shape, out_dtype),
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.REGULAR],
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(chunk, *statics)
    return out


def _one_shot_ag_body(*refs, tile, axis, world, n_static, tile_m, out_dtype):
    (chunk_ref, *rest) = refs
    static_refs = rest[:n_static]
    gather_direct = tile is _identity and n_static == 0
    if gather_direct:
        o_ref = rest[n_static]
        local_sem, send_sem, recv_sem = rest[n_static + 1:]
    else:
        o_ref, ws_ref = rest[n_static], rest[n_static + 1]
        chunk_vmem = rest[n_static + 2]
        static_vmems = rest[n_static + 3:2 * n_static + 3]
        o_vmem = rest[2 * n_static + 3]
        local_sem, send_sem, recv_sem = rest[2 * n_static + 4:]

    me = lax.axis_index(axis)
    tpu_backend.barrier_all(axis, world)

    # landing site: the gathered output itself (plain AllGather) or the
    # symmetric workspace slot `me` (a tile compute consumes the chunks)
    dst = (o_ref.at[pl.ds(me * tile_m, tile_m)] if gather_direct
           else ws_ref.at[me])
    lc = pltpu.make_async_copy(chunk_ref, dst, local_sem)
    lc.start()

    # One-shot: all W-1 puts issued before any wait (Alg. 4 structure —
    # no skew accumulation from a serial loop).
    sends = []
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        sends.append(tpu_backend.putmem_signal_nbi(
            chunk_ref, dst, send_sem, recv_sem, peer, axis=axis))
    lc.wait()
    # SPMD symmetry: my W-1 incoming messages are my peers' sends with the
    # same shape/semaphore, so waiting my own descriptors consumes exactly
    # the right signal count (send-drain + W-1 arrivals).
    tpu_backend.quiet(*sends)

    if not gather_direct:
        if n_static:
            _stage(tuple(static_refs), tuple(static_vmems), local_sem)
        for r in range(world):
            _stage((ws_ref.at[r],), (chunk_vmem,), local_sem)
            with tpu_backend.annotate("tile_compute", f"r{r}"):
                o_vmem[...] = tile(
                    chunk_vmem[...], *[v[...] for v in static_vmems]
                ).astype(out_dtype)
            _stage((o_vmem,), (o_ref.at[pl.ds(r * tile_m, tile_m)],), local_sem)


def _one_shot_ag_pltpu(tile, chunk, statics, *, axis, world, out_dtype, cid):
    ts = _tile_struct(tile, chunk, statics)
    gather_direct = tile is _identity and not statics
    body = functools.partial(
        _one_shot_ag_body, tile=tile, axis=axis, world=world,
        n_static=len(statics), tile_m=ts.shape[0], out_dtype=out_dtype)
    out_shape = [jax.ShapeDtypeStruct(
        (ts.shape[0] * world,) + ts.shape[1:], out_dtype)]
    scratch = [pltpu.SemaphoreType.DMA] * 3
    if not gather_direct:
        out_shape.append(  # symmetric landing workspace
            jax.ShapeDtypeStruct((world,) + chunk.shape, chunk.dtype))
        scratch = ([pltpu.VMEM(chunk.shape, chunk.dtype)]
                   + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
                   + [pltpu.VMEM(ts.shape, out_dtype)] + scratch)
    outs = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * len(out_shape),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(chunk, *statics)
    return outs[0] if isinstance(outs, (tuple, list)) else outs


def _push_rs_body(*refs, tile, axis, world, n_static, m_blk, one_shot,
                  out_dtype, decode=None):
    (a_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, ws_ref = rest[n_static], rest[n_static + 1]
    stage_ref = rest[n_static + 2] if one_shot else None
    base = n_static + (3 if one_shot else 2)
    a_vmem = rest[base]
    static_vmems = rest[base + 1:base + 1 + n_static]
    p_vmem = rest[base + 1 + n_static]
    # under a wire dtype p_vmem holds the packed partial; the decoded f32
    # accumulator needs its own (differently-shaped) output buffer
    o_vmem = rest[base + 2 + n_static] if decode is not None else None
    sem_base = base + 2 + n_static + (1 if decode is not None else 0)
    local_sem, send_sem, recv_sem = rest[sem_base:]

    me = lax.axis_index(axis)
    tpu_backend.barrier_all(axis, world)
    if n_static:
        _stage(tuple(static_refs), tuple(static_vmems), local_sem)

    def compute(blk):
        _stage((a_ref.at[pl.ds(blk * m_blk, m_blk)],), (a_vmem,), local_sem)
        with tpu_backend.annotate("tile_compute"):
            partial = tile(a_vmem[...], *[v[...] for v in static_vmems])
        # packed wire buffers are pushed verbatim (a cast would corrupt
        # the bytes); plain partials land in out_dtype as before
        p_vmem[...] = partial if decode is not None else partial.astype(out_dtype)

    sends = []
    if one_shot:
        # low-latency variant: ALL partials computed into local staging
        # first, then the W-1 puts issued up-front with no waits between
        # (own block, off 0, is a local copy — no self-targeted DMA).
        for off in range(world):
            compute(lax.rem(me + off, world))
            _stage((p_vmem,), (stage_ref.at[off],), local_sem)
        _stage((stage_ref.at[0],), (ws_ref.at[me],), local_sem)
        for off in range(1, world):
            tgt = lax.rem(me + off, world)
            sends.append(tpu_backend.putmem_signal_nbi(
                stage_ref.at[off], ws_ref.at[me], send_sem, recv_sem, tgt,
                axis=axis))
        for send in sends:
            send.wait_send()
    else:
        for s in range(world):
            # Alg. 3 swizzle: peers' blocks first, own block last
            blk = lax.rem(me - s - 1 + 2 * world, world)
            compute(blk)
            if s == world - 1:
                # my own block: local copy into my slot of my workspace
                _stage((p_vmem,), (ws_ref.at[me],), local_sem)
            else:
                # one-sided push + arrival signal to the owner (slot = me)
                send = tpu_backend.putmem_signal_nbi(
                    p_vmem, ws_ref.at[me], send_sem, recv_sem, blk, axis=axis)
                # the next step's compute overlaps this DMA; drain before
                # reusing p_vmem (single partial buffer)
                send.wait_send()
                sends.append(send)

    # signal_wait for the W-1 remote partials (SPMD symmetry: waiting my
    # own descriptors consumes my peers' arrivals), then the f32 reduction
    for send in sends:
        send.wait_recv()
    acc_vmem = p_vmem if decode is None else o_vmem
    acc = jnp.zeros(acc_vmem.shape, jnp.float32)
    for r in range(world):
        _stage((ws_ref.at[r],), (p_vmem,), local_sem)
        if decode is None:
            acc = acc + p_vmem[...].astype(jnp.float32)
        else:
            with tpu_backend.annotate("decode", f"r{r}"):
                acc = acc + decode(p_vmem[...])
    acc_vmem[...] = acc.astype(out_dtype)
    _stage((acc_vmem,), (o_ref,), local_sem)


def _rs_pltpu(tile, operand, statics, *, axis, world, out_dtype, cid,
              one_shot, decode=None):
    m_blk = operand.shape[0] // world
    blk_struct = jax.ShapeDtypeStruct((m_blk,) + operand.shape[1:],
                                      operand.dtype)
    ts = _tile_struct(tile, blk_struct, statics)
    # under a wire dtype the riding partial is the packed buffer (ts) and
    # the output block is its decoded shape
    ws_dtype = out_dtype if decode is None else ts.dtype
    out_struct = ts if decode is None else jax.eval_shape(decode, ts)
    body = functools.partial(
        _push_rs_body, tile=tile, axis=axis, world=world,
        n_static=len(statics), m_blk=m_blk, one_shot=one_shot,
        out_dtype=out_dtype, decode=decode)
    out_shape = [
        jax.ShapeDtypeStruct(out_struct.shape, out_dtype),
        jax.ShapeDtypeStruct((world,) + ts.shape, ws_dtype),  # landing ws
    ]
    if one_shot:
        out_shape.append(  # local staging for the up-front puts
            jax.ShapeDtypeStruct((world,) + ts.shape, ws_dtype))
    scratch = ([pltpu.VMEM(blk_struct.shape, operand.dtype)]
               + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
               + [pltpu.VMEM(ts.shape, ws_dtype)])
    if decode is not None:
        scratch.append(pltpu.VMEM(out_struct.shape, out_dtype))
    scratch += [pltpu.SemaphoreType.DMA,
                pltpu.SemaphoreType.DMA,
                pltpu.SemaphoreType.DMA]
    outs = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * len(out_shape),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(operand, *statics)
    return outs[0]


def _bidir_ring_ag_body(*refs, tile, axis, world, n_static, half_rows, tile_h,
                        out_dtype):
    (chunk_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, wsf_ref, wsb_ref = rest[n_static:n_static + 3]
    half_vmem = rest[n_static + 3]
    static_vmems = rest[n_static + 4:2 * n_static + 4]
    o_vmem = rest[2 * n_static + 4]
    (local_sem, send_f, recv_f, send_b, recv_b,
     cap_f, cap_b) = rest[2 * n_static + 5:]

    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)
    tile_m = 2 * tile_h

    tpu_backend.barrier_all(axis, world)

    # Stage statics once; copy my chunk halves into each ring's slot 0.
    _stage((chunk_ref.at[pl.ds(0, half_rows)],
            chunk_ref.at[pl.ds(half_rows, half_rows)]) + tuple(static_refs),
           (wsf_ref.at[0], wsb_ref.at[0]) + tuple(static_vmems), local_sem)

    # One initial credit per direction (fwd: I receive from left; bwd:
    # from right) — the neighbor's slot 1 starts free.
    tpu_backend.signal_op(cap_f, left, axis=axis)
    tpu_backend.signal_op(cap_b, right, axis=axis)

    for s in range(world):
        slot = s % 2
        sends = ()
        if s != world - 1:
            tpu_backend.signal_wait_until(cap_f, 1)
            sf = tpu_backend.putmem_signal_nbi(
                wsf_ref.at[slot], wsf_ref.at[(s + 1) % 2],
                send_f, recv_f, right, axis=axis)
            tpu_backend.signal_wait_until(cap_b, 1)
            sb = tpu_backend.putmem_signal_nbi(
                wsb_ref.at[slot], wsb_ref.at[(s + 1) % 2],
                send_b, recv_b, left, axis=axis)
            sends = (sf, sb)

        # both directions' tiles overlap the two in-flight remote DMAs;
        # arrivals of slot s were ordered by the previous step's waits.
        for direction, ws_ref, owner in (
                (0, wsf_ref, lax.rem(me - s + world, world)),
                (1, wsb_ref, lax.rem(me + s, world))):
            _stage((ws_ref.at[slot],), (half_vmem,), local_sem)
            with tpu_backend.annotate("tile_compute", f"s{s}d{direction}"):
                o_vmem[...] = tile(
                    half_vmem[...], *[v[...] for v in static_vmems]
                ).astype(out_dtype)
            _stage((o_vmem,),
                   (o_ref.at[pl.ds(owner * tile_m + direction * tile_h,
                                   tile_h)],),
                   local_sem)

        for send in sends:
            # send drained + my incoming half landed (SPMD symmetry)
            send.wait()
        if s < world - 2:
            # both slots fully consumed — the neighbors may overwrite
            tpu_backend.signal_op(cap_f, left, axis=axis)
            tpu_backend.signal_op(cap_b, right, axis=axis)


def _bidir_ring_ag_pltpu(tile, chunk, statics, *, axis, world, out_dtype, cid):
    m = chunk.shape[0]
    if world < 3 or m % 2:
        # mirror the graph lowering: bidir degenerates to ring
        return _ring_ag_pltpu(tile, chunk, statics, axis=axis, world=world,
                              out_dtype=out_dtype, cid=cid)
    half_rows = m // 2
    half_struct = jax.ShapeDtypeStruct((half_rows,) + chunk.shape[1:],
                                       chunk.dtype)
    ts = _tile_struct(tile, half_struct, statics)
    tile_h = ts.shape[0]
    body = functools.partial(
        _bidir_ring_ag_body, tile=tile, axis=axis, world=world,
        n_static=len(statics), half_rows=half_rows, tile_h=tile_h,
        out_dtype=out_dtype)
    out, _wsf, _wsb = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((2 * tile_h * world,) + ts.shape[1:],
                                 out_dtype),
            jax.ShapeDtypeStruct((2,) + half_struct.shape, chunk.dtype),
            jax.ShapeDtypeStruct((2,) + half_struct.shape, chunk.dtype),
        ],
        scratch_shapes=[pltpu.VMEM(half_struct.shape, chunk.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(ts.shape, out_dtype),
           pltpu.SemaphoreType.DMA,   # local staging
           pltpu.SemaphoreType.DMA,   # fwd send
           pltpu.SemaphoreType.DMA,   # fwd recv
           pltpu.SemaphoreType.DMA,   # bwd send
           pltpu.SemaphoreType.DMA,   # bwd recv
           pltpu.SemaphoreType.REGULAR,   # fwd credits
           pltpu.SemaphoreType.REGULAR],  # bwd credits
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(chunk, *statics)
    return out


def _one_shot_a2a_body(*refs, tile, axis, world, n_static, out_dtype,
                       a2a_direct):
    (xs_ref, *rest) = refs
    static_refs = rest[:n_static]
    if a2a_direct:
        o_ref = rest[n_static]
        local_sem, send_sem, recv_sem = rest[n_static + 1:]
    else:
        o_ref, ws_ref = rest[n_static], rest[n_static + 1]
        blk_vmem = rest[n_static + 2]
        static_vmems = rest[n_static + 3:2 * n_static + 3]
        o_vmem = rest[2 * n_static + 3]
        local_sem, send_sem, recv_sem = rest[2 * n_static + 4:]

    me = lax.axis_index(axis)
    tpu_backend.barrier_all(axis, world)

    # landing site: the output itself (pure a2a data movement) or the
    # symmetric workspace (a tile compute consumes the blocks). Slot =
    # sender id: my block for PE t lands in t's row ``me``.
    dst = o_ref if a2a_direct else ws_ref
    lc = pltpu.make_async_copy(xs_ref.at[me], dst.at[me], local_sem)
    lc.start()

    # One-shot: all W-1 puts issued before any wait — no serial chain.
    sends = []
    for off in range(1, world):
        tgt = lax.rem(me + off, world)
        sends.append(tpu_backend.putmem_signal_nbi(
            xs_ref.at[tgt], dst.at[me], send_sem, recv_sem, tgt, axis=axis))
    lc.wait()
    # SPMD symmetry: waiting my own descriptors consumes exactly my send
    # drains + my W-1 arrivals.
    tpu_backend.quiet(*sends)

    if not a2a_direct:
        if n_static:
            _stage(tuple(static_refs), tuple(static_vmems), local_sem)
        for src in range(world):
            _stage((ws_ref.at[src],), (blk_vmem,), local_sem)
            with tpu_backend.annotate("tile_compute", f"src{src}"):
                o_vmem[...] = tile(
                    blk_vmem[...], *[v[...] for v in static_vmems]
                ).astype(out_dtype)
            _stage((o_vmem,), (o_ref.at[src],), local_sem)


def _one_shot_a2a_pltpu(tile, xs, statics, *, axis, world, out_dtype, cid):
    assert xs.shape[0] == world, (xs.shape, world)
    blk_struct = jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype)
    ts = _tile_struct(tile, blk_struct, statics)
    a2a_direct = (tile is _identity and not statics
                  and jnp.dtype(out_dtype) == xs.dtype)
    body = functools.partial(
        _one_shot_a2a_body, tile=tile, axis=axis, world=world,
        n_static=len(statics), out_dtype=out_dtype, a2a_direct=a2a_direct)
    out_shape = [jax.ShapeDtypeStruct((world,) + ts.shape, out_dtype)]
    scratch = [pltpu.SemaphoreType.DMA] * 3
    if not a2a_direct:
        out_shape.append(  # symmetric landing workspace
            jax.ShapeDtypeStruct((world,) + xs.shape[1:], xs.dtype))
        scratch = ([pltpu.VMEM(xs.shape[1:], xs.dtype)]
                   + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
                   + [pltpu.VMEM(ts.shape, out_dtype)] + scratch)
    outs = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * len(out_shape),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(xs, *statics)
    return outs[0] if isinstance(outs, (tuple, list)) else outs


def _ring_fold_body(*refs, fold, axis, world, n_static, n_state,
                    state_treedef, out_dtype):
    (chunk_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, ws_ref = rest[n_static], rest[n_static + 1]
    chunk_vmem = rest[n_static + 2]
    static_vmems = rest[n_static + 3:2 * n_static + 3]
    state_vmems = rest[2 * n_static + 3:2 * n_static + 3 + n_state]
    o_vmem = rest[2 * n_static + 3 + n_state]
    local_sem, send_sem, recv_sem, cap_sem = rest[2 * n_static + 4 + n_state:]

    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    tpu_backend.barrier_all(axis, world)
    _stage((chunk_ref,) + tuple(static_refs),
           (ws_ref.at[0],) + tuple(static_vmems), local_sem)
    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    tpu_backend.signal_op(cap_sem, left, axis=axis)

    def statics():
        return [v[...] for v in static_vmems]

    def write_state(state):
        for sv, leaf in zip(state_vmems, jax.tree_util.tree_leaves(state)):
            sv[...] = leaf

    def read_state():
        return jax.tree_util.tree_unflatten(
            state_treedef, [sv[...] for sv in state_vmems])

    # resident f32 fold state, carried across steps in VMEM scratch
    # (chunk_vmem holds my own chunk after this — step 0 reuses it)
    _stage((ws_ref.at[0],), (chunk_vmem,), local_sem)
    write_state(fold.init(chunk_vmem[...], *statics()))

    for s in range(world):
        slot = s % 2
        send = None
        if s != world - 1:
            tpu_backend.signal_wait_until(cap_sem, 1)
            send = tpu_backend.putmem_signal_nbi(
                ws_ref.at[slot], ws_ref.at[(s + 1) % 2],
                send_sem, recv_sem, right, axis=axis)
        # the fold of chunk s overlaps the in-flight remote DMA of s+1;
        # s=0's chunk is already VMEM-resident from the init staging
        if s != 0:
            _stage((ws_ref.at[slot],), (chunk_vmem,), local_sem)
        owner = lax.rem(me - s + world, world)
        with tpu_backend.annotate("tile_compute", f"s{s}"):
            write_state(fold.fold(read_state(), chunk_vmem[...], owner,
                                  *statics()))
        if send is not None:
            send.wait()
        if s < world - 2:
            tpu_backend.signal_op(cap_sem, left, axis=axis)

    with tpu_backend.annotate("tile_compute", "finalize"):
        o_vmem[...] = fold.finalize(read_state(), *statics()).astype(out_dtype)
    _stage((o_vmem,), (o_ref,), local_sem)


def _ring_fold_pltpu(fold, chunk, statics, *, axis, world, out_dtype, cid):
    assert isinstance(fold, FoldTile), fold
    chunk_struct = jax.ShapeDtypeStruct(chunk.shape, chunk.dtype)
    state_struct = jax.eval_shape(fold.init, chunk_struct, *statics)
    state_leaves, state_treedef = jax.tree_util.tree_flatten(state_struct)
    out_struct = jax.eval_shape(fold.finalize, state_struct, *statics)
    body = functools.partial(
        _ring_fold_body, fold=fold, axis=axis, world=world,
        n_static=len(statics), n_state=len(state_leaves),
        state_treedef=state_treedef, out_dtype=out_dtype)
    out, _ws = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_shape=[
            jax.ShapeDtypeStruct(out_struct.shape, out_dtype),
            jax.ShapeDtypeStruct((2,) + chunk.shape, chunk.dtype),  # ring ws
        ],
        scratch_shapes=[pltpu.VMEM(chunk.shape, chunk.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(leaf.shape, leaf.dtype) for leaf in state_leaves]
        + [pltpu.VMEM(out_struct.shape, out_dtype),
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.DMA,
           pltpu.SemaphoreType.REGULAR],
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(chunk, *statics)
    return out


def _two_level_ag_body(*refs, tile, axes, worlds, n_static, tile_m, out_dtype):
    # axes/worlds ordered (outer, inner), matching the 2D device ids
    outer, inner = axes
    wo, wi = worlds
    (chunk_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, pws_ref, ows_ref = rest[n_static:n_static + 3]
    chunk_vmem = rest[n_static + 3]
    static_vmems = rest[n_static + 4:2 * n_static + 4]
    o_vmem = rest[2 * n_static + 4]
    (local_sem, psend, precv, osend, orecv, cap_sem) = rest[2 * n_static + 5:]

    iid = lax.axis_index(inner)
    oid = lax.axis_index(outer)
    left = lax.rem(oid + wo - 1, wo)
    right = lax.rem(oid + 1, wo)

    tpu_backend.barrier_all_grid(axes, worlds)
    _stage((chunk_ref,) + tuple(static_refs),
           (ows_ref.at[0],) + tuple(static_vmems), local_sem)
    if wo > 1:
        tpu_backend.signal_op(cap_sem, (left, iid))

    for so in range(wo):
        slot = so % 2
        region = lax.rem(oid - so + wo, wo)
        send_o = None
        if so != wo - 1:
            # the slow-link hop of region so+1 overlaps this region's
            # pod-local exchange + compute (ring_ag credits over pods)
            tpu_backend.signal_wait_until(cap_sem, 1)
            send_o = tpu_backend.putmem_signal_nbi(
                ows_ref.at[slot], ows_ref.at[(so + 1) % 2],
                osend, orecv, (right, iid))
        # pod-local one_shot: local copy for self + Wi-1 puts, all issued
        # before any wait (the emulated body's per-source signals become
        # the SPMD-symmetric descriptor waits here)
        lc = pltpu.make_async_copy(
            ows_ref.at[slot], pws_ref.at[slot * wi + iid], local_sem)
        lc.start()
        sends = []
        for off in range(1, wi):
            sends.append(tpu_backend.putmem_signal_nbi(
                ows_ref.at[slot], pws_ref.at[slot * wi + iid],
                psend, precv, (oid, lax.rem(iid + off, wi))))
        lc.wait()
        tpu_backend.quiet(*sends)
        for d in range(wi):
            src = lax.rem(iid - d + wi, wi)
            _stage((pws_ref.at[slot * wi + src],), (chunk_vmem,), local_sem)
            with tpu_backend.annotate("tile_compute", f"o{so}d{d}"):
                o_vmem[...] = tile(
                    chunk_vmem[...], *[v[...] for v in static_vmems]
                ).astype(out_dtype)
            owner = region * wi + src
            _stage((o_vmem,), (o_ref.at[pl.ds(owner * tile_m, tile_m)],),
                   local_sem)
        if send_o is not None:
            send_o.wait()
        if so < wo - 2:
            tpu_backend.signal_op(cap_sem, (left, iid))


def _two_level_ag_pltpu(tile, chunk, statics, *, axis, world, out_dtype, cid):
    inner, outer = axis
    wi, wo = world
    ts = _tile_struct(tile, chunk, statics)
    body = functools.partial(
        _two_level_ag_body, tile=tile, axes=(outer, inner), worlds=(wo, wi),
        n_static=len(statics), tile_m=ts.shape[0], out_dtype=out_dtype)
    out, _pws, _ows = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((ts.shape[0] * wi * wo,) + ts.shape[1:],
                                 out_dtype),
            jax.ShapeDtypeStruct((2 * wi,) + chunk.shape, chunk.dtype),  # pod
            jax.ShapeDtypeStruct((2,) + chunk.shape, chunk.dtype),  # outer
        ],
        scratch_shapes=[pltpu.VMEM(chunk.shape, chunk.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(ts.shape, out_dtype),
           pltpu.SemaphoreType.DMA,   # local staging
           pltpu.SemaphoreType.DMA,   # pod send
           pltpu.SemaphoreType.DMA,   # pod recv
           pltpu.SemaphoreType.DMA,   # outer send
           pltpu.SemaphoreType.DMA,   # outer recv
           pltpu.SemaphoreType.REGULAR],  # outer credits
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(chunk, *statics)
    return out


def _two_level_rs_body(*refs, tile, axes, worlds, n_static, m_blk, out_dtype):
    outer, inner = axes
    wo, wi = worlds
    (a_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, pws_ref, ows_ref, stage_ref = rest[n_static:n_static + 4]
    a_vmem = rest[n_static + 4]
    static_vmems = rest[n_static + 5:2 * n_static + 5]
    p_vmem = rest[2 * n_static + 5]       # f32 partial / pod landing
    acc_vmem = rest[2 * n_static + 6]     # f32 inter-pod accumulator
    o_vmem = rest[2 * n_static + 7]
    (local_sem, psend, precv, osend, orecv, cap_sem) = rest[2 * n_static + 8:]

    iid = lax.axis_index(inner)
    oid = lax.axis_index(outer)
    left = lax.rem(oid + wo - 1, wo)
    right = lax.rem(oid + 1, wo)

    tpu_backend.barrier_all_grid(axes, worlds)
    if n_static:
        _stage(tuple(static_refs), tuple(static_vmems), local_sem)
    if wo > 1:
        tpu_backend.signal_op(cap_sem, (left, iid))

    for so in range(wo):
        slot = so % 2
        region = lax.rem(oid - so - 1 + 2 * wo, wo)
        # pod-local one_shot RS: all Wi partials into local staging first
        for off in range(wi):
            blk = region * wi + lax.rem(iid + off, wi)
            _stage((a_ref.at[pl.ds(blk * m_blk, m_blk)],), (a_vmem,),
                   local_sem)
            with tpu_backend.annotate("tile_compute", f"o{so}off{off}"):
                p_vmem[...] = tile(
                    a_vmem[...], *[v[...] for v in static_vmems]
                ).astype(jnp.float32)
            _stage((p_vmem,), (stage_ref.at[off],), local_sem)
        lc = pltpu.make_async_copy(
            stage_ref.at[0], pws_ref.at[slot * wi + iid], local_sem)
        lc.start()
        sends = []
        for off in range(1, wi):
            sends.append(tpu_backend.putmem_signal_nbi(
                stage_ref.at[off], pws_ref.at[slot * wi + iid],
                psend, precv, (oid, lax.rem(iid + off, wi))))
        lc.wait()
        tpu_backend.quiet(*sends)
        acc = jnp.zeros(p_vmem.shape, jnp.float32)
        for d in range(wi):
            src = lax.rem(iid - d + wi, wi)
            _stage((pws_ref.at[slot * wi + src],), (p_vmem,), local_sem)
            acc = acc + p_vmem[...]
        if so > 0:
            # this region's inter-pod accumulator arrived from the left
            # pod; its landing was ordered by the previous step's send
            # wait (SPMD symmetry)
            _stage((ows_ref.at[slot],), (acc_vmem,), local_sem)
            acc = acc + acc_vmem[...]
            if so < wo - 1:
                tpu_backend.signal_op(cap_sem, (left, iid))
        acc_vmem[...] = acc
        if so != wo - 1:
            tpu_backend.signal_wait_until(cap_sem, 1)
            send = tpu_backend.putmem_signal_nbi(
                acc_vmem, ows_ref.at[(so + 1) % 2], osend, orecv,
                (right, iid))
            send.wait()

    o_vmem[...] = acc_vmem[...].astype(out_dtype)
    _stage((o_vmem,), (o_ref,), local_sem)


def _two_level_rs_pltpu(tile, operand, statics, *, axis, world, out_dtype,
                        cid):
    inner, outer = axis
    wi, wo = world
    m_blk = operand.shape[0] // (wi * wo)
    blk_struct = jax.ShapeDtypeStruct((m_blk,) + operand.shape[1:],
                                      operand.dtype)
    ts = _tile_struct(tile, blk_struct, statics)
    body = functools.partial(
        _two_level_rs_body, tile=tile, axes=(outer, inner), worlds=(wo, wi),
        n_static=len(statics), m_blk=m_blk, out_dtype=out_dtype)
    outs = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_shape=[
            jax.ShapeDtypeStruct(ts.shape, out_dtype),
            jax.ShapeDtypeStruct((2 * wi,) + ts.shape, jnp.float32),  # pod
            jax.ShapeDtypeStruct((2,) + ts.shape, jnp.float32),  # outer
            jax.ShapeDtypeStruct((wi,) + ts.shape, jnp.float32),  # staging
        ],
        scratch_shapes=[pltpu.VMEM(blk_struct.shape, operand.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(ts.shape, jnp.float32),
           pltpu.VMEM(ts.shape, jnp.float32),
           pltpu.VMEM(ts.shape, out_dtype),
           pltpu.SemaphoreType.DMA,   # local staging
           pltpu.SemaphoreType.DMA,   # pod send
           pltpu.SemaphoreType.DMA,   # pod recv
           pltpu.SemaphoreType.DMA,   # outer send
           pltpu.SemaphoreType.DMA,   # outer recv
           pltpu.SemaphoreType.REGULAR],  # outer credits
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(operand, *statics)
    return outs[0]


def _push_rs_ring_ag_body(*refs, chain, axis, world, n_rs, n_ag, n_mid,
                          m_blk, tile_m, out_dtype, h_dtype):
    n_static = n_rs + n_ag + n_mid
    (a_ref, *rest) = refs
    static_refs = rest[:n_static]
    o_ref, wsr_ref, wsa_ref = rest[n_static:n_static + 3]
    a_vmem = rest[n_static + 3]
    static_vmems = rest[n_static + 4:2 * n_static + 4]
    p_vmem = rest[2 * n_static + 4]       # f32 rs partial / landed partial
    h_vmem = rest[2 * n_static + 5]       # boundary activation chunk
    o_vmem = rest[2 * n_static + 6]
    (local_sem, rs_send, rs_recv, ag_send, ag_recv,
     ag_cap) = rest[2 * n_static + 7:]

    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    tpu_backend.barrier_all(axis, world)
    if n_static:
        _stage(tuple(static_refs), tuple(static_vmems), local_sem)
    # the ag ring's initial credit, granted before the rs half even
    # starts — no barrier separates the halves (the fusion)
    tpu_backend.signal_op(ag_cap, left, axis=axis)

    # --- rs half: Alg. 3 push into the rs workspace (f32 partials)
    sends = []
    for s in range(world):
        blk = lax.rem(me - s - 1 + 2 * world, world)
        _stage((a_ref.at[pl.ds(blk * m_blk, m_blk)],), (a_vmem,), local_sem)
        with tpu_backend.annotate("tile_compute", f"rs_s{s}"):
            p_vmem[...] = chain.rs(
                a_vmem[...], *[v[...] for v in static_vmems[:n_rs]]
            ).astype(jnp.float32)
        if s == world - 1:
            _stage((p_vmem,), (wsr_ref.at[me],), local_sem)
        else:
            send = tpu_backend.putmem_signal_nbi(
                p_vmem, wsr_ref.at[me], rs_send, rs_recv, blk, axis=axis)
            # next step's compute overlaps the DMA; drain before reusing
            # p_vmem (single partial buffer)
            send.wait_send()
            sends.append(send)
    for send in sends:
        send.wait_recv()
    acc = jnp.zeros(p_vmem.shape, jnp.float32)
    for r in range(world):
        _stage((wsr_ref.at[r],), (p_vmem,), local_sem)
        acc = acc + p_vmem[...]

    # --- boundary: rank-local mid, landed into the ag ring's slot 0
    with tpu_backend.annotate("tile_compute", "mid"):
        reduced = acc.astype(out_dtype)
        if chain.mid is not None:
            reduced = chain.mid(
                reduced, *[v[...] for v in static_vmems[n_rs + n_ag:]])
        h_vmem[...] = reduced.astype(h_dtype)
    _stage((h_vmem,), (wsa_ref.at[0],), local_sem)

    # --- ag half: Fig. 4 ring + credit over the boundary activation
    for s in range(world):
        slot = s % 2
        send = None
        if s != world - 1:
            tpu_backend.signal_wait_until(ag_cap, 1)
            send = tpu_backend.putmem_signal_nbi(
                wsa_ref.at[slot], wsa_ref.at[(s + 1) % 2],
                ag_send, ag_recv, right, axis=axis)
        _stage((wsa_ref.at[slot],), (h_vmem,), local_sem)
        with tpu_backend.annotate("tile_compute", f"ag_s{s}"):
            o_vmem[...] = chain.ag(
                h_vmem[...], *[v[...] for v in static_vmems[n_rs:n_rs + n_ag]]
            ).astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        _stage((o_vmem,), (o_ref.at[pl.ds(owner * tile_m, tile_m)],),
               local_sem)
        if send is not None:
            send.wait()
        if s < world - 2:
            tpu_backend.signal_op(ag_cap, left, axis=axis)


def _push_rs_ring_ag_pltpu(chain, operand, statics, *, axis, world, out_dtype,
                           cid):
    assert isinstance(chain, ChainTile), chain
    n_rs, n_ag = chain.n_rs, chain.n_ag
    rs_statics = statics[:n_rs]
    ag_statics = statics[n_rs:n_rs + n_ag]
    mid_statics = statics[n_rs + n_ag:]
    m_blk = operand.shape[0] // world
    blk_struct = jax.ShapeDtypeStruct((m_blk,) + operand.shape[1:],
                                      operand.dtype)
    rs_ts = _tile_struct(chain.rs, blk_struct, rs_statics)

    def _boundary(acc, *ms):
        reduced = acc.astype(out_dtype)
        return chain.mid(reduced, *ms) if chain.mid is not None else reduced

    h_struct = jax.eval_shape(
        _boundary, jax.ShapeDtypeStruct(rs_ts.shape, jnp.float32),
        *mid_statics)
    ag_ts = _tile_struct(chain.ag, h_struct, ag_statics)
    body = functools.partial(
        _push_rs_ring_ag_body, chain=chain, axis=axis, world=world,
        n_rs=n_rs, n_ag=n_ag, n_mid=len(mid_statics), m_blk=m_blk,
        tile_m=ag_ts.shape[0], out_dtype=out_dtype, h_dtype=h_struct.dtype)
    out, _wsr, _wsa = pl.pallas_call(
        body,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (1 + len(statics)),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((ag_ts.shape[0] * world,) + ag_ts.shape[1:],
                                 out_dtype),
            jax.ShapeDtypeStruct((world,) + rs_ts.shape, jnp.float32),  # rs ws
            jax.ShapeDtypeStruct((2,) + h_struct.shape, h_struct.dtype),  # ag
        ],
        scratch_shapes=[pltpu.VMEM(blk_struct.shape, operand.dtype)]
        + [pltpu.VMEM(s.shape, s.dtype) for s in statics]
        + [pltpu.VMEM(rs_ts.shape, jnp.float32),
           pltpu.VMEM(h_struct.shape, h_struct.dtype),
           pltpu.VMEM(ag_ts.shape, out_dtype),
           pltpu.SemaphoreType.DMA,   # local staging
           pltpu.SemaphoreType.DMA,   # rs send
           pltpu.SemaphoreType.DMA,   # rs recv
           pltpu.SemaphoreType.DMA,   # ag send
           pltpu.SemaphoreType.DMA,   # ag recv
           pltpu.SemaphoreType.REGULAR],  # ag credits
        compiler_params=pltpu.CompilerParams(collective_id=cid),
    )(operand, *statics)
    return out


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_EMULATED = {
    "ring_ag": _ring_ag_emulated,
    "one_shot_ag": _one_shot_ag_emulated,
    "push_rs": _push_rs_emulated,
    "one_shot_rs": _one_shot_rs_emulated,
    "one_shot_a2a": _one_shot_a2a_emulated,
    "bidir_ring_ag": _bidir_ring_ag_emulated,
    "ring_fold": _ring_fold_emulated,
    "two_level_ag": _two_level_ag_emulated,
    "two_level_rs": _two_level_rs_emulated,
    "push_rs_ring_ag": _push_rs_ring_ag_emulated,
}

_PLTPU = {
    "ring_ag": _ring_ag_pltpu,
    "one_shot_ag": _one_shot_ag_pltpu,
    "push_rs": functools.partial(_rs_pltpu, one_shot=False),
    "one_shot_rs": functools.partial(_rs_pltpu, one_shot=True),
    "one_shot_a2a": _one_shot_a2a_pltpu,
    "bidir_ring_ag": _bidir_ring_ag_pltpu,
    "ring_fold": _ring_fold_pltpu,
    "two_level_ag": _two_level_ag_pltpu,
    "two_level_rs": _two_level_rs_pltpu,
    "push_rs_ring_ag": _push_rs_ring_ag_pltpu,
}


def run(
    protocol: str,
    tile: Optional[Callable],
    operand: Array,
    statics: Sequence[Array] = (),
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 0,
    backend: Optional[str] = None,
    decode: Optional[Callable] = None,
) -> Array:
    """Execute ``tile`` under a shmem communication protocol.

    ``operand`` is the tensor that moves (AG protocols: the chunk that
    rides/broadcasts; RS protocols: the local tensor whose dim-0 blocks
    produce the pushed partials; one_shot_a2a: a ``(world, ...)`` tensor
    whose block ``t`` is destined for PE ``t``). ``statics`` stay
    rank-resident.
    ``tile=None`` is the identity (pure data movement); ``ring_fold``
    takes a :class:`FoldTile` instead of a pure tile. The two-level
    protocols compose two mesh axes: pass ``axis=(inner, outer)`` and
    ``world=(Wi, Wo)``. ``backend`` is a shmem backend name
    ("pltpu" | "emulated"); default picks per platform
    (``shmem.default_backend``).

    ``decode`` is the RS-side wire hook (push_rs / one_shot_rs only):
    when set, ``tile`` returns a PACKED wire buffer (ops.wire.pack) that
    is pushed verbatim, and ``decode(packed) -> f32`` unpacks each landed
    partial before the owner's reduction. The AG/a2a protocols need no
    hook — the caller packs the riding operand and unpacks inside
    ``tile``, since their payloads pass through workspaces unmodified.
    """
    if protocol not in PROTOCOLS:
        raise ValueError(f"unknown protocol {protocol!r} (not in {PROTOCOLS})")
    two_level = protocol in TWO_LEVEL_PROTOCOLS
    if two_level != isinstance(axis, (tuple, list)):
        raise ValueError(
            f"{protocol}: axis must be {'(inner, outer)' if two_level else 'one axis name'}, got {axis!r}")
    if decode is not None and protocol not in ("push_rs", "one_shot_rs"):
        raise ValueError(
            f"{protocol}: decode is only supported for push_rs/one_shot_rs")
    if two_level:
        axis, world = tuple(axis), tuple(world)
    if protocol == "ring_fold":
        if not isinstance(tile, FoldTile):
            raise ValueError("ring_fold takes a FoldTile (init/fold/finalize)")
    elif protocol == "push_rs_ring_ag":
        if not isinstance(tile, ChainTile):
            raise ValueError("push_rs_ring_ag takes a ChainTile (rs/ag/mid)")
    else:
        tile = tile or _identity
    backend = backend or default_backend()
    impl = (_PLTPU if backend == "pltpu" else _EMULATED)[protocol]
    kwargs = {} if decode is None else {"decode": decode}
    return impl(tile, operand, tuple(statics), axis=axis, world=world,
                out_dtype=out_dtype or operand.dtype, cid=collective_id,
                **kwargs)
