"""The pltpu backend: shmem primitives INSIDE a Pallas TPU kernel.

The faithful port of the paper's OpenSHMEM / NVSHMEM primitive set to
TPU hardware. Symmetric memory is ``pl.ANY`` refs under SPMD shard_map
(declare workspaces as extra kernel outputs so the interpreter and
Mosaic both give them stable cross-device addresses); signals are
DMA/REGULAR semaphores; data transfer is the chip's async remote-DMA
engine. The recv semaphore *is* the paper's signal: TPU DMAs signal
data arrival in hardware, which is why the LL flag-in-word protocol
does not need porting.

These functions are only meaningful inside a Pallas kernel body and
only lower on real TPU (Mosaic). For the CPU-emulated implementation of
the same API (value-level, host-side symmetric heaps) see
:mod:`repro.shmem.emulated`.
"""
from __future__ import annotations

from typing import Optional

from jax import lax
from jax.experimental.pallas import tpu as pltpu

from .. import _compat  # noqa: F401  (pltpu name backfills)


def annotate(kind: str, name: str = ""):
    """The pltpu mapping of :mod:`repro.obs` span labels: a
    ``jax.named_scope`` (+ profiler TraceAnnotation) context, so a real
    TPU profile of a pallas protocol carries the SAME
    ``obs.tile_compute`` / ``obs.pack`` / ``obs.decode`` labels the
    emulated backend's host timeline records. Trace-time metadata only —
    zero runtime cost."""
    from .. import obs

    return obs.phase(kind, name)


def _device_id(peer):
    """MESH device id: scalar peer = 1D mesh; tuple peer = one coordinate
    per mesh axis (the two-level protocols address a (pod, ring) grid —
    the kernel's mesh axis order must match the tuple order)."""
    return tuple(peer) if isinstance(peer, tuple) else (peer,)


def putmem_signal_nbi(
    src_ref,
    dst_ref,
    send_sem,
    recv_sem,
    peer,
    *,
    axis: Optional[str] = None,
):
    """Non-blocking one-sided put + arrival signal (paper: putmem_signal_nbi).

    Starts an async remote DMA copying ``src_ref`` (local) into ``dst_ref``
    *on device* ``peer`` along mesh axis ``axis``. The remote ``recv_sem``
    is incremented by the hardware when the data lands — the signal write
    and the data transfer are one operation, as in NVSHMEM's putmem_signal.
    Returns the copy descriptor; call ``.wait()`` (or ``quiet``) later.
    """
    del axis
    copy = pltpu.make_async_remote_copy(
        src_ref=src_ref,
        dst_ref=dst_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=_device_id(peer),
        device_id_type=pltpu.DeviceIdType.MESH,
    )
    copy.start()
    return copy


def putmem_signal(src_ref, dst_ref, send_sem, recv_sem, peer, *, axis=None):
    """Blocking variant: returns after the local send side has completed."""
    copy = putmem_signal_nbi(src_ref, dst_ref, send_sem, recv_sem, peer, axis=axis)
    copy.wait_send()
    return copy


def local_copy_nbi(src_ref, dst_ref, sem):
    """Async local (HBM<->HBM/VMEM) DMA — the 'copy engine' analogue."""
    copy = pltpu.make_async_copy(src_ref, dst_ref, sem)
    copy.start()
    return copy


def signal_op(sem, peer, *, inc: int = 1, axis: Optional[str] = None):
    """Increment a remote signal (paper: signal_op / notify)."""
    del axis
    pltpu.semaphore_signal(
        sem,
        inc=inc,
        device_id=_device_id(peer),
        device_id_type=pltpu.DeviceIdType.MESH,
    )


notify = signal_op


def signal_wait_until(sem, value: int):
    """Spin-wait until the local signal reaches ``value``, then consume it
    (paper: signal_wait_until / wait)."""
    pltpu.semaphore_wait(sem, value)


wait = signal_wait_until


def quiet(*copies):
    """Ensure completion of outstanding one-sided ops (paper: quiet)."""
    for c in copies:
        c.wait()


def barrier_all(axis: str, world: int):
    """Barrier across all ranks on ``axis`` (paper: barrier_all).

    Uses the kernel's collective barrier semaphore: signal every peer, then
    wait for ``world - 1`` arrivals. Requires
    ``compiler_params=pltpu.CompilerParams(collective_id=...)``.
    """
    barrier = pltpu.get_barrier_semaphore()
    me = lax.axis_index(axis)
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        pltpu.semaphore_signal(
            barrier, inc=1, device_id=(peer,), device_id_type=pltpu.DeviceIdType.MESH
        )
    pltpu.semaphore_wait(barrier, world - 1)


def barrier_all_grid(axes, worlds):
    """Barrier across a two-axis (outer, inner) device grid (the
    two-level protocols' rendezvous): signal every (o, i) peer on the
    kernel's collective barrier semaphore, wait for Wo*Wi - 1 arrivals.
    ``axes``/``worlds`` are ordered (outer, inner), matching the 2D
    device ids the protocols use."""
    outer, inner = axes
    wo, wi = worlds
    barrier = pltpu.get_barrier_semaphore()
    oid = lax.axis_index(outer)
    iid = lax.axis_index(inner)
    for o_off in range(wo):
        for i_off in range(wi):
            if o_off == 0 and i_off == 0:
                continue  # self
            peer = (lax.rem(oid + o_off, wo), lax.rem(iid + i_off, wi))
            pltpu.semaphore_signal(
                barrier, inc=1, device_id=peer,
                device_id_type=pltpu.DeviceIdType.MESH,
            )
    pltpu.semaphore_wait(barrier, wo * wi - 1)


def broadcast_put(src_ref, dst_ref, send_sem, recv_sem, axis: str, world: int):
    """multimem_st analogue: store the same data to all peers.

    ICI exposes no multicast primitive, so this is a peer loop of one-sided
    puts (documented hardware-adaptation change). All DMAs are started
    before any wait — they proceed in parallel on the DMA engines.
    """
    me = lax.axis_index(axis)
    copies = []
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        copies.append(
            putmem_signal_nbi(src_ref, dst_ref, send_sem, recv_sem, peer, axis=axis)
        )
    for c in copies:
        c.wait_send()
