"""repro.shmem — the paper's OpenSHMEM-style communication subsystem.

The paper's first contribution (§2-3) is compiling OpenSHMEM-compliant
primitives INTO the kernels, so an overlapped kernel issues its own
communication instead of delegating to a graph-level collective. This
package is that primitive layer, with one API and two backends:

  ``tpu_backend``   the in-kernel primitive set for real TPU Pallas
                    kernels: symmetric memory is ``pl.ANY`` refs under
                    SPMD shard_map, signals are DMA/REGULAR semaphores,
                    data transfer is the chip's async remote-DMA engine
                    (``pltpu.make_async_remote_copy``). Only lowerable
                    on actual TPU (Mosaic).

  ``emulated``      the emulated-DMA backend: per-device host-side
                    symmetric heaps and signal slots, driven by ordered
                    ``io_callback``s from inside ``shard_map``. Every
                    virtual CPU device runs its SPMD program on its own
                    thread, so blocking ``signal_wait_until`` calls
                    really do wait for a peer's ``putmem_signal`` — the
                    full signal-exchange protocol (credits, barriers,
                    arrival signals) executes on CPU with N virtual
                    devices. This is what makes the fused kernels in
                    ``repro.kernels`` testable without hardware.

Backend selection: :func:`default_backend` returns ``"pltpu"`` on real
TPU and ``"emulated"`` everywhere else; ``REPRO_SHMEM_BACKEND`` forces
either. The shared **tile executor** (:mod:`executor`) consumes this —
it implements every fused-kernel communication protocol (ring+credit,
bidirectional ring, Alg.-3 push, one-shot puts, one-shot AllToAll)
once, generic over a per-tile compute, on both backends; the fused
kernels (``kernels/ag_gemm.py`` etc.) and the ``repro.ops`` kernel
lowerings are declarations over it.

Rank identity (``my_pe`` / ``n_pes``) is backend-independent (mesh axis
arithmetic) and lives in :mod:`api`.
"""
from __future__ import annotations

import os

from . import api, emulated, tpu_backend
from .api import my_pe, n_pes

BACKENDS = ("pltpu", "emulated")


def default_backend() -> str:
    """The shmem backend for the current platform.

    ``"pltpu"`` — real TPU: primitives lower to Mosaic remote DMAs.
    ``"emulated"`` — everything else: host-side symmetric heaps.
    ``REPRO_SHMEM_BACKEND`` overrides (tests / forcing emulation on TPU).
    """
    forced = os.environ.get("REPRO_SHMEM_BACKEND", "")
    if forced:
        if forced not in BACKENDS:
            raise ValueError(f"REPRO_SHMEM_BACKEND={forced!r} not in {BACKENDS}")
        return forced
    import jax

    return "pltpu" if jax.default_backend() == "tpu" else "emulated"


from . import executor  # noqa: E402  (needs default_backend above)

__all__ = [
    "api",
    "emulated",
    "executor",
    "tpu_backend",
    "my_pe",
    "n_pes",
    "BACKENDS",
    "default_backend",
]
