"""whisper-medium [audio] — enc-dec, conv frontend STUB.

[arXiv:2212.04356; unverified]

The conv1d frontend is stubbed per the assignment: ``input_specs()``
provides precomputed frame embeddings (batch, 1500, d_model). Positional
encodings are sinusoidal (computed on the fly) so the assigned 32k decode
shape does not require a 32k learned table.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="whisper",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    encoder_frames=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    gated_mlp=False,
    use_rope=False,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
