"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

[hf:meta-llama/Llama-3.2-90B-Vision family; unverified]

100 layers total: every 5th layer is a cross-attention layer attending to
precomputed patch embeddings (the vision frontend is a STUB per the
assignment — ``input_specs()`` provides the patch embeddings).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    gated_mlp=True,
    cross_attn_every=5,
    vision_tokens=1601,
    vision_dim=1280,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
