"""Architecture registry: ``get_config("<arch-id>")`` and ``ARCHS``."""
from __future__ import annotations

from .base import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig, reduced
from .shapes import SHAPES, shape_applicable

from . import (
    granite_moe_3b_a800m,
    kimi_k2_1t_a32b,
    command_r_plus_104b,
    granite_3_2b,
    qwen15_4b,
    nemotron_4_15b,
    llama_32_vision_90b,
    mamba2_1_3b,
    whisper_medium,
    zamba2_2_7b,
)

_MODULES = (
    granite_moe_3b_a800m,
    kimi_k2_1t_a32b,
    command_r_plus_104b,
    granite_3_2b,
    qwen15_4b,
    nemotron_4_15b,
    llama_32_vision_90b,
    mamba2_1_3b,
    whisper_medium,
    zamba2_2_7b,
)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


__all__ = [
    "ModelConfig",
    "ParallelConfig",
    "ShapeConfig",
    "TrainConfig",
    "ARCHS",
    "SHAPES",
    "get_config",
    "reduced",
    "shape_applicable",
]
