"""Configuration dataclasses for the repro framework.

Every assigned architecture gets one module in this package exporting
``CONFIG: ModelConfig``. Shapes (seq_len x global_batch cells) live in
``shapes.py``. Parallelism / training / serving knobs are orthogonal and
combined by the launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (one per assigned arch).

    ``family`` selects the block structure:
      dense   — pre-norm decoder-only transformer (GQA + gated/ungated MLP)
      moe     — dense attention + top-k routed expert MLP
      ssm     — Mamba2 (SSD) attention-free stack
      hybrid  — Mamba2 blocks with a shared attention block every K layers
      whisper — encoder-decoder (conv frontend stubbed as frame embeddings)
      vlm     — decoder with cross-attention image layers (patch stub)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    # capacity factor for expert dispatch (tokens per expert buffer)
    capacity_factor: float = 1.25

    # --- options ---
    qkv_bias: bool = False
    activation: str = "silu"  # "silu" | "gelu" | "relu2"
    gated_mlp: bool = True  # False -> 2-matrix MLP (e.g. nemotron relu2)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    use_rope: bool = True  # False -> sinusoidal absolute positions (whisper)

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128  # SSD chunk length
    ssm_conv_width: int = 4
    ssm_num_groups: int = 1

    # --- hybrid (zamba2): shared attention block every K mamba blocks ---
    shared_attn_every: int = 0

    # --- whisper ---
    encoder_layers: int = 0
    encoder_frames: int = 1500  # stub: precomputed conv-frontend output length

    # --- vlm ---
    cross_attn_every: int = 0  # every K-th layer is a cross-attention layer
    vision_tokens: int = 1601  # stub: precomputed patch embeddings per image
    vision_dim: int = 1280

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    # Derived quantities (used by the roofline analysis and the tuner)
    # ------------------------------------------------------------------
    @property
    def q_heads_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def _mlp_params(self, d_ff: Optional[int] = None) -> int:
        d_ff = self.d_ff if d_ff is None else d_ff
        n_mat = 3 if self.gated_mlp else 2
        return n_mat * self.d_model * d_ff

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, s, nh = self.ssm_num_groups, self.ssm_state, self.ssm_num_heads
        in_proj = d * (2 * di + 2 * g * s + nh)
        conv = self.ssm_conv_width * (di + 2 * g * s)
        out_proj = di * d
        extra = 2 * nh  # A_log, dt_bias (D is nh more)
        return in_proj + conv + out_proj + extra + nh

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        emb = self.vocab_size * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = self._attn_params() + self._mlp_params() + 2 * self.d_model
            total += self.num_layers * per_layer
            if self.family == "vlm" and self.cross_attn_every:
                # cross-attn layers replace self-attn; extra cost: none beyond
                # the vision projection below (kv come from image tokens).
                total += self.vision_dim * self.d_model  # patch projection
        elif self.family == "moe":
            attn = self._attn_params() + 2 * self.d_model
            experts = self.num_experts * self._mlp_params()
            router = self.d_model * self.num_experts
            total += self.num_layers * (attn + experts + router)
        elif self.family == "ssm":
            total += self.num_layers * (self._ssm_params() + self.d_model)
        elif self.family == "hybrid":
            total += self.num_layers * (self._ssm_params() + self.d_model)
            # one shared attention+MLP block (weights reused at each site)
            total += self._attn_params() + self._mlp_params() + 2 * self.d_model
        elif self.family == "whisper":
            blk = self._attn_params() + self._mlp_params() + 2 * self.d_model
            dec_blk = blk + self._attn_params() + self.d_model  # + cross attn
            total += self.encoder_layers * blk + self.num_layers * dec_blk
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        emb = self.vocab_size * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        attn = self._attn_params() + 2 * self.d_model
        experts = self.experts_per_token * self._mlp_params()
        router = self.d_model * self.num_experts
        return total + self.num_layers * (attn + experts + router)

    def flops_per_token(self, training: bool = True) -> float:
        """MODEL_FLOPS/token: 6*N_active (train) or 2*N_active (inference)."""
        mult = 6.0 if training else 2.0
        return mult * float(self.active_param_count())


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh."""

    dp: int = 1  # size of the "data" axis
    tp: int = 1  # size of the "model" axis
    pods: int = 1  # size of the "pod" axis (1 = single-pod mesh)

    fsdp: bool = True  # shard params + optimizer state along data axis
    fsdp_pods: bool = False  # extend the FSDP shard over the pod axis too
    sequence_parallel: bool = True  # SP between TP regions
    expert_parallel: bool = True  # shard experts along model axis
    # EP MoE activation chunking: process tokens in N sequential chunks to
    # bound the (E, capacity, d) dispatch buffers (trades a little latency
    # for peak memory; also the natural grain for overlapped a2a).
    moe_chunks: int = 1

    # Overlap strategy for the paper's technique. The consolidated knob
    # is ``overlap``: an ``repro.ops.OverlapPolicy`` (mode/backend
    # defaults, per-op override maps, chunk counts) with one
    # ``resolve(op, hw)`` clamped against the engine registry. When
    # ``overlap`` is None, the legacy fields below are folded into a
    # policy on the fly (``ParallelConfig.policy``), so existing
    # configs keep working:
    #   none     — plain XLA all_gather/psum_scatter (the NCCL-baseline analogue)
    #   ring     — unidirectional ring collective-matmul (paper Fig. 7 swizzle)
    #   bidir    — bidirectional ring (2 links, halves the steps)
    #   one_shot — low-latency one-shot transport (paper Alg. 4 analogue, decode)
    # Latency-bound small-message ops (a2a_ep, flash_decode) default to
    # one_shot, matching the paper's low-latency kernels.
    overlap: object = None  # Optional[repro.ops.OverlapPolicy]
    overlap_mode: str = "ring"
    overlap_modes: tuple = (("a2a_ep", "one_shot"), ("flash_decode", "one_shot"))
    ag_chunks: int = 0  # 0 = one chunk per TP rank (paper default)
    rs_chunks: int = 0  # RS-side sub-chunking (accumulator column groups)

    # HOW a transport is lowered (orthogonal to the mode):
    #   graph  — lax.ppermute engine pipelines (runs everywhere)
    #   kernel — the fused shmem-based kernels (repro.kernels over
    #            repro.shmem): remote DMAs on TPU, emulated DMA on CPU.
    overlap_backend: str = "graph"
    overlap_backends: tuple = ()
    # Wire dtype riding chunks travel as: "f32" (as-is) or "int8"/"fp8"
    # per-row scaled 1-byte blocks (ops/wire.py); clamped per-op to the
    # registry's wire-capable ops.
    overlap_wire: str = "f32"

    remat: str = "block"  # "none" | "dots" | "block"
    grad_compression: str = "none"  # "none" | "int8"
    # decode-time KV cache placement: "heads" (TP-local flash decode) or
    # "sequence" (shard KV over the data axis -> the paper's distributed
    # flash decode with low-latency combine; required for long_500k)
    kv_shard: str = "heads"

    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # bf16 for the 1T config

    # Legacy overlap fields: with an explicit ``overlap`` policy set, any
    # of these moved off its field default is a CONFLICT (two sources of
    # truth) and raises instead of silently losing. Defaults are read
    # from the dataclass fields themselves, so the check cannot drift.
    _LEGACY_OVERLAP_FIELDS = ("overlap_mode", "overlap_modes",
                              "overlap_backend", "overlap_backends",
                              "ag_chunks", "rs_chunks", "overlap_wire")

    def __post_init__(self):
        # accept a dict for ergonomics; store a hashable sorted tuple
        if isinstance(self.overlap_modes, dict):
            object.__setattr__(
                self, "overlap_modes", tuple(sorted(self.overlap_modes.items()))
            )
        if isinstance(self.overlap_backends, dict):
            object.__setattr__(
                self, "overlap_backends",
                tuple(sorted(self.overlap_backends.items())),
            )
        from ..ops.policy import WIRE_DTYPES  # lazy: stay import-light

        if self.overlap_wire not in WIRE_DTYPES:
            raise ValueError(
                f"unknown wire dtype {self.overlap_wire!r} "
                f"(valid: {WIRE_DTYPES})")
        if self.overlap is not None:
            defaults = {f.name: f.default for f in dataclasses.fields(self)}
            conflicts = sorted(
                name for name in self._LEGACY_OVERLAP_FIELDS
                if getattr(self, name) != defaults[name]
            )
            if conflicts:
                raise ValueError(
                    "ParallelConfig: both an explicit `overlap` policy and "
                    f"conflicting legacy overlap fields ({', '.join(conflicts)}) "
                    "were supplied; fold the legacy values into the "
                    "OverlapPolicy (mode=/modes=/backend=/backends=/"
                    "ag_chunks=/rs_chunks=/wire=) or drop `overlap`"
                )

    @property
    def policy(self):
        """The consolidated overlap policy (``repro.ops.OverlapPolicy``).

        ``overlap`` when set; otherwise the legacy per-field knobs folded
        into a policy, so both config styles resolve identically."""
        if self.overlap is not None:
            return self.overlap
        from ..ops.policy import OverlapPolicy  # lazy: stay import-light

        return OverlapPolicy(
            mode=self.overlap_mode,
            backend=self.overlap_backend,
            modes=self.overlap_modes,
            backends=self.overlap_backends,
            ag_chunks=self.ag_chunks,
            rs_chunks=self.rs_chunks,
            wire=self.overlap_wire,
        )

    def mode_for(self, op: str) -> str:
        """Effective overlap mode for registry op ``op`` (policy.resolve)."""
        return self.policy.mode_for(op)

    def backend_for(self, op: str) -> str:
        """Effective lowering backend for ``op`` (policy.resolve)."""
        return self.policy.backend_for(op)

    @property
    def world(self) -> int:
        return self.dp * self.tp * self.pods


@dataclass(frozen=True)
class TrainConfig:
    # "adamw" | "momentum" — momentum (Muon-style single buffer) is the
    # production choice for 1T-class models whose AdamW states cannot fit
    # (Kimi K2 itself trained with Muon).
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    small = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == "moe":
        small.update(num_experts=4, experts_per_token=2)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        small.update(shared_attn_every=2)
    if cfg.family == "whisper":
        small.update(encoder_layers=2, encoder_frames=8)
    if cfg.family == "vlm":
        small.update(cross_attn_every=2, vision_tokens=8, vision_dim=32)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
