"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE (paper-table).

[arXiv:2501.kimi2; unverified] — per the assignment spec: 61L d_model=7168
64H (GQA kv=8) per-expert d_ff=2048, 384 experts top-8, vocab 163840.

Check: 61 * 384 * 3*7168*2048 = 1.03e12 routed params — matches "1T".
Active: 61 * (8 experts + attn) + embeddings ~= 32B — matches "a32b".

Production note: with 1T params the optimizer moments must be bf16
(``ParallelConfig.moment_dtype="bfloat16"``) to fit 512 x 16 GB HBM —
the launcher applies this automatically for this config.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,  # 7168 / 64
    d_ff=2048,  # per-expert hidden size
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    activation="silu",
    gated_mlp=True,
    source="arXiv:2501.kimi2",
)
