"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]
d_inner = 2*d_model = 4096, 64 SSD heads of dim 64, state 128.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv_width=4,
    ssm_num_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
