"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]

54 Mamba2 layers; every 6th position additionally applies a SHARED
(weight-tied) attention+MLP block — the Zamba2 design point.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv_width=4,
    ssm_num_groups=1,
    shared_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
