"""command-r-plus-104b [dense] — GQA, no-bias.

[hf:CohereForAI/c4ai-command-r-plus family; unverified]
64 * (attn 327M + mlp 1.245B) + tied embed 3.1B ~= 104B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
