"""The four assigned LM input-shape cells.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token with a KV cache
of ``seq_len``), NOT ``train_step``. ``long_500k`` requires sub-quadratic
sequence mixing and therefore only runs for SSM/hybrid architectures (the
skip is recorded in DESIGN.md §Arch-applicability and EXPERIMENTS.md).
"""
from __future__ import annotations

from .base import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524288, global_batch=1, kind="decode")
# the serving engine's cell: short-context decode slots fed by the
# chunked-prefill program against the paged KV pool (launch/serve.py)
SERVE_2K = ShapeConfig(name="serve_2k", seq_len=2048, global_batch=8, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, SERVE_2K)}

# Families for which the long-context decode cell is runnable
# (sub-quadratic sequence mixing).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(family: str, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return family in LONG_CONTEXT_FAMILIES
    return True
