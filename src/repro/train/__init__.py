from . import checkpoint, optimizer, train_step
