"""Checkpointing: async save, atomic commit, GC, and ELASTIC resharding.

Fault-tolerance contract:
  - atomic: write to ``step_N.tmp/`` then rename — a crash mid-save never
    corrupts the latest checkpoint;
  - async: the device->host copy is synchronous (snapshot isolation) but
    serialization runs on a background thread, off the training path;
  - restart: ``latest_step`` + ``restore`` resume training; the data
    pipeline is stateless-by-step so the stream continues exactly;
  - elastic: checkpoints store the PACKED leaves plus their logical
    LeafSpecs; ``reshard`` re-slices to a different (dp, tp) mesh so a job
    can restart on fewer/more healthy pods (node-failure recovery).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..configs.base import ParallelConfig
from ..models.params import LeafSpec


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/") for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(vals) if not hasattr(template, "_fields") else type(template)(*vals)
    return flat[prefix[:-1]]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Dict[str, Any], *, blocking: bool = False):
        """Snapshot to host NOW, serialize in the background."""
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self.wait()

        def commit():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            commit()
        else:
            self._thread = threading.Thread(target=commit, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ----------------------------------------------------------- restore
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return out

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return max(s) if s else None

    def restore(self, step: int, template: Dict[str, Any]) -> Dict[str, Any]:
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        data = np.load(path)
        flat = {k: jnp.asarray(data[k]) for k in data.files}
        return _unflatten_into(template, flat)


# ---------------------------------------------------------------------------
# Elastic resharding (packed-leaf re-slicing across mesh sizes)
# ---------------------------------------------------------------------------


def repack_leaf(
    arr: np.ndarray,
    spec: LeafSpec,
    old: ParallelConfig,
    new: ParallelConfig,
) -> np.ndarray:
    """Convert one packed GLOBAL leaf between (dp, tp) layouts.

    tp-sharded leaves are [tp x ceil(numel/dp)] segment-concats; changing
    dp only changes padding, changing tp changes the logical split — which
    is only valid when the TP-local shape itself is unchanged (same tp) or
    the leaf is replicated. For tp changes of tp-sharded leaves the caller
    must rebuild via the logical tensors (concat + re-split)."""
    stacked = arr.ndim == 2
    rows = arr if stacked else arr[None]
    old_seg = ((spec.numel + old.dp - 1) // old.dp) * old.dp
    new_seg = ((spec.numel + new.dp - 1) // new.dp) * new.dp
    reps = (old.tp if spec.tp_sharded else 1)
    assert (not spec.tp_sharded) or old.tp == new.tp, (
        "tp resize requires logical repack (unpack+repack per rank)"
    )
    out_rows = []
    for row in rows:
        segs = row.reshape(reps, old_seg)[:, : spec.numel]
        pad = np.zeros((reps, new_seg - spec.numel), segs.dtype)
        out_rows.append(np.concatenate([segs, pad], axis=1).reshape(-1))
    out = np.stack(out_rows)
    return out if stacked else out[0]


def reshard_checkpoint(
    flat_state: Dict[str, np.ndarray],
    flat_specs: Dict[str, LeafSpec],
    old: ParallelConfig,
    new: ParallelConfig,
) -> Dict[str, np.ndarray]:
    """Reshard every packed leaf from the old mesh layout to the new one —
    the restart path for elastic scaling (e.g. 2 pods -> 1 pod)."""
    out = {}
    for k, v in flat_state.items():
        spec = flat_specs.get(k)
        if spec is None:  # opt step scalar etc.
            out[k] = v
        else:
            out[k] = repack_leaf(v, spec, old, new)
    return out
