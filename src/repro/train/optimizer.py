"""AdamW on packed parameter leaves (shard_map-local, elementwise).

Moments live in the same packed/sharded layout as the parameters (FSDP
shards optimizer state for free — ZeRO). ``moment_dtype`` is configurable:
the 1T-param config uses bf16 moments to fit 512 x 16 GB HBM (recorded in
DESIGN.md). A master fp32 copy is intentionally NOT kept: bf16 params +
fp32 (or bf16) moments with fp32 update arithmetic.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # pytree like params
    nu: Any


def init_opt_state(params, moment_dtype=jnp.float32, kind: str = "adamw") -> OptState:
    def z(p):
        return jnp.zeros(p.shape, moment_dtype)

    zn = (lambda p: jnp.zeros((1,), moment_dtype)) if kind == "momentum" else z
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(zn, params),
    )


def opt_state_shapes(param_shapes, moment_dtype=jnp.float32, kind: str = "adamw") -> OptState:
    def z(p):
        return jax.ShapeDtypeStruct(p.shape, moment_dtype)
    zn = (
        (lambda p: jax.ShapeDtypeStruct((1,), moment_dtype))
        if kind == "momentum"
        else z
    )
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(z, param_shapes),
        nu=jax.tree.map(zn, param_shapes),
    )


def lr_schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step.astype(jnp.float32) - tcfg.warmup_steps)
        / max(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_grad_norm(grads, psum_axes, *, local=False) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    if not local:
        sq = jax.lax.psum(sq, psum_axes)
    return jnp.sqrt(sq)


def adamw_update(
    params,
    grads,
    state: OptState,
    tcfg: TrainConfig,
    *,
    grad_norm: jax.Array,
    ok: jax.Array | None = None,
):
    """One AdamW step (elementwise on local shards).

    ``ok`` (scalar bool): when False the whole update is a no-op — the
    donation-safe in-graph form of "skip this step" used by the NaN /
    fault guard (buffers are donated, so a host-side rollback after the
    fact is impossible)."""
    ok_b = jnp.bool_(True) if ok is None else ok
    step = state.step + ok_b.astype(jnp.int32)
    lr = lr_schedule(tcfg, step)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (grad_norm + 1e-6))
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    kind = getattr(tcfg, "optimizer", "adamw")

    def _sel(new, old):
        # donation-safe skip: freeze params AND moments when !ok
        return new if ok is None else jnp.where(ok_b, new, old)

    def upd_row(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        if kind == "momentum":
            # Muon-style single-buffer momentum + decoupled weight decay
            m_new = b1 * m.astype(jnp.float32) + gf
            pf = p.astype(jnp.float32)
            pf = pf - lr * (m_new + wd * pf)
            return (_sel(pf.astype(p.dtype), p), _sel(m_new.astype(m.dtype), m), v)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + eps) + wd * pf)
        return (
            _sel(pf.astype(p.dtype), p),
            _sel(m_new.astype(m.dtype), m),
            _sel(v_new.astype(v.dtype), v),
        )

    def upd_mom(p, g, m, v):
        # v is a (1,) placeholder in momentum mode — not scanned
        if p.ndim == 2 and p.shape[0] > 1:
            def body(_, xs):
                pp, gg, mm = xs
                np_, nm, _ = upd_row(pp, gg, mm, v)
                return None, (np_, nm)

            _, (np_, nm) = jax.lax.scan(body, None, (p, g, m))
            return np_, nm, v
        return upd_row(p, g, m, v)

    def upd(p, g, m, v):
        if kind == "momentum":
            return upd_mom(p, g, m, v)
        # stacked (L, packed) leaves update via scan over L so the f32
        # update temporaries stay one-layer-sized (not whole-stack-sized)
        if p.ndim == 2 and p.shape[0] > 1:
            def body(_, xs):
                return None, upd_row(*xs)

            _, out = jax.lax.scan(body, None, (p, g, m, v))
            return out
        return upd_row(p, g, m, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out_p, out_m, out_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        out_p.append(np_)
        out_m.append(nm)
        out_v.append(nv)
    return (
        jax.tree.unflatten(tdef, out_p),
        OptState(step, jax.tree.unflatten(tdef, out_m), jax.tree.unflatten(tdef, out_v)),
        lr,
    )
