"""The training step: loss -> grad -> sync -> AdamW, all shard_map-local.

Gradient synchronization map (per parameter leaf):
  data axis   FSDP: automatic — the layer-body ring all-gather of packed
              shards transposes to a ring reduce-scatter of gradients
              (overlapped with the backward pass by XLA's scheduler).
              Non-FSDP: explicit psum.
  model axis  tp-sharded leaves: no sync needed (each rank's segment saw
              every token via the gathered activations); EXCEPT replicated
              KV groups (tp > kv_heads) -> subgroup psum.
              replicated leaves (norms, routers): psum.
  pod axis    params replicated across pods -> ring all-reduce; optionally
              int8-compressed with error feedback (dist/compress.py) since
              this is the slow link.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ParallelConfig, TrainConfig
from ..dist import compress
from ..models.common import DATA_AXIS, MODEL_AXIS, POD_AXIS
from ..models.params import LeafSpec
from .optimizer import OptState, adamw_update


def _walk(tree, spec_tree):
    """Yield (path, leaf, spec) for aligned pytrees."""
    if isinstance(tree, dict):
        for k in tree:
            yield from _walk(tree[k], spec_tree[k])
    else:
        yield tree, spec_tree


def sync_grads(
    grads,
    spec_tree,
    pcfg: ParallelConfig,
    ef_state=None,
):
    """Apply the gradient synchronization map. Returns (grads, new_ef)."""
    tp = pcfg.tp

    def leaf_sync(g, spec: LeafSpec):
        if not spec.tp_sharded and tp > 1:
            g = lax.psum(g, MODEL_AXIS)
        elif spec.tp_sharded and spec.replica_groups > 1 and tp > 1:
            rep = spec.replica_groups
            groups = [
                list(range(b * rep, (b + 1) * rep)) for b in range(tp // rep)
            ]
            g = lax.psum(g, MODEL_AXIS, axis_index_groups=groups)
        if not pcfg.fsdp and pcfg.dp > 1:
            g = lax.psum(g, DATA_AXIS)
        return g

    flat, tdef = jax.tree.flatten(grads)
    specs = [s for _, s in _walk(grads, spec_tree)]
    synced = [leaf_sync(g, s) for g, s in zip(flat, specs)]
    grads = jax.tree.unflatten(tdef, synced)

    new_ef = ef_state
    if pcfg.pods > 1 and pcfg.fsdp and pcfg.fsdp_pods:
        # pod-spanning FSDP: the param-gather transpose already
        # reduce-scattered gradients across pods — no pod sync needed.
        return grads, new_ef
    if pcfg.pods > 1:
        if pcfg.grad_compression == "int8" and ef_state is not None:
            flat_g, tdef2 = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(ef_state)
            out_g, out_e = [], []
            for g, e in zip(flat_g, flat_e):
                gg, ee = compress.pod_allreduce_int8(g, e, POD_AXIS)
                out_g.append(gg.astype(g.dtype))
                out_e.append(ee)
            grads = jax.tree.unflatten(tdef2, out_g)
            new_ef = jax.tree.unflatten(tdef2, out_e)
        else:
            grads = jax.tree.map(lambda g: lax.psum(g, POD_AXIS), grads)
    return grads, new_ef


class TrainStepOut(NamedTuple):
    loss: jax.Array
    grad_norm: jax.Array
    lr: jax.Array


def make_train_step(model, tcfg: TrainConfig, pcfg: ParallelConfig, spec_tree):
    """Returns train_step(params, opt_state, ef, batch) -> (params, opt,
    ef, metrics) — call inside shard_map."""

    def train_step(params, opt_state: OptState, ef, tokens, labels, extra=None):
        def loss_fn(p):
            return model.loss_local(p, tokens, labels, extra)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, ef_new = sync_grads(grads, spec_tree, pcfg, ef)
        # grad-norm psum axes: tp-sharded + (FSDP-)data-sharded segments are
        # disjoint across model+data ranks -> psum over both reconstructs
        # the true global norm. (Replicated leaves are double counted by at
        # most tp — acceptable for clipping; exact accounting would weight
        # per-leaf. We weight exactly below instead.)
        def _sqnorm(g):
            # scan stacked leaves over the layer dim so the f32 upcast is
            # one-layer-sized (CPU XLA materializes bf16->f32 converts)
            def row(x):
                return jnp.sum(jnp.square(x.astype(jnp.float32)))

            if g.ndim == 2 and g.shape[0] > 1:
                return jnp.sum(jax.lax.map(row, g))
            return row(g)

        flat, tdef = jax.tree.flatten(grads)
        specs = [s for _, s in _walk(grads, spec_tree)]
        sq = jnp.float32(0.0)
        for g, s in zip(flat, specs):
            contrib = _sqnorm(g)
            if not s.tp_sharded:
                contrib = contrib / pcfg.tp  # replicated across model ranks
            elif s.replica_groups > 1:
                contrib = contrib / s.replica_groups
            sq = sq + contrib
        axes = (MODEL_AXIS, DATA_AXIS) if pcfg.pods == 1 else (
            MODEL_AXIS, DATA_AXIS, POD_AXIS)
        if not pcfg.fsdp:
            # data ranks hold identical (already-synced) grads
            sq_scale = 1.0 / pcfg.dp / (pcfg.pods if pcfg.pods > 1 else 1)
        else:
            sq_scale = 1.0 / (pcfg.pods if pcfg.pods > 1 else 1)
        gnorm = jnp.sqrt(lax.psum(sq * sq_scale, axes))

        # in-graph fault/straggler guard: a non-finite loss or grad norm
        # freezes params AND optimizer state for this step (buffers are
        # donated, so a host-side rollback is impossible by design)
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        params, opt_state, lr = adamw_update(
            params, grads, opt_state, tcfg, grad_norm=gnorm, ok=ok
        )
        return params, opt_state, ef_new, TrainStepOut(loss, gnorm, lr)

    return train_step


def init_ef_state(params, pcfg: ParallelConfig):
    if pcfg.pods > 1 and pcfg.grad_compression == "int8":
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return None
