"""Deterministic synthetic token pipeline, host-sharded, double-buffered.

Production shape: every (step, global_row) cell is a pure function of the
seed — restart-reproducible (a restarted job regenerates the exact stream
from the checkpointed step) and host-shardable (each host materializes
only its addressable rows via ``jax.make_array_from_callback``).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _row_tokens(seed: int, step: int, row: int, seq_len: int, vocab: int) -> np.ndarray:
    """Deterministic pseudo-text: a per-row LCG over a skewed vocab (zipf-ish
    via squaring) — cheap, reproducible, non-degenerate for loss curves."""
    rng = np.random.Generator(np.random.Philox(key=np.uint64(seed),
                                               counter=[0, 0, step, row]))
    u = rng.random(seq_len + 1)
    toks = ((u * u) * (vocab - 1)).astype(np.int32)
    return toks


class SyntheticTokens:
    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, mesh=None, batch_sharding: Optional[P] = None):
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.gb = int(global_batch)
        self.seed = seed
        self.mesh = mesh
        self.spec = batch_sharding if batch_sharding is not None else P()

    def global_batch_np(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        toks = np.stack(
            [_row_tokens(self.seed, step, r, self.seq, self.vocab) for r in range(self.gb)]
        )
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def batch_at(self, step: int):
        """Device arrays for one step (sharded when a mesh is given)."""
        tokens, labels = self.global_batch_np(step)
        if self.mesh is None:
            return jnp.asarray(tokens), jnp.asarray(labels)
        sh = NamedSharding(self.mesh, self.spec)

        def put(arr):
            return jax.make_array_from_callback(
                arr.shape, sh, lambda idx: arr[idx]
            )

        return put(tokens), put(labels)

    def iterate(self, start_step: int = 0, prefetch: int = 2) -> Iterator:
        """Background-thread prefetching iterator (double buffering)."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put((step, self.batch_at(step)), timeout=1.0)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
