from .pipeline import SyntheticTokens
