"""JAX version compatibility layer.

The repro targets the current jax API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``pltpu.CompilerParams`` / ``pltpu.InterpretParams``); the pinned
container jax may predate some of it. This module backfills the missing
names with semantically equivalent aliases so the same source runs on
both. Installed once from ``repro.__init__`` (idempotent); tests and
examples get it transitively by importing any ``repro`` module before
touching the new names.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax

def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        sig = inspect.signature(jax.shard_map)
        if "check_vma" in sig.parameters:
            return
        inner = jax.shard_map
        accepts = set(sig.parameters)
    else:
        from jax.experimental.shard_map import shard_map as inner

        accepts = set(inspect.signature(inner).parameters)

    @functools.wraps(inner)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = True if check_vma is None else bool(check_vma)
        if "check_rep" in accepts:
            kwargs["check_rep"] = check_rep
        elif "check_vma" in accepts:
            kwargs["check_vma"] = check_rep
        return inner(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kwargs)

    jax.shard_map = shard_map


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    inner = jax.make_mesh

    @functools.wraps(inner)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-AxisType jax: every mesh axis is Auto
        return inner(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_axis_size() -> None:
    from jax import lax

    if hasattr(lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of a static 1 constant-folds to the (static) axis size.
        if isinstance(axis_name, (tuple, list)):
            n = 1
            for a in axis_name:
                n *= int(lax.psum(1, a))
            return n
        return int(lax.psum(1, axis_name))

    lax.axis_size = axis_size


def _install_pallas_tpu() -> None:
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:  # pallas not available at all: nothing to backfill
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams
    if not hasattr(pltpu, "InterpretParams"):
        # Older jax has no TPU-interpret parameter object; plain
        # interpret=True is the closest equivalent for pallas_call.
        def _interpret_params(**kwargs):
            return True

        pltpu.InterpretParams = _interpret_params


def install() -> None:
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_axis_size()
    _install_pallas_tpu()


install()
