"""repro — Triton-distributed (overlapping distributed kernels) on TPU in JAX."""
from . import _compat  # noqa: F401  (backfills jax API names; must be first)
