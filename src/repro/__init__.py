"""repro — Triton-distributed (overlapping distributed kernels) on TPU in JAX."""
