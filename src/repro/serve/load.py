"""Seeded synthetic request streams + the arrival-aware drive loop.

The generator is the serving benchmark's workload: Poisson arrivals at
``rate_rps`` with prompt lengths uniform over ``prompt_lens`` (the
mixed 128–2048-token regime of bench_serve) and seeded token ids, so
every run of a given (spec, vocab) pair replays the identical stream.
:func:`drive` releases requests by wall clock and steps any engine
implementing the shared protocol (``add / can_accept / step /
leftover`` — both serve.Engine and serve.PagedEngine), applying
backpressure when the engine's queue is full.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import numpy as np

from .engine import Request


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One synthetic stream: ``n_requests`` Poisson arrivals."""

    n_requests: int = 64
    rate_rps: float = 32.0              # mean arrival rate (requests/s)
    prompt_lens: Tuple[int, int] = (128, 2048)  # uniform inclusive range
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0


def generate(spec: LoadSpec, vocab_size: int) -> List[Tuple[float, Request]]:
    """[(arrival_time_s, Request)] sorted by arrival; fully seeded."""
    rng = np.random.RandomState(spec.seed)
    gaps = rng.exponential(1.0 / spec.rate_rps, size=spec.n_requests)
    arrive = np.cumsum(gaps)
    lo, hi = spec.prompt_lens
    lens = rng.randint(lo, hi + 1, size=spec.n_requests)
    out = []
    for t, n in zip(arrive, lens):
        prompt = rng.randint(1, vocab_size, size=int(n)).tolist()
        out.append((float(t), Request(prompt=prompt,
                                      max_new_tokens=spec.max_new_tokens,
                                      temperature=spec.temperature)))
    return out


def drive(engine, arrivals: List[Tuple[float, Request]], *,
          max_steps: int = 1_000_000, time_scale: float = 1.0):
    """Release ``arrivals`` by wall clock (arrival times multiplied by
    ``time_scale`` — 0 releases everything up front, the
    closed-loop/offline regime) and step the engine until all work
    drains or ``max_steps``. Returns the engine's leftover requests."""
    t0 = time.perf_counter()
    i, n = 0, len(arrivals)
    for _ in range(max_steps):
        now = time.perf_counter() - t0
        while (i < n and arrivals[i][0] * time_scale <= now
               and engine.can_accept()):
            engine.add(arrivals[i][1])
            i += 1
        if not engine.step():
            if i >= n:
                break  # drained: no queued, live, or future work
            # idle but arrivals remain: sleep until the next one lands
            time.sleep(min(0.002, max(0.0, arrivals[i][0] * time_scale - now)))
    return engine.leftover()
