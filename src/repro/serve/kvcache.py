"""Paged KV cache: block tables + free-list page allocation (host side).

Device side, each attention layer's KV lives in a PAGE POOL
``(num_pages, Hkv_loc, page_size, hd)`` instead of a dense per-slot
``(B, Hkv_loc, S_max, hd)`` buffer. A request's tokens map onto pool
pages through its BLOCK-TABLE row (``pages_per_slot`` page ids), so
requests of wildly different lengths pack densely and a freed slot's
pages simply return to the free list — the successor request gets a
fresh table row and the stale KV is unreachable by construction (no
slot-reuse leak).

Host side, :class:`PagedKVCache` is the allocator:

* **per-DP-shard free lists** — each data rank holds its own pool
  replica and serves its own batch slots, so page ids are local to the
  shard that owns the slot;
* **whole-request allocation at admission** (prompt + max_new tokens),
  so an admitted request can never stall mid-decode for pages;
* **scratch page 0** — reserved on every shard. Masked writes (idle
  batch lanes, prompt padding) are steered there and unallocated table
  entries point at it, so the device programs need no bounds branches;
  attention masks it out by length, and masked logits underflow to
  exact zeros, which is what makes slot isolation bit-exact.

This module is pure host Python/NumPy (no jax import) so the allocator
unit tests stay sub-millisecond.
"""
from __future__ import annotations

from typing import List

import numpy as np


class PagedKVCache:
    """Free-list page allocator + per-slot block tables.

    ``table`` is the (batch, pages_per_slot) int32 array handed to the
    device programs; ``lens`` tracks tokens currently cached per slot
    (the next write position).
    """

    def __init__(self, *, batch: int, max_len: int, page_size: int = 16,
                 num_pages: int = 0, dp_shards: int = 1):
        assert batch % dp_shards == 0, (batch, dp_shards)
        assert page_size > 0 and max_len > 0
        self.batch = batch
        self.max_len = max_len
        self.page_size = page_size
        self.dp_shards = dp_shards
        self.slots_per_shard = batch // dp_shards
        self.pages_per_slot = -(-max_len // page_size)  # ceil
        if num_pages <= 0:
            # dense-equivalent residency: every local slot can hold max_len
            num_pages = 1 + self.slots_per_shard * self.pages_per_slot
        if num_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_len={max_len} "
                f"request (+scratch); need >= {self.pages_per_slot + 1}")
        self.num_pages = num_pages
        # LIFO free stacks per shard; page 0 reserved as scratch
        self._free: List[List[int]] = [
            list(range(num_pages - 1, 0, -1)) for _ in range(dp_shards)
        ]
        self._slot_pages: List[List[int]] = [[] for _ in range(batch)]
        self.table = np.zeros((batch, self.pages_per_slot), np.int32)
        self.lens = np.zeros((batch,), np.int32)

    # ------------------------------------------------------------------
    def shard(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def free_pages(self, shard: int) -> int:
        return len(self._free[shard])

    def can_alloc(self, slot: int, n_tokens: int) -> bool:
        return (not self._slot_pages[slot]
                and self.pages_needed(n_tokens) <= self.free_pages(self.shard(slot)))

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Reserve pages for a request of ``n_tokens`` total (prompt +
        generation) in ``slot``. All-or-nothing; False if short on pages."""
        if not self.can_alloc(slot, n_tokens):
            return False
        need = self.pages_needed(n_tokens)
        free = self._free[self.shard(slot)]
        pages = [free.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        row = np.zeros((self.pages_per_slot,), np.int32)
        row[:need] = pages
        self.table[slot] = row
        self.lens[slot] = 0
        return True

    def free(self, slot: int) -> None:
        """Return the slot's pages to its shard's free list and zero the
        table row (successor requests can never reach the old KV)."""
        pages = self._slot_pages[slot]
        self._free[self.shard(slot)].extend(reversed(pages))
        self._slot_pages[slot] = []
        self.table[slot] = 0
        self.lens[slot] = 0

    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently allocated."""
        total = self.dp_shards * (self.num_pages - 1)
        free = sum(len(f) for f in self._free)
        return (total - free) / max(1, total)
