from .engine import Engine, Metrics, PagedEngine, Request  # noqa: F401
from .kvcache import PagedKVCache  # noqa: F401
from .load import LoadSpec, drive, generate  # noqa: F401
from .scheduler import Plan, Scheduler, ServeConfig  # noqa: F401
