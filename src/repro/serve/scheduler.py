"""Continuous-batching scheduler: mixed prefill+decode planning.

Each engine step the scheduler (1) ADMITS requests from the bounded
queue into free slots — with whole-request KV page allocation up front
(prompt + max_new_tokens), so an admitted request can never stall
mid-decode for pages; (2) PLANS one mixed batch under the
``token_budget`` knob: every decoding slot contributes one token, and
the remaining budget is filled with prefill chunks — at most one per DP
shard per step, because the chunked-prefill program runs one request
stream per data rank.

Everything is deterministic by construction (FIFO queue, lowest-fitting-
slot admission, lowest-slot-first prefill) so tests can pin
hand-computed schedules.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Tuple

from .kvcache import PagedKVCache


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the paged serving engine."""

    batch: int = 8          # decode slots (global batch of the decode program)
    max_len: int = 256      # per-request cap: prompt + generated tokens
    page_size: int = 16     # KV tokens per pool page
    num_pages: int = 0      # pool pages per DP shard; 0 = dense-equivalent
    chunk: int = 32         # prefill chunk length (multiple of tp)
    token_budget: int = 64  # decode tokens + prefill-chunk tokens per step
    queue_cap: int = 256    # bounded admission queue


@dataclasses.dataclass
class Slot:
    req: Optional[object] = None  # serve.engine.Request
    phase: str = "idle"           # idle | prefill | decode
    prompt_len: int = 0           # possibly clipped to fit max_len
    prompt_done: int = 0          # prompt tokens already prefilled
    gen_budget: int = 0           # output tokens this slot may produce
    last_token: int = 0           # decode input for the next step


@dataclasses.dataclass
class Plan:
    """One step's work: decode slot ids + prefill chunks (slot, start, n)
    — the prefill list holds at most one chunk per DP shard."""

    decode: List[int]
    prefill: List[Tuple[int, int, int]]


class Scheduler:
    def __init__(self, scfg: ServeConfig, kv: PagedKVCache, dp_shards: int = 1):
        assert scfg.batch % dp_shards == 0
        self.scfg = scfg
        self.kv = kv
        self.dp_shards = dp_shards
        self.slots_per_shard = scfg.batch // dp_shards
        self.queue: deque = deque()
        self.slots = [Slot() for _ in range(scfg.batch)]

    # ------------------------------------------------------------------
    def submit(self, req) -> bool:
        """Enqueue; False when the bounded queue is full (backpressure)."""
        if len(self.queue) >= self.scfg.queue_cap:
            return False
        self.queue.append(req)
        return True

    def queue_depth(self) -> int:
        return len(self.queue)

    def occupancy(self) -> float:
        return sum(s.phase != "idle" for s in self.slots) / self.scfg.batch

    def idle(self) -> bool:
        return not self.queue and all(s.phase == "idle" for s in self.slots)

    # ------------------------------------------------------------------
    def admit(self) -> List[int]:
        """FIFO admission into the lowest free slot whose shard has pages.
        Head-of-line blocking is deliberate: requests are never reordered,
        so scheduling stays deterministic and starvation-free."""
        admitted = []
        while self.queue:
            req = self.queue[0]
            prompt_len = min(len(req.prompt), self.scfg.max_len - 1)
            total = min(prompt_len + req.max_new_tokens, self.scfg.max_len)
            slot_id = None
            for i, s in enumerate(self.slots):
                if s.phase == "idle" and self.kv.can_alloc(i, total):
                    slot_id = i
                    break
            if slot_id is None:
                break
            self.queue.popleft()
            self.kv.alloc(slot_id, total)
            if prompt_len < len(req.prompt):
                req.truncated = True  # prompt clipped to fit the slot
            s = self.slots[slot_id]
            s.req = req
            s.phase = "prefill"
            s.prompt_len = prompt_len
            s.prompt_done = 0
            s.gen_budget = total - prompt_len
            s.last_token = 0
            admitted.append(slot_id)
        return admitted

    def plan(self) -> Plan:
        """Decode slots first (latency priority), then prefill chunks into
        the remaining token budget — at most one chunk per DP shard. One
        chunk always proceeds when nothing is decoding, so the engine
        never stalls on an over-tight budget."""
        decode = [i for i, s in enumerate(self.slots) if s.phase == "decode"]
        room = self.scfg.token_budget - len(decode)
        prefill: List[Tuple[int, int, int]] = []
        used_shards = set()
        for i, s in enumerate(self.slots):
            if s.phase != "prefill":
                continue
            shard = i // self.slots_per_shard
            if shard in used_shards:
                continue
            n = min(self.scfg.chunk, s.prompt_len - s.prompt_done)
            if n > room and (decode or prefill):
                continue
            prefill.append((i, s.prompt_done, n))
            used_shards.add(shard)
            room -= n
        return Plan(decode, prefill)

    # ------------------------------------------------------------------
    # notifications from the engine after it runs a planned step
    def note_chunk(self, slot_id: int, n: int) -> bool:
        """Record ``n`` prefilled prompt tokens; True when the prompt just
        completed (the chunk's logits carry the request's first token)."""
        s = self.slots[slot_id]
        s.prompt_done += n
        self.kv.lens[slot_id] += n
        if s.prompt_done >= s.prompt_len:
            s.phase = "decode"
            return True
        return False

    def note_decode(self, slot_id: int) -> None:
        self.kv.lens[slot_id] += 1

    def release(self, slot_id: int) -> None:
        self.kv.free(slot_id)
        self.slots[slot_id] = Slot()
