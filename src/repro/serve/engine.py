"""Serving engines over the shard_map'd SPMD programs.

Two engines share one protocol (``add / can_accept / step / run /
metrics``, see :func:`repro.serve.load.drive`):

* :class:`Engine` — the original slot loop: prompt tokens are fed
  one-by-one through the decode program against dense per-slot KV
  caches. Slots advance on INDEPENDENT per-slot lengths (a freed slot's
  successor starts at position 0, so stale KV is masked out exactly —
  no slot-reuse leak), and a slot that hits the cache capacity is
  finished with an explicit ``truncated`` flag instead of silently
  stranding the run.

* :class:`PagedEngine` — the production path: a block/paged KV cache
  (serve/kvcache.py), a dedicated chunked-prefill program that writes
  straight into the page pool, and continuous batching with mixed
  prefill+decode scheduling under a token budget (serve/scheduler.py).
  Prefill and decode are separate compiled programs and may carry
  separate overlap policies (prefill resolves ag_matmul/matmul_rs in
  the chunk projections; decode resolves flash_decode/a2a_ep).

Serving metrics: both engines keep the standard latency/occupancy
counters as they run — TTFT (arrival -> first generated token), TPOT
(mean seconds per output token after the first), queue depth and slot
occupancy sampled per step, prefill-vs-decode step split — reduced into
a :class:`Metrics` snapshot via ``metrics()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from .kvcache import PagedKVCache
from .scheduler import Scheduler, ServeConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False  # clipped by cache capacity, not eos/max_new
    # serving-metrics timestamps (time.perf_counter seconds)
    t_arrive: float = 0.0   # stamped by Engine.add
    t_first: float = 0.0    # first generated (non-prompt) token
    t_done: float = 0.0     # request completion


@dataclasses.dataclass(frozen=True)
class Metrics:
    """Aggregate serving metrics for one engine run."""

    requests_completed: int
    tokens_generated: int       # output tokens across completed + live
    steps: int                  # engine steps executed (prefill + decode)
    ttft_mean_s: float          # arrival -> first token, mean (completed)
    ttft_max_s: float
    tpot_mean_s: float          # per-output-token seconds after the first
    queue_depth_mean: float     # pending requests, sampled per step
    queue_depth_max: int
    slot_occupancy_mean: float  # occupied batch slots / batch, per step
    steps_prefill: int = 0      # chunked-prefill program calls
    steps_decode: int = 0       # decode program calls
    requests_truncated: int = 0  # finished by capacity, not eos/max_new

    def __str__(self) -> str:
        return (f"Metrics(completed={self.requests_completed} "
                f"tokens={self.tokens_generated} steps={self.steps} "
                f"(prefill {self.steps_prefill} decode {self.steps_decode}) "
                f"truncated={self.requests_truncated} "
                f"ttft={self.ttft_mean_s * 1e3:.1f}ms "
                f"(max {self.ttft_max_s * 1e3:.1f}ms) "
                f"tpot={self.tpot_mean_s * 1e3:.2f}ms "
                f"queue={self.queue_depth_mean:.2f} "
                f"(max {self.queue_depth_max}) "
                f"occupancy={self.slot_occupancy_mean:.2f})")


def _sample_row(rng, row: np.ndarray, temperature: float) -> int:
    if temperature <= 0:
        return int(np.argmax(row))
    p = np.exp((row - row.max()) / temperature)
    p /= p.sum()
    return int(rng.choice(len(row), p=p))


def _describe(policy, op: str) -> str:
    """'mode/backend[/xN]/wire' — the wire dtype is always explicit so
    the wire axis shows up in serve provenance."""
    r = policy.resolve(op)
    desc = f"{r.mode}/{r.backend}"
    if r.chunks > 1:
        desc += f"/x{r.chunks}"
    return desc + f"/{r.wire}"


class _EngineBase:
    """Shared bookkeeping: metrics accumulators + the run loop."""

    def _init_metrics(self, seed: int):
        self.rng = np.random.RandomState(seed)
        self._steps = 0
        self._steps_prefill = 0
        self._steps_decode = 0
        self._completed = 0
        self._truncated = 0
        self._tokens_completed = 0
        self._ttfts: List[float] = []
        self._tpots: List[float] = []
        self._queue_samples: List[int] = []
        self._occ_samples: List[float] = []

    def _finish(self, req: Request, now: float) -> None:
        req.done = True
        req.t_done = now
        self._completed += 1
        self._truncated += bool(req.truncated)
        self._tokens_completed += len(req.out_tokens)
        if req.t_first:
            self._ttfts.append(req.t_first - req.t_arrive)
            if len(req.out_tokens) > 1:
                self._tpots.append((req.t_done - req.t_first)
                                   / (len(req.out_tokens) - 1))

    def _live_requests(self) -> List[Request]:
        raise NotImplementedError

    def metrics(self) -> Metrics:
        """Snapshot of the run's serving metrics."""
        n_steps = max(1, self._steps)
        tokens = self._tokens_completed
        tokens += sum(len(r.out_tokens) for r in self._live_requests())
        return Metrics(
            requests_completed=self._completed,
            tokens_generated=tokens,
            steps=self._steps,
            ttft_mean_s=(sum(self._ttfts) / len(self._ttfts)
                         if self._ttfts else 0.0),
            ttft_max_s=max(self._ttfts, default=0.0),
            tpot_mean_s=(sum(self._tpots) / len(self._tpots)
                         if self._tpots else 0.0),
            queue_depth_mean=sum(self._queue_samples) / n_steps,
            queue_depth_max=max(self._queue_samples, default=0),
            slot_occupancy_mean=sum(self._occ_samples) / n_steps,
            steps_prefill=self._steps_prefill,
            steps_decode=self._steps_decode,
            requests_truncated=self._truncated,
        )

    def step(self) -> bool:
        raise NotImplementedError

    def leftover(self) -> List[Request]:
        return self._live_requests()

    def run(self, max_steps: int = 256):
        """Drive all requests to completion (or max_steps); returns the
        requests still live/pending when the step budget runs out."""
        for _ in range(max_steps):
            if not self.step():
                break
        return self.leftover()


class Engine(_EngineBase):
    """step_fn(params, caches, cache_len, token) -> (logits, new_caches)
    — the jit(shard_map(decode_step_local)) closure built by the
    launcher. ``cache_len`` is passed as per-slot (B,) lengths."""

    # decode-path ops whose effective overlap mode the engine reports
    OVERLAP_OPS = ("ag_matmul", "matmul_rs", "a2a_ep", "flash_decode")

    def __init__(
        self,
        step_fn: Callable,
        params,
        init_caches,
        batch: int,
        max_len: int,
        eos_id: int = -1,
        seed: int = 0,
        pcfg=None,  # ParallelConfig: per-op overlap-mode provenance
    ):
        self.step_fn = step_fn
        self.params = params
        self.caches = init_caches
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pcfg = pcfg
        self.requests: List[Optional[Request]] = [None] * batch
        self.pending: List[Request] = []
        self.slot_lens = np.zeros((batch,), np.int32)
        self._prompt_cursor = [0] * batch
        self._last = np.zeros((batch,), np.int32)
        self._init_metrics(seed)

    @property
    def cache_len(self) -> int:
        """Deepest slot position (display/compat; slots advance per-slot)."""
        return int(self.slot_lens.max())

    def overlap_modes(self) -> dict:
        """Effective per-op overlap lowering of the compiled decode step
        ('mode/backend[/xN]/wire', resolved through the policy + engine
        registry); {} when no pcfg given."""
        if self.pcfg is None:
            return {}
        return {op: _describe(self.pcfg.policy, op) for op in self.OVERLAP_OPS}

    def _live_requests(self) -> List[Request]:
        return list(self.pending) + [r for r in self.requests if r]

    # ------------------------------------------------------------------
    def add(self, req: Request) -> bool:
        req.t_arrive = time.perf_counter()
        self.pending.append(req)
        return True

    def can_accept(self) -> bool:
        return True  # unbounded pending list (PagedEngine bounds its queue)

    def _admit(self):
        for i in range(self.batch):
            if self.requests[i] is None and self.pending:
                self.requests[i] = self.pending.pop(0)
                self._prompt_cursor[i] = 0
                self.slot_lens[i] = 0  # fresh slot: stale KV is masked out

    def _next_tokens(self, last_sampled: np.ndarray) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            cur = self._prompt_cursor[i]
            if cur < len(req.prompt):
                toks[i, 0] = req.prompt[cur]
                self._prompt_cursor[i] = cur + 1
            else:
                toks[i, 0] = last_sampled[i]
        return toks

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        out = np.zeros((self.batch,), np.int32)
        for i, req in enumerate(self.requests):
            if req is not None:
                out[i] = _sample_row(self.rng, logits[i], req.temperature)
        return out

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One decode step over all occupied slots; False when idle."""
        self._admit()
        if all(r is None for r in self.requests) and not self.pending:
            return False
        self._queue_samples.append(len(self.pending))
        self._occ_samples.append(
            sum(r is not None for r in self.requests) / self.batch)
        toks = self._next_tokens(self._last)
        logits, self.caches = self.step_fn(
            self.params, self.caches, jnp.asarray(self.slot_lens),
            jnp.asarray(toks),
        )
        self._steps += 1
        self._steps_decode += 1
        logits = np.asarray(logits)
        now = time.perf_counter()
        self._last = self._sample(logits)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            self.slot_lens[i] += 1
            if self._prompt_cursor[i] >= len(req.prompt):
                if not req.out_tokens:
                    req.t_first = now
                req.out_tokens.append(int(self._last[i]))
                if (
                    len(req.out_tokens) >= req.max_new_tokens
                    or self._last[i] == self.eos_id
                ):
                    self._finish(req, now)
                    self.requests[i] = None
                    continue
            if self.slot_lens[i] >= self.max_len:
                # cache full mid-request: account for it explicitly
                # instead of silently stranding the slot
                req.truncated = True
                self._finish(req, now)
                self.requests[i] = None
        return True

    def run(self, max_steps: int = 256):
        return super().run(max_steps)


class PagedEngine(_EngineBase):
    """Continuous-batching engine over the paged KV pools.

    prefill_fn(params, pools, table_rows, starts, n_valids, tokens)
        -> (logits (n_streams, vocab), pools)
    decode_fn(params, pools, table, lengths, active, token)
        -> (logits (batch, vocab), pools)
    — the two jit(shard_map(...)) programs built by the launcher
    (launch/steps.py build_prefill_chunk_step / build_paged_decode_step).
    """

    # ops resolved by each phase's compiled program (context-parallel
    # prefill additionally resolves the placement-aware ring_attention)
    PHASE_OPS = {"prefill": ("ag_matmul", "matmul_rs"),
                 "decode": ("a2a_ep", "flash_decode")}

    def __init__(
        self,
        prefill_fn: Callable,
        decode_fn: Callable,
        params,
        init_pools,
        scfg: ServeConfig,
        *,
        dp_shards: int = 1,
        eos_id: int = -1,
        seed: int = 0,
        pcfg=None,          # decode-phase ParallelConfig (provenance)
        prefill_pcfg=None,  # prefill-phase ParallelConfig; defaults to pcfg
        prefill_cp: bool = False,
        cp_placement: str = "zigzag",
    ):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.params = params
        self.pools = init_pools
        self.scfg = scfg
        self.dp_shards = dp_shards
        self.eos_id = eos_id
        self.pcfg = pcfg
        self.prefill_pcfg = prefill_pcfg if prefill_pcfg is not None else pcfg
        self.prefill_cp = prefill_cp
        self.cp_placement = cp_placement
        if prefill_cp:
            self.PHASE_OPS = dict(self.PHASE_OPS)
            self.PHASE_OPS["prefill"] = (
                self.PHASE_OPS["prefill"] + ("ring_attention",))
        self.kv = PagedKVCache(
            batch=scfg.batch, max_len=scfg.max_len, page_size=scfg.page_size,
            num_pages=scfg.num_pages, dp_shards=dp_shards)
        self.sched = Scheduler(scfg, self.kv, dp_shards)
        self._init_metrics(seed)

    @property
    def cache_len(self) -> int:
        """Deepest slot fill (display/compat with the dense engine)."""
        return int(self.kv.lens.max())

    def overlap_modes(self) -> dict:
        """Per-PHASE overlap provenance: 'phase:op' ->
        'mode/backend[/xN]/wire' — prefill and decode are separate
        compiled programs and may resolve through separate policies."""
        if self.pcfg is None:
            return {}
        out = {}
        for phase, ops_ in self.PHASE_OPS.items():
            pcfg = self.prefill_pcfg if phase == "prefill" else self.pcfg
            for op in ops_:
                row = _describe(pcfg.policy, op)
                # the CP prefill's placement is a step-level knob (threaded
                # straight into the placed op, not via the policy) — report
                # it where the policy would have (contiguous stays implied)
                if (phase == "prefill" and op == "ring_attention"
                        and self.prefill_cp
                        and self.cp_placement != "contiguous"
                        and not row.endswith(("/zigzag", "/striped"))):
                    row += f"/{self.cp_placement}"
                out[f"{phase}:{op}"] = row
        return out

    def _live_requests(self) -> List[Request]:
        live = [s.req for s in self.sched.slots if s.req is not None]
        return list(self.sched.queue) + live

    # ------------------------------------------------------------------
    def add(self, req: Request) -> bool:
        """Submit to the bounded queue; False = backpressure (caller
        retries after draining)."""
        req.t_arrive = time.perf_counter()
        return self.sched.submit(req)

    def can_accept(self) -> bool:
        return self.sched.queue_depth() < self.scfg.queue_cap

    # ------------------------------------------------------------------
    def _emit(self, slot_id: int, tok: int, now: float) -> None:
        """Record one generated token for the slot's request; finish +
        release the slot on eos / max_new / capacity."""
        s = self.sched.slots[slot_id]
        req = s.req
        if not req.out_tokens:
            req.t_first = now
        req.out_tokens.append(tok)
        s.last_token = tok
        limit = min(req.max_new_tokens, s.gen_budget)
        if tok == self.eos_id or len(req.out_tokens) >= limit:
            if (tok != self.eos_id
                    and len(req.out_tokens) < req.max_new_tokens):
                req.truncated = True  # out of KV capacity, not finished
            self._finish(req, now)
            self.sched.release(slot_id)

    def _prefill_step(self, items) -> None:
        """Run one chunked-prefill program call covering <= 1 chunk per
        DP shard; a prompt-completing chunk's logits carry the request's
        FIRST generated token (TTFT stamps here, not at first decode)."""
        n_streams = self.dp_shards
        p = self.kv.pages_per_slot
        c = self.scfg.chunk
        table = np.zeros((n_streams, p), np.int32)
        starts = np.zeros((n_streams,), np.int32)
        nvalid = np.zeros((n_streams,), np.int32)
        toks = np.zeros((n_streams, c), np.int32)
        for slot_id, start, n in items:
            sh = self.kv.shard(slot_id)
            table[sh] = self.kv.table[slot_id]
            starts[sh] = start
            nvalid[sh] = n
            toks[sh, :n] = self.sched.slots[slot_id].req.prompt[start:start + n]
        logits, self.pools = self.prefill_fn(
            self.params, self.pools, jnp.asarray(table), jnp.asarray(starts),
            jnp.asarray(nvalid), jnp.asarray(toks))
        self._steps_prefill += 1
        logits = np.asarray(logits)
        now = time.perf_counter()
        for slot_id, start, n in items:
            s = self.sched.slots[slot_id]
            if self.sched.note_chunk(slot_id, n):
                tok = _sample_row(self.rng, logits[self.kv.shard(slot_id)],
                                  s.req.temperature)
                self._emit(slot_id, tok, now)

    def _decode_step(self, slot_ids) -> None:
        b = self.scfg.batch
        toks = np.zeros((b, 1), np.int32)
        active = np.zeros((b,), bool)
        for i in slot_ids:
            toks[i, 0] = self.sched.slots[i].last_token
            active[i] = True
        logits, self.pools = self.decode_fn(
            self.params, self.pools, jnp.asarray(self.kv.table),
            jnp.asarray(self.kv.lens), jnp.asarray(active),
            jnp.asarray(toks))
        self._steps_decode += 1
        logits = np.asarray(logits)
        now = time.perf_counter()
        for i in slot_ids:
            s = self.sched.slots[i]
            self.sched.note_decode(i)
            tok = _sample_row(self.rng, logits[i], s.req.temperature)
            self._emit(i, tok, now)

    def step(self) -> bool:
        """One scheduler iteration: admit, plan one mixed prefill+decode
        batch under the token budget, execute. False when idle."""
        self.sched.admit()
        if self.sched.idle():
            return False
        self._queue_samples.append(self.sched.queue_depth())
        self._occ_samples.append(self.sched.occupancy())
        plan = self.sched.plan()
        if plan.prefill:
            self._prefill_step(plan.prefill)
        if plan.decode:
            self._decode_step(plan.decode)
        self._steps += 1
        return True

    def run(self, max_steps: int = 10_000):
        return super().run(max_steps)
