"""Batched serving engine: slot-based continuous batching over the
shard_map'd decode step.

Production notes: the decode step is ONE compiled SPMD program for the
whole batch (slot occupancy handled by masking); prompt ingestion reuses
the decode program token-by-token (a dedicated chunked-prefill program is
the documented fast path — the dry-run's prefill_32k cell lowers it).

Serving metrics: the engine keeps the standard latency/occupancy
counters as it runs — TTFT (arrival -> first generated token), TPOT
(mean seconds per output token after the first), queue depth and slot
occupancy sampled per decode step — and reduces them into a
:class:`Metrics` snapshot via :meth:`Engine.metrics` (surfaced by
``examples/serve_lm.py`` and the launcher's serve path).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # serving-metrics timestamps (time.perf_counter seconds)
    t_arrive: float = 0.0   # stamped by Engine.add
    t_first: float = 0.0    # first generated (non-prompt) token
    t_done: float = 0.0     # request completion


@dataclasses.dataclass(frozen=True)
class Metrics:
    """Aggregate serving metrics for one engine run."""

    requests_completed: int
    tokens_generated: int       # output tokens across completed + live
    steps: int                  # decode steps executed
    ttft_mean_s: float          # arrival -> first token, mean (completed)
    ttft_max_s: float
    tpot_mean_s: float          # per-output-token seconds after the first
    queue_depth_mean: float     # pending requests, sampled per step
    queue_depth_max: int
    slot_occupancy_mean: float  # occupied batch slots / batch, per step

    def __str__(self) -> str:
        return (f"Metrics(completed={self.requests_completed} "
                f"tokens={self.tokens_generated} steps={self.steps} "
                f"ttft={self.ttft_mean_s * 1e3:.1f}ms "
                f"(max {self.ttft_max_s * 1e3:.1f}ms) "
                f"tpot={self.tpot_mean_s * 1e3:.2f}ms "
                f"queue={self.queue_depth_mean:.2f} "
                f"(max {self.queue_depth_max}) "
                f"occupancy={self.slot_occupancy_mean:.2f})")


class Engine:
    """step_fn(params, caches, cache_len, token) -> (logits, new_caches)
    — the jit(shard_map(decode_step_local)) closure built by the launcher."""

    # decode-path ops whose effective overlap mode the engine reports
    OVERLAP_OPS = ("ag_matmul", "matmul_rs", "a2a_ep", "flash_decode")

    def __init__(
        self,
        step_fn: Callable,
        params,
        init_caches,
        batch: int,
        max_len: int,
        eos_id: int = -1,
        seed: int = 0,
        pcfg=None,  # ParallelConfig: per-op overlap-mode provenance
    ):
        self.step_fn = step_fn
        self.params = params
        self.caches = init_caches
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pcfg = pcfg
        self.requests: List[Optional[Request]] = [None] * batch
        self.pending: List[Request] = []
        self.cache_len = 0
        self.rng = np.random.RandomState(seed)
        self._prompt_cursor = [0] * batch
        # metrics accumulators
        self._steps = 0
        self._completed = 0
        self._tokens_completed = 0
        self._ttfts: List[float] = []
        self._tpots: List[float] = []
        self._queue_samples: List[int] = []
        self._occ_samples: List[float] = []

    def overlap_modes(self) -> dict:
        """Effective per-op overlap lowering of the compiled decode step
        ('mode/backend[/xN]/wire', resolved through the policy + engine
        registry — the wire dtype is always explicit, so the PR-6 wire
        axis shows up in serve provenance); {} when no pcfg given."""
        if self.pcfg is None:
            return {}
        out = {}
        for op in self.OVERLAP_OPS:
            r = self.pcfg.policy.resolve(op)
            desc = f"{r.mode}/{r.backend}"
            if r.chunks > 1:
                desc += f"/x{r.chunks}"
            out[op] = desc + f"/{r.wire}"
        return out

    def metrics(self) -> Metrics:
        """Snapshot of the run's serving metrics."""
        n_steps = max(1, self._steps)
        tokens = sum(len(r.out_tokens) for r in self.requests if r)
        tokens += sum(len(r.out_tokens) for r in self.pending)
        tokens += self._tokens_completed
        return Metrics(
            requests_completed=self._completed,
            tokens_generated=tokens,
            steps=self._steps,
            ttft_mean_s=(sum(self._ttfts) / len(self._ttfts)
                         if self._ttfts else 0.0),
            ttft_max_s=max(self._ttfts, default=0.0),
            tpot_mean_s=(sum(self._tpots) / len(self._tpots)
                         if self._tpots else 0.0),
            queue_depth_mean=sum(self._queue_samples) / n_steps,
            queue_depth_max=max(self._queue_samples, default=0),
            slot_occupancy_mean=sum(self._occ_samples) / n_steps,
        )

    # ------------------------------------------------------------------
    def add(self, req: Request):
        req.t_arrive = time.perf_counter()
        self.pending.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.requests[i] is None and self.pending:
                self.requests[i] = self.pending.pop(0)
                self._prompt_cursor[i] = 0

    def _next_tokens(self, last_sampled: np.ndarray) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            cur = self._prompt_cursor[i]
            if cur < len(req.prompt):
                toks[i, 0] = req.prompt[cur]
                self._prompt_cursor[i] = cur + 1
            else:
                toks[i, 0] = last_sampled[i]
        return toks

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        out = np.zeros((self.batch,), np.int32)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            row = logits[i]
            if req.temperature <= 0:
                out[i] = int(np.argmax(row))
            else:
                p = np.exp((row - row.max()) / req.temperature)
                p /= p.sum()
                out[i] = int(self.rng.choice(len(row), p=p))
        return out

    def _finish(self, req: Request, now: float) -> None:
        req.done = True
        req.t_done = now
        self._completed += 1
        self._tokens_completed += len(req.out_tokens)
        if req.t_first:
            self._ttfts.append(req.t_first - req.t_arrive)
            if len(req.out_tokens) > 1:
                self._tpots.append((req.t_done - req.t_first)
                                   / (len(req.out_tokens) - 1))

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 256):
        """Drive all requests to completion (or max_steps)."""
        self._admit()
        last = np.zeros((self.batch,), np.int32)
        for _ in range(max_steps):
            if all(r is None for r in self.requests) and not self.pending:
                break
            self._queue_samples.append(len(self.pending))
            self._occ_samples.append(
                sum(r is not None for r in self.requests) / self.batch)
            toks = self._next_tokens(last)
            logits, self.caches = self.step_fn(
                self.params, self.caches, jnp.int32(self.cache_len),
                jnp.asarray(toks),
            )
            self.cache_len += 1
            self._steps += 1
            logits = np.asarray(logits)
            now = time.perf_counter()
            last = self._sample(logits)
            for i, req in enumerate(self.requests):
                if req is None:
                    continue
                if self._prompt_cursor[i] >= len(req.prompt):
                    if not req.out_tokens:
                        req.t_first = now
                    req.out_tokens.append(int(last[i]))
                    if (
                        len(req.out_tokens) >= req.max_new_tokens
                        or last[i] == self.eos_id
                    ):
                        self._finish(req, now)
                        self.requests[i] = None
            if self.cache_len >= self.max_len - 1:
                break
            self._admit()
        return [r for r in self.pending] + [r for r in self.requests if r]
