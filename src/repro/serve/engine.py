"""Batched serving engine: slot-based continuous batching over the
shard_map'd decode step.

Production notes: the decode step is ONE compiled SPMD program for the
whole batch (slot occupancy handled by masking); prompt ingestion reuses
the decode program token-by-token (a dedicated chunked-prefill program is
the documented fast path — the dry-run's prefill_32k cell lowers it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """step_fn(params, caches, cache_len, token) -> (logits, new_caches)
    — the jit(shard_map(decode_step_local)) closure built by the launcher."""

    # decode-path ops whose effective overlap mode the engine reports
    OVERLAP_OPS = ("ag_matmul", "matmul_rs", "a2a_ep", "flash_decode")

    def __init__(
        self,
        step_fn: Callable,
        params,
        init_caches,
        batch: int,
        max_len: int,
        eos_id: int = -1,
        seed: int = 0,
        pcfg=None,  # ParallelConfig: per-op overlap-mode provenance
    ):
        self.step_fn = step_fn
        self.params = params
        self.caches = init_caches
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pcfg = pcfg
        self.requests: List[Optional[Request]] = [None] * batch
        self.pending: List[Request] = []
        self.cache_len = 0
        self.rng = np.random.RandomState(seed)
        self._prompt_cursor = [0] * batch

    def overlap_modes(self) -> dict:
        """Effective per-op overlap lowering of the compiled decode step
        ('mode/backend', resolved through the policy + engine registry);
        {} when no pcfg given."""
        if self.pcfg is None:
            return {}
        return {op: self.pcfg.policy.describe(op) for op in self.OVERLAP_OPS}

    # ------------------------------------------------------------------
    def add(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.requests[i] is None and self.pending:
                self.requests[i] = self.pending.pop(0)
                self._prompt_cursor[i] = 0

    def _next_tokens(self, last_sampled: np.ndarray) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            cur = self._prompt_cursor[i]
            if cur < len(req.prompt):
                toks[i, 0] = req.prompt[cur]
                self._prompt_cursor[i] = cur + 1
            else:
                toks[i, 0] = last_sampled[i]
        return toks

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        out = np.zeros((self.batch,), np.int32)
        for i, req in enumerate(self.requests):
            if req is None:
                continue
            row = logits[i]
            if req.temperature <= 0:
                out[i] = int(np.argmax(row))
            else:
                p = np.exp((row - row.max()) / req.temperature)
                p /= p.sum()
                out[i] = int(self.rng.choice(len(row), p=p))
        return out

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 256):
        """Drive all requests to completion (or max_steps)."""
        self._admit()
        last = np.zeros((self.batch,), np.int32)
        for _ in range(max_steps):
            if all(r is None for r in self.requests) and not self.pending:
                break
            toks = self._next_tokens(last)
            logits, self.caches = self.step_fn(
                self.params, self.caches, jnp.int32(self.cache_len),
                jnp.asarray(toks),
            )
            self.cache_len += 1
            logits = np.asarray(logits)
            last = self._sample(logits)
            for i, req in enumerate(self.requests):
                if req is None:
                    continue
                if self._prompt_cursor[i] >= len(req.prompt):
                    req.out_tokens.append(int(last[i]))
                    if (
                        len(req.out_tokens) >= req.max_new_tokens
                        or last[i] == self.eos_id
                    ):
                        req.done = True
                        self.requests[i] = None
            if self.cache_len >= self.max_len - 1:
                break
            self._admit()
        return [r for r in self.pending] + [r for r in self.requests if r]
