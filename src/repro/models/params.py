"""Parameter layout: packed-flat leaves with uniform TP + FSDP sharding.

Every parameter leaf is stored as a PACKED 1-D (or (L, packed) for scanned
layer stacks) array:

    tp-sharded leaf:  concat over tp ranks of flatten(tp_local_tensor),
                      each rank's segment padded to a multiple of dp
                      -> PartitionSpec(("model", "data")) on the packed dim
    replicated leaf:  flatten(tensor) padded to dp multiple
                      -> PartitionSpec(("data",))  (replicated across tp)

Why: one uniform layout lets FSDP be *just a sharding spec*: inside
shard_map the layer body all-gathers its packed slice along "data" with the
overlapped ring collective (core.collective_matmul.all_gather_chunked) and
reshapes. Autodiff transposes that gather into the matching ring
reduce-scatter of gradients — ZeRO-3 with paper-style overlap for free.
Parameters are always replicated across the "pod" axis; gradient sync
adds a ring all-reduce over pods (hierarchical schedule).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ParallelConfig


@dataclass(frozen=True)
class TPInfo:
    """Head / width bookkeeping for tensor parallelism (incl. padding)."""

    tp: int
    hq_pad: int  # padded q heads (multiple of tp)
    hkv_pad: int  # padded/replicated kv heads (multiple of tp)
    hq_loc: int
    hkv_loc: int
    group: int  # q heads per kv head, per rank
    kv_rep: int  # tp ranks sharing one true kv head (grad sync groups)
    dff_loc: int
    vocab_loc: int
    # ssm
    di_loc: int = 0  # d_inner per rank
    nh_loc: int = 0  # ssd heads per rank
    # moe
    e_loc: int = 0  # experts per rank (EP mode)
    moe_mode: str = "none"  # "tp" | "ep" | "none"


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def tp_info(cfg: ModelConfig, pcfg: ParallelConfig) -> TPInfo:
    tp = pcfg.tp
    hq = max(cfg.num_heads, 1)
    hkv = max(cfg.num_kv_heads, 1)
    hq_pad = _ceil_to(hq, tp)
    if hkv >= tp:
        hkv_pad = _ceil_to(hkv, tp)
        kv_rep = 1
    else:
        assert tp % hkv == 0, f"tp={tp} must be a multiple of kv heads {hkv}"
        hkv_pad = tp
        kv_rep = tp // hkv
    hq_loc = hq_pad // tp
    hkv_loc = hkv_pad // tp
    assert hq_loc % hkv_loc == 0, (hq_loc, hkv_loc)
    dff_loc = _ceil_to(cfg.d_ff, tp) // tp if cfg.d_ff else 0
    vocab_loc = _ceil_to(cfg.vocab_size, tp) // tp

    di_loc = nh_loc = 0
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        nh = cfg.ssm_num_heads
        assert di % tp == 0 and nh % tp == 0, (di, nh, tp)
        di_loc, nh_loc = di // tp, nh // tp

    e_loc = 0
    moe_mode = "none"
    if cfg.family == "moe":
        if pcfg.expert_parallel and cfg.num_experts % tp == 0:
            moe_mode = "ep"
            e_loc = cfg.num_experts // tp
        else:
            moe_mode = "tp"  # all experts on every rank, d_ff sharded
            e_loc = cfg.num_experts
    return TPInfo(
        tp=tp,
        hq_pad=hq_pad,
        hkv_pad=hkv_pad,
        hq_loc=hq_loc,
        hkv_loc=hkv_loc,
        group=hq_loc // hkv_loc,
        kv_rep=kv_rep,
        dff_loc=dff_loc,
        vocab_loc=vocab_loc,
        di_loc=di_loc,
        nh_loc=nh_loc,
        e_loc=e_loc,
        moe_mode=moe_mode,
    )


# ---------------------------------------------------------------------------
# Leaf specs and the packed layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeafSpec:
    local_shape: Tuple[int, ...]  # TP-local logical shape
    tp_sharded: bool = True
    init: str = "normal"  # normal | zeros | ones | ssm_a | ssm_dt
    fan_in: Optional[int] = None  # for scaled normal init
    # >1: groups of adjacent tp ranks hold IDENTICAL values (e.g. replicated
    # KV heads when tp > num_kv_heads); init uses one key per group and the
    # gradient is psum'ed over the replica subgroup.
    replica_groups: int = 1

    @property
    def numel(self) -> int:
        return int(np.prod(self.local_shape))


def fsdp_world(pcfg: ParallelConfig) -> int:
    return pcfg.dp * (pcfg.pods if pcfg.fsdp_pods else 1)


def packed_width(spec: LeafSpec, pcfg: ParallelConfig) -> int:
    """Width of the packed global dim for one leaf."""
    seg = _ceil_to(spec.numel, fsdp_world(pcfg))  # pad for the FSDP axes
    return seg * (pcfg.tp if spec.tp_sharded else 1)


def leaf_pspec(spec: LeafSpec, stacked: bool, pcfg: ParallelConfig = None) -> P:
    fsdp_axes = ("data", "pod") if (pcfg is not None and pcfg.fsdp_pods) else ("data",)
    axes = (("model",) + fsdp_axes) if spec.tp_sharded else fsdp_axes
    return P(None, axes) if stacked else P(axes)


def _init_segment(key, spec: LeafSpec, dtype) -> jax.Array:
    n = spec.numel
    if spec.init == "zeros":
        seg = jnp.zeros((n,), dtype)
    elif spec.init == "ones":
        seg = jnp.ones((n,), dtype)
    elif spec.init == "ssm_a":
        # A_log: log of uniform [1, 16] -> A = -exp(A_log)
        seg = jnp.log(
            jax.random.uniform(key, (n,), jnp.float32, minval=1.0, maxval=16.0)
        ).astype(dtype)
    elif spec.init == "ssm_dt":
        # dt_bias: softplus^-1 of uniform [1e-3, 1e-1]
        dt = jax.random.uniform(key, (n,), jnp.float32, minval=1e-3, maxval=1e-1)
        seg = jnp.log(jnp.expm1(dt)).astype(dtype)
    else:
        fan = spec.fan_in or spec.local_shape[0]
        std = 1.0 / math.sqrt(max(fan, 1))
        seg = (jax.random.normal(key, (n,), jnp.float32) * std).astype(dtype)
    pad = _ceil_to(n, 1) - n
    del pad
    return seg


def init_leaf(
    key, spec: LeafSpec, pcfg: ParallelConfig, *, layers: int = 0, dtype=jnp.bfloat16
) -> jax.Array:
    """Build the packed GLOBAL leaf ((L, W) if ``layers`` else (W,))."""
    seg_w = _ceil_to(spec.numel, fsdp_world(pcfg))
    reps = pcfg.tp if spec.tp_sharded else 1
    n_layers = max(layers, 1)
    keys = jax.random.split(key, n_layers * reps).reshape(n_layers, reps, -1)
    rep = spec.replica_groups if spec.tp_sharded else 1
    rows = []
    for li in range(n_layers):
        segs = []
        for r in range(reps):
            kr = (r // rep) * rep if rep > 1 else r  # same key within a group
            seg = _init_segment(keys[li, kr].reshape(2), spec, dtype)
            segs.append(jnp.pad(seg, (0, seg_w - spec.numel)))
        rows.append(jnp.concatenate(segs))
    out = jnp.stack(rows)
    return out if layers else out[0]


def unpack(packed_local: jax.Array, spec: LeafSpec, dtype=None) -> jax.Array:
    """Inside shard_map: packed TP-local (and data-gathered) vector ->
    logical local tensor."""
    x = packed_local[: spec.numel].reshape(spec.local_shape)
    return x.astype(dtype) if dtype is not None else x


def build_params(
    tree: Dict[str, "LeafSpec | dict"],
    key,
    pcfg: ParallelConfig,
    *,
    layers: int = 0,
    dtype=jnp.bfloat16,
):
    """Initialize a (possibly nested) dict of LeafSpec -> packed leaves.
    Returns (params_pytree, pspec_pytree)."""
    params, pspecs = {}, {}
    names = sorted(tree.keys())
    keys = jax.random.split(key, len(names))
    for k, name in zip(keys, names):
        node = tree[name]
        if isinstance(node, dict):
            params[name], pspecs[name] = build_params(
                node, k, pcfg, layers=layers, dtype=dtype
            )
        else:
            params[name] = init_leaf(k, node, pcfg, layers=layers, dtype=dtype)
            pspecs[name] = leaf_pspec(node, stacked=layers > 0, pcfg=pcfg)
    return params, pspecs


def spec_tree_shapes(
    tree: Dict[str, "LeafSpec | dict"], pcfg: ParallelConfig, *, layers: int = 0,
    dtype=jnp.bfloat16,
):
    """ShapeDtypeStructs + pspecs for the packed params (dry-run path —
    no allocation)."""
    shapes, pspecs = {}, {}
    for name, node in tree.items():
        if isinstance(node, dict):
            shapes[name], pspecs[name] = spec_tree_shapes(
                node, pcfg, layers=layers, dtype=dtype
            )
        else:
            w = packed_width(node, pcfg)
            shape = (layers, w) if layers else (w,)
            shapes[name] = jax.ShapeDtypeStruct(shape, dtype)
            pspecs[name] = leaf_pspec(node, stacked=layers > 0, pcfg=pcfg)
    return shapes, pspecs
