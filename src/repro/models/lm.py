"""Decoder-LM assembly for dense / moe / ssm / hybrid / vlm families.

All forward functions are shard_map-LOCAL (tensors are per-device shards;
collectives are explicit). Layers are scanned in super-blocks (uniform
period) with configurable remat so 100-layer models compile to small HLO.

Layouts:
  train/prefill hidden: (B_loc, S_loc, D)  SP along "model"
  decode hidden:        (B_loc, 1, D)      replicated along "model"
  KV caches:  heads-sharded (B, Hkv_loc, S_max, hd)  [kv_shard="heads"]
              or sequence-sharded over "data" for the paper's distributed
              flash decode [kv_shard="sequence"]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig, ParallelConfig
from ..core import flash_decode as dfd
from ..core import schedules
from . import blocks
from .common import (
    DATA_AXIS,
    MODEL_AXIS,
    POD_AXIS,
    embed_lookup,
    embed_lookup_sp,
    fsdp_get,
    get_params,
    local_linear,
    psum_tp,
    rmsnorm,
    rope,
    sinusoidal_positions,
    vocab_parallel_logits,
    vocab_parallel_loss,
)
from .params import LeafSpec, build_params, spec_tree_shapes, tp_info

Array = jax.Array


def _stack_specs(specs: Dict[str, LeafSpec], n: int) -> Dict[str, LeafSpec]:
    """Give each leaf a leading (n,) dim (sub-layers inside a super-block)."""
    return {
        k: LeafSpec((n,) + s.local_shape, s.tp_sharded, s.init, s.fan_in,
                    s.replica_groups)
        for k, s in specs.items()
    }


def _index_params(p: dict, i: int) -> dict:
    return {k: v[i] for k, v in p.items()}


@dataclass
class LayerPlan:
    n_super: int  # scan length
    period: int  # layers per super-block
    kinds: Tuple[str, ...]


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    if cfg.family in ("dense", "moe"):
        return LayerPlan(cfg.num_layers, 1, ("attn_mlp",))
    if cfg.family == "ssm":
        return LayerPlan(cfg.num_layers, 1, ("ssm",))
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        assert cfg.num_layers % k == 0
        return LayerPlan(cfg.num_layers // k, k, ("ssm",) * k + ("shared_attn",))
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        assert cfg.num_layers % k == 0
        return LayerPlan(cfg.num_layers // k, k, ("self",) * (k - 1) + ("cross",))
    raise ValueError(cfg.family)


class LM:
    """Decoder LM (family in dense/moe/ssm/hybrid/vlm)."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig):
        self.cfg = cfg
        self.pcfg = pcfg
        self.info = tp_info(cfg, pcfg)
        self.plan = layer_plan(cfg)
        self._build_specs()

    # ------------------------------------------------------------------
    def _ffn_specs(self):
        cfg, info = self.cfg, self.info
        return (
            blocks.moe_specs(cfg, info)
            if cfg.family == "moe"
            else blocks.mlp_specs(cfg, info)
        )

    def _build_specs(self):
        cfg, info = self.cfg, self.info
        plan = self.plan
        layer: Dict[str, Dict[str, LeafSpec]] = {}
        if cfg.family in ("dense", "moe"):
            layer["attn"] = blocks.attention_specs(cfg, info)
            layer["ffn"] = self._ffn_specs()
        elif cfg.family == "ssm":
            layer["ssm"] = _stack_specs(blocks.ssm_specs(cfg, info), 1)
        elif cfg.family == "hybrid":
            layer["ssm"] = _stack_specs(blocks.ssm_specs(cfg, info), plan.period)
        elif cfg.family == "vlm":
            k = plan.period
            layer["attn"] = _stack_specs(blocks.attention_specs(cfg, info), k - 1)
            layer["cross"] = blocks.attention_specs(cfg, info, cross=True)
            layer["ffn"] = _stack_specs(blocks.mlp_specs(cfg, info), k)
        self.layer_specs = layer

        top: Dict[str, Any] = {
            "embed": LeafSpec((info.vocab_loc, cfg.d_model), fan_in=cfg.d_model),
            "ln_f": LeafSpec((cfg.d_model,), tp_sharded=False, init="ones"),
        }
        if not cfg.tie_embeddings:
            top["unembed"] = LeafSpec((info.vocab_loc, cfg.d_model), fan_in=cfg.d_model)
        if cfg.family == "hybrid":
            top["shared_attn"] = blocks.attention_specs(cfg, info)
            top["shared_mlp"] = blocks.mlp_specs(cfg, info)
        if cfg.family == "vlm":
            top["vision_proj"] = LeafSpec(
                (cfg.vision_dim, cfg.d_model), tp_sharded=False, fan_in=cfg.vision_dim
            )
        self.top_specs = top

    def init(self, key, dtype=jnp.bfloat16):
        k1, k2 = jax.random.split(key)
        top, top_sp = build_params(self.top_specs, k1, self.pcfg, dtype=dtype)
        lay, lay_sp = build_params(
            self.layer_specs, k2, self.pcfg, layers=self.plan.n_super, dtype=dtype
        )
        return {"top": top, "layers": lay}, {"top": top_sp, "layers": lay_sp}

    def param_shapes(self, dtype=jnp.bfloat16):
        top, top_sp = spec_tree_shapes(self.top_specs, self.pcfg, dtype=dtype)
        lay, lay_sp = spec_tree_shapes(
            self.layer_specs, self.pcfg, layers=self.plan.n_super, dtype=dtype
        )
        return {"top": top, "layers": lay}, {"top": top_sp, "layers": lay_sp}

    # ------------------------------------------------------------------
    def _unpack_layer(self, p_layer: dict) -> dict:
        """Packed per-super-block leaves -> logical tensors (FSDP gather)."""
        return {
            grp: get_params(p_layer[grp], self.layer_specs[grp], self.pcfg)
            for grp in self.layer_specs
        }

    def _unpack_top(self, params: dict, *names) -> dict:
        return {
            n: get_params(params["top"][n], self.top_specs[n], self.pcfg)
            for n in names
            if n in params["top"]
        }

    def _ckpt(self, fn):
        """remat="nested": additionally checkpoint each sub-block so the
        backward live-set is one sub-block's internals, not a whole
        super-block's (2-level remat for the 90B/1T-class models)."""
        return jax.checkpoint(fn) if self.pcfg.remat == "nested" else fn

    def _super_block_train(self, pl: dict, h: Array, shared: dict,
                           cross_src: Optional[Array]):
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        attn = self._ckpt(
            lambda p_, h_: blocks.attention_train(cfg, pcfg, info, p_, h_)
        )
        mlp = self._ckpt(lambda p_, h_: blocks.mlp_train(cfg, pcfg, info, p_, h_))
        moe = self._ckpt(lambda p_, h_: blocks.moe_train(cfg, pcfg, info, p_, h_))
        ssm = self._ckpt(lambda p_, h_: blocks.ssm_train(cfg, pcfg, info, p_, h_))
        cross = self._ckpt(
            lambda p_, h_, src: blocks.attention_train(
                cfg, pcfg, info, p_, h_, cross_src=src
            )
        )
        if cfg.family in ("dense", "moe"):
            if cfg.family == "dense" and blocks.boundary_fused(pcfg):
                # policy turned the attention->MLP seam into the fused
                # rs->ag boundary op — route the pair as one unit
                pair = self._ckpt(
                    lambda pa, pf, h_: blocks.attn_mlp_train(
                        cfg, pcfg, info, pa, pf, h_))
                h = pair(pl["attn"], pl["ffn"], h)
            else:
                h = attn(pl["attn"], h)
                h = moe(pl["ffn"], h) if cfg.family == "moe" else mlp(pl["ffn"], h)
        elif cfg.family == "ssm":
            h = ssm(_index_params(pl["ssm"], 0), h)
        elif cfg.family == "hybrid":
            for i in range(self.plan.period):
                h = ssm(_index_params(pl["ssm"], i), h)
            h = attn(shared["shared_attn"], h)
            h = mlp(shared["shared_mlp"], h)
        elif cfg.family == "vlm":
            k = self.plan.period
            for i in range(k - 1):
                h = attn(_index_params(pl["attn"], i), h)
                h = mlp(_index_params(pl["ffn"], i), h)
            h = cross(pl["cross"], h, cross_src)
            h = mlp(_index_params(pl["ffn"], k - 1), h)
        return h

    def _remat(self, fn):
        if self.pcfg.remat == "none":
            return fn
        if self.pcfg.remat == "dots":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
        if self.pcfg.remat == "block_save_ag":
            # keep AG+GEMM products across backward: no recompute of the
            # gather rings (-1/3 collective volume, +activation memory)
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.save_only_these_names("ag_out")
            )
        return jax.checkpoint(fn)  # "block" and the outer level of "nested"

    def _backbone_train(self, params: dict, h: Array, cross_src: Optional[Array]):
        shared = self._unpack_top(params, "shared_attn", "shared_mlp")

        def body(carry, xs):
            pl = self._unpack_layer(xs)
            return self._super_block_train(pl, carry, shared, cross_src), None

        body = self._remat(body)
        h, _ = lax.scan(body, h, params["layers"])
        return h

    # ------------------------------------------------------------------
    def loss_local(
        self,
        params: dict,
        tokens: Array,  # (B_loc, S) int32
        labels: Array,  # (B_loc, S) int32, -1 = pad
        extra: Optional[dict] = None,  # e.g. {"vision": (B, Tv, D_vis)}
    ) -> Array:
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        b, s = tokens.shape
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        lbl_sp = lax.dynamic_slice(labels, (0, me * s_loc), (b, s_loc))

        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup_sp(tokens, embed, info, tp)
        if not cfg.use_rope:
            pos = me * s_loc + jnp.arange(s_loc)
            h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

        cross_src = None
        if cfg.family == "vlm":
            vis = extra["vision"]  # (B, Tv, D_vis)
            wproj = fsdp_get(
                params["top"]["vision_proj"], self.top_specs["vision_proj"], pcfg, cdt
            )
            cross_src = local_linear(
                vis.reshape(-1, vis.shape[-1]).astype(cdt), wproj
            ).reshape(vis.shape[0], vis.shape[1], cfg.d_model)

        h = self._backbone_train(params, h, cross_src)

        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        h = rmsnorm(h, ln_f, cfg.norm_eps).reshape(b * s_loc, cfg.d_model)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg, h.dtype).T
        loss_sum, count = vocab_parallel_loss(
            h, w_out, lbl_sp.reshape(-1), info, cfg.vocab_size
        )
        axes = (
            (MODEL_AXIS, DATA_AXIS)
            if pcfg.pods == 1
            else (MODEL_AXIS, DATA_AXIS, "pod")
        )
        total = lax.psum(loss_sum, axes)
        n = lax.psum(count, axes)
        return total / jnp.maximum(n, 1.0)

    def prefill_logits_local(
        self, params: dict, tokens: Array, extra: Optional[dict] = None
    ) -> Array:
        """Forward-only inference prefill: last-token logits (B, vocab)."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        b, s = tokens.shape
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup_sp(tokens, embed, info, tp)
        if not cfg.use_rope:
            pos = me * s_loc + jnp.arange(s_loc)
            h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)
        cross_src = None
        if cfg.family == "vlm":
            vis = extra["vision"]
            wproj = fsdp_get(
                params["top"]["vision_proj"], self.top_specs["vision_proj"], pcfg, cdt
            )
            cross_src = local_linear(
                vis.reshape(-1, vis.shape[-1]).astype(cdt), wproj
            ).reshape(vis.shape[0], vis.shape[1], cfg.d_model)
        h = self._backbone_train(params, h, cross_src)
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        # the TRUE last token lives on the last model rank's SP window;
        # vocab_parallel_logits column-gathers per-rank partials, so its
        # input must be TP-replicated — replicate that row FIRST (a
        # post-hoc mask of the gathered logits cannot unmix the columns
        # the other ranks contributed from their own windows)
        keep = (me == tp - 1).astype(h.dtype)
        h_last = lax.psum(h[:, -1, :] * keep, MODEL_AXIS)
        h_last = rmsnorm(h_last, ln_f, cfg.norm_eps)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg,
                         h.dtype).T
        return vocab_parallel_logits(h_last, w_out, info, cfg.vocab_size)

    def prefill_with_cache_local(
        self,
        params: dict,
        tokens: Array,  # (B_loc, S) int32
        s_max: int,  # KV cache capacity (>= S)
        extra: Optional[dict] = None,
    ) -> Tuple[Array, dict]:
        """Batched chunked-prefill: one forward pass that BOTH computes the
        last-token logits and materializes the decode KV caches — the
        serving fast path (vs. token-by-token prompt ingestion). Dense/MoE
        families, heads-sharded KV."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        assert cfg.family in ("dense", "moe"), cfg.family
        assert not self._kv_seq_sharded(), "prefill cache path is heads-sharded"
        b, s = tokens.shape
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup_sp(tokens, embed, info, tp)

        def body(carry, xs):
            pl = self._unpack_layer(xs)
            hh, (k, v) = blocks.attention_train(
                cfg, pcfg, info, pl["attn"], carry, return_kv=True
            )
            if cfg.family == "moe":
                hh = blocks.moe_train(cfg, pcfg, info, pl["ffn"], hh)
            else:
                hh = blocks.mlp_train(cfg, pcfg, info, pl["ffn"], hh)
            pad = s_max - k.shape[2]
            kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            return hh, {"attn": {"k": kc, "v": vc}}

        h, caches = lax.scan(self._remat(body), h, params["layers"])
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        # replicate the last rank's final row over TP before the
        # vocab-parallel projection (see prefill_logits_local)
        keep = (me == tp - 1).astype(h.dtype)
        h_last = lax.psum(h[:, -1, :] * keep, MODEL_AXIS)
        h_last = rmsnorm(h_last, ln_f, cfg.norm_eps)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg,
                         h.dtype).T
        return vocab_parallel_logits(h_last, w_out, info, cfg.vocab_size), caches

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def _kv_seq_sharded(self) -> bool:
        return self.pcfg.kv_shard == "sequence"

    def cache_shapes(self, batch_local: int, s_max: int, dtype=jnp.bfloat16):
        """ShapeDtypeStructs for decode state, stacked over n_super."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        n = self.plan.n_super
        hd = cfg.head_dim
        s_kv = s_max // pcfg.dp if self._kv_seq_sharded() else s_max

        def kv(n_sub=None):
            shape = (batch_local, info.hkv_loc, s_kv, hd)
            if n_sub is not None:
                shape = (n_sub,) + shape
            return {
                "k": jax.ShapeDtypeStruct((n,) + shape, dtype),
                "v": jax.ShapeDtypeStruct((n,) + shape, dtype),
            }

        def ssm_state(n_sub):
            conv_ch = info.di_loc + 2 * cfg.ssm_num_groups * cfg.ssm_state
            return {
                "conv": jax.ShapeDtypeStruct(
                    (n, n_sub, batch_local, cfg.ssm_conv_width - 1, conv_ch), dtype
                ),
                "ssd": jax.ShapeDtypeStruct(
                    (n, n_sub, batch_local, info.nh_loc, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32,
                ),
            }

        fam = cfg.family
        if fam in ("dense", "moe"):
            return {"attn": kv()}
        if fam == "ssm":
            return {"ssm": ssm_state(1)}
        if fam == "hybrid":
            return {"ssm": ssm_state(self.plan.period), "attn": kv()}
        if fam == "vlm":
            k = self.plan.period
            tv = cfg.vision_tokens
            return {
                "attn": kv(k - 1),
                "cross_k": jax.ShapeDtypeStruct(
                    (n, batch_local, info.hkv_loc, tv, hd), dtype
                ),
                "cross_v": jax.ShapeDtypeStruct(
                    (n, batch_local, info.hkv_loc, tv, hd), dtype
                ),
            }
        raise ValueError(fam)

    def paged_cache_shapes(self, num_pages: int, page_size: int,
                           dtype=jnp.bfloat16):
        """ShapeDtypeStructs for the paged decode pools (dense/moe,
        heads-sharded KV), stacked over n_super like cache_shapes."""
        cfg, info = self.cfg, self.info
        assert cfg.family in ("dense", "moe"), cfg.family
        assert not self._kv_seq_sharded(), "paged KV is heads-sharded"
        n = self.plan.n_super
        shape = (n, num_pages, info.hkv_loc, page_size, cfg.head_dim)
        return {"attn": {"k": jax.ShapeDtypeStruct(shape, dtype),
                         "v": jax.ShapeDtypeStruct(shape, dtype)}}

    def decode_step_paged_local(
        self,
        params: dict,
        pools: dict,     # paged_cache_shapes tree
        table: Array,    # (B_loc, P) int32 page ids
        lengths: Array,  # (B_loc,) tokens already cached per slot
        active: Array,   # (B_loc,) bool — idle lanes write to scratch
        token: Array,    # (B_loc, 1) int32
    ) -> Tuple[Array, dict]:
        """One decode step against the paged KV pools (serve/kvcache.py).
        Inactive lanes produce garbage logits and scratch-page writes;
        the engine ignores both."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        assert cfg.family in ("dense", "moe"), cfg.family
        b = token.shape[0]
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup(token, embed, info)  # (B, 1, D)
        if not cfg.use_rope:
            h = h + sinusoidal_positions(
                lengths, cfg.d_model)[:, None, :].astype(h.dtype)

        def body(carry, xs):
            p_layer, pk, pv = xs
            pl = self._unpack_layer(p_layer)
            hh, pk, pv = blocks.attention_decode_paged(
                cfg, pcfg, info, pl["attn"], carry, pk, pv, table, lengths,
                active)
            if cfg.family == "moe":
                hh = blocks.moe_decode(cfg, pcfg, info, pl["ffn"], hh)
            else:
                hh = blocks.mlp_decode(cfg, pcfg, info, pl["ffn"], hh)
            return hh, (pk, pv)

        h, (pk, pv) = lax.scan(
            body, h, (params["layers"], pools["attn"]["k"], pools["attn"]["v"]))
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        h = rmsnorm(h, ln_f, cfg.norm_eps).reshape(b, cfg.d_model)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg,
                         h.dtype).T
        logits = vocab_parallel_logits(h, w_out, info, cfg.vocab_size)
        return logits, {"attn": {"k": pk, "v": pv}}

    def prefill_chunk_local(
        self,
        params: dict,
        pools: dict,       # paged_cache_shapes tree
        table_row: Array,  # (1, P) int32 — ONE request's block table
        start: Array,      # (1,) int32 absolute position of the chunk
        n_valid: Array,    # (1,) int32 real tokens in the chunk (0 = idle)
        tokens: Array,     # (1, C) int32 chunk tokens, right-padded
    ) -> Tuple[Array, dict]:
        """Chunked prefill: C prompt tokens of ONE request (per data
        shard) in a single SP forward, K/V written into the paged pools,
        last-valid-token logits out — the serving fast path vs
        token-by-token decode ingestion. The leading dim is the local
        slice of the per-data-shard request stream (always 1)."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        assert cfg.family in ("dense", "moe"), cfg.family
        assert not self._kv_seq_sharded(), "chunked prefill is heads-sharded"
        row = table_row[0]
        start = start[0]
        n_valid = n_valid[0]
        b, s = tokens.shape  # (1, C)
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup_sp(tokens, embed, info, tp)
        if not cfg.use_rope:
            pos = start + me * s_loc + jnp.arange(s_loc)
            h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

        def body(carry, xs):
            p_layer, pk, pv = xs
            pl = self._unpack_layer(p_layer)
            hh, pk, pv = blocks.attention_prefill_chunk(
                cfg, pcfg, info, pl["attn"], carry, pk, pv, row, start, n_valid)
            if cfg.family == "moe":
                hh = blocks.moe_train(cfg, pcfg, info, pl["ffn"], hh)
            else:
                hh = blocks.mlp_train(cfg, pcfg, info, pl["ffn"], hh)
            return hh, (pk, pv)

        h, (pk, pv) = lax.scan(
            self._remat(body), h,
            (params["layers"], pools["attn"]["k"], pools["attn"]["v"]))
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        # logits of the LAST VALID chunk token (the next-token logits when
        # this is the prompt's final chunk); it lives on model rank
        # idx // s_loc — replicate that row over TP before the
        # vocab-parallel projection (see prefill_logits_local)
        idx = jnp.maximum(n_valid - 1, 0)
        local_idx = jnp.clip(idx - me * s_loc, 0, s_loc - 1)
        h_sel = lax.dynamic_slice(h, (0, local_idx, 0), (b, 1, cfg.d_model))[:, 0]
        keep = (me == idx // s_loc).astype(h.dtype)
        h_last = lax.psum(h_sel * keep, MODEL_AXIS)
        h_last = rmsnorm(h_last, ln_f, cfg.norm_eps)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg,
                         h.dtype).T
        logits = vocab_parallel_logits(h_last, w_out, info, cfg.vocab_size)
        return logits, {"attn": {"k": pk, "v": pv}}

    def prefill_chunk_cp_local(
        self,
        params: dict,
        pools: dict,       # paged_cache_shapes tree
        table_row: Array,  # (1, P) int32 — ONE request's block table
        start: Array,      # (1,) int32 absolute position of the chunk
        n_valid: Array,    # (1,) int32 real tokens in the chunk (0 = idle)
        tokens: Array,     # (1, C) int32 chunk tokens, right-padded, replicated
        *,
        placement: str = "zigzag",
        cp_attend: str = "ring",
    ) -> Tuple[Array, dict]:
        """Context-parallel chunked prefill: ONE request's C-token chunk
        sharded over the DATA axis by the balanced placement map — every
        data shard owns C/dp position-ordered chunk rows (zigzag: one
        early + one late half-chunk, equalizing causal attention work)
        and runs the SP/TP projections on its rows only; chunk K/V
        merges into the paged pools via the same scatter-by-table write
        on every rank (pool replicas stay bitwise equal to the dense
        path). All inputs are replicated (the whole mesh cooperates on
        one stream instead of one stream per data shard)."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        assert cfg.family in ("dense", "moe"), cfg.family
        assert not self._kv_seq_sharded(), "chunked prefill is heads-sharded"
        assert pcfg.pods == 1, "cp prefill shards the chunk over 'data' only"
        row = table_row[0]
        start = start[0]
        n_valid = n_valid[0]
        b, c = tokens.shape  # (1, C)
        tp = pcfg.tp
        cp = pcfg.dp
        assert c % (cp * tp) == 0, (c, cp, tp)
        s_cp = c // cp
        s_loc = s_cp // tp
        if placement == "zigzag" and s_cp % 2:
            placement = "contiguous"
        # static owner maps: chunk row <-> (cp rank, local slot)
        rows_np = np.stack([schedules.placement_rows(placement, cp, r, s_cp)
                            for r in range(cp)])
        table = jnp.asarray(rows_np, jnp.int32)
        inv_perm = jnp.asarray(np.argsort(rows_np.reshape(-1), kind="stable"),
                               jnp.int32)  # rank-major gather -> position order
        ci = lax.axis_index(DATA_AXIS)
        me = lax.axis_index(MODEL_AXIS)
        rows_own = table[ci]  # (C/cp,) global chunk-row indices
        toks_own = jnp.take(tokens, rows_own, axis=1)  # (1, C/cp)
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup_sp(toks_own, embed, info, tp)  # (1, C/(cp*tp), D)
        if not cfg.use_rope:
            pos_loc = start + lax.dynamic_slice(rows_own, (me * s_loc,), (s_loc,))
            h = h + sinusoidal_positions(pos_loc, cfg.d_model)[None].astype(h.dtype)

        def body(carry, xs):
            p_layer, pk, pv = xs
            pl = self._unpack_layer(p_layer)
            hh, pk, pv = blocks.attention_prefill_chunk_cp(
                cfg, pcfg, info, pl["attn"], carry, pk, pv, row, start,
                n_valid, rows_own, inv_perm, placement=placement,
                cp_attend=cp_attend)
            if cfg.family == "moe":
                hh = blocks.moe_train(cfg, pcfg, info, pl["ffn"], hh)
            else:
                hh = blocks.mlp_train(cfg, pcfg, info, pl["ffn"], hh)
            return hh, (pk, pv)

        h, (pk, pv) = lax.scan(
            self._remat(body), h,
            (params["layers"], pools["attn"]["k"], pools["attn"]["v"]))
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        # last-valid-token logits: the row lives on exactly one (cp, tp)
        # rank under the placement map — one-hot select, then replicate
        # over BOTH axes (adding exact zeros keeps it bit-equal to the
        # dense path's model-axis psum)
        idx = jnp.maximum(n_valid - 1, 0)
        loc_rows = lax.dynamic_slice(rows_own, (me * s_loc,), (s_loc,))
        keep = (loc_rows == idx).astype(h.dtype)  # (s_loc,)
        h_sel = jnp.sum(h * keep[None, :, None], axis=1)  # (1, D)
        h_last = lax.psum(h_sel, (DATA_AXIS, MODEL_AXIS))
        h_last = rmsnorm(h_last, ln_f, cfg.norm_eps)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg,
                         h.dtype).T
        logits = vocab_parallel_logits(h_last, w_out, info, cfg.vocab_size)
        return logits, {"attn": {"k": pk, "v": pv}}

    def decode_step_local(
        self,
        params: dict,
        caches: dict,
        cache_len: Array,  # scalar int32, or per-slot (B_loc,) int32
        token: Array,  # (B_loc, 1) int32
    ) -> Tuple[Array, dict]:
        """One decode step. Returns (logits (B_loc, vocab), new caches).

        ``cache_len`` may be per-slot so continuously batched slots
        advance independently (scalar = all slots in lockstep; the
        sequence-sharded distributed-flash-decode path is scalar-only).
        A per-slot vector arrives REPLICATED at the global batch size
        (its in_spec is shared with the scalar form) — each data shard
        slices its own (B_loc,) window here."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        b = token.shape[0]
        if jnp.ndim(cache_len) == 1 and cache_len.shape[0] != b:
            shard = lax.axis_index(DATA_AXIS)
            if pcfg.pods > 1:
                shard = lax.axis_index(POD_AXIS) * pcfg.dp + shard
            cache_len = lax.dynamic_slice(
                jnp.asarray(cache_len, jnp.int32), (shard * b,), (b,))
        cdt = jnp.dtype(pcfg.compute_dtype)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, cdt)
        h = embed_lookup(token, embed, info)  # (B, 1, D)
        if not cfg.use_rope:
            pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
            h = h + sinusoidal_positions(pos, cfg.d_model)[:, None, :].astype(h.dtype)

        shared = self._unpack_top(params, "shared_attn", "shared_mlp")

        def body(carry, xs):
            hh = carry
            p_layer, cache = xs
            pl = self._unpack_layer(p_layer)
            hh, new_cache = self._super_block_decode(pl, cache, hh, cache_len, shared)
            return hh, new_cache

        h, new_caches = lax.scan(body, h, (params["layers"], caches))
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        h = rmsnorm(h, ln_f, cfg.norm_eps).reshape(b, cfg.d_model)
        un_name = "embed" if cfg.tie_embeddings else "unembed"
        w_out = fsdp_get(params["top"][un_name], self.top_specs[un_name], pcfg, h.dtype).T
        logits = vocab_parallel_logits(h, w_out, info, cfg.vocab_size)
        return logits, new_caches

    def _attn_decode_dispatch(self, pl, h, cache, cache_len, cross_kv=None):
        """Heads-sharded local decode, or the paper's distributed flash
        decode when the KV cache is sequence-sharded over "data"."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        if not self._kv_seq_sharded() or cross_kv is not None:
            return blocks.attention_decode(
                cfg, pcfg, info, pl, h, cache["k"], cache["v"], cache_len,
                cross_kv=cross_kv,
            )
        # sequence-sharded KV over the data axis: distributed flash decode
        if jnp.ndim(cache_len) != 0:
            raise ValueError(
                "sequence-sharded KV decode takes a scalar cache_len; "
                "per-slot lengths need kv_shard='heads' (or the paged path)")
        b, _, d = h.shape
        hd = cfg.head_dim
        pp = blocks._get_attn(pl, h.dtype)
        hh = rmsnorm(h, pp.ln, cfg.norm_eps).reshape(b, d)
        q = local_linear(hh, pp.wq, pp.bq).reshape(b, info.hq_loc, hd)
        kv = local_linear(hh, pp.wkv, pp.bkv).reshape(b, 2, info.hkv_loc, hd)
        k_new, v_new = kv[:, 0], kv[:, 1]
        if cfg.use_rope:
            posq = jnp.full((b, 1), cache_len, jnp.int32)
            q = rope(q[:, None], posq, cfg.rope_theta)[:, 0]
            k_new = rope(k_new[:, None], posq, cfg.rope_theta)[:, 0]
        s_shard = cache["k"].shape[2]
        me_d = lax.axis_index(DATA_AXIS)
        local_pos = cache_len - me_d * s_shard
        owns = (local_pos >= 0) & (local_pos < s_shard)
        safe = jnp.clip(local_pos, 0, s_shard - 1)
        upd_k = lax.dynamic_update_slice(
            cache["k"], k_new[:, :, None, :].astype(cache["k"].dtype), (0, 0, safe, 0)
        )
        ck = jnp.where(owns, upd_k, cache["k"])
        upd_v = lax.dynamic_update_slice(
            cache["v"], v_new[:, :, None, :].astype(cache["v"].dtype), (0, 0, safe, 0)
        )
        cv = jnp.where(owns, upd_v, cache["v"])
        valid = jnp.clip(cache_len + 1 - me_d * s_shard, 0, s_shard)
        lengths = jnp.full((b,), valid, jnp.int32)
        fd = pcfg.policy.resolve("flash_decode")
        o = dfd.distributed_flash_decode(q, ck, cv, lengths, DATA_AXIS,
                                         mode=fd.mode, backend=fd.backend)
        o = o.astype(h.dtype).reshape(b, info.hq_loc * hd)
        out = psum_tp(local_linear(o, pp.wo), pcfg)
        return h + out.reshape(b, 1, d), ck, cv

    def _super_block_decode(self, pl, cache, h, cache_len, shared):
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        if cfg.family in ("dense", "moe"):
            h, ck, cv = self._attn_decode_dispatch(pl["attn"], h, cache["attn"], cache_len)
            new_cache = {"attn": {"k": ck, "v": cv}}
            if cfg.family == "moe":
                h = blocks.moe_decode(cfg, pcfg, info, pl["ffn"], h)
            else:
                h = blocks.mlp_decode(cfg, pcfg, info, pl["ffn"], h)
        elif cfg.family == "ssm":
            h, conv, ssd = blocks.ssm_decode(
                cfg, pcfg, info, _index_params(pl["ssm"], 0), h,
                cache["ssm"]["conv"][0], cache["ssm"]["ssd"][0],
            )
            new_cache = {"ssm": {"conv": conv[None], "ssd": ssd[None]}}
        elif cfg.family == "hybrid":
            convs, ssds = [], []
            for i in range(self.plan.period):
                h, conv, ssd = blocks.ssm_decode(
                    cfg, pcfg, info, _index_params(pl["ssm"], i), h,
                    cache["ssm"]["conv"][i], cache["ssm"]["ssd"][i],
                )
                convs.append(conv)
                ssds.append(ssd)
            h, ck, cv = self._attn_decode_dispatch(
                shared["shared_attn"], h, cache["attn"], cache_len
            )
            h = blocks.mlp_decode(cfg, pcfg, info, shared["shared_mlp"], h)
            new_cache = {
                "ssm": {"conv": jnp.stack(convs), "ssd": jnp.stack(ssds)},
                "attn": {"k": ck, "v": cv},
            }
        elif cfg.family == "vlm":
            k = self.plan.period
            ks, vs = [], []
            for i in range(k - 1):
                h, ck, cv = blocks.attention_decode(
                    cfg, pcfg, info, _index_params(pl["attn"], i), h,
                    cache["attn"]["k"][i], cache["attn"]["v"][i], cache_len,
                )
                ks.append(ck)
                vs.append(cv)
                h = blocks.mlp_decode(cfg, pcfg, info, _index_params(pl["ffn"], i), h)
            h, _, _ = blocks.attention_decode(
                cfg, pcfg, info, pl["cross"], h,
                cache["cross_k"], cache["cross_v"], cache_len,
                cross_kv=(cache["cross_k"], cache["cross_v"]),
            )
            h = blocks.mlp_decode(cfg, pcfg, info, _index_params(pl["ffn"], k - 1), h)
            new_cache = {
                "attn": {"k": jnp.stack(ks), "v": jnp.stack(vs)},
                "cross_k": cache["cross_k"],
                "cross_v": cache["cross_v"],
            }
        return h, new_cache
