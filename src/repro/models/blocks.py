"""Model blocks (attention / MLP / MoE / Mamba2-SSD), shard_map-local.

Every SP->TP boundary is an overlapped AllGather-GEMM and every TP->SP
boundary an overlapped GEMM-ReduceScatter (the paper's technique as the
model's default projection path). Decode-time blocks use replicated
single-token activations with local projections + one small psum — the
small-message regime the paper serves with low-latency kernels.

Blocks take LOGICAL (already unpacked, see common.get_params) parameter
dicts; packing/FSDP-gather happens in the caller so stacked sub-layer
leaves can be indexed per sub-layer.

Row-order convention: a sequence-parallel tensor (B, S_loc, D) flattens to
(B*S_loc, D); the gathered full-sequence layout is rank-major
(tp, B, S_loc, ...). `_sp_gathered_to_bsd` / `_bsd_to_sp_rows` convert.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .. import ops as oplib
from ..configs.base import ModelConfig, ParallelConfig
from ..core import collective_matmul as cm
from ..core import moe_overlap as mo
from ..kernels import ops
from .common import (
    DATA_AXIS,
    MODEL_AXIS,
    activation,
    ag_linear,
    local_linear,
    psum_tp,
    rmsnorm,
    rope,
    rs_linear,
)
from .params import LeafSpec, TPInfo

Array = jax.Array


def _sp_gathered_to_bsd(y: Array, tp: int, b: int, s_loc: int) -> Array:
    """(tp*B*S_loc, C) rank-major -> (B, S, C)."""
    c = y.shape[-1]
    return (
        y.reshape(tp, b, s_loc, c).transpose(1, 0, 2, 3).reshape(b, tp * s_loc, c)
    )


def _bsd_to_sp_rows(x: Array, tp: int) -> Array:
    """(B, S, C) -> (tp*B*S_loc, C) rank-major rows for GEMM+RS."""
    b, s, c = x.shape
    s_loc = s // tp
    return x.reshape(b, tp, s_loc, c).transpose(1, 0, 2, 3).reshape(tp * b * s_loc, c)


# ===========================================================================
# Attention
# ===========================================================================


def attention_specs(
    cfg: ModelConfig, info: TPInfo, *, cross: bool = False, kv_dim: Optional[int] = None
) -> Dict[str, LeafSpec]:
    d, hd = cfg.d_model, cfg.head_dim
    kv_dim = kv_dim or d
    specs = {
        "ln": LeafSpec((d,), tp_sharded=False, init="ones"),
        "wq": LeafSpec((d, info.hq_loc * hd), fan_in=d),
        "wkv": LeafSpec(
            (kv_dim, 2 * info.hkv_loc * hd), fan_in=kv_dim,
            replica_groups=info.kv_rep,
        ),
        "wo": LeafSpec((info.hq_loc * hd, d), fan_in=info.hq_loc * hd * info.tp),
    }
    if cfg.qkv_bias:
        specs["bq"] = LeafSpec((info.hq_loc * hd,), init="zeros")
        specs["bkv"] = LeafSpec(
            (2 * info.hkv_loc * hd,), init="zeros", replica_groups=info.kv_rep
        )
    return specs


class AttnParams(NamedTuple):
    ln: Array
    wq: Array
    wkv: Array
    wo: Array
    bq: Optional[Array]
    bkv: Optional[Array]


def _get_attn(p: dict, dtype) -> AttnParams:
    def c(n):
        return p[n].astype(dtype) if n in p else None

    return AttnParams(
        ln=c("ln"), wq=c("wq"), wkv=c("wkv"), wo=c("wo"), bq=c("bq"), bkv=c("bkv")
    )


def _attn_core(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    pp: AttnParams,
    x_sp: Array,  # (B, S_loc, D)
    *,
    causal: bool = True,
    cross_src: Optional[Array] = None,  # (B, T_src, D) replicated over tp
):
    """Attention up to (excluding) the output projection: norm, fused
    QKV AG+GEMM, rope, flash attention. Returns the context as rank-major
    TP rows (tp*B*S_loc, Hq_loc*hd) — ready for ``rs_linear(.., wo)`` or
    the fused boundary op — plus (k, v) in cache layout."""
    b, s_loc, d = x_sp.shape
    tp = pcfg.tp
    s = s_loc * tp
    hd = cfg.head_dim

    h = rmsnorm(x_sp, pp.ln, cfg.norm_eps).reshape(b * s_loc, d)
    # SP -> TP: one fused AG+GEMM for q and kv (single gather of the tokens)
    if cross_src is None:
        wqkv = jnp.concatenate([pp.wq, pp.wkv], axis=1)
        bqkv = jnp.concatenate([pp.bq, pp.bkv]) if pp.bq is not None else None
        y = ag_linear(h, wqkv, pcfg, bqkv)  # (tp*B*S_loc, cols)
        y = _sp_gathered_to_bsd(y, tp, b, s_loc)  # (B, S, cols)
        q, kv = jnp.split(y, [info.hq_loc * hd], axis=-1)
        k, v = jnp.split(kv, 2, axis=-1)
        src_len = s
    else:
        q = ag_linear(h, pp.wq, pcfg, pp.bq)
        q = _sp_gathered_to_bsd(q, tp, b, s_loc)
        kv = local_linear(cross_src.reshape(-1, cross_src.shape[-1]), pp.wkv, pp.bkv)
        kv = kv.reshape(b, cross_src.shape[1], -1)
        k, v = jnp.split(kv, 2, axis=-1)
        src_len = cross_src.shape[1]

    q = q.reshape(b, s, info.hq_loc, hd)
    k = k.reshape(b, src_len, info.hkv_loc, hd)
    v = v.reshape(b, src_len, info.hkv_loc, hd)
    if cfg.use_rope and cross_src is None:
        pos = jnp.arange(s)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    o = ops.flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal and cross_src is None,
    )  # (B, Hq_loc, S, hd)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, info.hq_loc * hd)
    return (_bsd_to_sp_rows(o, tp),
            (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)))


def attention_train(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p: dict,  # logical tensors
    x_sp: Array,  # (B, S_loc, D)
    *,
    causal: bool = True,
    cross_src: Optional[Array] = None,  # (B, T_src, D) replicated over tp
    return_kv: bool = False,  # also return (k, v) as (B, Hkv_loc, S, hd)
):
    b, s_loc, d = x_sp.shape
    pp = _get_attn(p, x_sp.dtype)
    o_rows, kv = _attn_core(cfg, pcfg, info, pp, x_sp,
                            causal=causal, cross_src=cross_src)
    # TP -> SP: GEMM + ReduceScatter
    out = rs_linear(o_rows, pp.wo, pcfg)
    y = x_sp + out.reshape(b, s_loc, d)
    if return_kv:
        return y, kv
    return y


def boundary_mid(r: Array, x_rows: Array, ln: Array, eps: Array) -> Array:
    """The rank-local row-wise seam of the fused attention->MLP boundary:
    residual add + pre-MLP rmsnorm of the reduced attention output.
    Module-level on purpose — the fused op carries ``mid`` as a STATIC,
    so a stable function object keeps trace caches warm. ``eps`` rides
    as a () mid tensor (row-broadcast; its grad is discarded)."""
    return rmsnorm(x_rows + r.astype(x_rows.dtype), ln, eps)


def boundary_fused(pcfg: ParallelConfig) -> bool:
    """Whether the policy turns the attention->MLP seam into the fused
    ``matmul_rs_ag_matmul`` op. Opt-in: the registered default mode is
    "none" (see ``ops.policy.DEFAULT_MODES``), which keeps the composed
    unfused pair — the oracle the equivalence tests pin against."""
    return pcfg.tp > 1 and pcfg.policy.mode_for("matmul_rs_ag_matmul") != "none"


def attn_mlp_train(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p_attn: dict,
    p_mlp: dict,
    x_sp: Array,  # (B, S_loc, D)
    *,
    causal: bool = True,
) -> Array:
    """One attention + MLP pair with the attention->MLP seam under policy
    control.

    Unfused (the oracle, and the default): ``attention_train`` then
    ``mlp_train`` — three boundary collectives (attention GEMM+RS, MLP
    AG+GEMM, MLP GEMM+RS), the first two fully exposed back to back at
    the seam.

    Fused (when the policy enables ``matmul_rs_ag_matmul``): the seam's
    rs and ag become ONE chained pipeline with the residual+rmsnorm as
    its rank-local ``mid``, and BOTH residual branches close through one
    combined GEMM+RS — ``rs(o @ wo_attn + act(z) @ wo_mlp)`` equals
    ``attn_out + mlp_out``, so the pair runs two boundary crossings
    instead of three. The trade: the attention out-projection GEMM runs
    twice (once inside the fused seam, once in the combined close);
    values match the oracle to f32-accumulation rounding."""
    if not boundary_fused(pcfg):
        h = attention_train(cfg, pcfg, info, p_attn, x_sp, causal=causal)
        return mlp_train(cfg, pcfg, info, p_mlp, h)
    b, s_loc, d = x_sp.shape
    dt = x_sp.dtype
    pp = _get_attn(p_attn, dt)
    o_rows, _ = _attn_core(cfg, pcfg, info, pp, x_sp, causal=causal)
    x_rows = x_sp.reshape(b * s_loc, d)
    ln_mlp = p_mlp["ln"].astype(dt)
    wi, wo_mlp = p_mlp["wi"].astype(dt), p_mlp["wo"].astype(dt)
    eps = jnp.asarray(cfg.norm_eps, jnp.float32)
    z = oplib.matmul_rs_ag_matmul(
        o_rows, pp.wo, wi, x_rows, ln_mlp, eps,
        axis=MODEL_AXIS, policy=pcfg.policy, out_dtype=dt, mid=boundary_mid)
    a = _mlp_act(cfg, z)
    out = rs_linear(jnp.concatenate([o_rows, a], axis=-1),
                    jnp.concatenate([pp.wo, wo_mlp], axis=0), pcfg)
    return x_sp + out.reshape(b, s_loc, d)


def attention_cp(
    pcfg: ParallelConfig,
    q: Array,  # (B, H, S_loc, hd) — sequence-sharded on ``axis``
    k: Array,  # (B, Hkv, S_loc, hd)
    v: Array,  # (B, Hkv, S_loc, hd)
    *,
    axis: str,
    causal: bool = True,
) -> Array:
    """Context-parallel attention: the long-context TRAIN-side attention
    call site. Sequence is sharded on ``axis`` with heads REPLICATED
    there (compose with TP on a different mesh axis — e.g. CP over the
    data axis while projections stay TP-sharded on the model axis); the
    K/V blocks ride the engine transport as ring attention, with the
    transport AND lowering backend resolved by the overlap policy
    (``backend="kernel"`` runs the executor's carry-passing ring_fold
    protocol; grads stay bit-identical across backends)."""
    from ..core.ring_attention import ring_attention

    r = pcfg.policy.resolve("ring_attention")
    return ring_attention(q, k, v, axis, causal=causal, mode=r.mode,
                          backend=r.backend, placement=r.placement,
                          wire=r.wire)


def attention_decode(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p: dict,
    x: Array,  # (B, 1, D) replicated over tp
    cache_k: Array,  # (B, Hkv_loc, S_max, hd)
    cache_v: Array,
    cache_len: Array,  # scalar OR per-slot (B,) int32
    *,
    cross_kv: Optional[Tuple[Array, Array]] = None,  # precomputed (k, v)
) -> Tuple[Array, Array, Array]:
    b, _, d = x.shape
    hd = cfg.head_dim
    pp = _get_attn(p, x.dtype)
    h = rmsnorm(x, pp.ln, cfg.norm_eps).reshape(b, d)
    q = local_linear(h, pp.wq, pp.bq).reshape(b, info.hq_loc, hd)

    if cross_kv is None:
        kv = local_linear(h, pp.wkv, pp.bkv).reshape(b, 2, info.hkv_loc, hd)
        k_new, v_new = kv[:, 0], kv[:, 1]
        # per-slot write positions (a scalar cache_len broadcasts: the
        # pre-continuous-batching callers advance all slots in lockstep)
        pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
        if cfg.use_rope:
            q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
            k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, :, pos, :].set(k_new.astype(cache_k.dtype))
        cache_v = cache_v.at[rows, :, pos, :].set(v_new.astype(cache_v.dtype))
        o, _ = ops.flash_decode(q, cache_k, cache_v, pos + 1)
    else:
        ck, cv = cross_kv
        lengths = jnp.full((b,), ck.shape[2], jnp.int32)
        o, _ = ops.flash_decode(q, ck, cv, lengths)

    o = o.astype(x.dtype).reshape(b, info.hq_loc * hd)
    out = psum_tp(local_linear(o, pp.wo), pcfg)  # small AR (low-latency regime)
    return x + out.reshape(b, 1, d), cache_k, cache_v


# ===========================================================================
# Paged attention (block tables over a page pool — serve/kvcache.py)
# ===========================================================================


def _gather_pages(pool: Array, table: Array) -> Array:
    """Materialize per-slot KV from the page pool.

    pool (num_pages, H, page_size, hd), table (B, P) int32 ->
    (B, H, P*page_size, hd). Unallocated table entries point at scratch
    page 0; callers mask those positions out by length.
    """
    _, h, ps, hd = pool.shape
    b, pcount = table.shape
    g = pool[table]  # (B, P, H, ps, hd)
    return g.transpose(0, 2, 1, 3, 4).reshape(b, h, pcount * ps, hd)


def attention_decode_paged(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p: dict,
    x: Array,        # (B, 1, D) replicated over tp
    pool_k: Array,   # (num_pages, Hkv_loc, page_size, hd)
    pool_v: Array,
    table: Array,    # (B, P) int32 page ids
    lengths: Array,  # (B,) tokens already cached per slot
    active: Array,   # (B,) bool — idle lanes write to the scratch page
) -> Tuple[Array, Array, Array]:
    """Decode-step attention against the paged KV pool: write this
    token's K/V at each live slot's next position (routed through its
    block table), then flash-decode over the slot's gathered pages."""
    b, _, d = x.shape
    hd = cfg.head_dim
    ps = pool_k.shape[2]
    pp = _get_attn(p, x.dtype)
    h = rmsnorm(x, pp.ln, cfg.norm_eps).reshape(b, d)
    q = local_linear(h, pp.wq, pp.bq).reshape(b, info.hq_loc, hd)
    kv = local_linear(h, pp.wkv, pp.bkv).reshape(b, 2, info.hkv_loc, hd)
    k_new, v_new = kv[:, 0], kv[:, 1]
    pos = lengths.astype(jnp.int32)
    if cfg.use_rope:
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    rows = jnp.arange(b)
    page = jnp.where(active, table[rows, pos // ps], 0)
    off = pos % ps
    pool_k = pool_k.at[page, :, off, :].set(k_new.astype(pool_k.dtype))
    pool_v = pool_v.at[page, :, off, :].set(v_new.astype(pool_v.dtype))
    k_all = _gather_pages(pool_k, table)
    v_all = _gather_pages(pool_v, table)
    eff = jnp.where(active, pos + 1, 1)
    o, _ = ops.flash_decode(q, k_all, v_all, eff)
    o = o.astype(x.dtype).reshape(b, info.hq_loc * hd)
    out = psum_tp(local_linear(o, pp.wo), pcfg)
    return x + out.reshape(b, 1, d), pool_k, pool_v


def _chunk_attend(q: Array, k_all: Array, v_all: Array, qpos: Array,
                  limit: Array) -> Array:
    """Attention of chunk queries at absolute positions ``qpos`` over the
    gathered page pool: key j visible iff j <= qpos_i and j < limit.
    q (B, C, Hq, hd), k_all/v_all (B, Hkv, L, hd) -> (B, C, Hq, hd) f32."""
    b, c, hq, hd = q.shape
    hkv = k_all.shape[1]
    kk = jnp.repeat(k_all, hq // hkv, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v_all, hq // hkv, axis=1).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bchd,bhld->bhcl", q.astype(jnp.float32), kk) * scale
    j = jnp.arange(k_all.shape[2])
    mask = (j[None, :] <= qpos[:, None]) & (j[None, :] < limit)  # (C, L)
    logits = jnp.where(mask[None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhcl,bhld->bchd", w, vv)


def attention_prefill_chunk(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p: dict,
    x_sp: Array,       # (1, C_loc, D) — one request's chunk, SP over tp
    pool_k: Array,     # (num_pages, Hkv_loc, page_size, hd)
    pool_v: Array,
    table_row: Array,  # (P,) int32 — the request's block table
    start: Array,      # scalar int32: absolute position of the chunk's 1st token
    n_valid: Array,    # scalar int32: real tokens in the chunk (rest padding)
) -> Tuple[Array, Array, Array]:
    """One chunked-prefill attention layer: AG+GEMM projections over the
    chunk (resolves ag_matmul), chunk K/V written into the paged pool,
    chunk queries attending over the pool (prefix + the chunk itself,
    causal at absolute positions), GEMM+RS back to SP rows (resolves
    matmul_rs). Padding lanes write to the scratch page."""
    b, s_loc, d = x_sp.shape
    tp = pcfg.tp
    c = s_loc * tp
    hd = cfg.head_dim
    ps = pool_k.shape[2]
    pp = _get_attn(p, x_sp.dtype)

    h = rmsnorm(x_sp, pp.ln, cfg.norm_eps).reshape(b * s_loc, d)
    wqkv = jnp.concatenate([pp.wq, pp.wkv], axis=1)
    bqkv = jnp.concatenate([pp.bq, pp.bkv]) if pp.bq is not None else None
    y = ag_linear(h, wqkv, pcfg, bqkv)  # (tp*B*S_loc, cols)
    y = _sp_gathered_to_bsd(y, tp, b, s_loc)  # (1, C, cols)
    q, kv = jnp.split(y, [info.hq_loc * hd], axis=-1)
    k, v = jnp.split(kv, 2, axis=-1)
    q = q.reshape(b, c, info.hq_loc, hd)
    k = k.reshape(b, c, info.hkv_loc, hd)
    v = v.reshape(b, c, info.hkv_loc, hd)
    pos = start + jnp.arange(c)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    valid = jnp.arange(c) < n_valid
    pages = jnp.where(valid, table_row[pos // ps], 0)
    offs = pos % ps
    pool_k = pool_k.at[pages, :, offs, :].set(k[0].astype(pool_k.dtype))
    pool_v = pool_v.at[pages, :, offs, :].set(v[0].astype(pool_v.dtype))

    k_all = _gather_pages(pool_k, table_row[None, :])
    v_all = _gather_pages(pool_v, table_row[None, :])
    # all-masked rows would NaN; an idle shard (n_valid == 0) attends one
    # scratch position instead, and its output is discarded by the caller
    limit = start + jnp.maximum(n_valid, 1)
    o = _chunk_attend(q, k_all, v_all, pos, limit)
    o = o.astype(x_sp.dtype).reshape(b, c, info.hq_loc * hd)
    out = rs_linear(_bsd_to_sp_rows(o, tp), pp.wo, pcfg)
    return x_sp + out.reshape(b, s_loc, d), pool_k, pool_v


def _prefix_partial(q: Array, k_all: Array, v_all: Array, start: Array):
    """Partial attention of chunk queries over the pool PREFIX [0, start)
    — the positions prefilled by earlier chunks. Returns the online-
    softmax triple (m, l, acc) with acc UN-normalized, for merging with
    the chunk-internal ring partial. ``start == 0`` yields an exact
    no-op partial (m = -1e30, l = 0, acc = 0)."""
    b, c, hq, hd = q.shape
    hkv = k_all.shape[1]
    kk = jnp.repeat(k_all, hq // hkv, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v_all, hq // hkv, axis=1).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bchd,bhld->bhcl", q.astype(jnp.float32), kk) * scale
    mask = jnp.arange(k_all.shape[2]) < start  # (L,)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1)  # (B, Hq, C)
    # p must be masked explicitly: with start == 0 every logit AND m sit
    # at -1e30, so exp(logits - m) would be exp(0) = 1, not 0
    p = jnp.where(mask[None, None, None], jnp.exp(logits - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhcl,bhld->bhcd", p, vv)
    return m, l, acc


def attention_prefill_chunk_cp(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    info: TPInfo,
    p: dict,
    x_sp: Array,       # (1, C/(cp*tp), D) — this rank's placement rows, SP over tp
    pool_k: Array,     # (num_pages, Hkv_loc, page_size, hd)
    pool_v: Array,
    table_row: Array,  # (P,) int32 — the request's block table
    start: Array,      # scalar int32: absolute position of the chunk's 1st token
    n_valid: Array,    # scalar int32: real tokens in the chunk (rest padding)
    rows_own: Array,   # (C/cp,) int32 — this cp rank's global chunk-row indices
    inv_perm: Array,   # (C,) int32 static — rank-major gather -> position order
    *,
    placement: str,
    cp_attend: str,    # "ring" | "dense"
) -> Tuple[Array, Array, Array]:
    """Context-parallel chunked-prefill attention: ONE request's chunk is
    sharded over the DATA axis by the balanced placement map (each cp
    rank owns C/cp position-ordered rows — zigzag: one early + one late
    half-chunk), with TP projections unchanged within each shard. Chunk
    K/V is all-gathered over the context axis and EVERY rank performs
    the identical scatter-by-table pool write, so the pool replicas stay
    bitwise equal to the dense single-shard path. ``cp_attend="dense"``
    attends each rank's rows over the gathered pages (bit-exact vs
    :func:`attention_prefill_chunk`); ``"ring"`` runs the chunk-internal
    part through the balanced ring_attention op (placement-aware causal
    fold, policy-resolved transport/backend) and merges the pool-prefix
    partial by online softmax."""
    from ..core.ring_attention import ring_attention

    b, s_loc, d = x_sp.shape
    tp = pcfg.tp
    c_own = s_loc * tp  # this cp rank's chunk rows
    hd = cfg.head_dim
    ps = pool_k.shape[2]
    pp = _get_attn(p, x_sp.dtype)

    h = rmsnorm(x_sp, pp.ln, cfg.norm_eps).reshape(b * s_loc, d)
    wqkv = jnp.concatenate([pp.wq, pp.wkv], axis=1)
    bqkv = jnp.concatenate([pp.bq, pp.bkv]) if pp.bq is not None else None
    y = ag_linear(h, wqkv, pcfg, bqkv)
    y = _sp_gathered_to_bsd(y, tp, b, s_loc)  # (1, C_own, cols)
    q, kv = jnp.split(y, [info.hq_loc * hd], axis=-1)
    k, v = jnp.split(kv, 2, axis=-1)
    q = q.reshape(b, c_own, info.hq_loc, hd)
    k = k.reshape(b, c_own, info.hkv_loc, hd)
    v = v.reshape(b, c_own, info.hkv_loc, hd)
    pos_own = start + rows_own
    if cfg.use_rope:
        q = rope(q, pos_own, cfg.rope_theta)
        k = rope(k, pos_own, cfg.rope_theta)

    # every cp rank reconstructs the FULL chunk K/V in position order and
    # performs the identical pool write — replicas stay bitwise equal
    k_ord = lax.all_gather(k[0], DATA_AXIS, axis=0, tiled=True)[inv_perm]
    v_ord = lax.all_gather(v[0], DATA_AXIS, axis=0, tiled=True)[inv_perm]
    c = k_ord.shape[0]
    pos = start + jnp.arange(c)
    valid = jnp.arange(c) < n_valid
    pages = jnp.where(valid, table_row[pos // ps], 0)
    offs = pos % ps
    pool_k = pool_k.at[pages, :, offs, :].set(k_ord.astype(pool_k.dtype))
    pool_v = pool_v.at[pages, :, offs, :].set(v_ord.astype(pool_v.dtype))

    k_all = _gather_pages(pool_k, table_row[None, :])
    v_all = _gather_pages(pool_v, table_row[None, :])
    limit = start + jnp.maximum(n_valid, 1)
    if cp_attend == "dense":
        o = _chunk_attend(q, k_all, v_all, pos_own, limit)
    else:  # "ring"
        r = pcfg.policy.resolve("ring_attention")
        stats = ring_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), DATA_AXIS, causal=True, mode=r.mode,
            backend=r.backend, placement=placement, wire=r.wire,
            with_stats=True)  # (1, Hq_loc, C_own, hd + 2)
        out_c = stats[..., :hd]
        m_c, l_c = stats[..., hd], stats[..., hd + 1]
        m_p, l_p, acc_p = _prefix_partial(q, k_all, v_all, start)
        mm = jnp.maximum(m_c, m_p)
        a_p = jnp.exp(m_p - mm)
        a_c = jnp.exp(m_c - mm) * l_c  # chunk acc = out_c * l_c
        num = a_p[..., None] * acc_p + a_c[..., None] * out_c
        den = a_p * l_p + a_c  # >= l_c > 0: causal self term always present
        o = (num / den[..., None]).transpose(0, 2, 1, 3)  # (1, C_own, Hq, hd)
    o = o.astype(x_sp.dtype).reshape(b, c_own, info.hq_loc * hd)
    out = rs_linear(_bsd_to_sp_rows(o, tp), pp.wo, pcfg)
    return x_sp + out.reshape(b, s_loc, d), pool_k, pool_v


# ===========================================================================
# MLP
# ===========================================================================


def mlp_specs(cfg: ModelConfig, info: TPInfo) -> Dict[str, LeafSpec]:
    d = cfg.d_model
    n_up = 2 if cfg.gated_mlp else 1
    return {
        "ln": LeafSpec((d,), tp_sharded=False, init="ones"),
        "wi": LeafSpec((d, n_up * info.dff_loc), fan_in=d),
        "wo": LeafSpec((info.dff_loc, d), fan_in=info.dff_loc * info.tp),
    }


def _mlp_act(cfg, y: Array) -> Array:
    act = activation(cfg.activation)
    if cfg.gated_mlp:
        gate, up = jnp.split(y, 2, axis=-1)
        return act(gate.astype(jnp.float32)).astype(y.dtype) * up
    return act(y.astype(jnp.float32)).astype(y.dtype)


def mlp_train(cfg, pcfg, info, p: dict, x_sp: Array) -> Array:
    b, s_loc, d = x_sp.shape
    dt = x_sp.dtype
    h = rmsnorm(x_sp, p["ln"].astype(dt), cfg.norm_eps).reshape(b * s_loc, d)
    y = ag_linear(h, p["wi"].astype(dt), pcfg)  # (tp*B*S_loc, n_up*dff_loc)
    y = _mlp_act(cfg, y)
    out = rs_linear(y, p["wo"].astype(dt), pcfg)  # rows already rank-major
    return x_sp + out.reshape(b, s_loc, d)


def mlp_decode(cfg, pcfg, info, p: dict, x: Array) -> Array:
    b, t, d = x.shape
    dt = x.dtype
    h = rmsnorm(x, p["ln"].astype(dt), cfg.norm_eps).reshape(b * t, d)
    y = _mlp_act(cfg, local_linear(h, p["wi"].astype(dt)))
    out = psum_tp(local_linear(y, p["wo"].astype(dt)), pcfg)
    return x + out.reshape(b, t, d)


# ===========================================================================
# MoE (TP mode: paper's AG+MoE / MoE+RS; EP mode: AllToAll dispatch/combine)
# ===========================================================================


def moe_specs(cfg: ModelConfig, info: TPInfo) -> Dict[str, LeafSpec]:
    d = cfg.d_model
    e = cfg.num_experts
    if info.moe_mode == "ep":
        e_loc, dff = info.e_loc, cfg.d_ff
    else:
        e_loc, dff = e, info.dff_loc
    n_up = 2 if cfg.gated_mlp else 1
    return {
        "ln": LeafSpec((d,), tp_sharded=False, init="ones"),
        "router": LeafSpec((d, e), tp_sharded=False, fan_in=d),
        "wi": LeafSpec((e_loc, d, n_up * dff), fan_in=d),
        "wo": LeafSpec(
            (e_loc, dff, d), fan_in=dff * (1 if info.moe_mode == "ep" else info.tp)
        ),
    }


def _expert_ffn(cfg, x_disp: Array, wi: Array, wo: Array) -> Array:
    """(E, cap, D) -> (E, cap, D) through the expert MLPs (grouped GEMMs)."""
    y = ops.grouped_matmul(x_disp, wi, out_dtype=x_disp.dtype)
    y = _mlp_act(cfg, y)
    return ops.grouped_matmul(y, wo, out_dtype=x_disp.dtype)


def _capacity(t: int, k: int, e: int, factor: float) -> int:
    cap = int(math.ceil(t * k / e * factor))
    return max(8, ((cap + 7) // 8) * 8)


def moe_train(cfg, pcfg, info, p: dict, x_sp: Array) -> Array:
    b, s_loc, d = x_sp.shape
    tp = pcfg.tp
    dt = x_sp.dtype
    ln, router = p["ln"].astype(dt), p["router"].astype(dt)
    wi, wo = p["wi"].astype(dt), p["wo"].astype(dt)
    h = rmsnorm(x_sp, ln, cfg.norm_eps).reshape(b * s_loc, d)
    logits = local_linear(h, router)  # (T_loc, E)
    k = cfg.experts_per_token

    if info.moe_mode == "ep" and tp > 1:
        # token chunking bounds the (E, cap, d) dispatch buffers AND is the
        # natural grain for overlapping a2a(chunk i+1) with experts(chunk i)
        t_loc = h.shape[0]
        n_chunks = max(1, min(pcfg.moe_chunks, t_loc))
        while t_loc % n_chunks != 0:
            n_chunks -= 1
        t_c = t_loc // n_chunks
        cap = _capacity(t_c, k, cfg.num_experts, cfg.capacity_factor)

        a2a = pcfg.policy.resolve("a2a_ep")

        def ep_chunk(hc, lc):
            disp, dinfo = mo.topk_dispatch(hc, lc, k, cap)  # (E, cap, D)
            x_ep = mo.a2a_ep(disp, MODEL_AXIS, mode=a2a.mode,
                             backend=a2a.backend, wire=a2a.wire)
            y_ep = _expert_ffn(cfg, x_ep, wi, wo)  # (E_loc, tp*cap, D)
            back = mo.a2a_ep_inverse(y_ep, MODEL_AXIS, mode=a2a.mode,
                                     backend=a2a.backend, wire=a2a.wire)
            return mo.topk_combine(back, dinfo, out_dtype=dt)

        if pcfg.remat != "none":
            ep_chunk = jax.checkpoint(ep_chunk)
        outs = []
        for ci in range(n_chunks):
            hc = lax.dynamic_slice(h, (ci * t_c, 0), (t_c, d))
            lc = lax.dynamic_slice(logits, (ci * t_c, 0), (t_c, logits.shape[1]))
            outs.append(ep_chunk(hc, lc))
        out = jnp.concatenate(outs, axis=0) if n_chunks > 1 else outs[0]
        return x_sp + out.reshape(b, s_loc, d)

    # TP mode: AllGather token chunks around the ring, run the d_ff-sharded
    # experts per chunk (AG+MoE), then ring-ReduceScatter the partial
    # outputs (MoE+RS). (EP configs on tp=1 meshes also land here.)
    cap = _capacity(h.shape[0], k, cfg.num_experts, cfg.capacity_factor)

    def expert_fn(tokens, tok_logits):
        dsp, dinfo = mo.topk_dispatch(tokens, tok_logits, k, cap)
        y = _expert_ffn(cfg, dsp, wi, wo)
        return mo.topk_combine(y, dinfo, out_dtype=tokens.dtype)

    if pcfg.remat != "none":
        # per-ring-chunk checkpoint: the backward live-set is one chunk's
        # dispatch buffers, not all W chunks' (the ring makes W of them)
        expert_fn = jax.checkpoint(expert_fn)

    if tp > 1:
        # ag_moe carries a derived vjp-of-closure backward (the kernel
        # forward keeps the graph-schedule dual through the ONE shared
        # custom_vjp), so the TRAIN path follows the policy's backend —
        # the graph-only pin is gone.
        ag = pcfg.policy.resolve("ag_moe")
        full = mo.ag_moe(h, logits, expert_fn, MODEL_AXIS,
                         mode=ag.mode, backend=ag.backend)
        rs = pcfg.policy.resolve("reduce_scatter")
        out = cm.reduce_scatter_chunked(full, MODEL_AXIS, mode=rs.mode,
                                        backend=rs.backend, wire=rs.wire)
    else:
        out = expert_fn(h, logits)
    return x_sp + out.reshape(b, s_loc, d)


def moe_decode(cfg, pcfg, info, p: dict, x: Array) -> Array:
    b, t, d = x.shape
    dt = x.dtype
    ln, router = p["ln"].astype(dt), p["router"].astype(dt)
    wi, wo = p["wi"].astype(dt), p["wo"].astype(dt)
    h = rmsnorm(x, ln, cfg.norm_eps).reshape(b * t, d)
    logits = local_linear(h, router)
    k = cfg.experts_per_token
    cap = _capacity(h.shape[0], k, cfg.num_experts, cfg.capacity_factor)
    disp, dinfo = mo.topk_dispatch(h, logits, k, cap)
    if info.moe_mode == "ep" and pcfg.tp > 1:
        a2a = pcfg.policy.resolve("a2a_ep")
        x_ep = mo.a2a_ep(disp, MODEL_AXIS, mode=a2a.mode,
                         backend=a2a.backend, wire=a2a.wire)
        y_ep = _expert_ffn(cfg, x_ep, wi, wo)
        back = mo.a2a_ep_inverse(y_ep, MODEL_AXIS, mode=a2a.mode,
                                 backend=a2a.backend, wire=a2a.wire)
        out = mo.topk_combine(back, dinfo, out_dtype=dt)
    else:
        y = _expert_ffn(cfg, disp, wi, wo)
        out = mo.topk_combine(y, dinfo, out_dtype=dt)
        out = psum_tp(out, pcfg) if info.moe_mode == "tp" else out
    return x + out.reshape(b, t, d)


# ===========================================================================
# Mamba2 (SSD) block
# ===========================================================================


def ssm_specs(cfg: ModelConfig, info: TPInfo) -> Dict[str, LeafSpec]:
    d = cfg.d_model
    gs = cfg.ssm_num_groups * cfg.ssm_state
    cols = 2 * info.di_loc + 2 * gs + info.nh_loc  # z | x | B | C | dt
    conv_ch = info.di_loc + 2 * gs
    return {
        "ln": LeafSpec((d,), tp_sharded=False, init="ones"),
        "w_in": LeafSpec((d, cols), fan_in=d),
        "conv": LeafSpec(
            (cfg.ssm_conv_width, conv_ch), init="normal", fan_in=cfg.ssm_conv_width
        ),
        "a_log": LeafSpec((info.nh_loc,), init="ssm_a"),
        "dt_bias": LeafSpec((info.nh_loc,), init="ssm_dt"),
        "d_skip": LeafSpec((info.nh_loc,), init="ones"),
        "w_out": LeafSpec((info.di_loc, d), fan_in=info.di_loc * info.tp),
    }


def _causal_depthwise_conv(x: Array, w: Array) -> Array:
    """x: (B, S, C), w: (width, C) — causal depthwise conv + silu."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(width):
        out = out + pad[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )[None, None, :]
    return jax.nn.silu(out).astype(x.dtype)


def _split_ssm_proj(y: Array, cfg, info):
    gs = cfg.ssm_num_groups * cfg.ssm_state
    di = info.di_loc
    z, xs, bmat, cmat, dt = jnp.split(
        y, [di, 2 * di, 2 * di + gs, 2 * di + 2 * gs], axis=-1
    )
    return z, xs, bmat, cmat, dt


def ssm_train(cfg, pcfg, info, p: dict, x_sp: Array) -> Array:
    b, s_loc, d = x_sp.shape
    tp = pcfg.tp
    s = s_loc * tp
    dt_ = x_sp.dtype
    a_log = p["a_log"].astype(jnp.float32)
    dt_bias = p["dt_bias"].astype(jnp.float32)
    d_skip = p["d_skip"].astype(jnp.float32)

    h = rmsnorm(x_sp, p["ln"].astype(dt_), cfg.norm_eps).reshape(b * s_loc, d)
    y = ag_linear(h, p["w_in"].astype(dt_), pcfg)  # SP->TP overlapped projection
    y = _sp_gathered_to_bsd(y, tp, b, s_loc)  # (B, S, cols)
    z, xs, bmat, cmat, dtp = _split_ssm_proj(y, cfg, info)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = _causal_depthwise_conv(conv_in, p["conv"])
    xs, bmat, cmat = jnp.split(
        conv_out,
        [info.di_loc, info.di_loc + cfg.ssm_num_groups * cfg.ssm_state],
        axis=-1,
    )

    nh, hp = info.nh_loc, cfg.ssm_head_dim
    xh = xs.reshape(b, s, nh, hp)
    dtv = jax.nn.softplus(dtp.astype(jnp.float32) + dt_bias)  # (B, S, nh)
    a = -jnp.exp(a_log)  # (nh,)
    bm = bmat.reshape(b, s, cfg.ssm_num_groups, cfg.ssm_state)
    cmx = cmat.reshape(b, s, cfg.ssm_num_groups, cfg.ssm_state)
    yh, _ = ops.ssd_scan(xh, dtv, a, bm, cmx)
    yh = yh.astype(jnp.float32) + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    y = (yh.reshape(b, s, nh * hp) * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = rs_linear(_bsd_to_sp_rows(y, tp), p["w_out"].astype(dt_), pcfg)  # TP->SP
    return x_sp + out.reshape(b, s_loc, d)


def ssm_decode(
    cfg, pcfg, info, p: dict, x: Array, conv_state: Array, ssd_state: Array
) -> Tuple[Array, Array, Array]:
    """x: (B, 1, D); conv_state: (B, width-1, conv_ch);
    ssd_state: (B, nh_loc, P, S) f32."""
    b, _, d = x.shape
    dt_ = x.dtype
    a_log = p["a_log"].astype(jnp.float32)
    dt_bias = p["dt_bias"].astype(jnp.float32)
    d_skip = p["d_skip"].astype(jnp.float32)

    h = rmsnorm(x, p["ln"].astype(dt_), cfg.norm_eps).reshape(b, d)
    y = local_linear(h, p["w_in"].astype(dt_))
    z, xs, bmat, cmat, dtp = _split_ssm_proj(y, cfg, info)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)  # (B, conv_ch)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B, w, C)
    conv_out = jax.nn.silu(
        jnp.sum(window.astype(jnp.float32) * p["conv"].astype(jnp.float32)[None], axis=1)
    ).astype(dt_)
    new_conv_state = window[:, 1:, :]
    xs, bmat, cmat = jnp.split(
        conv_out,
        [info.di_loc, info.di_loc + cfg.ssm_num_groups * cfg.ssm_state],
        axis=-1,
    )

    nh, hp = info.nh_loc, cfg.ssm_head_dim
    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    dtv = jax.nn.softplus(dtp.astype(jnp.float32) + dt_bias)  # (B, nh)
    a = -jnp.exp(a_log)
    rep = nh // cfg.ssm_num_groups if nh >= cfg.ssm_num_groups else 1
    bm = jnp.repeat(
        bmat.reshape(b, cfg.ssm_num_groups, cfg.ssm_state), rep, axis=1
    ).astype(jnp.float32)
    cmx = jnp.repeat(
        cmat.reshape(b, cfg.ssm_num_groups, cfg.ssm_state), rep, axis=1
    ).astype(jnp.float32)
    decay = jnp.exp(dtv * a[None, :])  # (B, nh)
    new_state = ssd_state * decay[..., None, None] + (
        xh[..., :, None] * bm[..., None, :]
    ) * dtv[..., None, None]
    yh = jnp.einsum("bhps,bhs->bhp", new_state, cmx) + d_skip[None, :, None] * xh
    yv = (yh.reshape(b, nh * hp) * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = psum_tp(local_linear(yv, p["w_out"].astype(dt_)), pcfg)
    return x + out.reshape(b, 1, d), new_conv_state, new_state
