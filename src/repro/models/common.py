"""Shared model math (runs INSIDE shard_map; all tensors are per-device).

Conventions:
  mesh axes: ("pod",) "data", "model"
  hidden between blocks (train/prefill): (B_loc, S_loc, D) — sequence-
    parallel along "model" (S_loc = S / tp); B_loc = B / (dp * pods)
  hidden in decode: (B_loc, 1, D) replicated along "model"
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .. import ops
from ..configs.base import ParallelConfig
from ..core import collective_matmul as cm
from .params import LeafSpec, TPInfo, unpack

Array = jax.Array

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# FSDP param access
# ---------------------------------------------------------------------------


def fsdp_get(packed_local: Array, spec: LeafSpec, pcfg: ParallelConfig, dtype=None) -> Array:
    """Packed per-device slice -> logical TP-local tensor.

    With FSDP the packed dim is additionally sharded along "data"; gather
    it with the overlapped ring collective (its autodiff transpose is the
    ring reduce-scatter of the gradient — ZeRO-3 with overlap)."""
    if pcfg.fsdp:
        if pcfg.fsdp_pods and pcfg.pods > 1:
            # 2-level gather: pod axis first (minor), then data (major);
            # the transpose is the matching hierarchical reduce-scatter.
            packed_local = cm.all_gather_chunked(packed_local, POD_AXIS)
        if pcfg.dp > 1:
            packed_local = cm.all_gather_chunked(packed_local, DATA_AXIS)
    return unpack(packed_local, spec, dtype)


def get_params(p: dict, specs: dict, pcfg: ParallelConfig) -> dict:
    """Unpack a whole block's packed leaves into logical tensors (FSDP
    gather + reshape). Stacked sub-layer leaves come out as
    (n_sub, ...) tensors, indexable per sub-layer."""
    return {k: fsdp_get(p[k], specs[k], pcfg) for k in specs}


# ---------------------------------------------------------------------------
# Elementwise / norm / rope
# ---------------------------------------------------------------------------


def rmsnorm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, Dh); positions: (..., S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # (..., S, 1, half)
    sin = sin[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Overlapped projections (the paper's AG+GEMM / GEMM+RS in the model)
# ---------------------------------------------------------------------------


def ag_linear(
    x_sp: Array,  # (T_loc, D) sequence-parallel tokens
    w: Array,  # (D, cols_loc) TP-local weight
    pcfg: ParallelConfig,
    b: Optional[Array] = None,
) -> Array:
    """SP -> TP boundary: AllGather-GEMM. Returns (T, cols_loc)."""
    if pcfg.tp > 1:
        y = ops.ag_matmul(x_sp, w, axis=MODEL_AXIS, policy=pcfg.policy,
                          out_dtype=x_sp.dtype)
    else:
        y = ops.ag_matmul(x_sp, w, axis=MODEL_AXIS, mode="none",
                          out_dtype=x_sp.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rs_linear(
    y_tp: Array,  # (T, cols_loc) TP activations
    w: Array,  # (cols_loc, D) TP-local weight
    pcfg: ParallelConfig,
) -> Array:
    """TP -> SP boundary: GEMM-ReduceScatter. Returns (T_loc, D)."""
    if pcfg.tp > 1:
        return ops.matmul_rs(y_tp, w, axis=MODEL_AXIS, policy=pcfg.policy,
                             out_dtype=y_tp.dtype)
    return ops.matmul_rs(y_tp, w, axis=MODEL_AXIS, mode="none",
                         out_dtype=y_tp.dtype)


def local_linear(x: Array, w: Array, b: Optional[Array] = None) -> Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def psum_tp(x: Array, pcfg: ParallelConfig) -> Array:
    return lax.psum(x, MODEL_AXIS) if pcfg.tp > 1 else x


# ---------------------------------------------------------------------------
# Vocab-parallel embedding & loss (Megatron-style)
# ---------------------------------------------------------------------------


def embed_lookup(
    ids: Array,  # (B_loc, S_any) int32
    table: Array,  # (V_loc, D) TP-local vocab slice
    info: TPInfo,
) -> Array:
    """Vocab-parallel lookup: mask + psum over the model axis."""
    v_loc = table.shape[0]
    me = lax.axis_index(MODEL_AXIS)
    off = me * v_loc
    local = ids - off
    in_range = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    emb = table[local]  # (B, S, D)
    emb = jnp.where(in_range[..., None], emb, 0)
    return lax.psum(emb, MODEL_AXIS)


def embed_lookup_sp(
    ids: Array,  # (B_loc, S) int32 — FULL sequence, replicated over tp
    table: Array,  # (V_loc, D) TP-local vocab slice
    info: TPInfo,
    tp: int,
) -> Array:
    """Vocab-parallel lookup for the sequence-parallel layout: returns
    this rank's (B, S/tp, D) rank-major sequence window.

    :func:`embed_lookup`'s mask+psum is only sound when every model rank
    looks up the SAME ids (decode: one replicated token). Under SP each
    rank owns a different sequence window, so psumming per-window
    partials would add embeddings of DIFFERENT positions. Instead every
    rank looks up the full sequence against its vocab shard and a
    reduce-scatter over the model axis does the cross-shard sum and the
    window split in one collective.
    """
    v_loc = table.shape[0]
    me = lax.axis_index(MODEL_AXIS)
    off = me * v_loc
    local = ids - off
    in_range = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    emb = jnp.where(in_range[..., None], table[local], 0)  # (B, S, D)
    if tp == 1:
        return emb
    return lax.psum_scatter(emb, MODEL_AXIS, scatter_dimension=1, tiled=True)


def vocab_parallel_loss(
    x: Array,  # (T_loc, D) sequence-parallel final hidden
    w_out: Array,  # (D, V_loc)
    labels: Array,  # (T_loc,) int32, -1 = ignore
    info: TPInfo,
    vocab_size: int,
) -> tuple[Array, Array]:
    """Cross entropy over the TP-sharded vocab. Returns (sum_loss, count)
    local to this rank's sequence shard (caller psums over model+data)."""
    logits = jnp.dot(x, w_out, preferred_element_type=jnp.float32)  # (T, V_loc)
    v_loc = w_out.shape[1]
    me = lax.axis_index(MODEL_AXIS)
    off = me * v_loc
    # padded vocab tail must not win the max
    col = off + jnp.arange(v_loc)
    logits = jnp.where(col[None, :] < vocab_size, logits, -1e30)

    # max subtraction is gradient-invariant for the LSE -> stop_gradient is
    # exact; it must wrap the pmax INPUT (pmax has no JVP rule, so its
    # tangent must be a symbolic zero)
    m = lax.pmax(lax.stop_gradient(jnp.max(logits, axis=-1)), MODEL_AXIS)  # (T,)
    sumexp = lax.psum(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1), MODEL_AXIS)
    lse = m + jnp.log(sumexp)

    local_label = labels - off
    in_range = (local_label >= 0) & (local_label < v_loc)
    safe = jnp.clip(local_label, 0, v_loc - 1)
    tgt_logit = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    tgt_logit = lax.psum(jnp.where(in_range, tgt_logit, 0.0), MODEL_AXIS)

    valid = labels >= 0
    loss = jnp.where(valid, lse - tgt_logit, 0.0)
    return jnp.sum(loss), jnp.sum(valid.astype(jnp.float32))


def vocab_parallel_logits(
    x: Array, w_out: Array, info: TPInfo, vocab_size: int
) -> Array:
    """Full logits (gathered over TP) — decode-time only (T is tiny)."""
    logits = jnp.dot(x, w_out, preferred_element_type=jnp.float32)  # (T, V_loc)
    full = lax.all_gather(logits, MODEL_AXIS, axis=1, tiled=True)
    return full[:, :vocab_size]
