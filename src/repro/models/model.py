"""Model factory: one constructor for all 10 assigned architectures."""
from __future__ import annotations

from ..configs.base import ModelConfig, ParallelConfig
from .lm import LM
from .whisper import Whisper


def build_model(cfg: ModelConfig, pcfg: ParallelConfig):
    if cfg.family == "whisper":
        return Whisper(cfg, pcfg)
    return LM(cfg, pcfg)
