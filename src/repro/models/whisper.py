"""Whisper-style encoder-decoder (conv frontend stubbed as frame embeddings).

Encoder: non-causal self-attention blocks over (B, F, D) frame embeddings
(the assignment stubs the conv frontend — ``input_specs()`` provides the
frames). Decoder: causal self-attention + cross-attention to the encoder
output + MLP. Sinusoidal positions (no learned tables, so the mechanical
32k decode shape needs no 32k embedding matrix).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig, ParallelConfig
from . import blocks
from .common import (
    DATA_AXIS,
    MODEL_AXIS,
    embed_lookup,
    embed_lookup_sp,
    fsdp_get,
    get_params,
    rmsnorm,
    sinusoidal_positions,
    vocab_parallel_logits,
    vocab_parallel_loss,
)
from ..core import collective_matmul as cm
from .params import LeafSpec, build_params, spec_tree_shapes, tp_info

Array = jax.Array


def _ceil_to(x, m):
    return ((x + m - 1) // m) * m


class Whisper:
    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig):
        self.cfg = cfg
        self.pcfg = pcfg
        self.info = tp_info(cfg, pcfg)
        self.frames_padded = _ceil_to(cfg.encoder_frames, max(pcfg.tp, 1))
        self.plan_n_enc = cfg.encoder_layers
        self.plan_n_dec = cfg.num_layers
        self._build_specs()

    @property
    def plan(self):
        class _P:
            n_super = self.plan_n_dec
        return _P()

    def _build_specs(self):
        cfg, info = self.cfg, self.info
        self.enc_specs = {
            "attn": blocks.attention_specs(cfg, info),
            "ffn": blocks.mlp_specs(cfg, info),
        }
        self.dec_specs = {
            "attn": blocks.attention_specs(cfg, info),
            "cross": blocks.attention_specs(cfg, info),
            "ffn": blocks.mlp_specs(cfg, info),
        }
        self.top_specs: Dict[str, LeafSpec] = {
            "embed": LeafSpec((info.vocab_loc, cfg.d_model), fan_in=cfg.d_model),
            "ln_enc": LeafSpec((cfg.d_model,), tp_sharded=False, init="ones"),
            "ln_f": LeafSpec((cfg.d_model,), tp_sharded=False, init="ones"),
        }

    def init(self, key, dtype=jnp.bfloat16):
        k1, k2, k3 = jax.random.split(key, 3)
        top, top_sp = build_params(self.top_specs, k1, self.pcfg, dtype=dtype)
        enc, enc_sp = build_params(self.enc_specs, k2, self.pcfg,
                                   layers=self.plan_n_enc, dtype=dtype)
        dec, dec_sp = build_params(self.dec_specs, k3, self.pcfg,
                                   layers=self.plan_n_dec, dtype=dtype)
        return (
            {"top": top, "encoder": enc, "layers": dec},
            {"top": top_sp, "encoder": enc_sp, "layers": dec_sp},
        )

    def param_shapes(self, dtype=jnp.bfloat16):
        top, top_sp = spec_tree_shapes(self.top_specs, self.pcfg, dtype=dtype)
        enc, enc_sp = spec_tree_shapes(self.enc_specs, self.pcfg,
                                       layers=self.plan_n_enc, dtype=dtype)
        dec, dec_sp = spec_tree_shapes(self.dec_specs, self.pcfg,
                                       layers=self.plan_n_dec, dtype=dtype)
        return (
            {"top": top, "encoder": enc, "layers": dec},
            {"top": top_sp, "encoder": enc_sp, "layers": dec_sp},
        )

    def _remat(self, fn):
        if self.pcfg.remat == "none":
            return fn
        if self.pcfg.remat == "dots":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return jax.checkpoint(fn)

    # ------------------------------------------------------------------
    def encode(self, params: dict, frames: Array) -> Array:
        """frames: (B, F_pad, D) replicated over tp -> (B, F_pad, D) replicated."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        b, f, d = frames.shape
        tp = pcfg.tp
        f_loc = f // tp
        me = lax.axis_index(MODEL_AXIS)
        h = lax.dynamic_slice(frames, (0, me * f_loc, 0), (b, f_loc, d))
        pos = me * f_loc + jnp.arange(f_loc)
        h = h + sinusoidal_positions(pos, d)[None].astype(h.dtype)

        def body(carry, xs):
            pa = get_params(xs["attn"], self.enc_specs["attn"], pcfg)
            pf = get_params(xs["ffn"], self.enc_specs["ffn"], pcfg)
            hh = blocks.attention_train(cfg, pcfg, info, pa, carry, causal=False)
            hh = blocks.mlp_train(cfg, pcfg, info, pf, hh)
            return hh, None

        h, _ = lax.scan(self._remat(body), h, params["encoder"])
        ln = fsdp_get(params["top"]["ln_enc"], self.top_specs["ln_enc"], pcfg, h.dtype)
        h = rmsnorm(h, ln, cfg.norm_eps)
        # decoder cross-attention needs the full encoder output on each rank
        full = cm.all_gather_chunked(
            h.transpose(1, 0, 2).reshape(f_loc, b * d), MODEL_AXIS
        )
        return full.reshape(f, b, d).transpose(1, 0, 2)

    def loss_local(
        self,
        params: dict,
        tokens: Array,  # (B_loc, S)
        labels: Array,
        extra: Optional[dict] = None,  # {"frames": (B_loc, F_pad, D)}
    ) -> Array:
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        enc_out = self.encode(params, extra["frames"])  # (B, F, D)
        b, s = tokens.shape
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        lbl_sp = lax.dynamic_slice(labels, (0, me * s_loc), (b, s_loc))
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg,
                         jnp.dtype(pcfg.compute_dtype))
        h = embed_lookup_sp(tokens, embed, info, tp)
        pos = me * s_loc + jnp.arange(s_loc)
        h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

        def body(carry, xs):
            pa = get_params(xs["attn"], self.dec_specs["attn"], pcfg)
            px = get_params(xs["cross"], self.dec_specs["cross"], pcfg)
            pf = get_params(xs["ffn"], self.dec_specs["ffn"], pcfg)
            hh = blocks.attention_train(cfg, pcfg, info, pa, carry)
            hh = blocks.attention_train(cfg, pcfg, info, px, hh, cross_src=enc_out)
            hh = blocks.mlp_train(cfg, pcfg, info, pf, hh)
            return hh, None

        h, _ = lax.scan(self._remat(body), h, params["layers"])
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        h = rmsnorm(h, ln_f, cfg.norm_eps).reshape(b * s_loc, cfg.d_model)
        w_out = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, h.dtype).T
        loss_sum, count = vocab_parallel_loss(
            h, w_out, lbl_sp.reshape(-1), info, cfg.vocab_size
        )
        axes = (MODEL_AXIS, DATA_AXIS) if pcfg.pods == 1 else (MODEL_AXIS, DATA_AXIS, "pod")
        return lax.psum(loss_sum, axes) / jnp.maximum(lax.psum(count, axes), 1.0)

    def prefill_logits_local(self, params, tokens, extra=None):
        """Forward-only prefill: last-token logits (B, vocab)."""
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        enc_out = self.encode(params, extra["frames"])
        b, s = tokens.shape
        tp = pcfg.tp
        s_loc = s // tp
        me = lax.axis_index(MODEL_AXIS)
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg,
                         jnp.dtype(pcfg.compute_dtype))
        h = embed_lookup_sp(tokens, embed, info, tp)
        pos = me * s_loc + jnp.arange(s_loc)
        h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

        def body(carry, xs):
            pa = get_params(xs["attn"], self.dec_specs["attn"], pcfg)
            px = get_params(xs["cross"], self.dec_specs["cross"], pcfg)
            pf = get_params(xs["ffn"], self.dec_specs["ffn"], pcfg)
            hh = blocks.attention_train(cfg, pcfg, info, pa, carry)
            hh = blocks.attention_train(cfg, pcfg, info, px, hh, cross_src=enc_out)
            hh = blocks.mlp_train(cfg, pcfg, info, pf, hh)
            return hh, None

        h, _ = lax.scan(self._remat(body), h, params["layers"])
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        # replicate the last rank's final row over TP before the
        # vocab-parallel projection — its input must be TP-replicated
        keep = (me == tp - 1).astype(h.dtype)
        h_last = lax.psum(h[:, -1, :] * keep, MODEL_AXIS)
        h_last = rmsnorm(h_last, ln_f, cfg.norm_eps)
        w_out = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg,
                         h.dtype).T
        return vocab_parallel_logits(h_last, w_out, info, cfg.vocab_size)

    # ------------------------------------------------------------------
    def cache_shapes(self, batch_local: int, s_max: int, dtype=jnp.bfloat16):
        cfg, info = self.cfg, self.info
        n, hd = self.plan_n_dec, cfg.head_dim
        fp = self.frames_padded
        return {
            "attn": {
                "k": jax.ShapeDtypeStruct((n, batch_local, info.hkv_loc, s_max, hd), dtype),
                "v": jax.ShapeDtypeStruct((n, batch_local, info.hkv_loc, s_max, hd), dtype),
            },
            "cross_k": jax.ShapeDtypeStruct((n, batch_local, info.hkv_loc, fp, hd), dtype),
            "cross_v": jax.ShapeDtypeStruct((n, batch_local, info.hkv_loc, fp, hd), dtype),
        }

    def _kv_seq_sharded(self):
        return False

    def decode_step_local(self, params, caches, cache_len, token):
        cfg, pcfg, info = self.cfg, self.pcfg, self.info
        b = token.shape[0]
        embed = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg,
                         jnp.dtype(pcfg.compute_dtype))
        h = embed_lookup(token, embed, info)
        pos = cache_len + jnp.arange(1)
        h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

        def body(carry, xs):
            hh = carry
            p_layer, cache = xs
            pa = get_params(p_layer["attn"], self.dec_specs["attn"], pcfg)
            px = get_params(p_layer["cross"], self.dec_specs["cross"], pcfg)
            pf = get_params(p_layer["ffn"], self.dec_specs["ffn"], pcfg)
            hh, ck, cv = blocks.attention_decode(
                cfg, pcfg, info, pa, hh,
                cache["attn"]["k"], cache["attn"]["v"], cache_len,
            )
            hh, _, _ = blocks.attention_decode(
                cfg, pcfg, info, px, hh,
                cache["cross_k"], cache["cross_v"], cache_len,
                cross_kv=(cache["cross_k"], cache["cross_v"]),
            )
            hh = blocks.mlp_decode(cfg, pcfg, info, pf, hh)
            new_cache = {
                "attn": {"k": ck, "v": cv},
                "cross_k": cache["cross_k"],
                "cross_v": cache["cross_v"],
            }
            return hh, new_cache

        h, new_caches = lax.scan(body, h, (params["layers"], caches))
        ln_f = fsdp_get(params["top"]["ln_f"], self.top_specs["ln_f"], pcfg, h.dtype)
        h = rmsnorm(h, ln_f, cfg.norm_eps).reshape(b, cfg.d_model)
        w_out = fsdp_get(params["top"]["embed"], self.top_specs["embed"], pcfg, h.dtype).T
        logits = vocab_parallel_logits(h, w_out, info, cfg.vocab_size)
        return logits, new_caches
