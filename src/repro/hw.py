"""Target hardware constants (TPU v5e) used by the roofline analysis and
the analytic autotuner. This container runs on CPU; v5e is the TARGET."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link per direction
    ici_links: int  # links per chip (2D torus)
    hbm_bytes: int  # capacity per chip
    vmem_bytes: int
    # inter-pod (DCN-ish) effective per-chip bandwidth for the pod axis
    pod_link_bandwidth: float = 6.25e9
    # fixed per-message cost of one ICI transfer (hop latency + DMA
    # descriptor setup): what sub-chunking trades bandwidth against
    ici_msg_overhead: float = 1e-6


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

DEFAULT = TPU_V5E
