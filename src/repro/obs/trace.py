"""Export a per-PE event trace as Chrome-trace / Perfetto JSON.

Open the written file in ``ui.perfetto.dev`` (or ``chrome://tracing``).
The layout mirrors how you read an overlap schedule: one *process* per
collective_id (one overlapped kernel), one *thread track* per PE, so a
4-PE ring shows four stacked timelines whose ``tile_compute`` spans
interleave with ``credit_wait`` / ``arrival_wait`` stalls — exposed
communication is literally visible as gaps the compute failed to cover.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Optional

from . import TraceEvent


def chrome_trace(events: Iterable[TraceEvent]) -> dict:
    """Build the Chrome-trace dict (``traceEvents`` list of complete
    "X" events, microsecond timestamps normalized to the trace start)."""
    events = list(events)
    t0 = min((ev.t0 for ev in events), default=0.0)
    rows: List[dict] = []
    seen_pids = set()
    seen_tracks = set()
    for ev in events:
        pid, tid = ev.cid, ev.pe
        if pid not in seen_pids:
            seen_pids.add(pid)
            rows.append({"ph": "M", "name": "process_name", "pid": pid,
                         "args": {"name": f"shmem cid {pid}"}})
        if (pid, tid) not in seen_tracks:
            seen_tracks.add((pid, tid))
            rows.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": f"PE {tid}"}})
        args = {"cid": ev.cid}
        if ev.bytes:
            args["bytes"] = ev.bytes
        rows.append({
            "ph": "X",
            "name": f"{ev.kind}:{ev.name}" if ev.name else ev.kind,
            "cat": ev.kind,
            "pid": pid,
            "tid": tid,
            "ts": (ev.t0 - t0) * 1e6,
            # sub-us durations still render as slivers instead of vanishing
            "dur": max((ev.t1 - ev.t0) * 1e6, 0.05),
            "args": args,
        })
    return {"traceEvents": rows, "displayTimeUnit": "ms"}


def save(path: str, events: Optional[Iterable[TraceEvent]] = None) -> int:
    """Write the Chrome-trace JSON for ``events`` (default: drain the
    live ring buffers via :func:`repro.obs.events`). Returns the number
    of events written."""
    if events is None:
        from . import events as _drain

        events = _drain()
    events = list(events)
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f)
    return len(events)
