"""Reduce a per-PE event trace into overlap-efficiency stats.

The reduction answers the question the whole repo exists to answer: of
the wall time a kernel took, how much communication was actually HIDDEN
behind compute? Per PE:

    stall   = sum of credit_wait + arrival_wait span durations, plus
              every barrier AFTER a PE's first per kernel instance
    compute = sum of tile_compute span durations

and across the trace:

    wall               = max(t1) - min(t0)
    exposed_comm       = mean per-PE stall
    overlap_efficiency = 1 - exposed_comm / wall        (clamped to [0, 1])

A perfectly-overlapped schedule has waits that return immediately
(the DMA landed while the previous tile computed) — exposed_comm ~ 0,
efficiency ~ 1. A serialized schedule spends whole chunk-flights inside
``signal_wait_until`` — efficiency falls toward 0.

Barriers split by position: the FIRST barrier a PE executes in a
kernel instance (per ``(pe, cid)``) is the launch rendezvous — it
measures launch skew, not schedule quality, and lands in the separate
``barrier`` bucket. Every LATER barrier in the same instance is a
MID-STREAM flush — PEs idling at a rendezvous the schedule put in the
middle of the work, e.g. the rs-exit barrier a back-to-back unfused
rs->ag pair pays at the op boundary — and counts as exposed comm.
Chained protocols that drop those rendezvous (``push_rs_ring_ag``)
read better here by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from . import COMPUTE_KINDS, STALL_KINDS, TraceEvent


@dataclasses.dataclass(frozen=True)
class Summary:
    """Per-(op, mode, backend, wire) overlap accounting for one trace."""

    wall: float                # seconds, max(t1) - min(t0) across PEs
    compute_busy: float        # mean per-PE tile_compute seconds
    exposed_comm: float        # mean per-PE stall seconds (credit +
    #                            arrival + mid-stream barrier flushes)
    barrier: float             # mean per-PE launch-rendezvous seconds
    #                            (first barrier per (pe, cid) only)
    wire_bytes: int            # total bytes pushed over the (emulated) wire
    overlap_efficiency: float  # 1 - exposed_comm / wall, in [0, 1]
    stall_frac: float          # exposed_comm / wall, in [0, 1]
    n_pes: int
    n_events: int
    per_pe: Dict[int, Dict[str, float]]  # pe -> {compute, stall, barrier}
    labels: Dict[str, str]     # caller-supplied (op/mode/backend/wire/...)

    def __str__(self) -> str:  # compact log line
        lab = " ".join(f"{k}={v}" for k, v in self.labels.items())
        return (f"Summary({lab + ' ' if lab else ''}wall={self.wall * 1e3:.2f}ms "
                f"compute={self.compute_busy * 1e3:.2f}ms "
                f"exposed={self.exposed_comm * 1e3:.2f}ms "
                f"wire={self.wire_bytes}B "
                f"overlap_eff={self.overlap_efficiency:.3f} "
                f"pes={self.n_pes} events={self.n_events})")


def split_by_cid(events: Iterable[TraceEvent]) -> Dict[int, List[TraceEvent]]:
    """Group a mixed trace by collective_id (one op's kernels per cid)."""
    out: Dict[int, List[TraceEvent]] = {}
    for ev in events:
        out.setdefault(ev.cid, []).append(ev)
    return out


def summarize(
    events: Iterable[TraceEvent],
    *,
    op: Optional[str] = None,
    mode: Optional[str] = None,
    backend: Optional[str] = None,
    wire: Optional[str] = None,
    **extra_labels: str,
) -> Summary:
    """Reduce ``events`` to a :class:`Summary`.

    The trace itself carries no op identity — pass the run's resolved
    ``(op, mode, backend, wire)`` as labels (benchmark rows and the
    tuner do; they ride along in the returned summary). Raises
    ``ValueError`` on an empty trace.
    """
    events = list(events)
    if not events:
        raise ValueError(
            "obs.metrics.summarize: empty trace — was obs.enable() called "
            "before the program was first compiled and run?")
    t_lo = min(ev.t0 for ev in events)
    t_hi = max(ev.t1 for ev in events)
    wall = max(t_hi - t_lo, 1e-12)
    per_pe: Dict[int, Dict[str, float]] = {}
    wire_bytes = 0
    # (pe, cid) -> (t0, dur) of the earliest barrier seen: the launch
    # rendezvous; any other barrier of the instance is a mid-stream
    # flush and counts as stall (see module docstring)
    launch: Dict[tuple, tuple] = {}
    for ev in events:
        acc = per_pe.setdefault(ev.pe, {"compute": 0.0, "stall": 0.0,
                                        "barrier": 0.0})
        dur = max(0.0, ev.t1 - ev.t0)
        if ev.kind in COMPUTE_KINDS:
            acc["compute"] += dur
        elif ev.kind in STALL_KINDS:
            acc["stall"] += dur
        elif ev.kind == "barrier":
            key = (ev.pe, ev.cid)
            prev = launch.get(key)
            if prev is None:
                launch[key] = (ev.t0, dur)
            elif ev.t0 < prev[0]:  # unsorted input: prev was mid-stream
                acc["stall"] += prev[1]
                launch[key] = (ev.t0, dur)
            else:
                acc["stall"] += dur
        if ev.kind == "put":
            wire_bytes += ev.bytes
    for (pe, _), (_, dur) in launch.items():
        per_pe[pe]["barrier"] += dur
    n = len(per_pe)
    compute = sum(a["compute"] for a in per_pe.values()) / n
    exposed = sum(a["stall"] for a in per_pe.values()) / n
    barrier = sum(a["barrier"] for a in per_pe.values()) / n
    stall_frac = min(1.0, exposed / wall)
    labels = {k: v for k, v in (("op", op), ("mode", mode),
                                ("backend", backend), ("wire", wire))
              if v is not None}
    labels.update(extra_labels)
    return Summary(
        wall=wall,
        compute_busy=compute,
        exposed_comm=exposed,
        barrier=barrier,
        wire_bytes=wire_bytes,
        overlap_efficiency=max(0.0, 1.0 - stall_frac),
        stall_frac=stall_frac,
        n_pes=n,
        n_events=len(events),
        per_pe=per_pe,
        labels=labels,
    )
