"""repro.obs — per-PE overlap timelines for the shmem engine.

The paper's claim is that compiler-generated overlapping kernels hide
communication latency. This package makes the overlap *visible*: when
tracing is enabled, every host-side op of the emulated DMA backend
(:mod:`repro.shmem.emulated`) appends a timestamped per-PE
:class:`TraceEvent` into its world's ring buffer — puts, signals,
credit/arrival waits, barriers, reads — and the tile executor brackets
its per-chunk computes with ``tile_compute`` (and wire ``pack`` /
``decode``) spans. A drained event list exports as a Chrome-trace /
Perfetto JSON (:mod:`repro.obs.trace`) and reduces to overlap-efficiency
stats (:mod:`repro.obs.metrics`):

    overlap_efficiency = 1 - exposed_comm / wall

where ``exposed_comm`` is the mean per-PE stall time (credit waits +
arrival waits + mid-stream barrier flushes — the communication and
synchronization the schedule failed to hide behind compute; only a
PE's FIRST barrier per kernel instance is launch skew, reported
separately).

Semantics
---------
* ``enable()`` / ``disable()`` flip one global flag. Host-side event
  recording is gated at RUN time (one bool check per callback — no
  measurable overhead when disabled), but the executor's compute *spans*
  are gated at TRACE time: enable tracing BEFORE the first
  jit-compilation of the program you want span-annotated (a program
  traced while disabled carries no span callbacks, and jax's jit cache
  will keep reusing it). With tracing disabled the traced program is the
  seed program — outputs are bit-identical.
* On the real-TPU pltpu backend there are no host callbacks to
  timestamp; the SAME span labels are mapped onto ``jax.named_scope`` +
  ``jax.profiler.TraceAnnotation`` (see :func:`phase`), so a real
  profiler capture (``jax.profiler.trace``) carries identical
  ``obs.tile_compute`` / ``obs.pack`` / ``obs.decode`` labels.
* Trace buffers live per shmem world (per traced-kernel instance) and
  are bounded rings: ``enable(capacity=...)`` sets the per-world event
  cap. ``shmem.emulated.reset()`` drops the worlds and their traces —
  drain with :func:`events` first.

Quickstart (see ``examples/trace_overlap.py``)::

    from repro import obs
    obs.enable()
    y = step()                      # emulated kernel-backend run
    ev = obs.events(clear=True)
    obs.trace.save("trace.json", ev)          # open in ui.perfetto.dev
    print(obs.metrics.summarize(ev))
"""
from __future__ import annotations

import collections
import contextlib
import threading
from typing import List, NamedTuple, Optional


class TraceEvent(NamedTuple):
    """One timestamped per-PE event of the emulated shmem engine."""

    pe: int        # the PE (linearized rank) the event belongs to
    cid: int       # collective_id of the kernel instance
    kind: str      # put | signal | credit_wait | arrival_wait | barrier |
    #                read | alloc | tile_compute | pack | decode
    name: str      # symmetric buffer / signal name ("" for spans)
    bytes: int     # payload bytes (puts/reads; 0 otherwise)
    t0: float      # span begin, seconds (time.perf_counter clock)
    t1: float      # span end, seconds


# Event kinds counted as exposed communication (stall) by the metrics
# reduction: credit waits (flow control) and arrival waits (data deps).
STALL_KINDS = ("credit_wait", "arrival_wait")
# Event kinds counted as compute-busy time.
COMPUTE_KINDS = ("tile_compute",)

_lock = threading.Lock()
_enabled = False
_capacity = 65536


def enabled() -> bool:
    """Is tracing on? Checked at run time by the emulated host ops and at
    trace time by the executor's span instrumentation."""
    return _enabled


def capacity() -> int:
    """Per-world ring-buffer capacity (events)."""
    return _capacity


def enable(capacity: Optional[int] = None) -> None:
    """Turn tracing on (optionally resizing the per-world ring buffers).

    Enable BEFORE the first compilation of the program you want
    span-annotated — span instrumentation is decided at trace time.
    """
    global _enabled, _capacity
    from ..shmem import emulated as em  # lazy: avoid import cycle

    with _lock:
        if capacity is not None:
            _capacity = int(capacity)
        _enabled = True
    with em._worlds_lock:
        worlds = list(em._worlds.values())
    for w in worlds:
        with w.cond:
            if w.trace.maxlen != _capacity:
                w.trace = collections.deque(w.trace, maxlen=_capacity)


def disable() -> None:
    """Turn tracing off (recorded events stay until :func:`clear` or
    ``shmem.emulated.reset``)."""
    global _enabled
    with _lock:
        _enabled = False


@contextlib.contextmanager
def tracing(capacity: Optional[int] = None):
    """Scoped ``enable()`` / ``disable()``."""
    enable(capacity)
    try:
        yield
    finally:
        disable()


def events(clear: bool = False) -> List[TraceEvent]:
    """Drain the per-world ring buffers into one t0-sorted event list.

    ``clear=True`` empties the buffers (and any un-ended pending spans)
    after collecting — use it to attribute events to one run at a time.
    """
    from ..shmem import emulated as em

    with em._worlds_lock:
        worlds = list(em._worlds.values())
    out: List[TraceEvent] = []
    for w in worlds:
        with w.cond:
            out.extend(w.trace)
            if clear:
                w.trace.clear()
                w.pending.clear()
    out.sort(key=lambda ev: ev.t0)
    return out


def clear() -> None:
    """Empty every world's trace ring buffer."""
    events(clear=True)


@contextlib.contextmanager
def phase(kind: str, name: str = ""):
    """The backend-independent span label: ``obs.<kind>[.<name>]``.

    Enters ``jax.named_scope`` (the label lands in XLA op metadata, so
    real-TPU profiles of the pltpu protocols carry the same
    ``obs.tile_compute`` / ``obs.pack`` / ``obs.decode`` names the
    emulated timeline records) and, when available,
    ``jax.profiler.TraceAnnotation`` (host-side perfetto annotation for
    profiled runs). Zero runtime cost inside jit — named scopes are
    trace-time metadata.
    """
    import jax

    label = f"obs.{kind}" + (f".{name}" if name else "")
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.named_scope(label))
        try:
            stack.enter_context(jax.profiler.TraceAnnotation(label))
        except Exception:  # profiler backend unavailable: label via scope only
            pass
        yield


from . import metrics, trace  # noqa: E402  (need the names above)

__all__ = [
    "TraceEvent",
    "STALL_KINDS",
    "COMPUTE_KINDS",
    "enabled",
    "enable",
    "disable",
    "tracing",
    "capacity",
    "events",
    "clear",
    "phase",
    "metrics",
    "trace",
]
