"""End-to-end training driver with fault tolerance.

Runs on any mesh (CPU smoke: --dp 2 --tp 2 with 4 virtual devices via
XLA_FLAGS, or the production pod). Features exercised here:
  - deterministic restart-reproducible data pipeline
  - checkpoint/restart (atomic, async, GC) + NaN-skip straggler guard
  - the overlapped train step (AG+GEMM / GEMM+RS everywhere)

Usage (CPU smoke):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.train --arch granite-3-2b --reduced --dp 2 --tp 2 \
      --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, reduced
from ..configs.base import ParallelConfig, ShapeConfig, TrainConfig
from ..data.pipeline import SyntheticTokens
from ..train import optimizer as opt_mod
from ..train.checkpoint import Checkpointer
from .mesh import make_mesh
from .steps import build_train_step, batch_spec


def run(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    pcfg = ParallelConfig(
        dp=args.dp, tp=args.tp, pods=args.pods,
        fsdp=not args.no_fsdp,
        overlap_mode=args.overlap,
        remat=args.remat,
        param_dtype=args.dtype, compute_dtype=args.dtype,
    )
    tcfg = TrainConfig(
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        learning_rate=args.lr, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
    )
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch, kind="train")
    mesh = make_mesh(args.dp, args.tp, args.pods)
    built = build_train_step(cfg, pcfg, shape, mesh, tcfg)
    model = built.model

    key = jax.random.PRNGKey(tcfg.seed)
    params, _ = model.init(key, jnp.dtype(pcfg.param_dtype))
    opt_state = opt_mod.init_opt_state(
        params, jnp.dtype(pcfg.moment_dtype), kind=tcfg.optimizer
    )

    ckpt = Checkpointer(tcfg.checkpoint_dir, keep=3)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None and not args.fresh:
        state = ckpt.restore(latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = latest
        print(f"[restore] resumed from step {latest}")

    data = SyntheticTokens(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=tcfg.seed, mesh=mesh,
        batch_sharding=batch_spec(shape.global_batch, pcfg),
    )

    losses = []
    t0 = time.time()
    skipped = 0
    for step, (tokens, labels) in (
        (s, data.batch_at(s)) for s in range(start_step, args.steps)
    ):
        params, opt_state, _, metrics = built.fn(
            params, opt_state, tokens, labels, None
        )
        loss = float(metrics.loss)
        if not np.isfinite(loss):
            # fault/straggler guard: the compiled step already froze
            # params + optimizer state in-graph (donation-safe); just log
            skipped += 1
            print(f"step {step}: non-finite loss, update skipped in-graph")
            continue
        losses.append(loss)
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(
                f"step {step:5d} loss={loss:.4f} gnorm={float(metrics.grad_norm):.3f} "
                f"lr={float(metrics.lr):.2e} ({dt:.1f}s)"
            )
        if tcfg.checkpoint_every and step > 0 and step % tcfg.checkpoint_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    ckpt.save(args.steps, {"params": params, "opt": opt_state}, blocking=True)
    if losses:
        print(
            f"done: {len(losses)} steps, loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
            f"{skipped} skipped, {(time.time()-t0):.1f}s"
        )
    else:
        print("done: nothing to do (already past target step)")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--overlap", default="ring",
                    choices=["ring", "bidir", "one_shot", "none", "auto"],
                    help="overlap transport; 'auto' asks the analytic "
                         "tuner for a whole OverlapPolicy")
    ap.add_argument("--remat", default="block", choices=["none", "dots", "block"])
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
