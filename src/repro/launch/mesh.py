"""Mesh construction for the production topology.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
slow inter-pod boundary (gradient sync rides it; see dist/compress.py).

A FUNCTION, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(dp: int, tp: int, pods: int = 1):
    """Arbitrary-size mesh (tests / CPU smoke runs)."""
    if pods > 1:
        return jax.make_mesh(
            (pods, dp, tp), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (dp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
