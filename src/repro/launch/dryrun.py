import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init). 512 virtual CPU devices back both production
meshes: (16, 16) single-pod and (2, 16, 16) multi-pod.

Per cell this prints/records:
  - compiled.memory_analysis()  (bytes per device -> fits 16 GB?)
  - compiled.cost_analysis()    (FLOPs / bytes for the roofline)
  - parsed collective wire bytes + the three roofline terms

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out reports/dryrun]
"""
import argparse
import json
import sys
import time
import traceback


from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from . import roofline
from .mesh import make_production_mesh
from .steps import build_step, default_pcfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             overlap_mode: str = "ring", force: bool = False, tag: str = ""):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_desc}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(out_path) and not force:
        print(f"[skip-cached] {cell}")
        with open(out_path) as f:
            return json.load(f)
    if not shape_applicable(cfg.family, shape):
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
                  "skipped": True,
                  "reason": "long_500k requires sub-quadratic sequence mixing "
                            "(see DESIGN.md §Arch-applicability)"}
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[skip-inapplicable] {cell}")
        return report

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.flatten())
    pcfg = default_pcfg(cfg, shape, multi_pod=multi_pod, overlap_mode=overlap_mode)
    built = build_step(cfg, pcfg, shape, mesh)
    lowered = built.fn.lower(*built.in_shapes)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    trips = built.model.plan.n_super
    training = shape.kind == "train"
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    else:
        tokens = shape.tokens
    model_flops = cfg.flops_per_token(training=training) * tokens
    rep = roofline.analyze(
        arch=arch,
        shape_name=shape_name,
        mesh_desc=mesh_desc,
        chips=chips,
        cost=cost,
        memory_stats=mem,
        hlo_text=hlo,
        loop_trips=trips,
        model_flops_total=model_flops,
        links_used={"ring": 1, "bidir": 2, "one_shot": 4, "none": 2}.get(
            pcfg.policy.resolve("ag_matmul").mode, 1),
        backward=training,
    )
    out = json.loads(rep.to_json())
    out.update(
        skipped=False,
        seconds_to_compile=round(time.time() - t0, 1),
        overlap_mode=overlap_mode,
        memory_analysis=dict(
            argument_size_in_bytes=mem.argument_size_in_bytes,
            output_size_in_bytes=mem.output_size_in_bytes,
            temp_size_in_bytes=mem.temp_size_in_bytes,
            alias_size_in_bytes=mem.alias_size_in_bytes,
            generated_code_size_in_bytes=mem.generated_code_size_in_bytes,
        ),
        collective_counts=roofline.parse_collectives(hlo, loop_trips=trips).op_counts,
    )
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(
        f"[ok] {cell}: compute={rep.t_compute*1e3:.2f}ms "
        f"memory={rep.t_memory*1e3:.2f}ms collective={rep.t_collective*1e3:.2f}ms "
        f"dominant={rep.dominant} dev_bytes={rep.device_bytes/2**30:.2f}GiB "
        f"fits={rep.fits_hbm} useful={rep.useful_flops_ratio:.2f} "
        f"(compile {out['seconds_to_compile']}s)"
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--overlap", default="ring",
                    choices=["ring", "bidir", "one_shot", "none", "auto"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = sorted(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multipod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                             overlap_mode=args.overlap, force=args.force,
                             tag=args.tag)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
                    print(f"[FAIL] {arch} {shape} multipod={mp}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
