from . import mesh, roofline, steps
