"""Roofline analysis from the compiled dry-run artifact (§Roofline).

Three terms per (arch x shape x mesh):

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = wire_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
wire bytes are NOT in cost_analysis: we parse ``compiled.as_text()`` and
sum, per collective op, the bytes that actually cross a link per device:

  all-gather        out_bytes * (W-1)/W       (ring receive)
  reduce-scatter    in_bytes  * (W-1)/W
  all-reduce        2 * bytes * (W-1)/W       (RS + AG halves)
  collective-permute out_bytes                 (one hop)
  all-to-all        out_bytes * (W-1)/W

Ops inside a scanned layer loop (detected via the ``while`` marker in the
op metadata) execute n_super times; the parser multiplies them by the
supplied trip count. cost_analysis' loop handling is validated in tests
against an analytic 6ND model (the MODEL_FLOPS/HLO_FLOPs ratio column).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Dict

from .. import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|collective-permute|all-to-all)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")
_PARAM_CONVERT_RE = re.compile(
    r"%wrapped_convert[.\d]* = (f32\[[0-9,]+\])[^\n]*fusion\(%param[.\d]*\)"
)


def cpu_bf16_artifact_bytes(hlo_text: str) -> float:
    """Sum f32 convert-of-parameter fusion buffers (see RooflineReport)."""
    total = 0.0
    for m in _PARAM_CONVERT_RE.finditer(hlo_text):
        total += _type_bytes(m.group(1))
    return total


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    wire_bytes: float  # per device, trip-multiplied
    op_counts: Dict[str, int]
    op_bytes: Dict[str, float]


def parse_collectives(hlo_text: str, *, loop_trips: int = 1) -> CollectiveStats:
    """Sum per-device wire bytes of every collective in compiled HLO text.

    ``-done`` halves of async pairs carry no shape work and are skipped by
    the regex (only the defining ``...-start(`` / sync form matches).
    """
    wire = 0.0
    counts: Dict[str, int] = {}
    bytes_by: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        out_bytes = _type_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        w = len(gm.group(1).split(",")) if gm else 2
        frac = (w - 1) / w if w > 1 else 1.0
        if op == "all-gather":
            b = out_bytes * frac
        elif op == "reduce-scatter":
            b = out_bytes * w * frac  # operand bytes ~ out * W
        elif op == "all-reduce":
            b = 2.0 * out_bytes * frac
        elif op == "all-to-all":
            b = out_bytes * frac
        else:  # collective-permute: one hop, full buffer
            b = float(out_bytes)
        trips = loop_trips if "while" in line else 1
        wire += b * trips
        counts[op] = counts.get(op, 0) + trips
        bytes_by[op] = bytes_by.get(op, 0.0) + b * trips
    return CollectiveStats(wire, counts, bytes_by)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: float
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    useful_flops_ratio: float
    # memory footprint
    device_bytes: float
    fits_hbm: bool
    # CPU-backend artifact: XLA:CPU has no native bf16 dot, so it inserts
    # f32 converts of the dot operands and HOISTS the loop-invariant weight
    # converts out of the layer scan — whole-parameter-stack f32 copies
    # that do NOT exist on TPU (bf16 feeds the MXU directly). We count
    # those hoisted param-convert buffers and report an adjusted figure.
    cpu_bf16_artifact_bytes: float
    device_bytes_tpu_adjusted: float
    fits_hbm_adjusted: bool
    collective_detail: Dict[str, float]

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def normalize_cost(cost) -> dict:
    """``compiled.cost_analysis()`` returns a dict on current jax and a
    one-element list of dicts on older builds; accept both."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost


def analyze(
    *,
    arch: str,
    shape_name: str,
    mesh_desc: str,
    chips: int,
    cost: dict,
    memory_stats,
    hlo_text: str,
    loop_trips: int,
    model_flops_total: float,
    spec: hw.HardwareSpec = hw.DEFAULT,
    links_used: int = 1,
    backward: bool = True,
) -> RooflineReport:
    """Build the three-term roofline report for one dry-run cell.

    cost_analysis on this JAX/XLA build does NOT multiply while-loop bodies
    by their trip count (validated in tests/test_roofline.py), so we scale
    flops/bytes by ``loop_trips`` for the scanned layer stack. The
    unscanned head/tail is a small correction, folded into the ratio
    column rather than double-counted.
    """
    cost = normalize_cost(cost)
    flops_dev = float(cost.get("flops", 0.0)) * loop_trips
    bytes_dev = float(cost.get("bytes accessed", 0.0)) * loop_trips
    coll = parse_collectives(hlo_text, loop_trips=loop_trips)

    t_comp = flops_dev / spec.peak_flops_bf16
    t_mem = bytes_dev / spec.hbm_bandwidth
    t_coll = coll.wire_bytes / (spec.ici_link_bandwidth * links_used)
    dominant = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    flops_total = flops_dev * chips
    ratio = model_flops_total / flops_total if flops_total else 0.0
    dev_bytes = float(
        memory_stats.output_size_in_bytes
        + memory_stats.temp_size_in_bytes
        + memory_stats.argument_size_in_bytes
        - memory_stats.alias_size_in_bytes
    )
    # fwd (+ bwd when training) keep hoisted f32 weight-convert copies on CPU
    artifact = (2.0 if backward else 1.0) * cpu_bf16_artifact_bytes(hlo_text)
    adjusted = max(dev_bytes - artifact, 0.0)
    return RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_desc,
        chips=chips,
        hlo_flops_per_device=flops_dev,
        hlo_bytes_per_device=bytes_dev,
        coll_bytes_per_device=coll.wire_bytes,
        model_flops_total=model_flops_total,
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        dominant=dominant,
        useful_flops_ratio=ratio,
        device_bytes=dev_bytes,
        fits_hbm=dev_bytes <= spec.hbm_bytes,
        cpu_bf16_artifact_bytes=artifact,
        device_bytes_tpu_adjusted=adjusted,
        fits_hbm_adjusted=adjusted <= spec.hbm_bytes,
        collective_detail=coll.op_bytes,
    )
