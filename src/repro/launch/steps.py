"""Step builders: wrap the shard_map-local model functions into jitted
SPMD programs with the correct input/output shardings for a given
(arch config, parallel config, shape cell, mesh).

This is the single place where logical batch placement is decided:
  batch dim -> ("pod", "data") when global_batch >= dp*pods, replicated
  otherwise (e.g. long_500k with global_batch=1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig
from ..models import build_model
from ..train import optimizer as opt_mod
from ..train.train_step import make_train_step


def batch_axes(pcfg: ParallelConfig):
    return ("pod", "data") if pcfg.pods > 1 else ("data",)


def data_world(pcfg: ParallelConfig) -> int:
    return pcfg.dp * pcfg.pods


def batch_spec(global_batch: int, pcfg: ParallelConfig, extra_dims: int = 1) -> P:
    if global_batch >= data_world(pcfg):
        return P(batch_axes(pcfg), *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def local_batch(global_batch: int, pcfg: ParallelConfig) -> int:
    w = data_world(pcfg)
    return global_batch // w if global_batch >= w else global_batch


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, pcfg: ParallelConfig, model=None
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (shapes, pspecs) for the step inputs of this cell."""
    gb, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    bspec = batch_spec(gb, pcfg)
    shapes: Dict[str, Any] = {}
    pspecs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        shapes["tokens"] = tok
        pspecs["tokens"] = bspec
        if shape.kind == "train":
            shapes["labels"] = tok
            pspecs["labels"] = bspec
        if cfg.family == "vlm":
            shapes["vision"] = jax.ShapeDtypeStruct(
                (gb, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16
            )
            pspecs["vision"] = batch_spec(gb, pcfg, extra_dims=2)
        if cfg.family == "whisper":
            fp = model.frames_padded if model is not None else cfg.encoder_frames
            shapes["frames"] = jax.ShapeDtypeStruct((gb, fp, cfg.d_model), jnp.bfloat16)
            pspecs["frames"] = batch_spec(gb, pcfg, extra_dims=2)
    else:  # decode: one new token + KV caches of length seq_len
        shapes["token"] = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
        pspecs["token"] = bspec
    return shapes, pspecs


def cache_specs(model, shape: ShapeConfig, pcfg: ParallelConfig, dtype=jnp.bfloat16):
    """Global cache ShapeDtypeStructs + pspecs for decode cells."""
    gb = shape.global_batch
    b_loc = local_batch(gb, pcfg)
    local = model.cache_shapes(b_loc, shape.seq_len, dtype)
    batched = gb >= data_world(pcfg)
    seq_sharded = model._kv_seq_sharded()
    baxes = batch_axes(pcfg)

    def globalize(leaf, name):
        shape_l = list(leaf.shape)
        spec = [None] * len(shape_l)
        # find batch dim: caches are (n_super, [n_sub,] B, ...) — B is the
        # dim whose size equals b_loc at index 1 or 2.
        b_idx = 1 if shape_l[1] == b_loc else 2
        if batched:
            shape_l[b_idx] = b_loc * data_world(pcfg)
            spec[b_idx] = baxes if len(baxes) > 1 else baxes[0]
        elif seq_sharded and name in ("k", "v"):
            # sequence-sharded KV over "data" (distributed flash decode)
            shape_l[-2] = leaf.shape[-2] * pcfg.dp
            spec[-2] = "data"
        return jax.ShapeDtypeStruct(tuple(shape_l), leaf.dtype), P(*spec)

    shapes, specs = {}, {}
    for k, v in local.items():
        if isinstance(v, dict):
            sub_s, sub_p = {}, {}
            for kk, vv in v.items():
                sub_s[kk], sub_p[kk] = globalize(vv, kk)
            shapes[k], specs[k] = sub_s, sub_p
        else:
            shapes[k], specs[k] = globalize(v, k)
    return shapes, specs


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclass
class BuiltStep:
    fn: Any  # jitted
    in_shapes: Tuple
    in_pspecs: Tuple
    model: Any


def _shard(mesh, fn, in_specs, out_specs, donate=()):
    return jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False),
        donate_argnums=donate,
    )


def build_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    shape: ShapeConfig,
    mesh,
    tcfg: Optional[TrainConfig] = None,
) -> BuiltStep:
    if tcfg is None:
        tcfg = TrainConfig(
            optimizer="momentum" if cfg.param_count() > 500e9 else "adamw"
        )
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    mdt = jnp.dtype(pcfg.moment_dtype)
    opt_shapes = opt_mod.opt_state_shapes(param_shapes, mdt, kind=tcfg.optimizer)
    # optimizer state shards exactly like params (nu is a placeholder in
    # momentum mode -> replicated)
    nu_pspec = (
        jax.tree.map(lambda _: P(), param_shapes) if tcfg.optimizer == "momentum"
        else pspec
    )
    opt_pspec = opt_mod.OptState(P(), pspec, nu_pspec)
    if cfg.family == "whisper":
        spec_tree = {"top": model.top_specs, "encoder": model.enc_specs,
                     "layers": model.dec_specs}
    else:
        spec_tree = {"top": model.top_specs, "layers": model.layer_specs}
    step_local = make_train_step(model, tcfg, pcfg, spec_tree)

    in_shapes, in_pspecs = input_specs(cfg, shape, pcfg, model)

    def fn(params, opt_state, tokens, labels, extra):
        return step_local(params, opt_state, None, tokens, labels, extra)

    extra_keys = [k for k in in_shapes if k not in ("tokens", "labels")]
    extra_shapes = {k: in_shapes[k] for k in extra_keys} if extra_keys else None
    extra_specs = {k: in_pspecs[k] for k in extra_keys} if extra_keys else None

    from ..train.train_step import TrainStepOut

    jitted = _shard(
        mesh,
        fn,
        (pspec, opt_pspec, in_pspecs["tokens"], in_pspecs["labels"], extra_specs),
        (pspec, opt_pspec, None, TrainStepOut(P(), P(), P())),
        donate=(0, 1),  # params + optimizer state update in place
    )
    all_shapes = (param_shapes, opt_shapes, in_shapes["tokens"],
                  in_shapes["labels"], extra_shapes)
    return BuiltStep(jitted, all_shapes, (pspec, opt_pspec), model)


def build_prefill_step(
    cfg: ModelConfig, pcfg: ParallelConfig, shape: ShapeConfig, mesh
) -> BuiltStep:
    """Forward-only (inference prefill): full-sequence forward, last-token
    logits out. No optimizer, no backward."""
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    in_shapes, in_pspecs = input_specs(cfg, shape, pcfg, model)

    def fn(params, tokens, extra):
        return model.prefill_logits_local(params, tokens, extra)

    extra_keys = [k for k in in_shapes if k != "tokens"]
    extra_shapes = {k: in_shapes[k] for k in extra_keys} if extra_keys else None
    extra_specs = {k: in_pspecs[k] for k in extra_keys} if extra_keys else None
    out_spec = batch_spec(shape.global_batch, pcfg)
    jitted = _shard(mesh, fn, (pspec, in_pspecs["tokens"], extra_specs), out_spec)
    return BuiltStep(jitted, (param_shapes, in_shapes["tokens"], extra_shapes),
                     (pspec,), model)


def build_decode_step(
    cfg: ModelConfig, pcfg: ParallelConfig, shape: ShapeConfig, mesh,
    cache_dtype=jnp.bfloat16,
) -> BuiltStep:
    """serve_step: one new token against KV caches of length seq_len."""
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    in_shapes, in_pspecs = input_specs(cfg, shape, pcfg, model)
    c_shapes, c_specs = cache_specs(model, shape, pcfg, cache_dtype)

    def fn(params, caches, cache_len, token):
        return model.decode_step_local(params, caches, cache_len, token)

    out_logits_spec = batch_spec(shape.global_batch, pcfg)
    jitted = _shard(
        mesh,
        fn,
        (pspec, c_specs, None, in_pspecs["token"]),
        (out_logits_spec, c_specs),
        donate=(1,),  # KV caches update in place
    )
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    return BuiltStep(
        jitted,
        (param_shapes, c_shapes, cache_len, in_shapes["token"]),
        (pspec, c_specs),
        model,
    )


def _pool_specs(model, num_pages: int, page_size: int, dtype):
    """Paged KV pools are replicated specs (each rank holds its own
    heads-local replica, like the dense decode caches)."""
    shapes = model.paged_cache_shapes(num_pages, page_size, dtype)
    specs = jax.tree.map(lambda s: P(*([None] * len(s.shape))), shapes)
    return shapes, specs


def build_paged_decode_step(
    cfg: ModelConfig, pcfg: ParallelConfig, shape: ShapeConfig, mesh,
    *, num_pages: int, page_size: int, pages_per_slot: int,
    cache_dtype=jnp.bfloat16,
) -> BuiltStep:
    """Decode step against the paged KV pools: one new token per slot at
    per-slot positions (serve/kvcache.py block tables)."""
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    p_shapes, p_specs = _pool_specs(model, num_pages, page_size, cache_dtype)
    gb = shape.global_batch
    vec_spec = batch_spec(gb, pcfg, extra_dims=0)

    def fn(params, pools, table, lengths, active, token):
        return model.decode_step_paged_local(
            params, pools, table, lengths, active, token)

    jitted = _shard(
        mesh,
        fn,
        (pspec, p_specs, batch_spec(gb, pcfg), vec_spec, vec_spec,
         batch_spec(gb, pcfg)),
        (batch_spec(gb, pcfg), p_specs),
        donate=(1,),  # pools update in place
    )
    in_shapes = (
        param_shapes,
        p_shapes,
        jax.ShapeDtypeStruct((gb, pages_per_slot), jnp.int32),
        jax.ShapeDtypeStruct((gb,), jnp.int32),
        jax.ShapeDtypeStruct((gb,), jnp.bool_),
        jax.ShapeDtypeStruct((gb, 1), jnp.int32),
    )
    return BuiltStep(jitted, in_shapes, (pspec, p_specs), model)


def build_prefill_chunk_step(
    cfg: ModelConfig, pcfg: ParallelConfig, mesh,
    *, chunk: int, n_streams: int, num_pages: int, page_size: int,
    pages_per_slot: int, cache_dtype=jnp.bfloat16,
) -> BuiltStep:
    """Chunked-prefill program: one C-token chunk of one request PER DATA
    SHARD (``n_streams`` = number of concurrent prefill streams — the
    data world when decode slots are sharded, else 1), writing K/V into
    the paged pools and returning the last-valid-token logits per
    stream. Prefill-phase overlap policy resolves through ``pcfg``
    (ag_matmul / matmul_rs in the chunk projections)."""
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    p_shapes, p_specs = _pool_specs(model, num_pages, page_size, cache_dtype)
    bspec = batch_spec(n_streams, pcfg)
    vec_spec = batch_spec(n_streams, pcfg, extra_dims=0)

    def fn(params, pools, table_rows, starts, n_valids, tokens):
        return model.prefill_chunk_local(
            params, pools, table_rows, starts, n_valids, tokens)

    jitted = _shard(
        mesh,
        fn,
        (pspec, p_specs, bspec, vec_spec, vec_spec, bspec),
        (bspec, p_specs),
        donate=(1,),
    )
    in_shapes = (
        param_shapes,
        p_shapes,
        jax.ShapeDtypeStruct((n_streams, pages_per_slot), jnp.int32),
        jax.ShapeDtypeStruct((n_streams,), jnp.int32),
        jax.ShapeDtypeStruct((n_streams,), jnp.int32),
        jax.ShapeDtypeStruct((n_streams, chunk), jnp.int32),
    )
    return BuiltStep(jitted, in_shapes, (pspec, p_specs), model)


def build_prefill_chunk_cp_step(
    cfg: ModelConfig, pcfg: ParallelConfig, mesh,
    *, chunk: int, num_pages: int, page_size: int, pages_per_slot: int,
    cache_dtype=jnp.bfloat16, placement: str = "zigzag",
    cp_attend: str = "ring",
) -> BuiltStep:
    """Context-parallel chunked prefill: ONE stream whose C-token chunk
    shards over the DATA axis by the balanced ``placement`` map — every
    data shard owns C/dp position-ordered rows (zigzag: one early + one
    late half-chunk, equalizing causal work) and the chunk-internal
    attention runs through the balanced ring_attention op
    (``cp_attend="ring"``; ``"dense"`` attends over the gathered pages,
    bit-exact vs the dense path). All inputs replicated: the whole mesh
    cooperates on one request instead of one request per data shard."""
    model = build_model(cfg, pcfg)
    pdt = jnp.dtype(pcfg.param_dtype)
    param_shapes, pspec = model.param_shapes(pdt)
    p_shapes, p_specs = _pool_specs(model, num_pages, page_size, cache_dtype)
    rep1, rep2 = P(None), P(None, None)

    def fn(params, pools, table_rows, starts, n_valids, tokens):
        return model.prefill_chunk_cp_local(
            params, pools, table_rows, starts, n_valids, tokens,
            placement=placement, cp_attend=cp_attend)

    jitted = _shard(
        mesh,
        fn,
        (pspec, p_specs, rep2, rep1, rep1, rep2),
        (rep2, p_specs),
        donate=(1,),
    )
    in_shapes = (
        param_shapes,
        p_shapes,
        jax.ShapeDtypeStruct((1, pages_per_slot), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1, chunk), jnp.int32),
    )
    return BuiltStep(jitted, in_shapes, (pspec, p_specs), model)


def build_step(cfg, pcfg, shape, mesh, tcfg=None) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, pcfg, shape, mesh, tcfg)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, pcfg, shape, mesh)
    return build_decode_step(cfg, pcfg, shape, mesh)


def default_pcfg(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
                 dp: int = 16, tp: int = 16, overlap_mode: str = "ring",
                 overlap_modes=()) -> ParallelConfig:
    """Production parallel config for one (arch x shape x mesh) cell.

    ``overlap_mode="auto"`` asks the analytic tuner for a per-op mode map
    (engine registry candidates) sized to this cell's dominant GEMM;
    explicit ``overlap_modes`` pairs always win over both.
    """
    from ..ops.policy import OverlapPolicy

    kv_shard = "heads"
    if shape.name == "long_500k":
        kv_shard = "sequence"  # distributed flash decode over "data"
    big = cfg.param_count() > 500e9
    moment = "bfloat16" if big else "float32"
    if overlap_mode == "auto":
        from ..core import tuner

        pods_n = 2 if multi_pod else 1
        m = max(tp, shape.tokens // max(1, dp * pods_n))  # rows per data rank
        # the tuner hands back a whole OverlapPolicy — no dict re-packing
        policy = tuner.recommend_overlap_modes(m, cfg.d_model, cfg.d_ff, tp)
    else:
        policy = OverlapPolicy(mode=overlap_mode)
    if overlap_modes:
        policy = policy.with_modes(**dict(overlap_modes))
    return ParallelConfig(
        dp=dp,
        tp=tp,
        pods=2 if multi_pod else 1,
        fsdp=True,
        fsdp_pods=multi_pod,  # 1T-class states only fit when FSDP spans pods
        overlap=policy,
        remat="block",
        moment_dtype=moment,
        kv_shard=kv_shard,
        moe_chunks=8 if (cfg.family == "moe" and cfg.d_model >= 4096) else 1,
    )
