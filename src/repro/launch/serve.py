"""Serving driver: paged KV cache + chunked prefill + continuous batching.

Builds BOTH serving programs from one model:
  * the chunked-prefill program (``build_prefill_chunk_step``) — C-token
    prompt chunks written straight into the paged KV pools, one request
    stream per data shard;
  * the paged decode step (``build_paged_decode_step``) — one token per
    slot at per-slot positions through the block tables;
and drives them with :class:`repro.serve.PagedEngine` under a seeded
synthetic load stream (``repro.serve.load``). ``--tokenwise`` instead
runs the legacy dense-cache engine (prompt ingestion token-by-token
through the decode program) for comparison.

Prefill and decode may carry SEPARATE overlap policies: prefill is
throughput-bound (ag_matmul/matmul_rs in the chunk projections), decode
latency-bound (flash_decode/a2a_ep) — pass ``--prefill-overlap`` to
split them.

CPU smoke:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.serve --arch granite-3-2b --reduced --dp 2 --tp 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config, reduced
from ..configs.base import ParallelConfig, ShapeConfig
from ..ops.policy import OverlapPolicy
from ..serve import (
    Engine,
    LoadSpec,
    PagedEngine,
    PagedKVCache,
    ServeConfig,
    drive,
    generate,
)
from .mesh import make_mesh
from .steps import (
    build_decode_step,
    build_paged_decode_step,
    build_prefill_chunk_cp_step,
    build_prefill_chunk_step,
    data_world,
)


def _with_policy(pcfg: ParallelConfig, policy) -> ParallelConfig:
    """A copy of ``pcfg`` carrying ``policy`` as its overlap policy
    (legacy overlap fields reset so the config conflict check is quiet)."""
    defaults = {
        f.name: f.default
        for f in dataclasses.fields(ParallelConfig)
        if f.name in ParallelConfig._LEGACY_OVERLAP_FIELDS
    }
    return dataclasses.replace(pcfg, overlap=policy, **defaults)


def build_paged_engine(
    cfg, pcfg: ParallelConfig, scfg: ServeConfig, mesh, *,
    cache_dtype=None, prefill_policy=None, seed: int = 0, eos_id: int = -1,
    prefill_cp: bool = False, cp_placement: str = "zigzag",
    cp_attend: str = "ring",
) -> PagedEngine:
    """Compile the two serving programs and wire up the paged engine.

    ``prefill_policy`` (an OverlapPolicy) gives the chunked-prefill
    program its own overlap resolution; decode keeps ``pcfg``'s.

    ``prefill_cp`` switches prefill to the CONTEXT-PARALLEL program:
    one stream whose chunk shards over the data axis by the balanced
    ``cp_placement`` map, chunk-internal attention through the
    placement-aware ring_attention op (``cp_attend="ring"``; ``"dense"``
    is the bit-exact-vs-dense-path variant). The engine then plans at
    most one prefill chunk per step (the whole mesh cooperates on it)
    while decode keeps its data-parallel slot sharding."""
    cache_dtype = cache_dtype or jnp.dtype(pcfg.compute_dtype)
    assert scfg.chunk % pcfg.tp == 0, "prefill chunk must split over tp"
    dw = data_world(pcfg)
    dp_shards = 1 if prefill_cp else (dw if scfg.batch >= dw else 1)
    # probe the allocator for the derived pool geometry
    kv = PagedKVCache(batch=scfg.batch, max_len=scfg.max_len,
                      page_size=scfg.page_size, num_pages=scfg.num_pages,
                      dp_shards=dp_shards)
    scfg = dataclasses.replace(scfg, num_pages=kv.num_pages)
    shape = ShapeConfig("serve", seq_len=scfg.max_len,
                        global_batch=scfg.batch, kind="decode")
    dec = build_paged_decode_step(
        cfg, pcfg, shape, mesh, num_pages=kv.num_pages,
        page_size=scfg.page_size, pages_per_slot=kv.pages_per_slot,
        cache_dtype=cache_dtype)
    pre_pcfg = (_with_policy(pcfg, prefill_policy)
                if prefill_policy is not None else pcfg)
    if prefill_cp:
        assert scfg.chunk % (data_world(pcfg) * pcfg.tp) == 0, \
            "cp prefill chunk must split over dp*tp"
        pre = build_prefill_chunk_cp_step(
            cfg, pre_pcfg, mesh, chunk=scfg.chunk,
            num_pages=kv.num_pages, page_size=scfg.page_size,
            pages_per_slot=kv.pages_per_slot, cache_dtype=cache_dtype,
            placement=cp_placement, cp_attend=cp_attend)
    else:
        pre = build_prefill_chunk_step(
            cfg, pre_pcfg, mesh, chunk=scfg.chunk, n_streams=dp_shards,
            num_pages=kv.num_pages, page_size=scfg.page_size,
            pages_per_slot=kv.pages_per_slot, cache_dtype=cache_dtype)
    params, _ = dec.model.init(jax.random.PRNGKey(seed),
                               jnp.dtype(pcfg.param_dtype))
    pools = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         dec.in_shapes[1])
    return PagedEngine(pre.fn, dec.fn, params, pools, scfg,
                       dp_shards=dp_shards, eos_id=eos_id, seed=seed,
                       pcfg=pcfg, prefill_pcfg=pre_pcfg,
                       prefill_cp=prefill_cp, cp_placement=cp_placement)


def build_tokenwise_engine(
    cfg, pcfg: ParallelConfig, batch: int, max_len: int, mesh, *,
    cache_dtype=None, seed: int = 0, eos_id: int = -1,
) -> Engine:
    """The legacy path: dense per-slot KV caches, prompt ingestion
    token-by-token through the decode program."""
    cache_dtype = cache_dtype or jnp.dtype(pcfg.compute_dtype)
    shape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                        kind="decode")
    built = build_decode_step(cfg, pcfg, shape, mesh, cache_dtype=cache_dtype)
    params, _ = built.model.init(jax.random.PRNGKey(seed),
                                 jnp.dtype(pcfg.param_dtype))
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          built.in_shapes[1])
    return Engine(built.fn, params, caches, batch=batch, max_len=max_len,
                  eos_id=eos_id, seed=seed, pcfg=pcfg)


def run(args):
    trace_path = getattr(args, "trace", None)
    if trace_path:
        # enable BEFORE the engines compile so compute spans are traced
        from .. import obs

        obs.enable()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    pcfg = ParallelConfig(
        dp=args.dp, tp=args.tp, fsdp=not args.no_fsdp,
        param_dtype=args.dtype, compute_dtype=args.dtype,
        overlap=OverlapPolicy(mode=getattr(args, "overlap", "none")),
    )
    mesh = make_mesh(args.dp, args.tp)
    tokenwise = getattr(args, "tokenwise", False)
    if tokenwise:
        eng = build_tokenwise_engine(cfg, pcfg, args.batch, args.max_len, mesh)
    else:
        prefill_policy = None
        if getattr(args, "prefill_overlap", None):
            prefill_policy = OverlapPolicy(mode=args.prefill_overlap)
        scfg = ServeConfig(
            batch=args.batch, max_len=args.max_len,
            page_size=getattr(args, "page_size", 16),
            num_pages=getattr(args, "num_pages", 0),
            chunk=getattr(args, "chunk", 16),
            token_budget=getattr(args, "token_budget", 64),
        )
        eng = build_paged_engine(
            cfg, pcfg, scfg, mesh, prefill_policy=prefill_policy,
            prefill_cp=getattr(args, "prefill_cp", False),
            cp_placement=getattr(args, "cp_placement", "zigzag"),
            cp_attend=getattr(args, "cp_attend", "ring"))
    print("engine:", "tokenwise" if tokenwise else "paged")
    print("overlap modes:", eng.overlap_modes())
    spec = LoadSpec(
        n_requests=args.requests,
        rate_rps=getattr(args, "rate", 32.0),
        prompt_lens=(getattr(args, "prompt_min", 4),
                     getattr(args, "prompt_max", 8)),
        max_new_tokens=args.new_tokens,
        temperature=args.temperature,
        seed=getattr(args, "seed", 0),
    )
    arrivals = generate(spec, cfg.vocab_size)
    t0 = time.time()
    leftover = drive(eng, arrivals,
                     max_steps=getattr(args, "max_steps", 100_000),
                     time_scale=getattr(args, "time_scale", 0.0))
    dt = time.time() - t0
    m = eng.metrics()
    print(f"served {args.requests - len(leftover)}/{args.requests} requests "
          f"in {dt:.1f}s ({m.steps} steps: {m.steps_prefill} prefill + "
          f"{m.steps_decode} decode)")
    print(m)
    if trace_path:
        from .. import obs

        ev = obs.events(clear=True)
        n = obs.trace.save(trace_path, ev)
        print(f"wrote {n} trace events to {trace_path}")
        if ev:
            print(obs.metrics.summarize(ev))
    return eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tokenwise", action="store_true",
                    help="legacy dense-cache engine (token-by-token prefill)")
    ap.add_argument("--overlap", default="none",
                    help="decode-phase overlap mode")
    ap.add_argument("--prefill-overlap", default=None,
                    help="separate overlap mode for the chunked-prefill program")
    ap.add_argument("--prefill-cp", action="store_true",
                    help="context-parallel chunked prefill: shard each "
                         "chunk over the data axis through the balanced "
                         "ring-attention op (one stream, whole-mesh)")
    ap.add_argument("--cp-placement", default="zigzag",
                    choices=("contiguous", "zigzag", "striped"),
                    help="chunk-row -> data-rank owner map for --prefill-cp")
    ap.add_argument("--cp-attend", default="ring", choices=("ring", "dense"),
                    help="--prefill-cp chunk attention: ring (balanced "
                         "ring_attention + prefix merge) or dense "
                         "(gathered pages; bit-exact vs the dense path)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="pool pages per DP shard (0 = dense-equivalent)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk length (multiple of tp)")
    ap.add_argument("--token-budget", type=int, default=64)
    ap.add_argument("--rate", type=float, default=32.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--prompt-min", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=8)
    ap.add_argument("--time-scale", type=float, default=0.0,
                    help="arrival-time multiplier (0 = release all up front)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", nargs="?", const="serve_trace.json",
                    default=None, metavar="PATH",
                    help="enable repro.obs tracing and write the run's "
                         "Chrome-trace JSON (kernel-backend runs record "
                         "per-PE engine events; graph runs span-label only)")
    run(ap.parse_args())


if __name__ == "__main__":
    main()
