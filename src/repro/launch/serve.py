"""Serving driver: batched requests through the decode engine.

CPU smoke:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.serve --arch granite-3-2b --reduced --dp 2 --tp 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, reduced
from ..configs.base import ParallelConfig, ShapeConfig
from ..serve.engine import Engine, Request
from .mesh import make_mesh
from .steps import build_decode_step


def run(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    pcfg = ParallelConfig(
        dp=args.dp, tp=args.tp, fsdp=not args.no_fsdp,
        param_dtype=args.dtype, compute_dtype=args.dtype,
    )
    shape = ShapeConfig("serve", seq_len=args.max_len,
                        global_batch=args.batch, kind="decode")
    mesh = make_mesh(args.dp, args.tp)
    built = build_decode_step(cfg, pcfg, shape, mesh,
                              cache_dtype=jnp.dtype(args.dtype))
    model = built.model
    params, _ = model.init(jax.random.PRNGKey(0), jnp.dtype(pcfg.param_dtype))
    _, cache_shapes, _, _ = built.in_shapes
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes)

    eng = Engine(built.fn, params, caches, batch=args.batch,
                 max_len=args.max_len, seed=0, pcfg=pcfg)
    print("overlap modes:", eng.overlap_modes())
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng.randint(1, cfg.vocab_size, size=rng.randint(3, 8)).tolist()
        eng.add(Request(prompt=prompt, max_new_tokens=args.new_tokens,
                        temperature=args.temperature))
    t0 = time.time()
    leftover = eng.run(max_steps=args.max_len - 2)
    dt = time.time() - t0
    print(f"served {args.requests - len(leftover)}/{args.requests} requests "
          f"in {dt:.1f}s ({eng.cache_len} decode steps)")
    print(eng.metrics())
    return eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--no-fsdp", action="store_true")
    run(ap.parse_args())


if __name__ == "__main__":
    main()
