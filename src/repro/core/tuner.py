"""Distributed autotuner (paper §3.8): analytic model + whole-step profiler.

Analytic mode — the TPU analogue of the paper's resource-partition
arithmetic (§3.5: "if local reduction exceeds 470 GB/s, perfect overlap").
On TPU the partition knob is temporal (chunk count/size), so the model
answers: for a given overlapped op, which (mode, chunks_per_rank) makes
per-step DMA time <= per-step MXU time, minimizing the critical path

    T = fill_bubble + sum_steps max(t_compute_step, t_comm_step).

Empirical mode — the paper's distributed-tuning protocol: overlapped
kernels synchronize through signals, so a naive repeat-the-kernel
profiler would deadlock or skew (signals must be reset between runs).
The tuner therefore times a USER-WRAPPED step function as a whole, one
candidate config per iteration, with an explicit reset callback, then
selects the globally best config (all ranks see the same argmin since
timing happens on the host driving the SPMD program).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Optional, Sequence

import jax

from .. import hw
from ..ops import wire as wirefmt
from . import overlap, schedules


@dataclass(frozen=True)
class OverlapChoice:
    mode: str  # a transport from the engine registry, or the op baseline
    chunks_per_rank: int
    # analytic estimates (seconds) for the roofline log
    t_compute: float
    t_comm: float
    t_total: float
    wire: str = "f32"  # riding-chunk wire dtype (registry wires axis)
    placement: str = "contiguous"  # chunk->rank row placement (registry axis)


def _dot_time(m: float, k: float, n: float, spec: hw.HardwareSpec, eff: float = 0.6) -> float:
    return 2.0 * m * k * n / (spec.peak_flops_bf16 * eff)


def _codec_time(rows: int, cols: int, spec: hw.HardwareSpec) -> float:
    """Per-chunk cost of the wire codec: one encode + one decode pass,
    each streaming the f32 view of the chunk through HBM. This is the
    term that keeps quantization from being a free lunch — when the op
    is compute-bound, the extra passes make a low-precision wire
    strictly WORSE, so the enumeration only picks int8/fp8 where the
    ICI-bytes term actually binds."""
    return 2.0 * rows * cols * 4 / spec.hbm_bandwidth


def analytic_ag_matmul(
    m_loc: int,
    k: int,
    n_loc: int,
    world: int,
    *,
    dtype_bytes: int = 2,
    spec: hw.HardwareSpec = hw.DEFAULT,
    candidates: Optional[Sequence[str]] = None,
    max_sub: int = 4,
) -> OverlapChoice:
    """Pick the overlap strategy for AllGather-GEMM.

    Candidates default to the engine registry's ag_matmul transports
    (baseline included) — adding a transport to the registry
    automatically enrolls it here.

    Per ring step: compute = dot(m_loc, k, n_loc); comm = ship one chunk
    (m_loc * k * bytes) over one link (ring) or both directions (bidir).
    one_shot: all (W-1) chunks in flight at once across the torus links —
    bandwidth-limited by links/chip, latency-optimal for small messages.

    The wire axis is enumerated jointly with mode x chunks: for every
    non-baseline mode, each registry wire dtype for ag_matmul scales the
    riding-chunk bytes (``ops.wire.wire_bytes`` — payload + per-row
    scales) and charges the codec passes to the compute side.
    """
    if candidates is None:
        candidates = overlap.transports_for("ag_matmul", include_baseline=True)
    f32_bytes = m_loc * k * dtype_bytes
    t_dot = _dot_time(m_loc, k, n_loc, spec)
    t_cod = _codec_time(m_loc, k, spec)
    best: Optional[OverlapChoice] = None
    for mode in candidates:
        if mode == "none":
            subs = (1,)
        elif mode == "ring":
            subs = tuple(s for s in range(1, max_sub + 1) if m_loc % s == 0)
        elif mode == "bidir":
            subs = (1,) if m_loc % 2 == 0 and world >= 3 else ()
        elif mode == "one_shot":
            subs = (1,)
        else:
            continue
        wires = ("f32",) if mode == "none" else overlap.wires_for("ag_matmul")
        for wname in wires:
            chunk_bytes = wirefmt.wire_bytes(m_loc, k, wname, dtype_bytes)
            cod = 0.0 if wname == "f32" else t_cod
            t_step = t_dot + cod  # per-chunk MXU time + codec passes
            for sub in subs:
                if mode == "none":
                    t_comm = (world - 1) * chunk_bytes / spec.ici_link_bandwidth
                    t_comp = world * t_step
                    t_total = t_comm + t_comp  # serialized: collective then GEMM
                elif mode == "ring":
                    # per-message fixed overhead is what caps useful sub-
                    # chunking: finer chunks shrink the fill bubble but pay
                    # the hop/descriptor cost world*sub times
                    t_step_comm = (chunk_bytes / sub) / spec.ici_link_bandwidth \
                        + spec.ici_msg_overhead
                    t_step_comp = t_step / sub
                    fill = t_step_comm  # first remote chunk latency
                    t_comm = (world - 1) * chunk_bytes / spec.ici_link_bandwidth
                    t_comp = world * t_step
                    t_total = fill + world * sub * max(t_step_comm, t_step_comp)
                elif mode == "bidir":
                    t_step_comm = (chunk_bytes / 2) / spec.ici_link_bandwidth
                    t_step_comp = t_step
                    t_comm = (world - 1) * chunk_bytes / (2 * spec.ici_link_bandwidth)
                    t_comp = world * t_step
                    t_total = t_step_comm + world * max(t_step_comm, t_step_comp)
                else:  # one_shot
                    total_bytes = (world - 1) * chunk_bytes
                    t_comm = total_bytes / (spec.ici_link_bandwidth * spec.ici_links)
                    t_comp = world * t_step
                    # local chunk computes during the flight of everything else
                    t_total = max(t_comm, t_step) + (world - 1) * t_step
                cand = OverlapChoice(mode, sub if mode == "ring" else 1,
                                     t_comp, t_comm, t_total, wname)
                if best is None or cand.t_total < best.t_total:
                    best = cand
    if best is None:
        # every candidate was infeasible (e.g. bidir with odd m_loc):
        # mirror the engine, which degrades such requests to ring
        t_step_comm = f32_bytes / spec.ici_link_bandwidth
        best = OverlapChoice(
            "ring", 1, world * t_dot,
            (world - 1) * t_step_comm,
            t_step_comm + world * max(t_step_comm, t_dot),
        )
    return best


def analytic_matmul_rs(
    m: int,
    k_loc: int,
    n: int,
    world: int,
    *,
    dtype_bytes: int = 2,
    spec: hw.HardwareSpec = hw.DEFAULT,
    candidates: Optional[Sequence[str]] = None,
    max_sub: int = 4,
) -> OverlapChoice:
    """Pick the overlap strategy for GEMM-ReduceScatter. Candidates
    default to the engine registry's matmul_rs transports (baseline
    included).

    ring also enumerates ``rs_chunks`` sub-chunking (the accumulator
    split into column groups, mirroring ag_chunks): sub-chunking shrinks
    the first-message fill bubble at the cost of more, smaller permutes.

    The wire axis rides the same enumeration: a low-precision wire
    shrinks the riding f32 accumulator to payload + per-row scales but
    pays encode+decode passes EVERY hop (the ring re-encodes the
    accumulator each step), so it only wins where the ICI term binds.
    """
    if candidates is None:
        candidates = overlap.transports_for("matmul_rs", include_baseline=True)
    m_blk = m // world
    t_dot = _dot_time(m_blk, k_loc, n, spec)
    acc_bytes = m_blk * n * 4  # f32 accumulator rides the ring
    f32_step_comm = acc_bytes / spec.ici_link_bandwidth
    t_cod = _codec_time(m_blk, n, spec)
    t_comp = world * t_dot
    t_comm = (world - 1) * f32_step_comm
    best: Optional[OverlapChoice] = None
    for mode in candidates:
        if mode == "ring":
            subs = tuple(s for s in range(1, max_sub + 1) if n % s == 0)
        else:
            subs = (1,)
        wires = ("f32",) if mode == "none" else overlap.wires_for("matmul_rs")
        for wname in wires:
            ride_bytes = wirefmt.wire_bytes(m_blk, n, wname, 4)
            t_step_comm = ride_bytes / spec.ici_link_bandwidth
            cod = 0.0 if wname == "f32" else t_cod
            t_step = t_dot + cod  # per-hop MXU time + codec passes
            for sub in subs:
                if mode == "none":
                    # serialized: all dots, then the monolithic reduce-scatter
                    t_total = t_comp + t_comm
                elif mode == "ring":
                    # sub column-groups: each ring step moves ride_bytes/sub
                    # per group (fill = one sub-message flight), paying the
                    # fixed per-message cost world*sub times — the trade-off
                    # that keeps the enumeration from degenerating to max_sub
                    t_sub_comm = t_step_comm / sub + spec.ici_msg_overhead
                    t_total = t_sub_comm + world * sub * max(t_step / sub, t_sub_comm)
                elif mode == "bidir":
                    if world < 3:
                        continue
                    # half the accumulator columns per direction, both links busy
                    t_total = t_step_comm / 2 + world * max(t_step, t_step_comm / 2)
                elif mode == "one_shot":
                    # W-1 full partials in flight at once across all links: latency
                    # optimal, bandwidth hungry ((W-1)x the wire bytes of ring's
                    # steady state per link); each partial is encoded once and
                    # decoded once on arrival
                    t_total = world * t_step + (world - 1) * ride_bytes / (
                        spec.ici_link_bandwidth * spec.ici_links
                    )
                else:
                    continue
                cand = OverlapChoice(mode, sub if mode == "ring" else 1,
                                     world * t_step, t_comm, t_total, wname)
                if best is None or cand.t_total < best.t_total:
                    best = cand
    if best is None:
        # every candidate was infeasible (e.g. bidir with world < 3):
        # mirror the engine, which degrades such requests to ring
        t_total = f32_step_comm + world * max(t_dot, f32_step_comm)
        best = OverlapChoice("ring", 1, t_comp, t_comm, t_total)
    return best


def causal_flop_fraction(placement: str, world: int, s_loc: int) -> float:
    """CRITICAL-PATH fraction of the dense blockwise-attention FLOPs a
    causal mask leaves live, per placement: ``max_r causal_pairs(r) /
    (s_loc * S)``. Contiguous concentrates the late (expensive) rows on
    the last rank — its fraction approaches 1 as world grows — while
    zigzag gives every rank one early + one late half-chunk (fraction
    ~1/2, rank-independent) and striped interleaves rows round-robin
    (~1/2 + 1/(2*s_loc)). The ring is lockstep, so the slowest rank IS
    the step time: this maximum is the term the analytic model charges.
    """
    total = s_loc * s_loc * world
    return max(
        schedules.causal_pairs(placement, world, r, s_loc)
        for r in range(world)) / float(total)


def analytic_ring_attention(
    s_loc: int,
    d: int,
    world: int,
    *,
    causal: bool = True,
    heads: int = 1,
    dtype_bytes: int = 2,
    spec: hw.HardwareSpec = hw.DEFAULT,
    candidates: Optional[Sequence[str]] = None,
    placements: Optional[Sequence[str]] = None,
) -> OverlapChoice:
    """Pick (mode, wire, placement) for causal/non-causal ring attention.

    Per ring step: compute = one blockwise-attention tile (QK^T + PV:
    ``4 * s_loc^2 * d`` FLOPs per head); comm = ship one packed K|V
    chunk (``s_loc * 2d * bytes`` per KV head). The causal model charges
    the TRUE per-rank live-FLOP fraction per placement
    (:func:`causal_flop_fraction`): under contiguous the last rank owns
    the most-attended rows, so the lockstep critical path stays ~dense,
    while zigzag/striped cut it toward 1/2 — the interior optimum that
    makes the placement axis worth enumerating. Non-causal placements
    are FLOP-identical, so the enumeration keeps contiguous (strict-<
    selection, contiguous first).
    """
    if candidates is None:
        candidates = overlap.transports_for("ring_attention",
                                            include_baseline=False)
    if placements is None:
        placements = overlap.placements_for("ring_attention")
    t_blk = 2.0 * heads * _dot_time(s_loc, d, s_loc, spec)  # QK^T + PV
    t_cod = _codec_time(s_loc, 2 * d, spec)
    best: Optional[OverlapChoice] = None
    for placement in placements:
        if placement == "zigzag" and s_loc % 2:
            continue  # the engine degrades odd-s_loc zigzag to contiguous
        frac = causal_flop_fraction(placement, world, s_loc) if causal \
            else 1.0
        t_step = t_blk * frac
        for mode in candidates:
            wires = overlap.wires_for("ring_attention")
            for wname in wires:
                chunk_bytes = wirefmt.wire_bytes(s_loc, 2 * d, wname,
                                                 dtype_bytes)
                cod = 0.0 if wname == "f32" else t_cod
                if mode == "ring":
                    t_step_comm = chunk_bytes / spec.ici_link_bandwidth \
                        + spec.ici_msg_overhead
                    t_total = t_step_comm + world * max(
                        t_step_comm, t_step + cod)
                elif mode == "one_shot":
                    t_comm_all = (world - 1) * chunk_bytes / (
                        spec.ici_link_bandwidth * spec.ici_links)
                    t_total = max(t_comm_all, t_step + cod) \
                        + (world - 1) * (t_step + cod)
                else:
                    continue
                cand = OverlapChoice(
                    mode, 1, world * (t_step + cod),
                    (world - 1) * chunk_bytes / spec.ici_link_bandwidth,
                    t_total, wname, placement)
                if best is None or cand.t_total < best.t_total:
                    best = cand
    if best is None:
        t_step_comm = s_loc * 2 * d * dtype_bytes / spec.ici_link_bandwidth
        best = OverlapChoice("ring", 1, world * t_blk,
                             (world - 1) * t_step_comm,
                             t_step_comm + world * max(t_step_comm, t_blk))
    return best


def recommend_backend(modes: Optional[Dict[str, str]] = None) -> str:
    """Lowering backend for the current platform (the backend axis of the
    registry, enumerated alongside the transport candidates).

    On real TPU the fused shmem kernels ("kernel") remove the per-step
    XLA dispatch between chunk compute and chunk DMA, so they are the
    default whenever the chosen mode has a kernel lowering for at least
    one op. On CPU the emulated-DMA backend is a correctness vehicle
    (host callbacks), not a fast path — recommend "graph".
    ``ParallelConfig.backend_for`` re-clamps per op, so emitting
    "kernel" is safe even when only some ops support it.
    """
    import jax

    if jax.default_backend() != "tpu":
        return "graph"
    modes = modes or {}
    for op, mode in modes.items():
        spec = overlap.registry().get(op)
        if spec is not None and mode in spec.kernel_transports:
            return "kernel"
    return "graph" if modes else "kernel"


def recommend_overlap_modes(
    m: int,
    k: int,
    n: int,
    world: int,
    *,
    dtype_bytes: int = 2,
    spec: hw.HardwareSpec = hw.DEFAULT,
):
    """Analytic :class:`repro.ops.OverlapPolicy` for a layer with GLOBAL
    GEMM dims (m, k, n) sharded over ``world`` TP ranks — drop it
    straight onto ``ParallelConfig.overlap`` (``launch/steps.default_pcfg``
    does, under ``overlap_mode="auto"``; no dict re-packing anywhere).

    The per-op mode map carries the analytic AG/RS picks plus the
    latency-bound ops' registry defaults (a2a_ep, flash_decode stay
    one_shot — their message sizes do not depend on the layer dims the
    analytic model sees); the chunk knobs are the enumerated sub-chunk
    winners; the backend is the lowering recommendation
    (:func:`recommend_backend`).
    """
    from ..ops.policy import LATENCY_OPS, OverlapPolicy

    ag = analytic_ag_matmul(max(1, m // world), k, max(1, n // world), world,
                            dtype_bytes=dtype_bytes, spec=spec)
    rs = analytic_matmul_rs(m, max(1, k // world), n, world,
                            dtype_bytes=dtype_bytes, spec=spec)
    modes = dict(LATENCY_OPS)
    modes.update({"ag_matmul": ag.mode, "matmul_rs": rs.mode})
    # the carry-passing / compound-mesh ops enumerate too (kernel-capable
    # since the ring_fold / two_level executor protocols): ring attention
    # follows the AG regime pick clamped to its transports — its K/V
    # chunks ride exactly the AG data path — and the 2-level ops have a
    # single (two_level) transport.
    modes["ring_attention"] = overlap.resolve_mode("ring_attention", ag.mode)
    modes["ag_matmul_2level"] = "two_level"
    modes["matmul_rs_2level"] = "two_level"
    # wire picks land as per-op entries (not the global default): the
    # analytic model only saw the AG/RS regimes, so only those ops get a
    # low-precision wire — everything else stays f32 under the default
    wires = {op: ch.wire
             for op, ch in (("ag_matmul", ag), ("matmul_rs", rs))
             if ch.wire != "f32"}
    # placement pick: the causal critical-path fraction is dimension-
    # independent (zigzag halves it at any world >= 2, and non-causal
    # placements are FLOP-identical — see analytic_ring_attention), so
    # ring attention always gets the balanced owner map. The policy
    # clamps it off ops that never declared placements.
    placements = {"ring_attention": "zigzag"}
    return OverlapPolicy(
        mode=ag.mode,
        # the latency-bound ops are kernel-capable too, so the backend
        # recommendation enumerates the full per-op mode map
        backend=recommend_backend(modes),
        modes=modes,
        ag_chunks=ag.chunks_per_rank,
        rs_chunks=rs.chunks_per_rank,
        wires=tuple(sorted(wires.items())),
        placements=tuple(sorted(placements.items())),
    )


# ---------------------------------------------------------------------------
# Empirical whole-step tuner (paper's protocol)
# ---------------------------------------------------------------------------


@dataclass
class TuneResult:
    config: object
    seconds: float
    all_timings: dict
    # repr(config) -> repro.obs.metrics.Summary (tune(record_stalls=True)):
    # the measured stall breakdown behind each candidate's timing
    stalls: dict = field(default_factory=dict)


def default_reset() -> Optional[Callable[[], None]]:
    """The platform's between-candidates signal reset.

    On hosts without real TPU remote DMA, ``backend="kernel"``
    candidates run on the emulated shmem backend, whose symmetric heaps
    and counting signal slots survive an aborted/partial timed run —
    stale state then skews (or deadlocks) the NEXT candidate's wait
    accounting. ``shmem.emulated.reset`` drops that state. On real TPU
    there is no host-side heap to clear; the caller supplies a
    device-appropriate reset (or None).
    """
    if jax.default_backend() == "tpu":
        return None
    from ..shmem import emulated

    return emulated.reset


def tune(
    make_step: Callable[[object], Callable[[], object]],
    configs: Iterable[object],
    *,
    reset="auto",
    warmup: int = 1,
    iters: int = 3,
    record_stalls: bool = False,
) -> TuneResult:
    """Time whole wrapped step functions, one config at a time.

    ``make_step(config)`` returns a zero-arg callable executing the full
    overlapped step (comm + compute + host logic). Between candidate
    configs ``reset()`` restores signal state — the paper's requirement
    that overlapped kernels cannot be replayed without resetting signals.
    The default ``reset="auto"`` resolves via :func:`default_reset`: on
    CPU hosts it is ``repro.shmem.emulated.reset``, clearing the
    symmetric heaps and signal slots a kernel-backend candidate leaves
    behind, so stale signal-slot state can never leak across timed
    candidates. Pass an explicit callable to override, or ``None`` to
    disable.

    ``record_stalls=True`` enables :mod:`repro.obs` tracing around each
    candidate (BEFORE its first compile, so compute spans are traced)
    and reduces the timed iterations' events into a per-candidate
    :class:`repro.obs.metrics.Summary` in ``TuneResult.stalls`` — the
    measured exposed-comm / overlap-efficiency breakdown behind each
    timing. Note: tracing adds host-callback overhead, so absolute
    ``seconds`` shift; the RELATIVE stall structure is the signal.
    """
    if reset == "auto":
        reset = default_reset()
    obs = None
    if record_stalls:
        from .. import obs as _obs

        obs = _obs
        was_enabled = obs.enabled()
        obs.enable()
    timings: dict = {}
    stalls: dict = {}
    best_cfg, best_t = None, float("inf")
    try:
        for cfg in configs:
            step = make_step(cfg)
            for _ in range(warmup):
                out = step()
                jax.block_until_ready(out)
                if obs is not None:
                    obs.clear()  # timed iterations only
                if reset is not None:
                    reset()
            acc = 0.0
            cfg_events = []
            for _ in range(iters):
                t0 = time.perf_counter()
                out = step()
                jax.block_until_ready(out)
                acc += time.perf_counter() - t0
                if obs is not None:
                    # drain BEFORE reset: reset drops worlds + traces
                    cfg_events.extend(obs.events(clear=True))
                if reset is not None:
                    reset()
            t = acc / iters
            timings[repr(cfg)] = t
            if obs is not None and cfg_events:
                stalls[repr(cfg)] = obs.metrics.summarize(
                    cfg_events, config=repr(cfg))
            if t < best_t:
                best_cfg, best_t = cfg, t
    finally:
        if obs is not None and not was_enabled:
            obs.disable()
    return TuneResult(best_cfg, best_t, timings, stalls)


# ---------------------------------------------------------------------------
# Chunk-centric per-layer-shape search (Syncopate-style): enumerate
# mode x backend x chunks x wire per layer shape, cache per
# (op, shape, world, hw), emit shape-keyed OverlapPolicy rules.
# ---------------------------------------------------------------------------

# (op, shape_key, world, hw_name) -> {"best": overrides, "timings": {...}}
_SEARCH_CACHE: Dict[tuple, dict] = {}

# Count of individual timed step executions performed by search() — the
# test hook pinning the cache contract: a second search with identical
# keys must leave this counter unchanged.
SEARCH_TIMINGS = 0


def clear_search_cache() -> None:
    _SEARCH_CACHE.clear()


def search_cache_key(op: str, shape, world: int, hw_spec=None) -> tuple:
    from ..ops.policy import shape_key

    hw_name = getattr(hw_spec, "name", None) if hw_spec is not None \
        else jax.default_backend()
    return (op, shape_key(shape), int(world), hw_name)


def search_candidates(op: str, chunks: Sequence[int] = (1, 2, 4)):
    """The deduplicated (mode, backend, chunks, wire) grid for ``op``,
    straight from the live registry (baseline included) — declaring a
    transport / kernel protocol / wire dtype automatically enrolls it."""
    seen, grid = set(), []
    for mode in overlap.transports_for(op, include_baseline=True):
        for backend in overlap.backends_for(op):
            if overlap.resolve_backend(op, backend, mode) != backend:
                continue  # (mode, backend) pair the registry would clamp away
            for wire in overlap.wires_for(op):
                if overlap.resolve_wire(op, wire, mode) != wire:
                    continue
                for sub in chunks:
                    n = 1 if mode in ("none", "xla", "one_shot") else int(sub)
                    cand = (mode, backend, n, wire)
                    if cand not in seen:
                        seen.add(cand)
                        grid.append(cand)
    return grid


def search(
    make_step: Callable[[tuple, object], Callable[[], object]],
    op: str,
    shapes: Sequence,
    *,
    world: int,
    hw_spec: Optional[hw.HardwareSpec] = None,
    chunks: Sequence[int] = (1, 2, 4),
    base=None,
    reset="auto",
    warmup: int = 1,
    iters: int = 2,
):
    """Search the chunk-centric schedule space PER LAYER SHAPE and
    return a shape-keyed :class:`repro.ops.OverlapPolicy`.

    For each layer shape in ``shapes`` (e.g. the QKV projection, the MLP
    matmul and the MoE dispatch of one block, as flat GEMM-dim tuples or
    per-operand shape tuples — both canonicalize through
    ``ops.shape_key``), the full registry grid
    mode x backend x chunks x wire (:func:`search_candidates`) is timed
    through the whole-step protocol of :func:`tune` —
    ``make_step(shape, resolved)`` must return the zero-arg step to
    time, with ``resolved`` a :class:`repro.ops.ResolvedOverlap`.

    Results are cached per ``(op, shape, world, hw)`` in the module
    cache: a second search with identical keys performs ZERO new
    timings (``SEARCH_TIMINGS`` is the test-pinned counter), and
    :func:`save_search_cache` / :func:`load_search_cache` round-trip the
    cache through JSON so searched policies can be committed.

    The returned policy is ``base`` (default: a fresh policy) with one
    ``with_layer`` rule per searched shape; call sites that thread
    shapes through ``policy.resolve(op, shape=...)`` — every
    ``ops.<name>(...)`` call does — then lower each site by its own
    searched schedule.
    """
    global SEARCH_TIMINGS
    from ..ops.policy import OverlapPolicy, ResolvedOverlap

    if reset == "auto":
        reset = default_reset()
    policy = base if base is not None else OverlapPolicy()
    for shape in shapes:
        key = search_cache_key(op, shape, world, hw_spec)
        entry = _SEARCH_CACHE.get(key)
        if entry is None:
            timings: Dict[str, float] = {}
            best, best_t = None, float("inf")
            for mode, backend, sub, wire in search_candidates(op, chunks):
                # the placement axis multiplies the grid only for ops
                # that declared non-contiguous placements (registry
                # clamp), so ag/rs grids — and their cache entries and
                # timing counts — are unchanged
                for placement in overlap.placements_for(op):
                    if overlap.resolve_placement(op, placement) != placement:
                        continue
                    resolved = ResolvedOverlap(mode, backend, sub, wire,
                                               placement)
                    step = make_step(shape, resolved)
                    for _ in range(warmup):
                        jax.block_until_ready(step())
                        if reset is not None:
                            reset()
                    acc = 0.0
                    for _ in range(iters):
                        t0 = time.perf_counter()
                        jax.block_until_ready(step())
                        acc += time.perf_counter() - t0
                        SEARCH_TIMINGS += 1
                        if reset is not None:
                            reset()
                    t = acc / iters
                    tag = f"{mode}/{backend}/x{sub}/{wire}"
                    if placement != "contiguous":
                        tag += f"/{placement}"
                    timings[tag] = t
                    if t < best_t:
                        best, best_t = resolved, t
            entry = {
                "best": {"mode": best.mode, "backend": best.backend,
                         "chunks": best.chunks, "wire": best.wire},
                "timings": timings,
            }
            if best.placement != "contiguous":
                entry["best"]["placement"] = best.placement
            _SEARCH_CACHE[key] = entry
        policy = policy.with_layer(op, shape, **entry["best"])
    return policy


def save_search_cache(path) -> None:
    """Commit the search cache as JSON (see :func:`load_search_cache`)."""
    import json

    entries = [
        {"op": op, "shape": list(shp), "world": world, "hw": hw_name,
         "best": entry["best"], "timings": entry["timings"]}
        for (op, shp, world, hw_name), entry in sorted(_SEARCH_CACHE.items())
    ]
    with open(path, "w") as f:
        json.dump(entries, f, indent=2, sort_keys=True)


def load_search_cache(path) -> int:
    """Load committed search results; returns the number of entries.
    Subsequent :func:`search` calls with matching keys perform zero new
    timings."""
    import json

    with open(path) as f:
        entries = json.load(f)
    for e in entries:
        key = (e["op"], tuple(e["shape"]), int(e["world"]), e["hw"])
        _SEARCH_CACHE[key] = {"best": dict(e["best"]),
                              "timings": dict(e.get("timings", {}))}
    return len(entries)
