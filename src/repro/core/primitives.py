"""The paper's communication primitives (Table 1), mapped to TPU.

Two levels:

1. **Kernel level** — the OpenSHMEM-style primitive set now lives in
   :mod:`repro.shmem` (one API, two backends: ``tpu_backend`` for real
   TPU Pallas kernels, ``emulated`` for host-side symmetric-heap
   emulation on CPU). The names are re-exported here unchanged, bound
   to the pltpu backend, so in-kernel code keeps reading as the paper
   writes it.

2. **Graph level** (inside shard_map, outside kernels) — decomposed
   collectives built from ``lax.ppermute``, which XLA lowers to async
   collective-permute (start/done) pairs; the "signal" is the data
   dependency on the permute result. These are the overlap engine's
   ``backend="graph"`` transport and live here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Rank identity + kernel-level primitives: re-exported from the shmem
# subsystem (pltpu backend) for compatibility with in-kernel callers.
from ..shmem.api import consume_token, my_pe, n_pes  # noqa: F401
from ..shmem.tpu_backend import (  # noqa: F401
    barrier_all,
    broadcast_put,
    local_copy_nbi,
    notify,
    putmem_signal,
    putmem_signal_nbi,
    quiet,
    signal_op,
    signal_wait_until,
    wait,
)

# ---------------------------------------------------------------------------
# Graph-level primitives (shard_map)
# ---------------------------------------------------------------------------


def ring_permute(x: jax.Array, axis: str, *, reverse: bool = False) -> jax.Array:
    """One ring hop (rank -> rank+1, or rank-1 when reversed)."""
    w = lax.axis_size(axis)
    if reverse:
        perm = [(i, (i - 1) % w) for i in range(w)]
    else:
        perm = [(i, (i + 1) % w) for i in range(w)]
    return lax.ppermute(x, axis, perm)


def offset_permute(x: jax.Array, axis: str, offset: int) -> jax.Array:
    """Send to rank + offset (used by the one-shot / low-latency paths)."""
    w = lax.axis_size(axis)
    perm = [(i, (i + offset) % w) for i in range(w)]
    return lax.ppermute(x, axis, perm)


def one_shot_all_gather(x: jax.Array, axis: str, *, tiled_axis: int = 0) -> jax.Array:
    """Low-latency AllGather (paper Alg. 4 analogue at graph level).

    All ``W-1`` transfers are issued up-front with distinct ring offsets
    (no serial dependency chain), mirroring the LL AllGather's
    all-transfers-at-once structure; on a torus, different offsets travel
    different links concurrently.
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    shards = [x] + [offset_permute(x, axis, off) for off in range(1, w)]
    # shards[off] came from rank (me - off). Scatter into position.
    chunk = x.shape[tiled_axis]
    out_shape = list(x.shape)
    out_shape[tiled_axis] = chunk * w
    out = jnp.zeros(out_shape, x.dtype)
    for off, s in enumerate(shards):
        owner = lax.rem(me - off + w, w)
        start = [0] * x.ndim
        start[tiled_axis] = owner * chunk
        out = lax.dynamic_update_slice(out, s, tuple(start))
    return out
