"""The paper's communication primitives (Table 1), mapped to TPU.

Two levels:

1. **Kernel level** (inside a Pallas TPU kernel) — the faithful port of the
   OpenSHMEM / non-OpenSHMEM primitive set. Symmetric memory is `pl.ANY`
   refs under SPMD shard_map; signals are DMA/REGULAR semaphores; data
   transfer is the chip's async remote-DMA engine. The recv semaphore *is*
   the paper's signal: TPU DMAs signal data arrival in hardware, which is
   why the LL flag-in-word protocol does not need porting.

2. **Graph level** (inside shard_map, outside kernels) — decomposed
   collectives built from `lax.ppermute`, which XLA lowers to async
   collective-permute (start/done) pairs; the "signal" is the data
   dependency on the permute result.

Validation: all kernel-level primitives run under
``pltpu.InterpretParams()`` on CPU with multiple virtual devices.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# Rank identity (OpenSHMEM: my_pe / n_pes)
# ---------------------------------------------------------------------------


def my_pe(axis: str | Sequence[str]) -> jax.Array:
    """Linearized rank along one or more mesh axes (row-major)."""
    if isinstance(axis, str):
        return lax.axis_index(axis)
    idx = lax.axis_index(axis[0])
    for a in axis[1:]:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def n_pes(axis: str | Sequence[str]) -> int:
    if isinstance(axis, str):
        return lax.axis_size(axis)
    n = 1
    for a in axis:
        n *= lax.axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Kernel-level primitives (Pallas TPU)
# ---------------------------------------------------------------------------


def putmem_signal_nbi(
    src_ref,
    dst_ref,
    send_sem,
    recv_sem,
    peer,
    *,
    axis: Optional[str] = None,
):
    """Non-blocking one-sided put + arrival signal (paper: putmem_signal_nbi).

    Starts an async remote DMA copying ``src_ref`` (local) into ``dst_ref``
    *on device* ``peer`` along mesh axis ``axis``. The remote ``recv_sem``
    is incremented by the hardware when the data lands — the signal write
    and the data transfer are one operation, as in NVSHMEM's putmem_signal.
    Returns the copy descriptor; call ``.wait()`` (or ``quiet``) later.
    """
    device_id = (peer,)
    copy = pltpu.make_async_remote_copy(
        src_ref=src_ref,
        dst_ref=dst_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=device_id,
        device_id_type=pltpu.DeviceIdType.MESH,
    )
    copy.start()
    return copy


def putmem_signal(src_ref, dst_ref, send_sem, recv_sem, peer, *, axis=None):
    """Blocking variant: returns after the local send side has completed."""
    copy = putmem_signal_nbi(src_ref, dst_ref, send_sem, recv_sem, peer, axis=axis)
    copy.wait_send()
    return copy


def local_copy_nbi(src_ref, dst_ref, sem):
    """Async local (HBM<->HBM/VMEM) DMA — the 'copy engine' analogue."""
    copy = pltpu.make_async_copy(src_ref, dst_ref, sem)
    copy.start()
    return copy


def signal_op(sem, peer, *, inc: int = 1, axis: Optional[str] = None):
    """Increment a remote signal (paper: signal_op / notify)."""
    pltpu.semaphore_signal(
        sem,
        inc=inc,
        device_id=(peer,),
        device_id_type=pltpu.DeviceIdType.MESH,
    )


notify = signal_op


def signal_wait_until(sem, value: int):
    """Spin-wait until the local signal reaches ``value``, then consume it
    (paper: signal_wait_until / wait)."""
    pltpu.semaphore_wait(sem, value)


wait = signal_wait_until


def consume_token(x, token=None):
    """Paper: consume_token — creates a data dependency between a wait and
    a following load. Pallas refs are effect-ordered, so loads issued after
    a ``semaphore_wait`` are already ordered; kept for source fidelity."""
    del token
    return x


def quiet(*copies):
    """Ensure completion of outstanding one-sided ops (paper: quiet)."""
    for c in copies:
        c.wait()


def barrier_all(axis: str, world: int):
    """Barrier across all ranks on ``axis`` (paper: barrier_all).

    Uses the kernel's collective barrier semaphore: signal every peer, then
    wait for ``world - 1`` arrivals. Requires
    ``compiler_params=pltpu.CompilerParams(collective_id=...)``.
    """
    barrier = pltpu.get_barrier_semaphore()
    me = lax.axis_index(axis)
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        pltpu.semaphore_signal(
            barrier, inc=1, device_id=(peer,), device_id_type=pltpu.DeviceIdType.MESH
        )
    pltpu.semaphore_wait(barrier, world - 1)


def broadcast_put(src_ref, dst_ref, send_sem, recv_sem, axis: str, world: int):
    """multimem_st analogue: store the same data to all peers.

    ICI exposes no multicast primitive, so this is a peer loop of one-sided
    puts (documented hardware-adaptation change). All DMAs are started
    before any wait — they proceed in parallel on the DMA engines.
    """
    me = lax.axis_index(axis)
    copies = []
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        copies.append(
            putmem_signal_nbi(src_ref, dst_ref, send_sem, recv_sem, peer, axis=axis)
        )
    for c in copies:
        c.wait_send()


# ---------------------------------------------------------------------------
# Graph-level primitives (shard_map)
# ---------------------------------------------------------------------------


def ring_permute(x: jax.Array, axis: str, *, reverse: bool = False) -> jax.Array:
    """One ring hop (rank -> rank+1, or rank-1 when reversed)."""
    w = lax.axis_size(axis)
    if reverse:
        perm = [(i, (i - 1) % w) for i in range(w)]
    else:
        perm = [(i, (i + 1) % w) for i in range(w)]
    return lax.ppermute(x, axis, perm)


def offset_permute(x: jax.Array, axis: str, offset: int) -> jax.Array:
    """Send to rank + offset (used by the one-shot / low-latency paths)."""
    w = lax.axis_size(axis)
    perm = [(i, (i + offset) % w) for i in range(w)]
    return lax.ppermute(x, axis, perm)


def one_shot_all_gather(x: jax.Array, axis: str, *, tiled_axis: int = 0) -> jax.Array:
    """Low-latency AllGather (paper Alg. 4 analogue at graph level).

    All ``W-1`` transfers are issued up-front with distinct ring offsets
    (no serial dependency chain), mirroring the LL AllGather's
    all-transfers-at-once structure; on a torus, different offsets travel
    different links concurrently.
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    shards = [x] + [offset_permute(x, axis, off) for off in range(1, w)]
    # shards[off] came from rank (me - off). Scatter into position.
    chunk = x.shape[tiled_axis]
    out_shape = list(x.shape)
    out_shape[tiled_axis] = chunk * w
    out = jnp.zeros(out_shape, x.dtype)
    for off, s in enumerate(shards):
        owner = lax.rem(me - off + w, w)
        start = [0] * x.ndim
        start[tiled_axis] = owner * chunk
        out = lax.dynamic_update_slice(out, s, tuple(start))
    return out
