"""Overlapped collective matmuls — compat wrappers + the 2-level ops.

The 1-level ops (``ag_matmul``, ``matmul_rs``, ``all_gather``) are now
DECLARED in :mod:`repro.ops.library` — one tile-level ``OverlapOp`` each,
from which the graph lowering (the ``ag_pipeline``/``rs_pipeline`` folds
of ``core.overlap``), the kernel lowering (the shmem tile executor) and
the dual-op backward are all derived. This module keeps:

  - thin functional wrappers with the historical signatures (callers
    inside ``shard_map`` and the benchmarks use these; they delegate to
    the declared ops with no deprecation cost),
  - the hierarchical (Fig. 10) 2-level variants, which compose two mesh
    axes and therefore sit outside the single-axis declaration shape,
  - the stand-alone chunked collectives used by grad sync & decode.

Differentiability is the engine's shared custom_vjp: each declared op's
backward is its DUAL overlapped op (O(1) permute buffers, vs. O(W) for
autodiff of an unrolled ring):

    d(AG+GEMM)/dA = GEMM+RS(g, .)      (dual RS ring)
    d(AG+GEMM)/dB = ring-accumulated A_s^T g_s
    d(GEMM+RS)/dA = AG+GEMM(g, .)      (dual AG ring)
    d(AG)/dx      = ring reduce-scatter
"""
from __future__ import annotations

import jax

from . import overlap as ov

Array = jax.Array


# ---------------------------------------------------------------------------
# Baselines (the NCCL-analogue: monolithic collective, no overlap)
# ---------------------------------------------------------------------------


def ag_matmul_baseline(a_blk: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """all_gather(A) @ B with XLA's built-in collective."""
    from ..ops.library import _ag_matmul_baseline

    return _ag_matmul_baseline(a_blk, (b_loc,), axis, out_dtype or a_blk.dtype)


def matmul_rs_baseline(a_loc: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """psum_scatter(A @ B) with XLA's built-in collective."""
    from ..ops.library import _matmul_rs_baseline

    return _matmul_rs_baseline(a_loc, (b_loc,), axis, out_dtype or a_loc.dtype)


# ---------------------------------------------------------------------------
# 1-level ops: wrappers over the repro.ops declarations
# ---------------------------------------------------------------------------


def ag_matmul(a_blk, b_loc, axis, *, mode="ring", chunks_per_rank=1,
              out_dtype=None, backend="graph", wire="f32"):
    """Overlapped AllGather-GEMM (see the ``ag_matmul`` declaration in
    ``repro.ops.library``). The backward pass is the dual overlapped
    GEMM+RS ring for BOTH backends — a kernel forward keeps the
    graph-lowered dual as its backward. ``wire`` quantizes the riding
    A-chunks (``repro.ops.wire``)."""
    from .. import ops

    return ops.ag_matmul(a_blk, b_loc, axis=axis, mode=mode,
                         chunks=max(1, chunks_per_rank),
                         out_dtype=out_dtype, backend=backend, wire=wire)


def matmul_rs(a_loc, b_loc, axis, *, mode="ring", chunks_per_rank=1,
              out_dtype=None, backend="graph", wire="f32"):
    """Overlapped GEMM-ReduceScatter; backward = dual AG+GEMM ring.
    ``chunks_per_rank`` (rs_chunks) sub-chunks the ring accumulator into
    column groups; ``backend="kernel"`` lowers through the shmem tile
    executor (ring = Alg. 3 push, one_shot = all partials up-front).
    ``wire`` quantizes the riding partial accumulators."""
    from .. import ops

    return ops.matmul_rs(a_loc, b_loc, axis=axis, mode=mode,
                         chunks=max(1, chunks_per_rank),
                         out_dtype=out_dtype, backend=backend, wire=wire)


def all_gather_chunked(x: Array, axis: str, *, mode: str = "ring",
                       backend: str = "graph", wire: str = "f32") -> Array:
    """Decomposed AllGather; backward = ring reduce-scatter (O(1)).
    ``backend="kernel"`` lowers one_shot through the executor's
    low-latency AllGather protocol."""
    from .. import ops

    return ops.all_gather(x, axis=axis, mode=mode, backend=backend, wire=wire)


# ---------------------------------------------------------------------------
# 2-level (Fig. 10) variants: compound (pod x ring-in-pod) meshes
# ---------------------------------------------------------------------------


def ag_matmul_2level(
    a_blk: Array,
    b_loc: Array,
    inner_axis: str,
    outer_axis: str,
    *,
    mode: str = "two_level",
    out_dtype=None,
    backend: str = "graph",
) -> Array:
    """AG+GEMM over a compound (outer=pod, inner=ring-in-pod) axis — the
    AG dual of ``matmul_rs_2level`` (see the ``ag_matmul_2level``
    declaration in ``repro.ops.library``). Own pod's inner ring runs
    first while peer-pod chunks travel the slow links (Fig. 10's shifted
    start). ``backend="kernel"`` lowers through the executor's two-axis
    ``two_level_ag`` protocol (pod-local one_shot exchange concurrent
    with the inter-pod ring). a_blk: (m_loc, k); returns
    (m_loc * Wo * Wi, n_loc)."""
    from .. import ops

    return ops.ag_matmul_2level(a_blk, b_loc, axis=(inner_axis, outer_axis),
                                mode=mode, out_dtype=out_dtype,
                                backend=backend)


def matmul_rs_2level(
    a_loc: Array,
    b_loc: Array,
    inner_axis: str,
    outer_axis: str,
    *,
    mode: str = "two_level",
    out_dtype=None,
    backend: str = "graph",
) -> Array:
    """GEMM+RS over a compound (outer=pod, inner=ring-in-pod) axis
    (Fig. 10 / Alg. 5; the ``matmul_rs_2level`` declaration in
    ``repro.ops.library``). ``backend="kernel"`` lowers through the
    executor's ``two_level_rs`` protocol. a_loc: (m, k_loc) with K
    sharded over outer*inner; returns (m / (Wo*Wi), n)."""
    from .. import ops

    return ops.matmul_rs_2level(a_loc, b_loc, axis=(inner_axis, outer_axis),
                                mode=mode, out_dtype=out_dtype,
                                backend=backend)


# The 2-level ops and "reduce_scatter" are DECLARED in repro.ops.library
# (two_level_ag/two_level_rs executor protocols; f32-accumulating tile
# over the RS pipelines + push_rs/one_shot_rs kernel protocols).


# ---------------------------------------------------------------------------
# Chunked stand-alone collectives (used by grad sync & decode paths)
# ---------------------------------------------------------------------------


def reduce_scatter_chunked(x: Array, axis: str, *, mode: str = "ring",
                           backend: str = "graph", wire: str = "f32") -> Array:
    """Decomposed reduce-scatter along dim 0 (accumulator in f32); see
    the ``reduce_scatter`` declaration in ``repro.ops.library``.
    ``backend="kernel"`` lowers ring through the executor's Alg.-3 push
    and one_shot through the all-partials-up-front protocol. ``wire``
    quantizes the riding partials (decoded + accumulated in f32)."""
    from .. import ops

    return ops.reduce_scatter(x, axis=axis, mode=mode, backend=backend,
                              wire=wire)


def hierarchical_reduce_scatter(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """RS along inner (fast links), then ring all-reduce along outer (slow
    links) on the already 1/Wi-sized shard — the gradient-sync pattern."""
    shard = reduce_scatter_chunked(x, inner_axis)
    return ov.ring_allreduce(shard, outer_axis)


def hierarchical_all_gather(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """Inverse of hierarchical RS: gather along inner axis only (params are
    replicated across pods, sharded within)."""
    return all_gather_chunked(x, inner_axis)


# ---------------------------------------------------------------------------
# shard_map wrappers (for tests / standalone use)
# ---------------------------------------------------------------------------


def make_sharded(fn, mesh, in_specs, out_specs):
    return jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    )


# Importing this module must populate the full registry (tests and the
# tuner enumerate it); the 1-level declarations live in repro.ops.
from .. import ops as _ops  # noqa: E402,F401
