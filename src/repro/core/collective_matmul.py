"""Overlapped collective matmuls — the paper's flagship kernels at graph level.

These functions run INSIDE ``shard_map`` (they take local shards and use
``lax`` collectives). They decompose XLA's monolithic
``all_gather -> dot`` / ``dot -> psum_scatter`` into per-chunk one-sided
transfers (``lax.ppermute`` = async collective-permute on TPU) interleaved
with per-chunk matmuls in the swizzled order from ``core.schedules``:

  AG+GEMM  (Fig. 4/7):  rank r computes chunk (r - s) % W at step s while
                        the next chunk rides the ring.
  GEMM+RS  (Alg. 3/5):  rank r computes output block (r - s - 1) % W and
                        forwards a running accumulator.
  2-level  (Fig. 10):   inner ring per pod region, peer-pod regions first,
                        inter-pod transfer overlapping the next region.

XLA's latency-hiding scheduler turns each ppermute into a
collective-permute-start/done pair that runs on the ICI DMA engines
concurrently with the MXU dots — the TPU analogue of the paper's
copy-engine / SM-partition async tasks.

The non-overlapped baselines (`*_baseline`) are the "PyTorch+NCCL"
equivalents used by benchmarks and tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from jax.ad_checkpoint import checkpoint_name

from .primitives import offset_permute, ring_permute

Array = jax.Array


def _owner_update(out: Array, partial: Array, owner, m_chunk: int, row_off: int = 0) -> Array:
    start = (owner * m_chunk + row_off,) + (0,) * (out.ndim - 1)
    return lax.dynamic_update_slice(out, partial, start)


# ---------------------------------------------------------------------------
# Baselines (the NCCL-analogue: monolithic collective, no overlap)
# ---------------------------------------------------------------------------


def ag_matmul_baseline(a_blk: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """all_gather(A) @ B with XLA's built-in collective."""
    out_dtype = out_dtype or a_blk.dtype
    a_full = lax.all_gather(a_blk, axis, tiled=True)
    return jnp.dot(a_full, b_loc, preferred_element_type=jnp.float32).astype(out_dtype)


def matmul_rs_baseline(a_loc: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """psum_scatter(A @ B) with XLA's built-in collective."""
    out_dtype = out_dtype or a_loc.dtype
    partial = jnp.dot(a_loc, b_loc, preferred_element_type=jnp.float32)
    return lax.psum_scatter(partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)


# ---------------------------------------------------------------------------
# AG + GEMM (overlapped)
# ---------------------------------------------------------------------------


def _ag_matmul_impl(
    a_blk: Array,
    b_loc: Array,
    axis: str,
    mode: str = "ring",
    chunks_per_rank: int = 1,
    out_dtype=None,
) -> Array:
    """Overlapped AllGather-GEMM (implementation; see ag_matmul).

    a_blk: (m_loc, k) — A sharded along M on ``axis`` (SP activations).
    b_loc: (k, n_loc) — B sharded along N (TP weights).
    Returns (m_loc * W, n_loc): the full-M strip of C this rank owns.

    mode:
      ring     unidirectional ring, Fig. 7 swizzle (paper default)
      bidir    bidirectional ring — both link directions, half bytes each
      one_shot all transfers issued up-front (low-latency, small messages)
      none     baseline (monolithic all_gather)
    """
    out_dtype = out_dtype or a_blk.dtype
    if mode == "bidir":
        return _ag_matmul_bidir(a_blk, b_loc, axis, out_dtype=out_dtype)
    if mode == "one_shot":
        return _ag_matmul_one_shot(a_blk, b_loc, axis, out_dtype=out_dtype)
    if mode != "ring":
        raise ValueError(f"unknown ag mode {mode!r}")

    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m_loc = a_blk.shape[0]
    n_loc = b_loc.shape[1]
    out = jnp.zeros((m_loc * w, n_loc), out_dtype)

    s_sub = max(1, chunks_per_rank)
    if m_loc % s_sub != 0:
        s_sub = 1
    m_sub = m_loc // s_sub
    # Sub-chunk ring: finer pipelining shrinks the first-chunk fill bubble
    # (the communication-tile-size knob of §3.6, exposed to the tuner).
    bufs = [
        lax.dynamic_slice(a_blk, (j * m_sub, 0), (m_sub, a_blk.shape[1]))
        for j in range(s_sub)
    ]
    for s in range(w):
        owner = lax.rem(me - s + w, w)
        for j in range(s_sub):
            partial = jnp.dot(bufs[j], b_loc, preferred_element_type=jnp.float32)
            out = _owner_update(out, partial.astype(out_dtype), owner, m_loc, j * m_sub)
            if s != w - 1:
                # next chunk rides the ring while later dots execute
                bufs[j] = ring_permute(bufs[j], axis)
    return out


def _ag_matmul_bidir(a_blk: Array, b_loc: Array, axis: str, *, out_dtype) -> Array:
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m_loc = a_blk.shape[0]
    if m_loc % 2 != 0 or w < 3:
        return _ag_matmul_impl(a_blk, b_loc, axis, mode="ring", out_dtype=out_dtype)
    h = m_loc // 2
    n_loc = b_loc.shape[1]
    out = jnp.zeros((m_loc * w, n_loc), out_dtype)
    fwd = a_blk[:h]
    bwd = a_blk[h:]
    for s in range(w):
        owner_f = lax.rem(me - s + w, w)
        owner_b = lax.rem(me + s, w)
        pf = jnp.dot(fwd, b_loc, preferred_element_type=jnp.float32)
        out = _owner_update(out, pf.astype(out_dtype), owner_f, m_loc, 0)
        pb = jnp.dot(bwd, b_loc, preferred_element_type=jnp.float32)
        out = _owner_update(out, pb.astype(out_dtype), owner_b, m_loc, h)
        if s != w - 1:
            fwd = ring_permute(fwd, axis)
            bwd = ring_permute(bwd, axis, reverse=True)
    return out


def _ag_matmul_one_shot(a_blk: Array, b_loc: Array, axis: str, *, out_dtype) -> Array:
    """Low-latency variant: issue every transfer before any dot (Alg. 4
    structure). First dot runs on the local chunk with zero comm latency."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m_loc = a_blk.shape[0]
    n_loc = b_loc.shape[1]
    shards = [a_blk] + [offset_permute(a_blk, axis, off) for off in range(1, w)]
    out = jnp.zeros((m_loc * w, n_loc), out_dtype)
    for off, shard in enumerate(shards):
        owner = lax.rem(me - off + w, w)
        partial = jnp.dot(shard, b_loc, preferred_element_type=jnp.float32)
        out = _owner_update(out, partial.astype(out_dtype), owner, m_loc)
    return out


# ---------------------------------------------------------------------------
# GEMM + ReduceScatter (overlapped)
# ---------------------------------------------------------------------------


def _matmul_rs_impl(
    a_loc: Array,
    b_loc: Array,
    axis: str,
    mode: str = "ring",
    out_dtype=None,
) -> Array:
    """Overlapped GEMM-ReduceScatter (implementation; see matmul_rs).

    a_loc: (m, k_loc) — activations with K sharded on ``axis`` (TP).
    b_loc: (k_loc, n) — weights sharded on K.
    Returns (m / W, n): this rank's reduced output block (SP activations).

    Ring schedule (Alg. 3): at step s rank r computes the partial product
    for output block (r - s - 1) % W, adds the accumulator arriving from
    rank r-1, and forwards it — the accumulator remains one block in
    flight while the next block's dot executes.
    """
    out_dtype = out_dtype or a_loc.dtype
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m = a_loc.shape[0]
    assert m % w == 0, (m, w)
    m_blk = m // w
    if mode == "bidir" and b_loc.shape[1] % 2 == 0 and w >= 3:
        # split the output columns across BOTH ring directions: two
        # accumulators, half the bytes per link per step (2 ICI links).
        # Reverse-ring handoff check: p(i-1, s+1) == p(i, s) for
        # p(i, s) = (i + s + 1) % W.
        bl, br = jnp.split(b_loc, 2, axis=1)
        acc_f = acc_r = None
        for s in range(w):
            blk_f = lax.rem(me - s - 1 + 2 * w, w)
            blk_r = lax.rem(me + s + 1, w)
            a_f = lax.dynamic_slice(a_loc, (blk_f * m_blk, 0), (m_blk, a_loc.shape[1]))
            a_r = lax.dynamic_slice(a_loc, (blk_r * m_blk, 0), (m_blk, a_loc.shape[1]))
            pf = jnp.dot(a_f, bl, preferred_element_type=jnp.float32)
            pr = jnp.dot(a_r, br, preferred_element_type=jnp.float32)
            acc_f = pf if acc_f is None else pf + ring_permute(acc_f, axis)
            acc_r = pr if acc_r is None else pr + ring_permute(acc_r, axis, reverse=True)
        return jnp.concatenate([acc_f, acc_r], axis=1).astype(out_dtype)
    if mode not in ("ring", "bidir"):
        raise ValueError(f"unknown rs mode {mode!r}")
    acc = None
    for s in range(w):
        blk = lax.rem(me - s - 1 + 2 * w, w)
        a_b = lax.dynamic_slice(a_loc, (blk * m_blk, 0), (m_blk, a_loc.shape[1]))
        partial = jnp.dot(a_b, b_loc, preferred_element_type=jnp.float32)
        if acc is None:
            acc = partial
        else:
            # the permute of the previous accumulator overlaps this dot
            acc = partial + ring_permute(acc, axis)
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# 2-level (multi-pod) GEMM + ReduceScatter — Fig. 10 / Alg. 5
# ---------------------------------------------------------------------------


def matmul_rs_2level(
    a_loc: Array,
    b_loc: Array,
    inner_axis: str,
    outer_axis: str,
    *,
    out_dtype=None,
) -> Array:
    """GEMM+RS over a compound (outer=pod, inner=ring-in-pod) axis.

    a_loc: (m, k_loc) with K sharded over outer*inner; returns
    (m / (Wo*Wi), n). Outer step s reduces — over the inner ring — the
    partial sums for pod region (pod - s - 1) % Wo (peer pods first, own
    pod last, Fig. 10's shifted start), then forwards the inter-pod
    accumulator, overlapping the slow-link transfer with the next region's
    Wi matmuls.
    """
    out_dtype = out_dtype or a_loc.dtype
    wo = lax.axis_size(outer_axis)
    wi = lax.axis_size(inner_axis)
    oid = lax.axis_index(outer_axis)
    iid = lax.axis_index(inner_axis)
    m = a_loc.shape[0]
    total = wo * wi
    assert m % total == 0, (m, total)
    m_blk = m // total

    outer_acc = None
    for s in range(wo):
        region = lax.rem(oid - s - 1 + 2 * wo, wo)
        # --- inner ring RS for this pod region (Alg. 5 "intra-node scatter
        # + local reduction", expressed as a compute/permute ring) ---
        inner_acc = None
        for t in range(wi):
            blk_inner = lax.rem(iid - t - 1 + 2 * wi, wi)
            blk = region * wi + blk_inner
            a_b = lax.dynamic_slice(a_loc, (blk * m_blk, 0), (m_blk, a_loc.shape[1]))
            partial = jnp.dot(a_b, b_loc, preferred_element_type=jnp.float32)
            if inner_acc is None:
                inner_acc = partial
            else:
                inner_acc = partial + ring_permute(inner_acc, inner_axis)
        # --- inter-pod P2P: forward the outer accumulator; this slow-link
        # permute overlaps the next region's inner ring of dots ---
        if outer_acc is None:
            outer_acc = inner_acc
        else:
            outer_acc = inner_acc + ring_permute(outer_acc, outer_axis)
    return outer_acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Custom VJPs: each op's backward IS its dual overlapped op.
#
# Autodiff of an unrolled W-step ring holds all W permute buffers live
# during the backward (O(W) memory — 20 GiB/layer-group at W=16 for 90B
# models, measured). The mathematical transpose is another ring with O(1)
# buffers:   d(AG+GEMM)/dA = GEMM+RS(g, B^T)      (ring)
#            d(AG+GEMM)/dB = ring-accumulated A_s^T g_s
#            d(GEMM+RS)/dA = AG+GEMM(g, B^T)      (ring)
#            d(AG)/dx      = ring reduce-scatter
# ---------------------------------------------------------------------------


def _weight_grad_ring(a_blk: Array, g: Array, axis: str) -> Array:
    """dB = A_full^T @ G without materializing A_full: ring A chunks past
    the static G strips. a_blk: (m_loc, k); g: (W*m_loc, n). -> (k, n)."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m_loc = a_blk.shape[0]
    db = jnp.zeros((a_blk.shape[1], g.shape[1]), jnp.float32)
    buf = a_blk
    for s in range(w):
        owner = lax.rem(me - s + w, w)
        g_s = lax.dynamic_slice(g, (owner * m_loc, 0), (m_loc, g.shape[1]))
        db = db + jax.lax.dot_general(
            buf, g_s, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        if s != w - 1:
            buf = ring_permute(buf, axis)
    return db


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _ag_matmul_cv(a_blk, b_loc, axis, mode, chunks_per_rank):
    return _ag_matmul_impl(a_blk, b_loc, axis, mode=mode,
                           chunks_per_rank=chunks_per_rank,
                           out_dtype=a_blk.dtype)


def _ag_matmul_cv_fwd(a_blk, b_loc, axis, mode, chunks_per_rank):
    out = _ag_matmul_cv(a_blk, b_loc, axis, mode, chunks_per_rank)
    return out, (a_blk, b_loc)


def _ag_matmul_cv_bwd(axis, mode, chunks_per_rank, res, g):
    a_blk, b_loc = res
    da = matmul_rs(g, b_loc.T, axis, mode="ring", out_dtype=a_blk.dtype)
    db = _weight_grad_ring(a_blk, g, axis).astype(b_loc.dtype)  # (k, n_loc)
    return da, db


_ag_matmul_cv.defvjp(_ag_matmul_cv_fwd, _ag_matmul_cv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul_rs_cv(a_loc, b_loc, axis, mode):
    return _matmul_rs_impl(a_loc, b_loc, axis, mode=mode, out_dtype=a_loc.dtype)


def _matmul_rs_cv_fwd(a_loc, b_loc, axis, mode):
    return _matmul_rs_cv(a_loc, b_loc, axis, mode), (a_loc, b_loc)


def _matmul_rs_cv_bwd(axis, mode, res, g):
    a_loc, b_loc = res
    # g: (m/W, n) block; dA = AG(g) @ B^T -> overlapped AG+GEMM ring
    da = ag_matmul(g, b_loc.T, axis, mode="ring", out_dtype=a_loc.dtype)
    # dB = A^T @ AG(g): ring the g blocks past the static A strips
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m_blk = g.shape[0]
    db = jnp.zeros((a_loc.shape[1], g.shape[1]), jnp.float32)
    buf = g
    for s in range(w):
        owner = lax.rem(me - s + w, w)
        a_s = lax.dynamic_slice(
            a_loc, (owner * m_blk, 0), (m_blk, a_loc.shape[1])
        )
        db = db + jax.lax.dot_general(
            a_s, buf, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        if s != w - 1:
            buf = ring_permute(buf, axis)
    return da, db.astype(b_loc.dtype)


_matmul_rs_cv.defvjp(_matmul_rs_cv_fwd, _matmul_rs_cv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _all_gather_cv(x, axis, mode):
    return _all_gather_impl(x, axis, mode=mode)


def _all_gather_cv_fwd(x, axis, mode):
    return _all_gather_cv(x, axis, mode), None


def _all_gather_cv_bwd(axis, mode, _, g):
    return (reduce_scatter_chunked(g, axis).astype(g.dtype),)


_all_gather_cv.defvjp(_all_gather_cv_fwd, _all_gather_cv_bwd)


# ---------------------------------------------------------------------------
# Public overlapped ops (route through the custom-VJP wrappers)
# ---------------------------------------------------------------------------


def ag_matmul(a_blk, b_loc, axis, *, mode="ring", chunks_per_rank=1,
              out_dtype=None):
    """Overlapped AllGather-GEMM (see _ag_matmul_impl for modes). The
    backward pass is the dual overlapped GEMM+RS ring (O(1) buffers).

    The output is tagged with checkpoint_name("ag_out") so the
    "block_save_ag" remat policy can keep gathered activations across the
    backward instead of re-running the gather ring (-1/3 collective
    volume for +per-layer-output memory)."""
    out_dtype = out_dtype or a_blk.dtype
    if mode == "none":
        out = ag_matmul_baseline(a_blk, b_loc, axis, out_dtype=out_dtype)
    else:
        out = _ag_matmul_cv(a_blk, b_loc, axis, mode, chunks_per_rank).astype(out_dtype)
    return checkpoint_name(out, "ag_out")


def matmul_rs(a_loc, b_loc, axis, *, mode="ring", out_dtype=None):
    """Overlapped GEMM-ReduceScatter; backward = dual AG+GEMM ring."""
    out_dtype = out_dtype or a_loc.dtype
    if mode == "none":
        return matmul_rs_baseline(a_loc, b_loc, axis, out_dtype=out_dtype)
    return _matmul_rs_cv(a_loc, b_loc, axis, mode).astype(out_dtype)


def all_gather_chunked(x: Array, axis: str, *, mode: str = "ring") -> Array:
    """Decomposed AllGather; backward = ring reduce-scatter (O(1))."""
    return _all_gather_cv(x, axis, mode)


# ---------------------------------------------------------------------------
# Chunked stand-alone collectives (used by grad sync & decode paths)
# ---------------------------------------------------------------------------


def _all_gather_impl(x: Array, axis: str, mode: str = "ring") -> Array:
    """One-sided decomposed AllGather (Alg. 1/2 push-ring, Alg. 4 one-shot)."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    chunk = x.shape[0]
    out = jnp.zeros((chunk * w,) + x.shape[1:], x.dtype)
    out = _owner_update(out, x, me, chunk)
    if mode == "one_shot":
        for off in range(1, w):
            shard = offset_permute(x, axis, off)
            out = _owner_update(out, shard, lax.rem(me - off + w, w), chunk)
        return out
    buf = x
    for s in range(1, w):
        buf = ring_permute(buf, axis)
        out = _owner_update(out, buf, lax.rem(me - s + w, w), chunk)
    return out


def reduce_scatter_chunked(x: Array, axis: str) -> Array:
    """Ring reduce-scatter along dim 0 (accumulator in f32)."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    m = x.shape[0]
    assert m % w == 0
    m_blk = m // w
    acc = None
    for s in range(w):
        blk = lax.rem(me - s - 1 + 2 * w, w)
        piece = lax.dynamic_slice(x, (blk * m_blk,) + (0,) * (x.ndim - 1), (m_blk,) + x.shape[1:])
        if acc is None:
            acc = piece.astype(jnp.float32)
        else:
            acc = piece.astype(jnp.float32) + ring_permute(acc, axis)
    return acc.astype(x.dtype)


def hierarchical_reduce_scatter(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """RS along inner (fast links), then ring all-reduce along outer (slow
    links) on the already 1/Wi-sized shard — the gradient-sync pattern."""
    shard = reduce_scatter_chunked(x, inner_axis)
    wo = lax.axis_size(outer_axis)
    acc = shard.astype(jnp.float32)
    buf = acc
    for _ in range(wo - 1):
        buf = ring_permute(buf, outer_axis)
        acc = acc + buf
    return acc.astype(x.dtype)


def hierarchical_all_gather(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """Inverse of hierarchical RS: gather along inner axis only (params are
    replicated across pods, sharded within)."""
    return all_gather_chunked(x, inner_axis)


# ---------------------------------------------------------------------------
# shard_map wrappers (for tests / standalone use)
# ---------------------------------------------------------------------------


def make_sharded(fn, mesh, in_specs, out_specs):
    return jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    )
