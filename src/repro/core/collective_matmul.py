"""Overlapped collective matmuls — thin declarations over the ring-pipeline
engine (``core.overlap``).

These functions run INSIDE ``shard_map`` (they take local shards and use
``lax`` collectives). Each op is its engine composition:

  ag_matmul        AG+GEMM (Fig. 4/7): per-chunk dot folded into a
                   scatter-into-output carry; transports ring / bidir /
                   one_shot, plus ``ag_matmul_2level`` for multi-pod
                   meshes (Fig. 10's AG side).
  matmul_rs        GEMM+RS (Alg. 3/5): per-block dot as the rs_pipeline's
                   compute; transports ring / bidir / one_shot, plus
                   ``matmul_rs_2level``.
  all_gather /     stand-alone decomposed collectives (gather_pipeline /
  reduce_scatter   rs_pipeline) used by grad sync & decode paths.

No step loop lives here: the schedule orders, the transport permutes, and
the compute/permute overlap all come from ``core.overlap`` (XLA lowers
each ``ppermute`` to an async collective-permute start/done pair that the
latency-hiding scheduler runs on the ICI DMA engines concurrently with
the MXU dots — the TPU analogue of the paper's copy-engine async tasks).

Differentiability is the engine's shared custom_vjp: each op registers
its backward as its DUAL overlapped op (O(1) permute buffers, vs. O(W)
for autodiff of an unrolled ring):

    d(AG+GEMM)/dA = GEMM+RS(g, B^T)      (ring)
    d(AG+GEMM)/dB = ring-accumulated A_s^T g_s
    d(GEMM+RS)/dA = AG+GEMM(g, B^T)      (ring)
    d(AG)/dx      = ring reduce-scatter

The non-overlapped baselines (``*_baseline``) are the "PyTorch+NCCL"
equivalents used by benchmarks and tests, and are each op's registered
``baseline`` mode in the registry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from jax.ad_checkpoint import checkpoint_name

from . import overlap as ov

Array = jax.Array


def _owner_update(out: Array, partial: Array, owner, m_chunk: int, row_off: int = 0) -> Array:
    start = (owner * m_chunk + row_off,) + (0,) * (out.ndim - 1)
    return lax.dynamic_update_slice(out, partial, start)


# ---------------------------------------------------------------------------
# Baselines (the NCCL-analogue: monolithic collective, no overlap)
# ---------------------------------------------------------------------------


def ag_matmul_baseline(a_blk: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """all_gather(A) @ B with XLA's built-in collective."""
    out_dtype = out_dtype or a_blk.dtype
    a_full = lax.all_gather(a_blk, axis, tiled=True)
    return jnp.dot(a_full, b_loc, preferred_element_type=jnp.float32).astype(out_dtype)


def matmul_rs_baseline(a_loc: Array, b_loc: Array, axis: str, *, out_dtype=None) -> Array:
    """psum_scatter(A @ B) with XLA's built-in collective."""
    out_dtype = out_dtype or a_loc.dtype
    partial = jnp.dot(a_loc, b_loc, preferred_element_type=jnp.float32)
    return lax.psum_scatter(partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)


# ---------------------------------------------------------------------------
# AG + GEMM (overlapped)
# ---------------------------------------------------------------------------


def _ag_matmul_impl(
    a_blk: Array,
    b_loc: Array,
    axis: str,
    mode: str = "ring",
    chunks_per_rank: int = 1,
    out_dtype=None,
) -> Array:
    """Overlapped AllGather-GEMM (implementation; see ag_matmul).

    a_blk: (m_loc, k) — A sharded along M on ``axis`` (SP activations).
    b_loc: (k, n_loc) — B sharded along N (TP weights).
    Returns (m_loc * W, n_loc): the full-M strip of C this rank owns.
    """
    out_dtype = out_dtype or a_blk.dtype
    w = lax.axis_size(axis)
    m_loc = a_blk.shape[0]
    n_loc = b_loc.shape[1]
    out0 = jnp.zeros((m_loc * w, n_loc), out_dtype)

    if mode == "bidir" and m_loc % 2 == 0 and w >= 3:
        h = m_loc // 2

        def fold2(out, bufs, s, owner, direction):
            partial = jnp.dot(bufs[0], b_loc, preferred_element_type=jnp.float32)
            return _owner_update(out, partial.astype(out_dtype), owner, m_loc,
                                 direction * h)

        return ov.bidir_ag_pipeline((a_blk,), fold2, out0, axis)
    if mode == "bidir":
        mode = "ring"  # odd chunk or W < 3: bidir degenerates to ring
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"unknown ag mode {mode!r}")

    # Sub-chunk ring: finer pipelining shrinks the first-chunk fill bubble
    # (the communication-tile-size knob of §3.6, exposed to the tuner).
    s_sub = max(1, chunks_per_rank)
    if m_loc % s_sub != 0 or mode == "one_shot":
        s_sub = 1
    m_sub = m_loc // s_sub
    subs = tuple(
        lax.dynamic_slice(a_blk, (j * m_sub, 0), (m_sub, a_blk.shape[1]))
        for j in range(s_sub)
    )

    def fold(out, bufs, s, owner):
        for j, bj in enumerate(bufs):
            partial = jnp.dot(bj, b_loc, preferred_element_type=jnp.float32)
            out = _owner_update(out, partial.astype(out_dtype), owner, m_loc,
                                j * m_sub)
        return out

    return ov.ag_pipeline(subs, fold, out0, axis, transport=mode)


def ag_matmul_2level(
    a_blk: Array,
    b_loc: Array,
    inner_axis: str,
    outer_axis: str,
    *,
    out_dtype=None,
) -> Array:
    """AG+GEMM over a compound (outer=pod, inner=ring-in-pod) axis — the
    AG dual of ``matmul_rs_2level``. Own pod's inner ring runs first
    while peer-pod chunks travel the slow links (Fig. 10's shifted
    start). a_blk: (m_loc, k); returns (m_loc * Wo * Wi, n_loc)."""
    out_dtype = out_dtype or a_blk.dtype
    total = lax.axis_size(outer_axis) * lax.axis_size(inner_axis)
    m_loc = a_blk.shape[0]
    out0 = jnp.zeros((m_loc * total, b_loc.shape[1]), out_dtype)

    def fold(out, bufs, s, owner):
        partial = jnp.dot(bufs[0], b_loc, preferred_element_type=jnp.float32)
        return _owner_update(out, partial.astype(out_dtype), owner, m_loc)

    return ov.two_level_ag_pipeline((a_blk,), fold, out0, inner_axis, outer_axis)


# ---------------------------------------------------------------------------
# GEMM + ReduceScatter (overlapped)
# ---------------------------------------------------------------------------


def _matmul_rs_impl(
    a_loc: Array,
    b_loc: Array,
    axis: str,
    mode: str = "ring",
    chunks_per_rank: int = 1,
    out_dtype=None,
) -> Array:
    """Overlapped GEMM-ReduceScatter (implementation; see matmul_rs).

    a_loc: (m, k_loc) — activations with K sharded on ``axis`` (TP).
    b_loc: (k_loc, n) — weights sharded on K.
    Returns (m / W, n): this rank's reduced output block (SP activations).
    """
    out_dtype = out_dtype or a_loc.dtype
    w = lax.axis_size(axis)
    m = a_loc.shape[0]
    assert m % w == 0, (m, w)
    m_blk = m // w

    def a_block(blk):
        return lax.dynamic_slice(a_loc, (blk * m_blk, 0), (m_blk, a_loc.shape[1]))

    if mode == "bidir" and b_loc.shape[1] % 2 == 0 and w >= 3:
        # split the output columns across BOTH ring directions: two
        # accumulators, half the bytes per link per step (2 ICI links).
        bl, br = jnp.split(b_loc, 2, axis=1)

        def compute2(blk, s, direction):
            return jnp.dot(a_block(blk), bl if direction == 0 else br,
                           preferred_element_type=jnp.float32)

        acc_f, acc_r = ov.bidir_rs_pipeline(compute2, axis)
        return jnp.concatenate([acc_f, acc_r], axis=1).astype(out_dtype)
    if mode == "bidir":
        mode = "ring"
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"unknown rs mode {mode!r}")

    # Sub-chunked RS ring (rs_chunks, mirroring the AG side's ag_chunks):
    # the accumulator is split into column groups, each riding its own
    # independent ring, so per-permute messages shrink by s_sub (the
    # communication-tile-size knob of §3.6) and XLA's latency-hiding
    # scheduler interleaves the pipelines' permutes with the dots.
    s_sub = max(1, chunks_per_rank)
    n = b_loc.shape[1]
    if n % s_sub != 0 or mode == "one_shot":
        s_sub = 1
    if s_sub > 1:
        n_sub = n // s_sub
        outs = []
        for j in range(s_sub):
            b_j = lax.dynamic_slice(b_loc, (0, j * n_sub),
                                    (b_loc.shape[0], n_sub))

            def compute_j(blk, s, b_j=b_j):
                return jnp.dot(a_block(blk), b_j,
                               preferred_element_type=jnp.float32)

            outs.append(ov.rs_pipeline(compute_j, axis, transport="ring"))
        return jnp.concatenate(outs, axis=1).astype(out_dtype)

    def compute(blk, s):
        return jnp.dot(a_block(blk), b_loc, preferred_element_type=jnp.float32)

    return ov.rs_pipeline(compute, axis, transport=mode).astype(out_dtype)


def matmul_rs_2level(
    a_loc: Array,
    b_loc: Array,
    inner_axis: str,
    outer_axis: str,
    *,
    out_dtype=None,
) -> Array:
    """GEMM+RS over a compound (outer=pod, inner=ring-in-pod) axis
    (Fig. 10 / Alg. 5). a_loc: (m, k_loc) with K sharded over
    outer*inner; returns (m / (Wo*Wi), n)."""
    out_dtype = out_dtype or a_loc.dtype
    total = lax.axis_size(outer_axis) * lax.axis_size(inner_axis)
    m = a_loc.shape[0]
    assert m % total == 0, (m, total)
    m_blk = m // total

    def compute(blk, s):
        a_b = lax.dynamic_slice(a_loc, (blk * m_blk, 0), (m_blk, a_loc.shape[1]))
        return jnp.dot(a_b, b_loc, preferred_element_type=jnp.float32)

    return ov.two_level_rs_pipeline(compute, inner_axis, outer_axis).astype(out_dtype)


# ---------------------------------------------------------------------------
# Weight-gradient rings (the "accumulate over static strips" duals)
# ---------------------------------------------------------------------------


def _weight_grad_ring(a_blk: Array, g: Array, axis: str) -> Array:
    """dB = A_full^T @ G without materializing A_full: ring A chunks past
    the static G strips. a_blk: (m_loc, k); g: (W*m_loc, n). -> (k, n)."""
    m_loc = a_blk.shape[0]
    db0 = jnp.zeros((a_blk.shape[1], g.shape[1]), jnp.float32)

    def fold(db, bufs, s, owner):
        g_s = lax.dynamic_slice(g, (owner * m_loc, 0), (m_loc, g.shape[1]))
        return db + lax.dot_general(
            bufs[0], g_s, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    return ov.ag_pipeline((a_blk,), fold, db0, axis, transport="ring")


def _rs_weight_grad_ring(a_loc: Array, g: Array, axis: str) -> Array:
    """dB for GEMM+RS: ring the g blocks past the static A strips.
    a_loc: (W*m_blk, k_loc); g: (m_blk, n). -> (k_loc, n)."""
    m_blk = g.shape[0]
    db0 = jnp.zeros((a_loc.shape[1], g.shape[1]), jnp.float32)

    def fold(db, bufs, s, owner):
        a_s = lax.dynamic_slice(a_loc, (owner * m_blk, 0), (m_blk, a_loc.shape[1]))
        return db + lax.dot_general(
            a_s, bufs[0], (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    return ov.ag_pipeline((g,), fold, db0, axis, transport="ring")


# ---------------------------------------------------------------------------
# Registry entries: fwd impls + dual-op backward rules, all routed through
# the engine's ONE shared custom_vjp (overlap.apply).
# ---------------------------------------------------------------------------


def _ag_fwd(static, a_blk, b_loc):
    return _ag_matmul_impl(a_blk, b_loc, static["axis"], mode=static["mode"],
                           chunks_per_rank=static["chunks"], out_dtype=a_blk.dtype)


def _ag_bwd(static, res, g):
    a_blk, b_loc = res
    axis = static["axis"]
    da = matmul_rs(g, b_loc.T, axis, mode="ring", out_dtype=a_blk.dtype)
    db = _weight_grad_ring(a_blk, g, axis).astype(b_loc.dtype)
    return da, db


def _rs_fwd(static, a_loc, b_loc):
    return _matmul_rs_impl(a_loc, b_loc, static["axis"], mode=static["mode"],
                           chunks_per_rank=static.get("chunks", 1),
                           out_dtype=a_loc.dtype)


def _rs_bwd(static, res, g):
    a_loc, b_loc = res
    axis = static["axis"]
    # g: (m/W, n) block; dA = AG(g) @ B^T -> overlapped AG+GEMM ring
    da = ag_matmul(g, b_loc.T, axis, mode="ring", out_dtype=a_loc.dtype)
    db = _rs_weight_grad_ring(a_loc, g, axis).astype(b_loc.dtype)
    return da, db


def _gather_fwd(static, x):
    if static["mode"] == "none":
        return lax.all_gather(x, static["axis"], tiled=True)
    return ov.gather_pipeline(x, static["axis"], transport=static["mode"])


def _gather_bwd(static, res, g):
    return (reduce_scatter_chunked(g, static["axis"]).astype(g.dtype),)


# --- kernel-backend lowerings: the fused shmem kernels -------------------
# (lazy kernel imports: repro.kernels imports are heavier than core's)


def _ag_kernel_fwd(static, a_blk, b_loc):
    """backend="kernel" AG+GEMM: ring -> the fused ag_gemm kernel (Fig. 4
    producer/consumer protocol); one_shot -> the low-latency AllGather
    kernel (Alg. 4) feeding the local dot. Sub-chunking (``chunks``) is
    the kernel's own double-buffer pipelining — the knob is ignored."""
    from ..kernels.ag_gemm import ag_gemm
    from ..kernels.ll_allgather import ll_allgather

    axis = static["axis"]
    w = lax.axis_size(axis)
    if static["mode"] == "one_shot":
        a_full = ll_allgather(a_blk, axis=axis, world=w)
        return jnp.dot(a_full, b_loc,
                       preferred_element_type=jnp.float32).astype(a_blk.dtype)
    return ag_gemm(a_blk, b_loc, axis=axis, world=w, out_dtype=a_blk.dtype)


def _rs_kernel_fwd(static, a_loc, b_loc):
    """backend="kernel" GEMM+RS: the fused rs_gemm kernel (Alg. 3 push
    protocol — partials one-sided-pushed to their owner as they retire).
    Sub-chunking (``chunks`` / rs_chunks) is a graph-pipeline knob; the
    kernel pushes one whole block per step and ignores it."""
    from ..kernels.rs_gemm import rs_gemm

    axis = static["axis"]
    return rs_gemm(a_loc, b_loc, axis=axis, world=lax.axis_size(axis),
                   out_dtype=a_loc.dtype)


def _gather_kernel_fwd(static, x):
    """backend="kernel" AllGather: the low-latency one-shot kernel."""
    from ..kernels.ll_allgather import ll_allgather

    axis = static["axis"]
    return ll_allgather(x, axis=axis, world=lax.axis_size(axis))


ov.register("ag_matmul", kind="ag", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring", fwd=_ag_fwd, bwd=_ag_bwd,
            kernel_transports=("ring", "one_shot"), kernel_fwd=_ag_kernel_fwd)
ov.register("matmul_rs", kind="rs", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring", fwd=_rs_fwd, bwd=_rs_bwd,
            kernel_transports=("ring",), kernel_fwd=_rs_kernel_fwd)
ov.register("ag_matmul_2level", kind="ag", transports=("two_level",),
            baseline="none", default="two_level")
ov.register("matmul_rs_2level", kind="rs", transports=("two_level",),
            baseline="none", default="two_level")
ov.register("all_gather", kind="gather", transports=("ring", "one_shot"),
            baseline="none", default="ring", fwd=_gather_fwd, bwd=_gather_bwd,
            kernel_transports=("one_shot",), kernel_fwd=_gather_kernel_fwd)
ov.register("reduce_scatter", kind="rs", transports=("ring",),
            baseline="none", default="ring")


# ---------------------------------------------------------------------------
# Public overlapped ops
# ---------------------------------------------------------------------------


def ag_matmul(a_blk, b_loc, axis, *, mode="ring", chunks_per_rank=1,
              out_dtype=None, backend="graph"):
    """Overlapped AllGather-GEMM (modes: see the "ag_matmul" registry
    entry). The backward pass is the dual overlapped GEMM+RS ring (O(1)
    buffers, engine shared custom_vjp) for BOTH backends — a kernel
    forward keeps the graph-lowered dual as its backward.

    ``backend="kernel"`` lowers through the fused shmem kernels
    (ag_gemm / ll_allgather) where the (mode) supports it; graph
    otherwise (overlap.resolve_backend).

    The output is tagged with checkpoint_name("ag_out") so the
    "block_save_ag" remat policy can keep gathered activations across the
    backward instead of re-running the gather ring (-1/3 collective
    volume for +per-layer-output memory)."""
    out_dtype = out_dtype or a_blk.dtype
    if mode == "none":
        out = ag_matmul_baseline(a_blk, b_loc, axis, out_dtype=out_dtype)
    else:
        out = ov.apply("ag_matmul", a_blk, b_loc, axis=axis, mode=mode,
                       chunks=max(1, chunks_per_rank),
                       backend=backend).astype(out_dtype)
    return checkpoint_name(out, "ag_out")


def matmul_rs(a_loc, b_loc, axis, *, mode="ring", chunks_per_rank=1,
              out_dtype=None, backend="graph"):
    """Overlapped GEMM-ReduceScatter; backward = dual AG+GEMM ring.
    ``chunks_per_rank`` (rs_chunks) sub-chunks the ring accumulator into
    column groups; ``backend="kernel"`` lowers through the fused rs_gemm
    shmem kernel (ring only)."""
    out_dtype = out_dtype or a_loc.dtype
    if mode == "none":
        return matmul_rs_baseline(a_loc, b_loc, axis, out_dtype=out_dtype)
    return ov.apply("matmul_rs", a_loc, b_loc, axis=axis, mode=mode,
                    chunks=max(1, chunks_per_rank),
                    backend=backend).astype(out_dtype)


def all_gather_chunked(x: Array, axis: str, *, mode: str = "ring",
                       backend: str = "graph") -> Array:
    """Decomposed AllGather; backward = ring reduce-scatter (O(1)).
    ``backend="kernel"`` lowers one_shot through the LL AllGather kernel."""
    return ov.apply("all_gather", x, axis=axis, mode=mode, backend=backend)


# ---------------------------------------------------------------------------
# Chunked stand-alone collectives (used by grad sync & decode paths)
# ---------------------------------------------------------------------------


def reduce_scatter_chunked(x: Array, axis: str) -> Array:
    """Ring reduce-scatter along dim 0 (accumulator in f32)."""
    w = lax.axis_size(axis)
    m = x.shape[0]
    assert m % w == 0
    m_blk = m // w

    def compute(blk, s):
        piece = lax.dynamic_slice(
            x, (blk * m_blk,) + (0,) * (x.ndim - 1), (m_blk,) + x.shape[1:]
        )
        return piece.astype(jnp.float32)

    return ov.rs_pipeline(compute, axis, transport="ring").astype(x.dtype)


def hierarchical_reduce_scatter(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """RS along inner (fast links), then ring all-reduce along outer (slow
    links) on the already 1/Wi-sized shard — the gradient-sync pattern."""
    shard = reduce_scatter_chunked(x, inner_axis)
    return ov.ring_allreduce(shard, outer_axis)


def hierarchical_all_gather(x: Array, inner_axis: str, outer_axis: str) -> Array:
    """Inverse of hierarchical RS: gather along inner axis only (params are
    replicated across pods, sharded within)."""
    return all_gather_chunked(x, inner_axis)


# ---------------------------------------------------------------------------
# shard_map wrappers (for tests / standalone use)
# ---------------------------------------------------------------------------


def make_sharded(fn, mesh, in_specs, out_specs):
    return jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    )
