"""Tile-swizzle schedule generators (paper §3.7, Figures 7/8/10).

A *schedule* answers: "at step s, which data chunk does rank r compute
with, and which chunk is in flight?" These pure-Python generators are the
single source of truth: the shard_map collective matmuls, the Pallas
ag_gemm kernel grid order, and the property tests all derive from them.

Conventions
-----------
- ``world`` ranks on a ring; communication direction is rank -> rank+1.
- AG (all-gather) schedules: chunk *c* means "the block of A owned by rank
  c". Rank r computes chunk ``(r - s) % world`` at step s — each rank
  starts on its own data (Fig. 7's per-rank shifted start).
- RS (reduce-scatter) schedules: chunk *c* means "the output block that
  rank c will keep". Rank r computes chunk ``(r - s - 1) % world`` at step
  s so that the accumulator it forwards to rank r+1 lines up:
  p(r+1, s+1) == p(r, s).
- Hierarchical (2-level, Fig. 10): outer axis = pods, inner axis = ring
  within a pod; outer regions are visited peer-pods-first so inter-pod
  transfers start as early as possible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple


# ---------------------------------------------------------------------------
# 1-level schedules
# ---------------------------------------------------------------------------

def ring_ag_order(world: int, rank: int) -> List[int]:
    """Chunk computed by ``rank`` at each step of a ring AllGather-GEMM."""
    return [(rank - s) % world for s in range(world)]


def ring_rs_order(world: int, rank: int) -> List[int]:
    """Output block computed by ``rank`` at each step of a ring GEMM-RS."""
    return [(rank - s - 1) % world for s in range(world)]


def one_shot_ag_order(world: int, rank: int) -> List[int]:
    """Low-latency order: local chunk first, then by arrival offset.

    All transfers are issued up-front (paper Alg. 4 — no serial ring
    dependency); compute consumes chunks in ring-distance order.
    """
    return [(rank - off) % world for off in range(world)]


def bidir_ag_order(world: int, rank: int) -> List[Tuple[int, int]]:
    """Bidirectional ring: (forward_chunk, backward_chunk) pairs per step.

    Each rank's block is split in half; the top half travels rank->rank+1,
    the bottom half rank->rank-1. Step s computes the *top* half of chunk
    (rank - s) and the *bottom* half of chunk (rank + s). Over ``world``
    steps every (chunk, half) pair is visited exactly once while each link
    direction carries only half the bytes — 2x effective link bandwidth.
    """
    return [((rank - s) % world, (rank + s) % world) for s in range(world)]


# ---------------------------------------------------------------------------
# 2-level (multi-pod / inter-node) schedules — Fig. 10
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TwoLevelStep:
    outer_step: int
    region: int  # outer region (pod) whose blocks are being reduced/gathered
    inner_order: Tuple[int, ...]  # inner-ring chunk order within the region


def hierarchical_rs_schedule(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[TwoLevelStep]:
    """Fig. 10 GEMM+ReduceScatter swizzle.

    Outer step s reduces (over the inner ring) the partial sums for region
    ``(outer_rank - s - 1) % n_outer`` — peer pods first, own pod last — so
    that each region's inter-pod transfer overlaps the next region's inner
    ring of matmuls.
    """
    steps = []
    for s in range(n_outer):
        region = (outer_rank - s - 1) % n_outer
        inner = tuple(ring_rs_order(n_inner, inner_rank))
        steps.append(TwoLevelStep(outer_step=s, region=region, inner_order=inner))
    return steps


def hierarchical_ag_schedule(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[TwoLevelStep]:
    """2-level AllGather: own pod's ring first while peer-pod blocks are in
    flight over the slow links, then peer-pod regions in arrival order."""
    steps = []
    for s in range(n_outer):
        region = (outer_rank - s) % n_outer
        inner = tuple(ring_ag_order(n_inner, inner_rank))
        steps.append(TwoLevelStep(outer_step=s, region=region, inner_order=inner))
    return steps


# ---------------------------------------------------------------------------
# Grid swizzles for compute kernels (used by kernels/matmul.py)
# ---------------------------------------------------------------------------

def swizzled_grid_order(m_tiles: int, n_tiles: int, rank: int, world: int) -> List[Tuple[int, int]]:
    """Tile visit order for a GEMM whose M dimension arrives chunk-by-chunk.

    M tiles are grouped into ``world`` chunks; the group owned by ``rank``
    is visited first, then groups in ring-arrival order — the Fig. 7 swizzle
    expressed as a flat (m_tile, n_tile) traversal.
    """
    assert m_tiles % world == 0, (m_tiles, world)
    per = m_tiles // world
    order: List[Tuple[int, int]] = []
    for chunk in ring_ag_order(world, rank):
        for mt in range(chunk * per, (chunk + 1) * per):
            for nt in range(n_tiles):
                order.append((mt, nt))
    return order


# ---------------------------------------------------------------------------
# Schedule validation helpers (used by tests AND the tuner's sanity pass)
# ---------------------------------------------------------------------------

def is_permutation(order: Sequence[int], world: int) -> bool:
    return sorted(order) == list(range(world))


def ag_arrival_step(world: int, rank: int, chunk: int) -> int:
    """Earliest step at which ``chunk`` is present on ``rank`` under the
    unidirectional ring transport (chunk moves one hop per step)."""
    return (rank - chunk) % world


def validate_ring_ag(world: int) -> bool:
    """Every rank computes each chunk no earlier than its arrival."""
    for r in range(world):
        order = ring_ag_order(world, r)
        if not is_permutation(order, world):
            return False
        for s, c in enumerate(order):
            if s < ag_arrival_step(world, r, c):
                return False
    return True


def validate_ring_rs(world: int) -> bool:
    """Accumulator hand-off lines up: p(r+1, s+1) == p(r, s), and the final
    block each rank computes is its own."""
    for r in range(world):
        order = ring_rs_order(world, r)
        if not is_permutation(order, world):
            return False
        nxt = ring_rs_order(world, (r + 1) % world)
        for s in range(world - 1):
            if nxt[s + 1] != order[s]:
                return False
        if order[-1] != r:
            return False
    return True
