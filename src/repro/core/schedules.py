"""Tile-swizzle schedule generators (paper §3.7, Figures 7/8/10).

A *schedule* answers: "at step s, which data chunk does rank r compute
with, and which chunk is in flight?" These pure-Python generators are the
single source of truth: the shard_map collective matmuls, the Pallas
ag_gemm kernel grid order, and the property tests all derive from them.

Conventions
-----------
- ``world`` ranks on a ring; communication direction is rank -> rank+1.
- AG (all-gather) schedules: chunk *c* means "the block of A owned by rank
  c". Rank r computes chunk ``(r - s) % world`` at step s — each rank
  starts on its own data (Fig. 7's per-rank shifted start).
- RS (reduce-scatter) schedules: chunk *c* means "the output block that
  rank c will keep". Rank r computes chunk ``(r - s - 1) % world`` at step
  s so that the accumulator it forwards to rank r+1 lines up:
  p(r+1, s+1) == p(r, s).
- Hierarchical (2-level, Fig. 10): outer axis = pods, inner axis = ring
  within a pod; outer regions are visited peer-pods-first so inter-pod
  transfers start as early as possible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple


# ---------------------------------------------------------------------------
# 1-level schedules
# ---------------------------------------------------------------------------

def ring_ag_order(world: int, rank: int) -> List[int]:
    """Chunk computed by ``rank`` at each step of a ring AllGather-GEMM."""
    return [(rank - s) % world for s in range(world)]


def ring_rs_order(world: int, rank: int) -> List[int]:
    """Output block computed by ``rank`` at each step of a ring GEMM-RS."""
    return [(rank - s - 1) % world for s in range(world)]


def one_shot_ag_order(world: int, rank: int) -> List[int]:
    """Low-latency order: local chunk first, then by arrival offset.

    All transfers are issued up-front (paper Alg. 4 — no serial ring
    dependency); compute consumes chunks in ring-distance order.
    """
    return [(rank - off) % world for off in range(world)]


def bidir_ag_order(world: int, rank: int) -> List[Tuple[int, int]]:
    """Bidirectional ring: (forward_chunk, backward_chunk) pairs per step.

    Each rank's block is split in half; the top half travels rank->rank+1,
    the bottom half rank->rank-1. Step s computes the *top* half of chunk
    (rank - s) and the *bottom* half of chunk (rank + s). Over ``world``
    steps every (chunk, half) pair is visited exactly once while each link
    direction carries only half the bytes — 2x effective link bandwidth.
    """
    return [((rank - s) % world, (rank + s) % world) for s in range(world)]


def bidir_rs_order(world: int, rank: int) -> List[Tuple[int, int]]:
    """Bidirectional-ring RS: (forward_block, backward_block) pairs per
    step. The forward accumulator (carrying one output half) follows the
    Alg. 3 order (rank - s - 1); the backward accumulator mirrors it on
    the reverse ring (rank + s + 1). Each direction's hand-off invariant
    matches its ring: p_f(r+1, s+1) == p_f(r, s) and
    p_b(r-1, s+1) == p_b(r, s)."""
    return [((rank - s - 1) % world, (rank + s + 1) % world) for s in range(world)]


# ---------------------------------------------------------------------------
# 2-level (multi-pod / inter-node) schedules — Fig. 10
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TwoLevelStep:
    outer_step: int
    region: int  # outer region (pod) whose blocks are being reduced/gathered
    inner_order: Tuple[int, ...]  # inner-ring chunk order within the region


def hierarchical_rs_schedule(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[TwoLevelStep]:
    """Fig. 10 GEMM+ReduceScatter swizzle.

    Outer step s reduces (over the inner ring) the partial sums for region
    ``(outer_rank - s - 1) % n_outer`` — peer pods first, own pod last — so
    that each region's inter-pod transfer overlaps the next region's inner
    ring of matmuls.
    """
    steps = []
    for s in range(n_outer):
        region = (outer_rank - s - 1) % n_outer
        inner = tuple(ring_rs_order(n_inner, inner_rank))
        steps.append(TwoLevelStep(outer_step=s, region=region, inner_order=inner))
    return steps


def hierarchical_ag_schedule(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[TwoLevelStep]:
    """2-level AllGather: own pod's ring first while peer-pod blocks are in
    flight over the slow links, then peer-pod regions in arrival order."""
    steps = []
    for s in range(n_outer):
        region = (outer_rank - s) % n_outer
        inner = tuple(ring_ag_order(n_inner, inner_rank))
        steps.append(TwoLevelStep(outer_step=s, region=region, inner_order=inner))
    return steps


# ---------------------------------------------------------------------------
# Grid swizzles for compute kernels (used by kernels/matmul.py)
# ---------------------------------------------------------------------------

def swizzled_grid_order(m_tiles: int, n_tiles: int, rank: int, world: int) -> List[Tuple[int, int]]:
    """Tile visit order for a GEMM whose M dimension arrives chunk-by-chunk.

    M tiles are grouped into ``world`` chunks; the group owned by ``rank``
    is visited first, then groups in ring-arrival order — the Fig. 7 swizzle
    expressed as a flat (m_tile, n_tile) traversal.
    """
    assert m_tiles % world == 0, (m_tiles, world)
    per = m_tiles // world
    order: List[Tuple[int, int]] = []
    for chunk in ring_ag_order(world, rank):
        for mt in range(chunk * per, (chunk + 1) * per):
            for nt in range(n_tiles):
                order.append((mt, nt))
    return order


# ---------------------------------------------------------------------------
# Schedule validation helpers (used by tests AND the tuner's sanity pass)
# ---------------------------------------------------------------------------

def is_permutation(order: Sequence[int], world: int) -> bool:
    return sorted(order) == list(range(world))


def ag_arrival_step(world: int, rank: int, chunk: int) -> int:
    """Earliest step at which ``chunk`` is present on ``rank`` under the
    unidirectional ring transport (chunk moves one hop per step)."""
    return (rank - chunk) % world


def validate_ring_ag(world: int) -> bool:
    """Every rank computes each chunk no earlier than its arrival."""
    for r in range(world):
        order = ring_ag_order(world, r)
        if not is_permutation(order, world):
            return False
        for s, c in enumerate(order):
            if s < ag_arrival_step(world, r, c):
                return False
    return True


def validate_ring_rs(world: int) -> bool:
    """Accumulator hand-off lines up: p(r+1, s+1) == p(r, s), and the final
    block each rank computes is its own."""
    for r in range(world):
        order = ring_rs_order(world, r)
        if not is_permutation(order, world):
            return False
        nxt = ring_rs_order(world, (r + 1) % world)
        for s in range(world - 1):
            if nxt[s + 1] != order[s]:
                return False
        if order[-1] != r:
            return False
    return True


def validate_bidir_ag(world: int) -> bool:
    """Both half-chunk streams are permutations, start on local data, and
    never compute a half before its transport can have delivered it: the
    forward half of chunk c arrives on rank r at step (r - c) % W (one
    forward hop per step) and the backward half at step (c - r) % W."""
    for r in range(world):
        pairs = bidir_ag_order(world, r)
        fwd = [p[0] for p in pairs]
        bwd = [p[1] for p in pairs]
        if not (is_permutation(fwd, world) and is_permutation(bwd, world)):
            return False
        if fwd[0] != r or bwd[0] != r:
            return False
        for s, (cf, cb) in enumerate(pairs):
            if s < (r - cf) % world or s < (cb - r) % world:
                return False
    return True


def validate_bidir_rs(world: int) -> bool:
    """Both accumulator hand-offs line up — forward rides rank->rank+1
    (p_f(r+1, s+1) == p_f(r, s)), backward rides rank->rank-1
    (p_b(r-1, s+1) == p_b(r, s)) — and each rank finishes on its own
    block in both directions."""
    for r in range(world):
        pairs = bidir_rs_order(world, r)
        fwd = [p[0] for p in pairs]
        bwd = [p[1] for p in pairs]
        if not (is_permutation(fwd, world) and is_permutation(bwd, world)):
            return False
        nxt_f = [p[0] for p in bidir_rs_order(world, (r + 1) % world)]
        nxt_b = [p[1] for p in bidir_rs_order(world, (r - 1) % world)]
        for s in range(world - 1):
            if nxt_f[s + 1] != fwd[s] or nxt_b[s + 1] != bwd[s]:
                return False
        if fwd[-1] != r or bwd[-1] != r:
            return False
    return True


# ---------------------------------------------------------------------------
# Chunk->rank placements (causal context parallelism / ring attention)
# ---------------------------------------------------------------------------
#
# A *placement* answers a different question than the schedules above: not
# "which chunk does rank r compute at step s" but "which GLOBAL sequence
# rows does rank r own in the first place". Under a causal mask the work
# for global row g is proportional to g+1, so the contiguous placement
# (rank r owns rows [r*s_loc, (r+1)*s_loc)) gives rank W-1 about 2x the
# mean FLOPs while rank 0 idles. The balanced placements fix the row
# *ownership* so every rank's causal triangle share is ~equal:
#
#   contiguous  rank r owns one block:       g = r*s_loc + j
#   zigzag      rank r owns one early + one late half-chunk of the 2W
#               global half-chunks {r, 2W-1-r} (requires s_loc even):
#               g = r*h + j (j < h), (2W-1-r)*h + (j-h) otherwise
#   striped     rank r owns every W-th row:  g = j*W + r
#
# Local rows stay in increasing global order under all three, so rope /
# causal masks can be written against per-row positions uniformly.

PLACEMENTS: Tuple[str, ...] = ("contiguous", "zigzag", "striped")


def placement_rows(placement: str, world: int, rank: int, s_loc: int) -> List[int]:
    """Global sequence positions (length ``s_loc``, strictly increasing)
    owned by ``rank`` under ``placement`` with per-rank chunk ``s_loc``."""
    if placement == "contiguous":
        return [rank * s_loc + j for j in range(s_loc)]
    if placement == "zigzag":
        if s_loc % 2 != 0:
            raise ValueError(f"zigzag placement needs even s_loc, got {s_loc}")
        h = s_loc // 2
        early = [rank * h + j for j in range(h)]
        late = [(2 * world - 1 - rank) * h + j for j in range(h)]
        return early + late
    if placement == "striped":
        return [j * world + rank for j in range(s_loc)]
    raise ValueError(f"unknown placement {placement!r} (valid: {PLACEMENTS})")


def validate_placement(placement: str, world: int, s_loc: int) -> bool:
    """Every global row is owned by exactly one rank, and each rank's
    local rows are strictly increasing global positions (so local row
    order == position order, which rope and the causal masks rely on)."""
    seen: List[int] = []
    for r in range(world):
        rows = placement_rows(placement, world, r, s_loc)
        if len(rows) != s_loc:
            return False
        if any(b <= a for a, b in zip(rows, rows[1:])):
            return False
        seen.extend(rows)
    return sorted(seen) == list(range(world * s_loc))


def causal_pairs(placement: str, world: int, rank: int, s_loc: int) -> int:
    """Number of (query, key) pairs inside the causal triangle whose query
    row is owned by ``rank``: the rank's true causal FLOP share."""
    return sum(g + 1 for g in placement_rows(placement, world, rank, s_loc))


def causal_imbalance(placement: str, world: int, s_loc: int) -> float:
    """max-rank / mean causal-pair share: the critical-path stretch a
    placement imposes on a causal ring. Contiguous tends to (2W-1+x)/W
    (~2 for large W); zigzag and striped stay ~1."""
    shares = [causal_pairs(placement, world, r, s_loc) for r in range(world)]
    mean = sum(shares) / len(shares)
    return max(shares) / mean


# ---------------------------------------------------------------------------
# 2-level flat orders + validators (the engine's two_level transports)
# ---------------------------------------------------------------------------

def two_level_ag_order(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[int]:
    """Flatten hierarchical_ag_schedule to GLOBAL chunk ids
    (region * n_inner + inner_chunk), one per engine step."""
    out: List[int] = []
    for step in hierarchical_ag_schedule(n_outer, n_inner, outer_rank, inner_rank):
        out.extend(step.region * n_inner + c for c in step.inner_order)
    return out


def two_level_rs_order(
    n_outer: int, n_inner: int, outer_rank: int, inner_rank: int
) -> List[int]:
    """Flatten hierarchical_rs_schedule to GLOBAL block ids."""
    out: List[int] = []
    for step in hierarchical_rs_schedule(n_outer, n_inner, outer_rank, inner_rank):
        out.extend(step.region * n_inner + c for c in step.inner_order)
    return out


def validate_two_level_ag(n_outer: int, n_inner: int) -> bool:
    """Every rank's flat order covers each global chunk exactly once and
    starts on its OWN chunk (Fig. 10: own pod's inner ring first, so
    compute begins with zero transport latency)."""
    total = n_outer * n_inner
    for ro in range(n_outer):
        for ri in range(n_inner):
            order = two_level_ag_order(n_outer, n_inner, ro, ri)
            if not is_permutation(order, total):
                return False
            if order[0] != ro * n_inner + ri:
                return False
    return True


def validate_two_level_rs(n_outer: int, n_inner: int) -> bool:
    """Flat RS order is a permutation, each rank's own block comes LAST
    (its inter-pod transfer does not exist), and within every region the
    inner hand-off matches the 1-level ring invariant."""
    total = n_outer * n_inner
    for ro in range(n_outer):
        for ri in range(n_inner):
            order = two_level_rs_order(n_outer, n_inner, ro, ri)
            if not is_permutation(order, total):
                return False
            if order[-1] != ro * n_inner + ri:
                return False
            nxt = two_level_rs_order(n_outer, n_inner, ro, (ri + 1) % n_inner)
            for so in range(n_outer):
                base = so * n_inner
                for si in range(n_inner - 1):
                    if nxt[base + si + 1] != order[base + si]:
                        return False
    return True
