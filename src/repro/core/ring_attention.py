"""Ring attention (context parallelism) — the engine's AG pipeline applied
to attention itself.

Sequence is sharded along ``axis`` (heads REPLICATED on that axis —
compose with TP on a different axis). Each rank keeps its Q block
resident; K/V blocks ride the engine transport ("ring": one hop per
step, exactly like the AG+GEMM data chunks of Fig. 7 — the ppermute of
block s+1 overlaps the blockwise-softmax compute of block s; "one_shot":
all K/V blocks issued up-front, the low-latency variant for short
sequences). Per-rank memory is O(S_loc) instead of O(S) under the ring
transport: this is the enabler for long-context (500k+) TRAINING, which
the paper's decode-side FlashDecode+AG does not cover.

The op itself is now a ``repro.ops`` STATEFUL FOLD declaration
(``ops.library``): the blockwise online softmax's (m, l, acc) carry is
the declared FoldTile's state, from which the graph lowering (engine AG
pipelines), the kernel lowering (the executor's carry-passing
``ring_fold`` protocol; ``one_shot`` gathers low-latency and replays the
fold host-side) and the jax.vjp-through-the-fold-chain backward are all
derived. This module keeps the historical functional signature (K and V
as separate arguments; the declaration rides them as one packed chunk).
Registry entry: "ring_attention".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import overlap as ov

Array = jax.Array


def ring_attention(
    q: Array,  # (B, H, S_loc, D) — sequence-sharded on ``axis``
    k: Array,  # (B, Hkv, S_loc, D)
    v: Array,  # (B, Hkv, S_loc, D)
    axis: str,
    *,
    causal: bool = True,
    scale: float | None = None,
    mode: str = "ring",
    backend: str = "graph",
    placement: str = "contiguous",
    wire: str = "f32",
    with_stats: bool = False,
) -> Array:
    """Returns (B, H, S_loc, D): attention over the GLOBAL sequence.

    ``backend="kernel"`` lowers ring through the executor's carry-passing
    ``ring_fold`` protocol (one_shot through the low-latency gather with
    a host-side fold replay); gradients are bit-identical across
    backends — the kernel forward keeps the graph dual as its backward
    through the ONE shared custom_vjp.

    ``placement`` names the chunk->rank owner map ("contiguous",
    "zigzag", "striped" — ``core.schedules.placement_rows``). The caller
    shards the sequence so each rank holds the rows that map names
    (local order == position order under every placement); zigzag gives
    every rank one early + one late half-chunk, equalizing per-rank
    causal work — the fold skips fully-masked blocks, so contiguous
    rank 0 sits idle for W-1 of W steps while zigzag never does. Zigzag
    needs an even S_loc; odd S_loc degrades to contiguous.

    ``wire`` quantizes the riding K/V chunk ("int8"/"fp8" — per-section
    per-row scales, K and V scaled independently). ``with_stats``
    appends the online-softmax stats (m, l) as two extra output
    channels in f32 (out becomes (B, H, S_loc, D+2)), for merging with
    other partial attentions (CP chunked prefill).
    """
    from .. import ops

    mode = ov.resolve_mode("ring_attention", mode)
    scale = scale if scale is not None else 1.0 / float(np.sqrt(q.shape[-1]))
    if placement == "zigzag" and q.shape[2] % 2:
        placement = "contiguous"
    packed = jnp.concatenate([k, v], axis=-1)  # ONE riding chunk
    extras = {}
    if with_stats:
        extras["with_stats"] = True
    out_dtype = jnp.float32 if with_stats else q.dtype
    return ops.ring_attention(packed, q, axis=axis, mode=mode,
                              backend=backend, wire=wire,
                              placement=placement, out_dtype=out_dtype,
                              causal=bool(causal), scale=float(scale),
                              **extras)


# Importing this module must populate the registry entry (declared in
# repro.ops.library) for direct importers.
from .. import ops as _ops  # noqa: E402,F401
