"""Ring attention (context parallelism) — the paper's overlap structure
applied to attention itself.

Sequence is sharded along ``axis`` (heads REPLICATED on that axis —
compose with TP on a different axis). Each rank keeps its Q block
resident; K/V blocks ride the ring, one hop per step, exactly like the
AG+GEMM data chunks of Fig. 7 — the ppermute of block s+1 overlaps the
blockwise-softmax compute of block s. Per-rank memory is O(S_loc) instead
of O(S): this is the enabler for long-context (500k+) TRAINING, which
the paper's decode-side FlashDecode+AG does not cover.

Blockwise online softmax carries (m, l, acc) in f32; causal masking uses
global offsets, and fully-future blocks contribute nothing (compute is
spent for SPMD uniformity — on TPU the skipped-block optimization would
be a per-step `lax.cond`, noted in EXPERIMENTS).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .primitives import ring_permute

Array = jax.Array


def ring_attention(
    q: Array,  # (B, H, S_loc, D) — sequence-sharded on ``axis``
    k: Array,  # (B, Hkv, S_loc, D)
    v: Array,  # (B, Hkv, S_loc, D)
    axis: str,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> Array:
    """Returns (B, H, S_loc, D): attention over the GLOBAL sequence."""
    b, h, s_loc, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)

    qf = q.astype(jnp.float32) * scale
    rows = me * s_loc + jnp.arange(s_loc)  # global q positions

    m = jnp.full((b, h, s_loc), -1e30, jnp.float32)
    l = jnp.zeros((b, h, s_loc), jnp.float32)
    acc = jnp.zeros((b, h, s_loc, d), jnp.float32)

    buf_k, buf_v = k, v
    for s in range(w):
        owner = lax.rem(me - s + w, w)  # whose KV block we hold (Fig. 7)
        kk = jnp.repeat(buf_k.astype(jnp.float32), group, axis=1)
        vv = jnp.repeat(buf_v.astype(jnp.float32), group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        if causal:
            cols = owner * s_loc + jnp.arange(s_loc)  # global kv positions
            mask = rows[:, None] >= cols[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        m = m_new
        if s != w - 1:
            # next KV block rides the ring while this block's FLOPs retire
            buf_k = ring_permute(buf_k, axis)
            buf_v = ring_permute(buf_v, axis)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
