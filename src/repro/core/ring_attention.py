"""Ring attention (context parallelism) — the engine's AG pipeline applied
to attention itself.

Sequence is sharded along ``axis`` (heads REPLICATED on that axis —
compose with TP on a different axis). Each rank keeps its Q block
resident; K/V blocks ride the engine transport ("ring": one hop per
step, exactly like the AG+GEMM data chunks of Fig. 7 — the ppermute of
block s+1 overlaps the blockwise-softmax compute of block s; "one_shot":
all K/V blocks issued up-front, the low-latency variant for short
sequences). Per-rank memory is O(S_loc) instead of O(S) under the ring
transport: this is the enabler for long-context (500k+) TRAINING, which
the paper's decode-side FlashDecode+AG does not cover.

The op itself is now a ``repro.ops`` STATEFUL FOLD declaration
(``ops.library``): the blockwise online softmax's (m, l, acc) carry is
the declared FoldTile's state, from which the graph lowering (engine AG
pipelines), the kernel lowering (the executor's carry-passing
``ring_fold`` protocol; ``one_shot`` gathers low-latency and replays the
fold host-side) and the jax.vjp-through-the-fold-chain backward are all
derived. This module keeps the historical functional signature (K and V
as separate arguments; the declaration rides them as one packed chunk).
Registry entry: "ring_attention".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import overlap as ov

Array = jax.Array


def ring_attention(
    q: Array,  # (B, H, S_loc, D) — sequence-sharded on ``axis``
    k: Array,  # (B, Hkv, S_loc, D)
    v: Array,  # (B, Hkv, S_loc, D)
    axis: str,
    *,
    causal: bool = True,
    scale: float | None = None,
    mode: str = "ring",
    backend: str = "graph",
) -> Array:
    """Returns (B, H, S_loc, D): attention over the GLOBAL sequence.

    ``backend="kernel"`` lowers ring through the executor's carry-passing
    ``ring_fold`` protocol (one_shot through the low-latency gather with
    a host-side fold replay); gradients are bit-identical across
    backends — the kernel forward keeps the graph dual as its backward
    through the ONE shared custom_vjp.
    """
    from .. import ops

    mode = ov.resolve_mode("ring_attention", mode)
    scale = scale if scale is not None else 1.0 / float(np.sqrt(q.shape[-1]))
    packed = jnp.concatenate([k, v], axis=-1)  # ONE riding chunk
    return ops.ring_attention(packed, q, axis=axis, mode=mode,
                              backend=backend, out_dtype=q.dtype,
                              causal=bool(causal), scale=float(scale))


# Importing this module must populate the registry entry (declared in
# repro.ops.library) for direct importers.
from .. import ops as _ops  # noqa: E402,F401
