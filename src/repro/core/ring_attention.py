"""Ring attention (context parallelism) — the engine's AG pipeline applied
to attention itself.

Sequence is sharded along ``axis`` (heads REPLICATED on that axis —
compose with TP on a different axis). Each rank keeps its Q block
resident; K/V blocks ride the engine transport ("ring": one hop per
step, exactly like the AG+GEMM data chunks of Fig. 7 — the ppermute of
block s+1 overlaps the blockwise-softmax compute of block s; "one_shot":
all K/V blocks issued up-front, the low-latency variant for short
sequences). Per-rank memory is O(S_loc) instead of O(S) under the ring
transport: this is the enabler for long-context (500k+) TRAINING, which
the paper's decode-side FlashDecode+AG does not cover.

The blockwise online softmax carries (m, l, acc) in f32 as the
pipeline's fold state; causal masking uses global offsets derived from
the fold's ``owner``, and fully-future blocks contribute nothing
(compute is spent for SPMD uniformity — on TPU the skipped-block
optimization would be a per-step ``lax.cond``, noted in EXPERIMENTS).
Registry entry: "ring_attention".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import overlap as ov

Array = jax.Array


def ring_attention(
    q: Array,  # (B, H, S_loc, D) — sequence-sharded on ``axis``
    k: Array,  # (B, Hkv, S_loc, D)
    v: Array,  # (B, Hkv, S_loc, D)
    axis: str,
    *,
    causal: bool = True,
    scale: float | None = None,
    mode: str = "ring",
) -> Array:
    """Returns (B, H, S_loc, D): attention over the GLOBAL sequence."""
    mode = ov.resolve_mode("ring_attention", mode)
    b, h, s_loc, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)

    if mode == "none":
        # monolithic baseline: gather the full K/V, one softmax pass
        kf = jnp.repeat(
            lax.all_gather(k, axis, axis=2, tiled=True).astype(jnp.float32),
            group, axis=1)
        vf = jnp.repeat(
            lax.all_gather(v, axis, axis=2, tiled=True).astype(jnp.float32),
            group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
        if causal:
            rows_g = me * s_loc + jnp.arange(s_loc)
            mask = rows_g[:, None] >= jnp.arange(s_loc * w)[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)

    qf = q.astype(jnp.float32) * scale
    rows = me * s_loc + jnp.arange(s_loc)  # global q positions

    init = (
        jnp.full((b, h, s_loc), -1e30, jnp.float32),  # running max
        jnp.zeros((b, h, s_loc), jnp.float32),  # running sum
        jnp.zeros((b, h, s_loc, d), jnp.float32),  # weighted-value acc
    )

    def fold(carry, bufs, s, owner):
        m, l, acc = carry
        buf_k, buf_v = bufs
        kk = jnp.repeat(buf_k.astype(jnp.float32), group, axis=1)
        vv = jnp.repeat(buf_v.astype(jnp.float32), group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        if causal:
            cols = owner * s_loc + jnp.arange(s_loc)  # global kv positions
            mask = rows[:, None] >= cols[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return m_new, l, acc

    _, l, acc = ov.ag_pipeline((k, v), fold, init, axis, transport=mode)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


ov.register("ring_attention", kind="attn", transports=("ring", "one_shot"),
            baseline="none", default="ring")
