"""Distributed flash decoding (paper §4.2 FlashDecode+AG).

Sequence-parallel decode: the KV cache is sharded along the sequence axis
across TP ranks; each rank runs the flash-decode kernel over its shard,
producing a partial (o, lse); the partials are exchanged with the
engine's stack-gather pipeline (small message — the one_shot transport is
where the paper's Alg. 4 kernel earns its keep) and merged with the
logsumexp combine.

The paper's scalability result reproduces structurally: per-rank HBM
traffic is KV_bytes / W (the bandwidth-bound term scales), while the
combine adds a W-sized small-message AllGather (the latency floor).
Registry entry: "flash_decode".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from . import overlap as ov

Array = jax.Array


def local_flash_decode(q, k_shard, v_shard, length_local, *, force=None):
    """Per-rank partial decode. Returns (o (B,H,D) f32, lse (B,H) f32)."""
    return ops.flash_decode(q, k_shard, v_shard, length_local, force=force)


def distributed_flash_decode(
    q: Array,  # (B, Hq, D) — replicated across the KV-shard axis
    k_shard: Array,  # (B, Hkv, S_loc, D)
    v_shard: Array,
    length_local: Array,  # (B,) valid KV length in THIS shard
    axis: str,
    *,
    mode: str = "one_shot",
    force=None,
) -> Array:
    """Call inside shard_map. Returns the combined (B, Hq, D) output."""
    mode = ov.resolve_mode("flash_decode", mode)
    o_part, lse_part = local_flash_decode(q, k_shard, v_shard, length_local, force=force)
    b, h, d = o_part.shape
    # pack (o, lse) into one message so the combine needs ONE small AllGather
    packed = jnp.concatenate([o_part, lse_part[..., None]], axis=-1)  # (B,H,D+1)
    if mode == "xla":
        gathered = lax.all_gather(packed, axis)  # (W,B,H,D+1)
    else:
        gathered = ov.stack_gather_pipeline(packed, axis, transport=mode)
    o_parts = gathered[..., :d]
    lse_parts = gathered[..., d]
    return ops.combine_flash_decode(o_parts, lse_parts)


ov.register("flash_decode", kind="combine", transports=("one_shot", "ring"),
            baseline="xla", default="one_shot")
