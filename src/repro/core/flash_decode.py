"""Distributed flash decoding (paper §4.2 FlashDecode+AG).

Sequence-parallel decode: the KV cache is sharded along the sequence axis
across TP ranks; each rank runs the flash-decode kernel over its shard,
producing a partial (o, lse); the partials are exchanged with the
engine's stack-gather pipeline (small message — the one_shot transport is
where the paper's Alg. 4 kernel earns its keep) and merged with the
logsumexp combine.

The paper's scalability result reproduces structurally: per-rank HBM
traffic is KV_bytes / W (the bandwidth-bound term scales), while the
combine adds a W-sized small-message AllGather (the latency floor).
Registry entry: "flash_decode".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import overlap as ov

Array = jax.Array


def local_flash_decode(q, k_shard, v_shard, length_local, *, force=None):
    """Per-rank partial decode. Returns (o (B,H,D) f32, lse (B,H) f32)."""
    return ops.flash_decode(q, k_shard, v_shard, length_local, force=force)


def distributed_flash_decode(
    q: Array,  # (B, Hq, D) — replicated across the KV-shard axis
    k_shard: Array,  # (B, Hkv, S_loc, D)
    v_shard: Array,
    length_local: Array,  # (B,) valid KV length in THIS shard
    axis: str,
    *,
    mode: str = "one_shot",
    backend: str = "graph",
    force=None,
) -> Array:
    """Call inside shard_map. Returns the combined (B, Hq, D) output.

    The combine's stacked small-message AllGather is the registered
    "flash_decode" op (declared in ``repro.ops.library``: the
    LSE-stacking tile over the engine gather pipelines, with a
    ``one_shot_ag`` executor kernel lowering for ``backend="kernel"``);
    the logsumexp merge itself stays local."""
    mode = ov.resolve_mode("flash_decode", mode)
    o_part, lse_part = local_flash_decode(q, k_shard, v_shard, length_local, force=force)
    b, h, d = o_part.shape
    # pack (o, lse) into one message so the combine needs ONE small AllGather
    packed = jnp.concatenate([o_part, lse_part[..., None]], axis=-1)  # (B,H,D+1)
    gathered = ov.dispatch("flash_decode", packed, axis=axis, mode=mode,
                           backend=backend)  # (W,B,H,D+1)
    o_parts = gathered[..., :d]
    lse_parts = gathered[..., d]
    return ops.combine_flash_decode(o_parts, lse_parts)


# The "flash_decode" registry entry is DECLARED in repro.ops.library;
# importing it here guarantees registration for direct importers.
from .. import ops as _repro_ops  # noqa: E402,F401
