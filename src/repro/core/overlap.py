"""The ring-pipeline engine: one implementation of "compute a chunk while
the next chunk rides the interconnect" for EVERY overlapped collective.

The paper's central argument (§3.7) is that an overlapped kernel is a
*composition*, not a monolith:

    overlapped op = schedule (core.schedules)
                  x transport (how chunks move between ranks)
                  x per-chunk compute (the op-specific FLOPs)
                  x combine (how per-chunk results become the output)

This module is that composition, written once. The five former
hand-rolled copies of the ``for step: compute chunk; ring_permute(buf)``
loop (collective matmuls x3, MoE overlap, ring attention) are now thin
declarations over these pipelines.

Transports
----------
  ring      unidirectional ring: operand chunks move one hop per step
            (rank -> rank+1); rank r holds chunk (r - s) % W at step s.
  bidir     bidirectional ring: each operand is split in half; the top
            half rides rank->rank+1, the bottom half rank->rank-1, so
            each link direction carries half the bytes.
  one_shot  all W-1 transfers issued up-front with distinct ring offsets
            (the paper's low-latency Alg. 4 structure) — no serial
            dependency chain; latency-optimal for small messages.
  two_level hierarchical (Fig. 10): an inner ring per pod plus an outer
            ring across pods; the slow inter-pod hop overlaps a full
            inner ring of compute.

Backends (orthogonal to transports)
-----------------------------------
A transport says WHAT moves when; a backend says HOW it is lowered:
``graph`` lowers hops to ``lax.ppermute`` (the pipelines below),
``kernel`` routes them through the fused shmem-based kernels in
``repro.kernels`` (the op issues its own putmem_signal / signal_wait
communication via ``repro.shmem`` — remote DMAs on TPU, the emulated
DMA engine on CPU). Ops declare their kernel-capable transports in
``OverlapSpec.kernel_transports``; ``resolve_backend`` degrades
everything else to graph.

Pipelines
---------
AG-side (``*ag_pipeline``): operand chunks ride the transport; a fold
function consumes each arriving chunk. The fold's carry generalizes all
combine styles: scatter-into-output (AG+GEMM), list-of-chunks
(AG+MoE's O(1)-buffer concat), online-softmax state (ring attention),
and weight-gradient accumulators.

RS-side (``*rs_pipeline``): the *accumulator* rides the transport while a
block-compute function produces the partial sum for the schedule's block
at each step (Alg. 3's accumulate-and-forward).

``a2a_pipeline`` (AllToAll) and ``ring_allreduce`` round out the set used
by expert parallelism and gradient sync.

Registry + shared custom_vjp
----------------------------
Every overlapped op registers an :class:`OverlapSpec` (name, kind,
supported transports, baseline, optional differentiation rule). The
registry is the single source of truth consumed by

  - ``configs.base.ParallelConfig.mode_for`` (per-op mode resolution),
  - ``core.tuner`` (analytic candidates enumerate the registry),
  - ``tests/test_overlap_engine.py`` (every (op, transport) pair is
    property-tested against its monolithic baseline).

Ops whose mathematical transpose is another overlapped op (AG+GEMM <->
GEMM+RS) declare a ``bwd`` rule and are routed through ONE shared
``jax.custom_vjp`` (:func:`dispatch`), so O(1)-buffer differentiability
is implemented exactly once instead of per kernel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import offset_permute, ring_permute

Array = jax.Array

# Transport names understood by the engine (baselines like "none"/"xla"
# are op-specific monolithic fallbacks, not transports).
TRANSPORTS = ("ring", "bidir", "one_shot", "two_level")

# Backend names: HOW a transport is lowered.
#   graph   lax.ppermute pipelines in this module (XLA async
#           collective-permute; runs everywhere).
#   kernel  the fused shmem-based kernels in repro.kernels — the op
#           issues its own communication (putmem_signal / signal_wait
#           via repro.shmem: remote DMAs on TPU, the emulated DMA
#           engine on CPU). Available for the (op, transport) pairs an
#           op declares in ``kernel_transports``.
BACKENDS = ("graph", "kernel")


def _advance(bufs: Tuple[Array, ...], axis: str, *, reverse: bool = False):
    return tuple(ring_permute(b, axis, reverse=reverse) for b in bufs)


# ---------------------------------------------------------------------------
# AG-side pipelines: operand chunks ride the transport, a fold consumes them
# ---------------------------------------------------------------------------


def ag_pipeline(
    operands: Tuple[Array, ...],
    fold: Callable[[Any, Tuple[Array, ...], int, Array], Any],
    init: Any,
    axis: str,
    *,
    transport: str = "ring",
):
    """Generic AllGather-style pipeline.

    ``operands`` are this rank's chunks (they ride the transport
    together); ``fold(carry, bufs, step, owner)`` consumes the chunk
    owned by rank ``owner`` at each step. Returns the final carry.

    ring:      chunks move one hop per step; the permute of step s+1's
               chunk overlaps the fold of step s (Fig. 7 swizzle).
    one_shot:  every transfer issued up-front at distinct offsets; folds
               consume chunks in ring-distance order (Alg. 4).
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    carry = init
    if transport == "one_shot":
        for s in range(w):
            bufs = operands if s == 0 else tuple(
                offset_permute(x, axis, s) for x in operands
            )
            carry = fold(carry, bufs, s, lax.rem(me - s + w, w))
        return carry
    if transport != "ring":
        raise ValueError(f"ag_pipeline: unknown transport {transport!r}")
    bufs = operands
    for s in range(w):
        carry = fold(carry, bufs, s, lax.rem(me - s + w, w))
        if s != w - 1:
            # the next chunk rides the ring while this fold's FLOPs retire
            bufs = _advance(bufs, axis)
    return carry


def bidir_ag_pipeline(
    operands: Tuple[Array, ...],
    fold: Callable[[Any, Tuple[Array, ...], int, Array, int], Any],
    init: Any,
    axis: str,
):
    """Bidirectional-ring AG pipeline (schedules.bidir_ag_order).

    Each operand is split in half along dim 0; the top halves travel the
    forward ring (owner (r - s) % W), the bottom halves the reverse ring
    (owner (r + s) % W). ``fold(carry, half_bufs, step, owner,
    direction)`` is called twice per step with direction 0 (forward /
    top) then 1 (backward / bottom). Each link direction carries half
    the bytes — 2x effective link bandwidth.
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    fwd = tuple(x[: x.shape[0] // 2] for x in operands)
    bwd = tuple(x[x.shape[0] // 2 :] for x in operands)
    carry = init
    for s in range(w):
        carry = fold(carry, fwd, s, lax.rem(me - s + w, w), 0)
        carry = fold(carry, bwd, s, lax.rem(me + s, w), 1)
        if s != w - 1:
            fwd = _advance(fwd, axis)
            bwd = _advance(bwd, axis, reverse=True)
    return carry


def two_level_ag_pipeline(
    operands: Tuple[Array, ...],
    fold: Callable[[Any, Tuple[Array, ...], int, Array], Any],
    init: Any,
    inner_axis: str,
    outer_axis: str,
):
    """Hierarchical AG (Fig. 10 / schedules.hierarchical_ag_schedule).

    Outer step s works on pod region (pod - s) % Wo — own pod first, so
    compute starts on local data while peer-pod chunks stream over the
    slow links; the single outer hop per region overlaps the next
    region's full inner ring. ``owner`` passed to ``fold`` is the
    linearized (outer * Wi + inner) rank whose chunk is being consumed.
    """
    wo = lax.axis_size(outer_axis)
    wi = lax.axis_size(inner_axis)
    oid = lax.axis_index(outer_axis)
    iid = lax.axis_index(inner_axis)
    carry = init
    outer_bufs = operands
    for so in range(wo):
        region = lax.rem(oid - so + wo, wo)
        inner_bufs = outer_bufs
        for si in range(wi):
            owner = region * wi + lax.rem(iid - si + wi, wi)
            carry = fold(carry, inner_bufs, so * wi + si, owner)
            if si != wi - 1:
                inner_bufs = _advance(inner_bufs, inner_axis)
        if so != wo - 1:
            # slow-link hop overlaps the next region's inner ring
            outer_bufs = _advance(outer_bufs, outer_axis)
    return carry


# ---------------------------------------------------------------------------
# RS-side pipelines: the accumulator rides the transport
# ---------------------------------------------------------------------------


def rs_pipeline(
    compute_block: Callable[[Array, int], Array],
    axis: str,
    *,
    transport: str = "ring",
    encode: Optional[Callable] = None,
    decode: Optional[Callable] = None,
) -> Array:
    """Generic ReduceScatter-style pipeline.

    ``compute_block(blk, step)`` returns the (f32) partial sum this rank
    contributes to output block ``blk``. Returns this rank's fully
    reduced block.

    ring:      Alg. 3 — rank r computes block (r - s - 1) % W at step s,
               adds the accumulator arriving from r-1 and forwards it;
               the permute overlaps the next block's compute.
    one_shot:  every peer's partial issued up-front at distinct offsets
               (low-latency structure); the owner sums arrivals.

    ``encode``/``decode`` are the optional wire hooks (ops.wire): a hop's
    payload is ``encode``d to (payload, scales) before the permute and
    ``decode``d back to f32 on arrival; accumulation stays f32. The ring
    flavor re-encodes the riding accumulator every hop (quantization
    error grows with ring distance); one_shot encodes each partial once.
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)

    def _hop(x, permute):
        if encode is None:
            return permute(x)
        p, s = encode(x)
        return decode(permute(p), permute(s))

    if transport == "one_shot":
        acc = compute_block(me, 0)
        for off in range(1, w):
            tgt = lax.rem(me + off, w)
            # my partial for rank tgt's block travels distance `off`; the
            # arrival (from rank me - off) is that rank's partial for MY
            # block. No serial dependency between the W-1 transfers.
            acc = acc + _hop(
                compute_block(tgt, off), lambda t: offset_permute(t, axis, off)
            )
        return acc
    if transport != "ring":
        raise ValueError(f"rs_pipeline: unknown transport {transport!r}")
    acc = None
    for s in range(w):
        blk = lax.rem(me - s - 1 + 2 * w, w)
        partial = compute_block(blk, s)
        if acc is None:
            acc = partial
        else:
            # the permute of the previous accumulator overlaps this compute
            acc = partial + _hop(acc, lambda t: ring_permute(t, axis))
    return acc


def bidir_rs_pipeline(
    compute_block: Callable[[Array, int, int], Array],
    axis: str,
    *,
    encode: Optional[Callable] = None,
    decode: Optional[Callable] = None,
) -> Tuple[Array, Array]:
    """Bidirectional-ring RS (schedules.bidir_rs_order): two accumulators,
    one per ring direction, each carrying half the per-block output
    (caller splits columns/rows across directions and concatenates the
    returned (acc_fwd, acc_bwd) pair).

    ``compute_block(blk, step, direction)`` returns the partial for block
    ``blk`` restricted to ``direction``'s half. Hand-off invariants:
    p_f(r+1, s+1) == p_f(r, s) and p_b(r-1, s+1) == p_b(r, s).
    """
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)

    def _hop(x, reverse):
        if encode is None:
            return ring_permute(x, axis, reverse=reverse)
        p, sc = encode(x)
        return decode(
            ring_permute(p, axis, reverse=reverse),
            ring_permute(sc, axis, reverse=reverse),
        )

    acc_f = acc_r = None
    for s in range(w):
        blk_f = lax.rem(me - s - 1 + 2 * w, w)
        blk_r = lax.rem(me + s + 1, w)
        pf = compute_block(blk_f, s, 0)
        pr = compute_block(blk_r, s, 1)
        acc_f = pf if acc_f is None else pf + _hop(acc_f, False)
        acc_r = pr if acc_r is None else pr + _hop(acc_r, True)
    return acc_f, acc_r


def two_level_rs_pipeline(
    compute_block: Callable[[Array, int], Array],
    inner_axis: str,
    outer_axis: str,
) -> Array:
    """Hierarchical RS (Fig. 10 / Alg. 5): outer step s reduces — over the
    inner ring — the partials for pod region (pod - s - 1) % Wo (peer
    pods first, own pod last), then forwards the inter-pod accumulator;
    the slow-link transfer overlaps the next region's Wi computes.
    ``compute_block(blk, step)`` takes a linearized (region * Wi + inner)
    block id."""
    wo = lax.axis_size(outer_axis)
    wi = lax.axis_size(inner_axis)
    oid = lax.axis_index(outer_axis)
    iid = lax.axis_index(inner_axis)
    outer_acc = None
    for so in range(wo):
        region = lax.rem(oid - so - 1 + 2 * wo, wo)
        inner_acc = None
        for si in range(wi):
            blk = region * wi + lax.rem(iid - si - 1 + 2 * wi, wi)
            partial = compute_block(blk, so * wi + si)
            if inner_acc is None:
                inner_acc = partial
            else:
                inner_acc = partial + ring_permute(inner_acc, inner_axis)
        if outer_acc is None:
            outer_acc = inner_acc
        else:
            outer_acc = inner_acc + ring_permute(outer_acc, outer_axis)
    return outer_acc


# ---------------------------------------------------------------------------
# AllToAll and allreduce pipelines
# ---------------------------------------------------------------------------


def a2a_pipeline(
    xs: Array,
    axis: str,
    *,
    transport: str = "one_shot",
    encode: Optional[Callable] = None,
    decode: Optional[Callable] = None,
) -> Array:
    """AllToAll over the leading dim: ``xs[i]`` is this rank's block
    destined for rank i; returns ``out`` with ``out[j]`` = the block rank
    j sent to this rank.

    one_shot: the paper's low-latency decomposition — all W-1 one-sided
    sends issued up-front with distinct ring offsets. xla: the monolithic
    ``lax.all_to_all`` baseline (wire hooks are ignored — nothing rides
    the engine there).

    With ``encode``/``decode`` wire hooks, every per-destination block is
    quantized exactly once — including the self block, which round-trips
    through the codec so the graph lowering matches the kernel executor
    (whose workspace holds all W blocks in wire format).
    """
    if transport == "xla":
        return lax.all_to_all(xs, axis, split_axis=0, concat_axis=0, tiled=False)
    if transport != "one_shot":
        raise ValueError(f"a2a_pipeline: unknown transport {transport!r}")
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    if encode is not None:
        payload, scales = encode(xs)
        mine = decode(
            lax.dynamic_slice_in_dim(payload, me, 1, axis=0),
            lax.dynamic_slice_in_dim(scales, me, 1, axis=0),
        ).astype(xs.dtype)
    else:
        mine = lax.dynamic_slice_in_dim(xs, me, 1, axis=0)
    out = jnp.zeros_like(xs)
    out = lax.dynamic_update_slice_in_dim(out, mine, me, axis=0)
    for off in range(1, w):
        tgt = lax.rem(me + off, w)
        if encode is not None:
            recv = decode(
                offset_permute(lax.dynamic_slice_in_dim(payload, tgt, 1, axis=0), axis, off),
                offset_permute(lax.dynamic_slice_in_dim(scales, tgt, 1, axis=0), axis, off),
            ).astype(xs.dtype)
        else:
            send = lax.dynamic_slice_in_dim(xs, tgt, 1, axis=0)
            recv = offset_permute(send, axis, off)  # arrives from rank me - off
        out = lax.dynamic_update_slice_in_dim(
            out, recv, lax.rem(me - off + w, w), axis=0
        )
    return out


def ring_allreduce(x: Array, axis: str, *, acc_dtype=jnp.float32) -> Array:
    """Ring all-reduce of same-shaped per-rank values (W-1 hops); the
    gradient-sync pattern for params replicated across pods."""
    def fold(acc, bufs, s, owner):
        del s, owner
        return acc + bufs[0].astype(acc_dtype)

    total = ag_pipeline(
        (x,), fold, jnp.zeros(x.shape, acc_dtype), axis, transport="ring"
    )
    return total.astype(x.dtype)


def gather_pipeline(x: Array, axis: str, *, transport: str = "ring") -> Array:
    """Decomposed AllGather along dim 0: (chunk, ...) -> (W * chunk, ...),
    owner-major. The ring flavor is Alg. 1/2's push-ring; one_shot is the
    low-latency Alg. 4 structure."""
    w = lax.axis_size(axis)
    chunk = x.shape[0]
    out0 = jnp.zeros((chunk * w,) + x.shape[1:], x.dtype)

    def fold(out, bufs, s, owner):
        del s
        start = (owner * chunk,) + (0,) * (x.ndim - 1)
        return lax.dynamic_update_slice(out, bufs[0], start)

    return ag_pipeline((x,), fold, out0, axis, transport=transport)


def stack_gather_pipeline(x: Array, axis: str, *, transport: str = "one_shot") -> Array:
    """AllGather with a NEW leading rank dim: (...) -> (W, ...). The
    small-message combine used by distributed flash decode."""
    w = lax.axis_size(axis)
    out0 = jnp.zeros((w,) + x.shape, x.dtype)

    def fold(out, bufs, s, owner):
        del s
        return lax.dynamic_update_slice(out, bufs[0][None], (owner,) + (0,) * x.ndim)

    return ag_pipeline((x,), fold, out0, axis, transport=transport)


# ---------------------------------------------------------------------------
# Mode registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OverlapSpec:
    """One overlapped op's declaration in the mode registry.

    name        op identifier (the key used by ParallelConfig.mode_for,
                the tuner, and the property tests)
    kind        "ag" | "rs" | "gather" | "a2a" | "attn" | "combine"
    transports  engine transports this op supports
    baseline    the monolithic fallback mode name ("none" = XLA
                collective + compute, "xla" = builtin collective)
    default     transport chosen when an unsupported mode is requested
    fwd         optional: fwd(static: dict, *tensors) -> out, routed
                through the shared custom_vjp when ``bwd`` is set
    bwd         optional: bwd(static: dict, residuals, cotangent) ->
                per-tensor gradients (the op's dual overlapped op)
    kernel_transports  transports with a kernel-backend lowering
                (``backend="kernel"`` routes these through kernel_fwd)
    kernel_fwd  optional: the fused shmem-kernel lowering,
                kernel_fwd(static: dict, *tensors) -> out. Shares the
                op's ``bwd`` rule (the backward of a fused kernel is
                its dual overlapped op regardless of lowering).
    wires       wire dtypes the op's riding chunks can travel as
                (("f32",) = always as-is; see ops/wire.py)
    placements  chunk->rank row placements the op's schedule understands
                (("contiguous",) = owner-major blocks only; causal ops
                declare the balanced zigzag/striped maps from
                core.schedules.PLACEMENTS)
    """

    name: str
    kind: str
    transports: Tuple[str, ...]
    baseline: str = "none"
    default: str = "ring"
    fwd: Optional[Callable] = None
    bwd: Optional[Callable] = None
    kernel_transports: Tuple[str, ...] = ()
    kernel_fwd: Optional[Callable] = None
    wires: Tuple[str, ...] = ("f32",)
    placements: Tuple[str, ...] = ("contiguous",)


_REGISTRY: Dict[str, OverlapSpec] = {}


def register(
    name: str,
    *,
    kind: str,
    transports: Sequence[str],
    baseline: str = "none",
    default: str = "ring",
    fwd: Optional[Callable] = None,
    bwd: Optional[Callable] = None,
    kernel_transports: Sequence[str] = (),
    kernel_fwd: Optional[Callable] = None,
    wires: Sequence[str] = ("f32",),
    placements: Sequence[str] = ("contiguous",),
) -> OverlapSpec:
    from ..ops.policy import WIRE_DTYPES  # import-light; avoids a cycle
    from .schedules import PLACEMENTS

    for t in transports:
        if t not in TRANSPORTS:
            raise ValueError(f"{name}: unknown transport {t!r}")
    if default not in transports:
        raise ValueError(f"{name}: default {default!r} not in {transports}")
    for t in kernel_transports:
        if t not in transports:
            raise ValueError(f"{name}: kernel transport {t!r} not in {transports}")
    if bool(kernel_transports) != (kernel_fwd is not None):
        raise ValueError(f"{name}: kernel_transports and kernel_fwd go together")
    for wname in wires:
        if wname not in WIRE_DTYPES:
            raise ValueError(f"{name}: unknown wire {wname!r} (valid: {WIRE_DTYPES})")
    for p in placements:
        if p not in PLACEMENTS:
            raise ValueError(
                f"{name}: unknown placement {p!r} (valid: {PLACEMENTS})")
    wires = tuple(dict.fromkeys(("f32",) + tuple(wires)))  # f32 always legal
    # contiguous always legal — it is the identity row map
    placements = tuple(dict.fromkeys(("contiguous",) + tuple(placements)))
    spec = OverlapSpec(name, kind, tuple(transports), baseline, default, fwd, bwd,
                       tuple(kernel_transports), kernel_fwd, wires, placements)
    _REGISTRY[name] = spec
    return spec


def registry() -> Mapping[str, OverlapSpec]:
    """The live op registry (populated on import of the op modules)."""
    return dict(_REGISTRY)


def get(name: str) -> OverlapSpec:
    return _REGISTRY[name]


def transports_for(name: str, *, include_baseline: bool = False) -> Tuple[str, ...]:
    spec = _REGISTRY[name]
    if include_baseline:
        return (spec.baseline,) + spec.transports
    return spec.transports


def resolve_mode(name: str, requested: str) -> str:
    """Clamp a requested overlap mode to what ``name`` supports.

    The baseline name passes through (explicitly asking for the
    monolithic path); a supported transport passes through; anything
    else falls back to the op's registered default (e.g. a global
    ``overlap_mode="ring"`` resolves to "one_shot" for a2a_ep, which has
    no ring transport)."""
    spec = _REGISTRY[name]
    if requested == spec.baseline or requested in spec.transports:
        return requested
    return spec.default


def wires_for(name: str) -> Tuple[str, ...]:
    """Wire dtypes op ``name``'s riding chunks can travel as."""
    return _REGISTRY[name].wires


def resolve_wire(name: str, requested: str, mode: Optional[str] = None) -> str:
    """Clamp a requested wire dtype to what (op, transport) supports.

    A low-precision wire sticks only when the op declared it in ``wires``
    AND the (resolved) mode actually rides the engine: the baseline mode
    (monolithic XLA path — nothing to quantize per-hop) and the
    hierarchical two_level transport (chunks ride two axes; kept f32 for
    cross-pod exactness) degrade to "f32". An unknown wire NAME is an
    error — the valid set is closed, like backends."""
    from ..ops.policy import WIRE_DTYPES  # import-light; avoids a cycle

    if requested not in WIRE_DTYPES:
        raise ValueError(
            f"{name}: unknown wire dtype {requested!r} (valid: {WIRE_DTYPES})")
    spec = _REGISTRY[name]
    if requested == "f32" or requested not in spec.wires:
        return "f32"
    if mode is not None and (mode == spec.baseline or mode == "two_level"):
        return "f32"
    return requested


def placements_for(name: str) -> Tuple[str, ...]:
    """Chunk->rank row placements op ``name``'s schedule understands."""
    return _REGISTRY[name].placements


def resolve_placement(name: str, requested: str,
                      mode: Optional[str] = None) -> str:
    """Clamp a requested row placement to what ``name`` declared.

    Placement is a property of the op's *math* (which global rows each
    rank owns), not of the transport, so — unlike wires — it survives the
    baseline mode: the monolithic lowering applies the same owner->row
    map locally. An unknown placement NAME is an error (closed set, like
    backends); an undeclared one degrades to "contiguous"."""
    from .schedules import PLACEMENTS

    del mode  # placement is transport-independent (see docstring)
    if requested not in PLACEMENTS:
        raise ValueError(
            f"{name}: unknown placement {requested!r} (valid: {PLACEMENTS})")
    spec = _REGISTRY[name]
    if requested not in spec.placements:
        return "contiguous"
    return requested


def backends_for(name: str) -> Tuple[str, ...]:
    """Backends op ``name`` can lower through (graph always; kernel when
    the op registered a fused shmem-kernel lowering)."""
    spec = _REGISTRY[name]
    return BACKENDS if spec.kernel_fwd is not None else ("graph",)


def resolve_backend(name: str, requested: str, mode: Optional[str] = None) -> str:
    """Clamp a requested backend to what (op, transport) supports.

    "kernel" sticks only when the op registered a kernel lowering AND
    the (resolved) mode is one of its kernel transports; everything
    else — including the baseline mode — lowers through "graph", the
    universal fallback. An unknown backend name is an error (unlike
    modes, there is no per-op backend default to degrade to)."""
    if requested not in BACKENDS:
        raise ValueError(f"unknown backend {requested!r} (not in {BACKENDS})")
    spec = _REGISTRY[name]
    if requested != "kernel" or spec.kernel_fwd is None:
        return "graph"
    if mode is not None and mode not in spec.kernel_transports:
        return "graph"
    return "kernel"


# ---------------------------------------------------------------------------
# The shared custom_vjp: differentiability implemented once
# ---------------------------------------------------------------------------


def _run_fwd(name: str, static: Dict[str, Any], *tensors):
    """Dispatch an op's forward to the lowering ``static['backend']``
    selects (resolved upstream by :func:`resolve_backend`)."""
    spec = _REGISTRY[name]
    if static.get("backend", "graph") == "kernel":
        return spec.kernel_fwd(static, *tensors)
    return spec.fwd(static, *tensors)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _diff_apply(name: str, static: Tuple[Tuple[str, Any], ...], *tensors):
    return _run_fwd(name, dict(static), *tensors)


def _diff_fwd(name, static, *tensors):
    return _diff_apply(name, static, *tensors), tensors


def _diff_bwd(name, static, residuals, g):
    return tuple(_REGISTRY[name].bwd(dict(static), residuals, g))


_diff_apply.defvjp(_diff_fwd, _diff_bwd)


def dispatch(name: str, *tensors, **static):
    """Run a registered op. Ops with a ``bwd`` rule are routed through the
    ONE shared custom_vjp (their backward is their dual overlapped ring,
    O(1) permute buffers instead of autodiff's O(W)); ops without one
    differentiate through the pipeline directly. ``static`` values must
    be hashable (mode strings, axis names, ints, dtypes).

    ``static["backend"]`` picks the lowering ("graph" default, "kernel"
    for the fused shmem kernels); it is resolved here against the op's
    kernel_transports, so requesting kernel for an unsupported
    (op, mode) silently degrades to graph — mirroring resolve_mode."""
    spec = _REGISTRY[name]
    if spec.fwd is None:
        raise ValueError(f"{name} has no registered fwd implementation")
    static["backend"] = resolve_backend(
        name, static.get("backend", "graph"), static.get("mode")
    )
    if spec.bwd is None:
        return _run_fwd(name, static, *tensors)
    return _diff_apply(name, tuple(sorted(static.items())), *tensors)
