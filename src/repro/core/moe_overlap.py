"""Overlapped MoE communication (paper: AG+MoE, MoE+RS, low-latency AllToAll).

Two parallelism modes, matching the paper's coverage:

  TP MoE (FLUX-style, the paper's AG+MoE / MoE+RS kernels): every rank
  holds a d_ff-shard of EVERY expert. Tokens are sequence-sharded; the
  layer AllGathers token chunks around the ring and runs the grouped GEMM
  per chunk as it arrives (Fig. 7 swizzle), then combines and
  Reduce-Scatters the outputs chunk-by-chunk (Alg. 3).

  EP MoE (DeepEP-style, the paper's AllToAll dispatch/combine): experts
  are sharded across ranks; tokens travel to their experts via a
  decomposed one-shot AllToAll (all transfers issued up-front — the
  low-latency structure of the paper's inference AllToAll), compute runs
  per-arrival, and a second AllToAll brings results home.

Dispatch is capacity-based (dense (E, cap, d) buffers) so the expert GEMM
is a regular grouped matmul — the TPU-native substitute for ragged grouping.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import offset_permute, ring_permute

Array = jax.Array


class DispatchInfo(NamedTuple):
    expert: Array  # (T, k) expert id per token-slot
    position: Array  # (T, k) position within the expert's capacity buffer
    weight: Array  # (T, k) combine weight (renormalized top-k prob, 0 if dropped)


def topk_dispatch(x: Array, logits: Array, k: int, capacity: int):
    """Capacity-based top-k dispatch.

    x: (T, d), logits: (T, E) -> (dispatched (E, cap, d), DispatchInfo).
    Tokens beyond an expert's capacity are dropped (weight 0) — standard
    capacity-factor routing.
    """
    t, d = x.shape
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * k, e)  # slot-major: token 0 slot 0, token 0 slot 1, ...
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat  # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(t, k)  # (T, k)
    keep = pos < capacity
    weight = jnp.where(keep, top_p, 0.0)
    pos_c = jnp.where(keep, pos, capacity - 1)

    disp = jnp.zeros((e, capacity, d), x.dtype)
    xk = jnp.broadcast_to(x[:, None, :], (t, k, d))
    mask = keep[..., None].astype(x.dtype)
    disp = disp.at[top_e.reshape(-1), pos_c.reshape(-1)].add(
        (xk * mask).reshape(t * k, d), mode="drop"
    )
    return disp, DispatchInfo(top_e, pos_c, weight)


def topk_combine(out: Array, info: DispatchInfo, out_dtype=None) -> Array:
    """Inverse of dispatch: (E, cap, d), info -> (T, d)."""
    t, k = info.expert.shape
    gathered = out[info.expert.reshape(-1), info.position.reshape(-1)]  # (T*k, d)
    gathered = gathered.reshape(t, k, -1).astype(jnp.float32)
    y = jnp.sum(gathered * info.weight[..., None], axis=1)
    return y.astype(out_dtype or out.dtype)


# ---------------------------------------------------------------------------
# EP AllToAll — decomposed one-shot (low-latency) and XLA baseline
# ---------------------------------------------------------------------------


def a2a_ep(x: Array, axis: str, *, mode: str = "one_shot") -> Array:
    """Expert-parallel AllToAll.

    x: (E_global, cap, d) where E_global = W * E_local; rank r keeps the
    slab for the experts it owns: returns (E_local, W * cap, d) — every
    rank's tokens for my local experts.
    """
    w = lax.axis_size(axis)
    e_global, cap, d = x.shape
    e_local = e_global // w
    xs = x.reshape(w, e_local, cap, d)
    if mode == "xla":
        y = lax.all_to_all(xs, axis, split_axis=0, concat_axis=0, tiled=False)
        # y: (W, e_local, cap, d) — block i is rank i's tokens for my experts
        return jnp.moveaxis(y, 0, 1).reshape(e_local, w * cap, d)
    # one-shot decomposition (paper's low-latency AllToAll structure):
    # all W-1 sends issued up-front with distinct ring offsets.
    me = lax.axis_index(axis)
    out = jnp.zeros((e_local, w, cap, d), x.dtype)
    my_blk = lax.dynamic_slice(xs, (me, 0, 0, 0), (1, e_local, cap, d))[0]
    out = lax.dynamic_update_slice(out, my_blk[:, None], (0, me, 0, 0))
    for off in range(1, w):
        # send my slab for the experts of rank (me+off) to that rank
        tgt = lax.rem(me + off, w)
        send_blk = lax.dynamic_slice(xs, (tgt, 0, 0, 0), (1, e_local, cap, d))[0]
        recv_blk = offset_permute(send_blk, axis, off)  # arrives from me-off
        src = lax.rem(me - off + w, w)
        out = lax.dynamic_update_slice(out, recv_blk[:, None], (0, src, 0, 0))
    return out.reshape(e_local, w * cap, d)


def a2a_ep_inverse(y: Array, axis: str, *, mode: str = "one_shot") -> Array:
    """Inverse AllToAll: (E_local, W*cap, d) -> (E_global, cap, d)."""
    w = lax.axis_size(axis)
    e_local, wc, d = y.shape
    cap = wc // w
    ys = jnp.moveaxis(y.reshape(e_local, w, cap, d), 1, 0)  # (W, e_local, cap, d)
    if mode == "xla":
        x = lax.all_to_all(ys, axis, split_axis=0, concat_axis=0, tiled=False)
        return x.reshape(w * e_local, cap, d)
    me = lax.axis_index(axis)
    out = jnp.zeros((w, e_local, cap, d), y.dtype)
    mine = lax.dynamic_slice(ys, (me, 0, 0, 0), (1, e_local, cap, d))
    out = lax.dynamic_update_slice(out, mine, (me, 0, 0, 0))
    for off in range(1, w):
        tgt = lax.rem(me + off, w)
        send_blk = lax.dynamic_slice(ys, (tgt, 0, 0, 0), (1, e_local, cap, d))
        recv_blk = offset_permute(send_blk, axis, off)
        src = lax.rem(me - off + w, w)
        out = lax.dynamic_update_slice(out, recv_blk, (src, 0, 0, 0))
    return out.reshape(w * e_local, cap, d)


# ---------------------------------------------------------------------------
# TP MoE: AG + GroupGEMM and GroupGEMM + RS (the paper's fused MoE ops)
# ---------------------------------------------------------------------------


def ag_moe(
    x_blk: Array,  # (T_loc, d) sequence-sharded tokens
    logits_blk: Array,  # (T_loc, E) their router logits
    expert_fn,  # (tokens (T_loc,d), logits (T_loc,E)) -> (T_loc, d_out)
    axis: str,
    *,
    mode: str = "ring",
) -> Array:
    """AllGather-MoE overlap: ring token chunks; run the (d_ff-sharded)
    expert computation on each chunk as it arrives; every rank produces
    the full sequence's partial outputs (to be reduced by rs afterwards
    or combined directly when expert_fn output is complete)."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    t_loc = x_blk.shape[0]
    ys = []
    buf_x, buf_l = x_blk, logits_blk
    for s in range(w):
        ys.append(expert_fn(buf_x, buf_l))  # chunk of owner (me - s) % w
        if s != w - 1:
            if mode == "one_shot":
                buf_x = offset_permute(x_blk, axis, s + 1)
                buf_l = offset_permute(logits_blk, axis, s + 1)
            else:
                buf_x = ring_permute(buf_x, axis)
                buf_l = ring_permute(buf_l, axis)
    # Assemble owner-ascending WITHOUT a dynamic_update_slice chain (whose
    # autodiff keeps all W buffer versions live in the backward): reversed
    # computation order is owners ascending cyclically from (me+1), so one
    # static concat + one cyclic roll (O(1)-buffer transpose) suffices.
    rev = jnp.concatenate(ys[::-1], axis=0)
    return jnp.roll(rev, shift=(me + 1) * t_loc, axis=0)


def moe_rs(
    x_full: Array,  # (T, d) full gathered tokens
    logits_full: Array,  # (T, E)
    expert_fn,  # partial-output expert computation (d_ff-sharded)
    axis: str,
) -> Array:
    """GroupGEMM-ReduceScatter overlap (paper MoE+RS): compute the expert
    output block destined for rank (me - s - 1) at step s and ring-reduce
    the accumulator (Alg. 3 schedule)."""
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    t = x_full.shape[0]
    t_blk = t // w
    acc = None
    for s in range(w):
        blk = lax.rem(me - s - 1 + 2 * w, w)
        xb = lax.dynamic_slice(x_full, (blk * t_blk, 0), (t_blk, x_full.shape[1]))
        lb = lax.dynamic_slice(
            logits_full, (blk * t_blk, 0), (t_blk, logits_full.shape[1])
        )
        partial = expert_fn(xb, lb).astype(jnp.float32)
        if acc is None:
            acc = partial
        else:
            acc = partial + ring_permute(acc, axis)
    return acc.astype(x_full.dtype)
