"""Overlapped MoE communication (paper: AG+MoE, MoE+RS, low-latency
AllToAll), declared over the ring-pipeline engine (``core.overlap``).

Two parallelism modes, matching the paper's coverage:

  TP MoE (FLUX-style, the paper's AG+MoE / MoE+RS kernels): every rank
  holds a d_ff-shard of EVERY expert. Tokens are sequence-sharded; the
  layer rides token chunks on the engine's AG transports (ring / bidir /
  one_shot) and runs the grouped GEMM per chunk as it arrives (Fig. 7
  swizzle), then combines and Reduce-Scatters the outputs chunk-by-chunk
  (Alg. 3 / the engine's RS transports).

  EP MoE (DeepEP-style, the paper's AllToAll dispatch/combine): experts
  are sharded across ranks; tokens travel to their experts via the
  engine's a2a_pipeline (one_shot = all transfers issued up-front — the
  low-latency structure of the paper's inference AllToAll), compute runs
  per-arrival, and a second AllToAll brings results home.

Dispatch is capacity-based (dense (E, cap, d) buffers) so the expert GEMM
is a regular grouped matmul — the TPU-native substitute for ragged
grouping. Registry entries: "ag_moe", "moe_rs", "a2a_ep".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import overlap as ov

Array = jax.Array


class DispatchInfo(NamedTuple):
    expert: Array  # (T, k) expert id per token-slot
    position: Array  # (T, k) position within the expert's capacity buffer
    weight: Array  # (T, k) combine weight (renormalized top-k prob, 0 if dropped)


def topk_dispatch(x: Array, logits: Array, k: int, capacity: int):
    """Capacity-based top-k dispatch.

    x: (T, d), logits: (T, E) -> (dispatched (E, cap, d), DispatchInfo).
    Tokens beyond an expert's capacity are dropped (weight 0) — standard
    capacity-factor routing.
    """
    t, d = x.shape
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * k, e)  # slot-major: token 0 slot 0, token 0 slot 1, ...
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat  # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(t, k)  # (T, k)
    keep = pos < capacity
    weight = jnp.where(keep, top_p, 0.0)
    pos_c = jnp.where(keep, pos, capacity - 1)

    disp = jnp.zeros((e, capacity, d), x.dtype)
    xk = jnp.broadcast_to(x[:, None, :], (t, k, d))
    mask = keep[..., None].astype(x.dtype)
    disp = disp.at[top_e.reshape(-1), pos_c.reshape(-1)].add(
        (xk * mask).reshape(t * k, d), mode="drop"
    )
    return disp, DispatchInfo(top_e, pos_c, weight)


def topk_combine(out: Array, info: DispatchInfo, out_dtype=None) -> Array:
    """Inverse of dispatch: (E, cap, d), info -> (T, d)."""
    t, k = info.expert.shape
    gathered = out[info.expert.reshape(-1), info.position.reshape(-1)]  # (T*k, d)
    gathered = gathered.reshape(t, k, -1).astype(jnp.float32)
    y = jnp.sum(gathered * info.weight[..., None], axis=1)
    return y.astype(out_dtype or out.dtype)


# ---------------------------------------------------------------------------
# EP AllToAll — engine a2a_pipeline (one_shot low-latency / XLA baseline)
# ---------------------------------------------------------------------------


def a2a_ep(x: Array, axis: str, *, mode: str = "one_shot") -> Array:
    """Expert-parallel AllToAll.

    x: (E_global, cap, d) where E_global = W * E_local; rank r keeps the
    slab for the experts it owns: returns (E_local, W * cap, d) — every
    rank's tokens for my local experts.
    """
    w = lax.axis_size(axis)
    e_global, cap, d = x.shape
    e_local = e_global // w
    xs = x.reshape(w, e_local, cap, d)  # block t = my tokens for rank t's experts
    y = ov.a2a_pipeline(xs, axis, transport=mode)
    # y[src] = rank src's tokens for my experts
    return jnp.moveaxis(y, 0, 1).reshape(e_local, w * cap, d)


def a2a_ep_inverse(y: Array, axis: str, *, mode: str = "one_shot") -> Array:
    """Inverse AllToAll: (E_local, W*cap, d) -> (E_global, cap, d)."""
    w = lax.axis_size(axis)
    e_local, wc, d = y.shape
    cap = wc // w
    ys = jnp.moveaxis(y.reshape(e_local, w, cap, d), 1, 0)  # (W, e_local, cap, d)
    x = ov.a2a_pipeline(ys, axis, transport=mode)
    return x.reshape(w * e_local, cap, d)


# ---------------------------------------------------------------------------
# TP MoE: AG + GroupGEMM and GroupGEMM + RS (the paper's fused MoE ops)
# ---------------------------------------------------------------------------


def ag_moe(
    x_blk: Array,  # (T_loc, d) sequence-sharded tokens
    logits_blk: Array,  # (T_loc, E) their router logits
    expert_fn,  # (tokens (T_loc,d), logits (T_loc,E)) -> (T_loc, d_out)
    axis: str,
    *,
    mode: str = "ring",
) -> Array:
    """AllGather-MoE overlap: token chunks ride the engine transport; the
    (d_ff-sharded) expert computation runs on each chunk as it arrives;
    every rank produces the full sequence's partial outputs (to be
    reduced by rs afterwards or combined directly when expert_fn output
    is complete).

    Assembly avoids a dynamic_update_slice chain (whose autodiff keeps
    all W buffer versions live in the backward): chunks are collected in
    computation order and realigned with ONE static concat + ONE cyclic
    roll per direction (an O(1)-buffer transpose).
    """
    mode = ov.resolve_mode("ag_moe", mode)
    if mode == "none":
        # monolithic baseline: gather everything, then one big expert pass
        return expert_fn(
            lax.all_gather(x_blk, axis, tiled=True),
            lax.all_gather(logits_blk, axis, tiled=True),
        )
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    t_loc = x_blk.shape[0]

    if mode == "bidir" and t_loc % 2 == 0 and w >= 3:
        h = t_loc // 2

        def fold2(carry, bufs, s, owner, direction):
            ys_f, ys_b = carry
            y = expert_fn(bufs[0], bufs[1])
            return (ys_f + [y], ys_b) if direction == 0 else (ys_f, ys_b + [y])

        ys_f, ys_b = ov.bidir_ag_pipeline((x_blk, logits_blk), fold2, ([], []), axis)
        d_out = ys_f[0].shape[-1]
        # forward halves: owners me, me-1, ... -> reversed is ascending
        # cyclically from me+1; backward halves: owners me, me+1, ... are
        # already ascending from me.
        tops = jnp.roll(jnp.concatenate(ys_f[::-1], 0), (me + 1) * h, axis=0)
        bots = jnp.roll(jnp.concatenate(ys_b, 0), me * h, axis=0)
        out = jnp.concatenate(
            [tops.reshape(w, h, d_out), bots.reshape(w, h, d_out)], axis=1
        )
        return out.reshape(w * t_loc, d_out)

    if mode == "bidir":
        mode = "ring"

    def fold(ys, bufs, s, owner):
        return ys + [expert_fn(bufs[0], bufs[1])]  # chunk of owner (me - s) % w

    ys = ov.ag_pipeline((x_blk, logits_blk), fold, [], axis, transport=mode)
    rev = jnp.concatenate(ys[::-1], axis=0)
    return jnp.roll(rev, shift=(me + 1) * t_loc, axis=0)


def moe_rs(
    x_full: Array,  # (T, d) full gathered tokens
    logits_full: Array,  # (T, E)
    expert_fn,  # partial-output expert computation (d_ff-sharded)
    axis: str,
    *,
    mode: str = "ring",
) -> Array:
    """GroupGEMM-ReduceScatter overlap (paper MoE+RS): the expert output
    block destined for each rank is the rs_pipeline's per-block compute;
    the accumulator rides the engine transport (Alg. 3 schedule, plus
    bidir token-halves and the one_shot low-latency variant)."""
    mode = ov.resolve_mode("moe_rs", mode)
    if mode == "none":
        # monolithic baseline: full expert pass, then XLA's reduce-scatter
        partial = expert_fn(x_full, logits_full).astype(jnp.float32)
        return lax.psum_scatter(
            partial, axis, scatter_dimension=0, tiled=True
        ).astype(x_full.dtype)
    w = lax.axis_size(axis)
    t = x_full.shape[0]
    t_blk = t // w

    def rows(start, size):
        xb = lax.dynamic_slice(x_full, (start, 0), (size, x_full.shape[1]))
        lb = lax.dynamic_slice(logits_full, (start, 0), (size, logits_full.shape[1]))
        return xb, lb

    if mode == "bidir" and t_blk % 2 == 0 and w >= 3:
        h = t_blk // 2

        def compute2(blk, s, direction):
            xb, lb = rows(blk * t_blk + direction * h, h)
            return expert_fn(xb, lb).astype(jnp.float32)

        acc_f, acc_r = ov.bidir_rs_pipeline(compute2, axis)
        return jnp.concatenate([acc_f, acc_r], axis=0).astype(x_full.dtype)

    if mode == "bidir":
        mode = "ring"

    def compute(blk, s):
        xb, lb = rows(blk * t_blk, t_blk)
        return expert_fn(xb, lb).astype(jnp.float32)

    return ov.rs_pipeline(compute, axis, transport=mode).astype(x_full.dtype)


# ---------------------------------------------------------------------------
# Registry entries (these ops differentiate through the pipeline directly:
# ag_moe's concat+roll assembly and moe_rs's accumulator chain are already
# O(1)-buffer under autodiff, and expert_fn is checkpointed per chunk by
# the caller)
# ---------------------------------------------------------------------------

ov.register("ag_moe", kind="ag", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring")
ov.register("moe_rs", kind="rs", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring")
ov.register("a2a_ep", kind="a2a", transports=("one_shot",),
            baseline="xla", default="one_shot")
