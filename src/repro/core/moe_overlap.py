"""Overlapped MoE communication (paper: AG+MoE, MoE+RS, low-latency
AllToAll), declared over the ring-pipeline engine (``core.overlap``).

Two parallelism modes, matching the paper's coverage:

  TP MoE (FLUX-style, the paper's AG+MoE / MoE+RS kernels): every rank
  holds a d_ff-shard of EVERY expert. Tokens are sequence-sharded; the
  layer rides token chunks on the engine's AG transports (ring / bidir /
  one_shot) and runs the grouped GEMM per chunk as it arrives (Fig. 7
  swizzle), then combines and Reduce-Scatters the outputs chunk-by-chunk
  (Alg. 3 / the engine's RS transports).

  EP MoE (DeepEP-style, the paper's AllToAll dispatch/combine): experts
  are sharded across ranks; tokens travel to their experts via the
  engine's a2a_pipeline (one_shot = all transfers issued up-front — the
  low-latency structure of the paper's inference AllToAll), compute runs
  per-arrival, and a second AllToAll brings results home.

Dispatch is capacity-based (dense (E, cap, d) buffers) so the expert GEMM
is a regular grouped matmul — the TPU-native substitute for ragged
grouping. Registry entries: "ag_moe", "moe_rs", "a2a_ep".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import overlap as ov

Array = jax.Array


class DispatchInfo(NamedTuple):
    expert: Array  # (T, k) expert id per token-slot
    position: Array  # (T, k) position within the expert's capacity buffer
    weight: Array  # (T, k) combine weight (renormalized top-k prob, 0 if dropped)


def topk_dispatch(x: Array, logits: Array, k: int, capacity: int):
    """Capacity-based top-k dispatch.

    x: (T, d), logits: (T, E) -> (dispatched (E, cap, d), DispatchInfo).
    Tokens beyond an expert's capacity are dropped (weight 0) — standard
    capacity-factor routing.
    """
    t, d = x.shape
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * k, e)  # slot-major: token 0 slot 0, token 0 slot 1, ...
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat  # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(t, k)  # (T, k)
    keep = pos < capacity
    weight = jnp.where(keep, top_p, 0.0)
    pos_c = jnp.where(keep, pos, capacity - 1)

    disp = jnp.zeros((e, capacity, d), x.dtype)
    xk = jnp.broadcast_to(x[:, None, :], (t, k, d))
    mask = keep[..., None].astype(x.dtype)
    disp = disp.at[top_e.reshape(-1), pos_c.reshape(-1)].add(
        (xk * mask).reshape(t * k, d), mode="drop"
    )
    return disp, DispatchInfo(top_e, pos_c, weight)


def topk_combine(out: Array, info: DispatchInfo, out_dtype=None) -> Array:
    """Inverse of dispatch: (E, cap, d), info -> (T, d)."""
    t, k = info.expert.shape
    gathered = out[info.expert.reshape(-1), info.position.reshape(-1)]  # (T*k, d)
    gathered = gathered.reshape(t, k, -1).astype(jnp.float32)
    y = jnp.sum(gathered * info.weight[..., None], axis=1)
    return y.astype(out_dtype or out.dtype)


# ---------------------------------------------------------------------------
# EP AllToAll — the declared "a2a_ep" op (repro.ops.library): graph =
# engine a2a_pipeline (one_shot low-latency / XLA baseline), kernel =
# the executor's one_shot_a2a push protocol. Both directions are the
# SAME registered op; the inverse just transposes block placement.
# ---------------------------------------------------------------------------


def a2a_ep(x: Array, axis: str, *, mode: str = "one_shot",
           backend: str = "graph", wire: str = "f32") -> Array:
    """Expert-parallel AllToAll.

    x: (E_global, cap, d) where E_global = W * E_local; rank r keeps the
    slab for the experts it owns: returns (E_local, W * cap, d) — every
    rank's tokens for my local experts. ``wire`` quantizes the riding
    token slabs (see ``repro.ops.wire``).
    """
    w = lax.axis_size(axis)
    e_global, cap, d = x.shape
    e_local = e_global // w
    mode = ov.resolve_mode("a2a_ep", mode)
    xs = x.reshape(w, e_local, cap, d)  # block t = my tokens for rank t's experts
    y = ov.dispatch("a2a_ep", xs, axis=axis, mode=mode, backend=backend,
                    wire=ov.resolve_wire("a2a_ep", wire, mode))
    # y[src] = rank src's tokens for my experts
    return jnp.moveaxis(y, 0, 1).reshape(e_local, w * cap, d)


def a2a_ep_inverse(y: Array, axis: str, *, mode: str = "one_shot",
                   backend: str = "graph", wire: str = "f32") -> Array:
    """Inverse AllToAll: (E_local, W*cap, d) -> (E_global, cap, d)."""
    w = lax.axis_size(axis)
    e_local, wc, d = y.shape
    cap = wc // w
    mode = ov.resolve_mode("a2a_ep", mode)
    ys = jnp.moveaxis(y.reshape(e_local, w, cap, d), 1, 0)  # (W, e_local, cap, d)
    x = ov.dispatch("a2a_ep", ys, axis=axis, mode=mode, backend=backend,
                    wire=ov.resolve_wire("a2a_ep", wire, mode))
    return x.reshape(w * e_local, cap, d)


# ---------------------------------------------------------------------------
# TP MoE: AG + GroupGEMM and GroupGEMM + RS (the paper's fused MoE ops)
# ---------------------------------------------------------------------------


def ag_moe(
    x_blk: Array,  # (T_loc, d) sequence-sharded tokens
    logits_blk: Array,  # (T_loc, E) their router logits
    expert_fn,  # (tokens (T_loc,d), logits (T_loc,E)) -> (T_loc, d_out)
    axis: str,
    *,
    mode: str = "ring",
    backend: str = "graph",
) -> Array:
    """AllGather-MoE overlap: token chunks ride the engine transport; the
    (d_ff-sharded) expert computation runs on each chunk as it arrives;
    every rank produces the full sequence's partial outputs (to be
    reduced by rs afterwards or combined directly when expert_fn output
    is complete). ``backend="kernel"`` lowers through the shmem tile
    executor (tokens+logits packed into one riding chunk)."""
    return ov.dispatch("ag_moe", x_blk, logits_blk, axis=axis,
                       mode=ov.resolve_mode("ag_moe", mode), backend=backend,
                       expert_fn=expert_fn)


def _ag_moe_graph(static, x_blk, logits_blk):
    """Engine (lax.ppermute) lowering of ag_moe.

    Assembly avoids a dynamic_update_slice chain (whose autodiff keeps
    all W buffer versions live in the backward): chunks are collected in
    computation order and realigned with ONE static concat + ONE cyclic
    roll per direction (an O(1)-buffer transpose).
    """
    axis, mode, expert_fn = static["axis"], static["mode"], static["expert_fn"]
    if mode == "none":
        # monolithic baseline: gather everything, then one big expert pass
        return expert_fn(
            lax.all_gather(x_blk, axis, tiled=True),
            lax.all_gather(logits_blk, axis, tiled=True),
        )
    w = lax.axis_size(axis)
    me = lax.axis_index(axis)
    t_loc = x_blk.shape[0]

    if mode == "bidir" and t_loc % 2 == 0 and w >= 3:
        h = t_loc // 2

        def fold2(carry, bufs, s, owner, direction):
            ys_f, ys_b = carry
            y = expert_fn(bufs[0], bufs[1])
            return (ys_f + [y], ys_b) if direction == 0 else (ys_f, ys_b + [y])

        ys_f, ys_b = ov.bidir_ag_pipeline((x_blk, logits_blk), fold2, ([], []), axis)
        d_out = ys_f[0].shape[-1]
        # forward halves: owners me, me-1, ... -> reversed is ascending
        # cyclically from me+1; backward halves: owners me, me+1, ... are
        # already ascending from me.
        tops = jnp.roll(jnp.concatenate(ys_f[::-1], 0), (me + 1) * h, axis=0)
        bots = jnp.roll(jnp.concatenate(ys_b, 0), me * h, axis=0)
        out = jnp.concatenate(
            [tops.reshape(w, h, d_out), bots.reshape(w, h, d_out)], axis=1
        )
        return out.reshape(w * t_loc, d_out)

    if mode == "bidir":
        mode = "ring"

    def fold(ys, bufs, s, owner):
        return ys + [expert_fn(bufs[0], bufs[1])]  # chunk of owner (me - s) % w

    ys = ov.ag_pipeline((x_blk, logits_blk), fold, [], axis, transport=mode)
    rev = jnp.concatenate(ys[::-1], axis=0)
    return jnp.roll(rev, shift=(me + 1) * t_loc, axis=0)


def moe_rs(
    x_full: Array,  # (T, d) full gathered tokens
    logits_full: Array,  # (T, E)
    expert_fn,  # partial-output expert computation (d_ff-sharded)
    axis: str,
    *,
    mode: str = "ring",
    backend: str = "graph",
) -> Array:
    """GroupGEMM-ReduceScatter overlap (paper MoE+RS): the expert output
    block destined for each rank is the rs_pipeline's per-block compute;
    the accumulator rides the engine transport (Alg. 3 schedule, plus
    bidir token-halves and the one_shot low-latency variant).
    ``backend="kernel"`` lowers ring through the executor's Alg.-3 push
    and one_shot through the all-partials-up-front protocol."""
    return ov.dispatch("moe_rs", x_full, logits_full, axis=axis,
                       mode=ov.resolve_mode("moe_rs", mode), backend=backend,
                       expert_fn=expert_fn)


def _moe_rs_graph(static, x_full, logits_full):
    """Engine (lax.ppermute) lowering of moe_rs."""
    axis, mode, expert_fn = static["axis"], static["mode"], static["expert_fn"]
    if mode == "none":
        # monolithic baseline: full expert pass, then XLA's reduce-scatter
        partial = expert_fn(x_full, logits_full).astype(jnp.float32)
        return lax.psum_scatter(
            partial, axis, scatter_dimension=0, tiled=True
        ).astype(x_full.dtype)
    w = lax.axis_size(axis)
    t = x_full.shape[0]
    t_blk = t // w

    def rows(start, size):
        xb = lax.dynamic_slice(x_full, (start, 0), (size, x_full.shape[1]))
        lb = lax.dynamic_slice(logits_full, (start, 0), (size, logits_full.shape[1]))
        return xb, lb

    if mode == "bidir" and t_blk % 2 == 0 and w >= 3:
        h = t_blk // 2

        def compute2(blk, s, direction):
            xb, lb = rows(blk * t_blk + direction * h, h)
            return expert_fn(xb, lb).astype(jnp.float32)

        acc_f, acc_r = ov.bidir_rs_pipeline(compute2, axis)
        return jnp.concatenate([acc_f, acc_r], axis=0).astype(x_full.dtype)

    if mode == "bidir":
        mode = "ring"

    def compute(blk, s):
        xb, lb = rows(blk * t_blk, t_blk)
        return expert_fn(xb, lb).astype(jnp.float32)

    return ov.rs_pipeline(compute, axis, transport=mode).astype(x_full.dtype)


# ---------------------------------------------------------------------------
# Kernel (shmem tile executor) lowerings: tokens and logits are packed
# into ONE riding chunk (the executor protocols move a single operand),
# and the tile unpacks the columns before calling expert_fn. The expert
# closure arrives per call in the static dict — these ops sit outside
# the declarative library only because their compute is a caller-
# supplied closure, not a declaration-time tile.
# ---------------------------------------------------------------------------

_AG_MOE_CID, _MOE_RS_CID = 24, 25
_AG_MOE_PROTOS = {"ring": "ring_ag", "bidir": "bidir_ring_ag",
                  "one_shot": "one_shot_ag"}
_MOE_RS_PROTOS = {"ring": "push_rs", "one_shot": "one_shot_rs"}


def _moe_pack(x: Array, logits: Array) -> Array:
    # pack in the PROMOTED dtype so neither side loses precision on the
    # wire (bf16 tokens + f32 router logits -> f32 packed; the unpack
    # cast back to each original dtype is then exact)
    pdt = jnp.promote_types(x.dtype, logits.dtype)
    return jnp.concatenate([x.astype(pdt), logits.astype(pdt)], axis=1)


def _moe_tile(expert_fn, d: int, x_dtype, logits_dtype):
    def tile(packed):
        return expert_fn(packed[:, :d].astype(x_dtype),
                         packed[:, d:].astype(logits_dtype))

    return tile


def _ag_moe_kernel(static, x_blk, logits_blk):
    from ..shmem import executor

    axis = static["axis"]
    tile = _moe_tile(static["expert_fn"], x_blk.shape[1], x_blk.dtype,
                     logits_blk.dtype)
    packed = _moe_pack(x_blk, logits_blk)
    out_dtype = jax.eval_shape(tile, packed).dtype
    return executor.run(
        _AG_MOE_PROTOS[static["mode"]], tile, packed, axis=axis,
        world=lax.axis_size(axis), out_dtype=out_dtype,
        collective_id=_AG_MOE_CID)


def _moe_rs_kernel(static, x_full, logits_full):
    from ..shmem import executor

    axis = static["axis"]
    tile = _moe_tile(static["expert_fn"], x_full.shape[1], x_full.dtype,
                     logits_full.dtype)
    # out_dtype=f32: partials ride and reduce in f32, matching the graph
    # lowering's f32 accumulator; the final cast happens here, once.
    acc = executor.run(
        _MOE_RS_PROTOS[static["mode"]], tile, _moe_pack(x_full, logits_full),
        axis=axis, world=lax.axis_size(axis), out_dtype=jnp.float32,
        collective_id=_MOE_RS_CID)
    return acc.astype(x_full.dtype)


# ---------------------------------------------------------------------------
# Derived backwards: jax.vjp OF THE EXPERT CLOSURE. The expert is a
# caller closure with no declared tile (it may be nonlinear AND
# rank-dependent), so the authoring API's linear-tile duals do not
# apply; instead each rank differentiates ITS OWN closure at the true
# primal chunks and the cotangents ride the dual schedules. Routing
# through the shared custom_vjp is what lets the TRAIN path use the
# KERNEL lowering: the kernel forward keeps this graph-schedule dual as
# its backward (autodiff cannot go through the io_callback kernel fwd).
# ---------------------------------------------------------------------------


def _ag_moe_bwd(static, res, g):
    """d(ag_moe): stack-gather the packed token|logit chunks once (ONE
    residual ring — the same packed riding chunk the kernel forward
    uses), vjp the local expert at every owner's chunk against that
    owner's output-row cotangents, then reduce the packed
    (d_tokens | d_logits) partials home on the dual RS ring."""
    axis, expert_fn = static["axis"], static["expert_fn"]
    x_blk, l_blk = res
    t_loc = x_blk.shape[0]
    d = x_blk.shape[1]
    stacked = ov.stack_gather_pipeline(_moe_pack(x_blk, l_blk), axis,
                                       transport="ring")

    def contrib(blk, s):
        del s
        chunk = lax.dynamic_index_in_dim(stacked, blk, 0, keepdims=False)
        xb = chunk[:, :d].astype(x_blk.dtype)  # exact unpack casts
        lb = chunk[:, d:].astype(l_blk.dtype)
        g_blk = lax.dynamic_slice(g, (blk * t_loc, 0), (t_loc, g.shape[1]))
        _, vjp = jax.vjp(expert_fn, xb, lb)
        dxb, dlb = vjp(g_blk)
        return jnp.concatenate(
            [dxb.astype(jnp.float32), dlb.astype(jnp.float32)], axis=1)

    packed = ov.rs_pipeline(contrib, axis, transport="ring")
    return (packed[:, :d].astype(x_blk.dtype),
            packed[:, d:].astype(l_blk.dtype))


def _moe_rs_bwd(static, res, g):
    """d(moe_rs): ONE dual AG ring of the per-rank output-block
    cotangents; each arriving g block is pushed back through this rank's
    expert closure at the true local primal rows (f32 accumulation,
    matching the forward's accumulator dtype)."""
    axis, expert_fn = static["axis"], static["expert_fn"]
    x_full, l_full = res
    w = lax.axis_size(axis)
    t_blk = x_full.shape[0] // w

    def rows(t, start):
        return lax.dynamic_slice(t, (start, 0), (t_blk, t.shape[1]))

    def fold(carry, bufs, s, owner):
        del s
        dx, dl = carry
        xb = rows(x_full, owner * t_blk)
        lb = rows(l_full, owner * t_blk)
        _, vjp = jax.vjp(
            lambda a, b: expert_fn(a, b).astype(jnp.float32), xb, lb)
        gxb, glb = vjp(bufs[0].astype(jnp.float32))
        dx = lax.dynamic_update_slice(dx, gxb.astype(jnp.float32),
                                      (owner * t_blk, 0))
        dl = lax.dynamic_update_slice(dl, glb.astype(jnp.float32),
                                      (owner * t_blk, 0))
        return dx, dl

    init = (jnp.zeros(x_full.shape, jnp.float32),
            jnp.zeros(l_full.shape, jnp.float32))
    dx, dl = ov.ag_pipeline((g,), fold, init, axis, transport="ring")
    return dx.astype(x_full.dtype), dl.astype(l_full.dtype)


# ---------------------------------------------------------------------------
# Registry entries. The "a2a_ep" entry is DECLARED in repro.ops.library
# (one_shot_a2a kernel protocol + self-dual backward); the trailing
# import below guarantees the declaration runs for anyone importing this
# module directly.
# ---------------------------------------------------------------------------

ov.register("ag_moe", kind="ag", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring", fwd=_ag_moe_graph,
            bwd=_ag_moe_bwd,
            kernel_transports=("ring", "bidir", "one_shot"),
            kernel_fwd=_ag_moe_kernel)
ov.register("moe_rs", kind="rs", transports=("ring", "bidir", "one_shot"),
            baseline="none", default="ring", fwd=_moe_rs_graph,
            bwd=_moe_rs_bwd,
            kernel_transports=("ring", "one_shot"),
            kernel_fwd=_moe_rs_kernel)

from .. import ops as _ops  # noqa: E402,F401  (registers a2a_ep et al.)
