"""repro.core — the paper's contribution: overlapping distributed kernels.

The centerpiece is the **ring-pipeline engine** (``overlap``): one
implementation of "compute a chunk while the next chunk rides the
interconnect", parameterized by schedule x transport x per-chunk compute
x combine. Every overlapped collective in the repo is a thin declaration
over it, and every op registers an :class:`overlap.OverlapSpec` in the
**mode registry** — the single source of truth for which transports
(ring / bidir / one_shot / two_level) an op supports, its monolithic
baseline, and its differentiation rule (one shared ``custom_vjp`` for
the ops whose backward is their dual overlapped op).

The registry is consumed by three layers:
  - ``repro.ops.OverlapPolicy`` (on ``ParallelConfig.overlap``) resolves
    per-op (mode, backend, chunks) in one place (``policy.resolve``);
  - ``tuner`` enumerates registry transports as its analytic candidates
    and returns a whole ``OverlapPolicy`` (``recommend_overlap_modes``);
  - ``tests/test_overlap_engine.py`` property-tests every registered
    (op, transport) pair against its baseline.

The registry also carries a backend axis (graph | kernel): "kernel"
lowers an op through the shmem tile executor / fused kernels (built on
the ``repro.shmem`` subsystem — remote DMAs on TPU, the emulated DMA
engine on CPU), resolved per (op, transport) by
``overlap.resolve_backend``. Ops are REGISTERED via the declarative
front-end ``repro.ops`` (``OverlapOp`` + ``declare``), which derives
graph/kernel lowerings and the dual-schedule backward from one
tile-level declaration; ``overlap.register`` remains the low-level hook
for hand-written entries (2-level ops, attention, MoE).

Modules:
- overlap: the engine — AG/RS/bidir/2-level/a2a pipelines, registry,
  shared custom_vjp
- primitives: graph-level permute primitives + re-exports of the
  repro.shmem kernel-level API (paper Table 1)
- schedules: tile-swizzle orders + validity checks (Fig. 7/8/10)
- collective_matmul: AG+GEMM / GEMM+RS declarations (1- and 2-level)
- moe_overlap: AG+MoE, MoE+RS, EP AllToAll dispatch/combine
- ring_attention: context parallelism as an engine AG pipeline
- flash_decode: distributed flash decoding with low-latency combine
- tuner: analytic + distributed-empirical autotuning (§3.8)
"""
from . import (
    collective_matmul,
    flash_decode,
    moe_overlap,
    overlap,
    primitives,
    ring_attention,
    schedules,
    tuner,
)

__all__ = [
    "collective_matmul",
    "flash_decode",
    "moe_overlap",
    "overlap",
    "primitives",
    "ring_attention",
    "schedules",
    "tuner",
]
