"""repro.core — the paper's contribution: overlapping distributed kernels.

- primitives: OpenSHMEM-style signal/symmetric-memory API on TPU
- schedules: tile-swizzle orders (Fig. 7/8/10)
- collective_matmul: overlapped AG+GEMM / GEMM+RS (1- and 2-level)
- moe_overlap: AG+MoE, MoE+RS, EP AllToAll dispatch/combine
- flash_decode: distributed flash decoding with low-latency combine
- tuner: analytic + distributed-empirical autotuning (§3.8)
"""
from . import (
    collective_matmul,
    flash_decode,
    moe_overlap,
    primitives,
    ring_attention,
    schedules,
    tuner,
)

__all__ = [
    "collective_matmul",
    "flash_decode",
    "moe_overlap",
    "primitives",
    "ring_attention",
    "schedules",
    "tuner",
]
