"""Grouped (per-expert) GEMM Pallas kernel for MoE layers.

Capacity-based layout: x is (E, cap, d_in) — tokens already dispatched to
expert buffers — and w is (E, d_in, d_out). One MXU pipeline computes all
experts: grid (E, cap_tiles, n_tiles, k_tiles); the expert index selects
both the x slab and the weight slab. VMEM working set is one (bm, bk) x
(bk, bn) pair plus the f32 accumulator, independent of E.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_tiles: int, out_dtype):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0],
        w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == k_tiles - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(out_dtype)


def grouped_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bk: int = 512,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(E, cap, d_in) x (E, d_in, d_out) -> (E, cap, d_out)."""
    e, cap, k = x.shape
    e2, k2, n = w.shape
    assert e == e2 and k == k2, (x.shape, w.shape)
    bm, bk, bn = min(bm, cap), min(bk, k), min(bn, n)
    assert cap % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, w.shape, (bm, bk, bn))
    grid = (e, cap // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, k_tiles=k // bk, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ee, i, j, kk: (ee, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda ee, i, j, kk: (ee, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ee, i, j, kk: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cap, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
