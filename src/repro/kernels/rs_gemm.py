"""Fused GEMM-ReduceScatter Pallas kernel — paper Algorithm 3 on TPU.

The paper's push-mode ReduceScatter: as soon as a tile of the producer
GEMM's output is ready, it is one-sided-pushed (putmem_signal) to the rank
that owns that output block; each rank then locally reduces the W partial
tiles that landed in its symmetric workspace after signal_wait.

On TPU, one kernel per rank plays both roles: per ring step s it computes
the partial block destined for rank (me - s - 1) % W (the Alg. 3 swizzle
order, peers first, own block last), pushes it with a remote DMA whose
recv semaphore is the arrival signal, and finally reduces its own W
arrived partials. Compute of step s+1 overlaps the DMA of step s.

Validated under ``pltpu.InterpretParams()`` (cross-device DMA emulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import _compat


def _rs_gemm_kernel(
    a_ref,  # (m, k_loc) ANY — my A shard (K sharded)
    b_ref,  # (k_loc, n) ANY — my B shard
    o_ref,  # (m_blk, n)  ANY — my reduced output block
    ws_ref,  # (W, m_blk, n) ANY — symmetric landing workspace
    a_vmem,  # (m_blk, k_loc) VMEM
    b_vmem,  # (k_loc, n) VMEM
    p_vmem,  # (m_blk, n) VMEM — partial tile
    local_sem,
    send_sem,
    recv_sem,
    *,
    axis: str,
    world: int,
    m_blk: int,
    out_dtype,
):
    me = lax.axis_index(axis)

    barrier = pltpu.get_barrier_semaphore()
    for off in range(1, world):
        pltpu.semaphore_signal(
            barrier, inc=1,
            device_id=(lax.rem(me + off, world),),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
    pltpu.semaphore_wait(barrier, world - 1)

    cb = pltpu.make_async_copy(b_ref, b_vmem, local_sem)
    cb.start()
    cb.wait()

    sends = []
    for s in range(world):
        # Alg. 3 swizzle: peers' blocks first, own block last
        blk = lax.rem(me - s - 1 + 2 * world, world)
        ca = pltpu.make_async_copy(
            a_ref.at[pl.ds(blk * m_blk, m_blk), :], a_vmem, local_sem
        )
        ca.start()
        ca.wait()
        p_vmem[...] = jnp.dot(
            a_vmem[...], b_vmem[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)
        if s == world - 1:
            # my own block: local copy into my slot of my workspace
            cl = pltpu.make_async_copy(p_vmem, ws_ref.at[me], local_sem)
            cl.start()
            cl.wait()
        else:
            # one-sided push + arrival signal to the owner (slot = me)
            send = pltpu.make_async_remote_copy(
                src_ref=p_vmem,
                dst_ref=ws_ref.at[me],
                send_sem=send_sem,
                recv_sem=recv_sem,
                device_id=(blk,),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
            send.start()
            # the next step's dot overlaps this DMA; drain before reusing
            # p_vmem (single partial buffer — correctness over depth here)
            send.wait_send()
            sends.append(send)

    # signal_wait for all W-1 remote partials, then local reduction
    for send in sends:
        send.wait_recv()
    acc = jnp.zeros((m_blk, o_ref.shape[1]), jnp.float32)
    for r in range(world):
        ct = pltpu.make_async_copy(ws_ref.at[r], p_vmem, local_sem)
        ct.start()
        ct.wait()
        acc = acc + p_vmem[...].astype(jnp.float32)
    p_vmem[...] = acc.astype(out_dtype)
    co = pltpu.make_async_copy(p_vmem, o_ref, local_sem)
    co.start()
    co.wait()


def rs_gemm(
    a_loc: jax.Array,  # (m, k_loc) — call inside shard_map, K sharded
    b_loc: jax.Array,  # (k_loc, n)
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 9,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused overlapped GEMM+ReduceScatter. Returns (m / world, n)."""
    m, k_loc = a_loc.shape
    _, n = b_loc.shape
    assert m % world == 0
    m_blk = m // world
    out_dtype = out_dtype or a_loc.dtype
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret and not _compat.PALLAS_REMOTE_INTERPRET:
        # no remote-DMA emulation in this jax's interpreter: same Alg. 3
        # schedule via the graph-level engine pipeline.
        from ..core import collective_matmul as cm

        return cm.matmul_rs(a_loc, b_loc, axis, mode="ring", out_dtype=out_dtype)
    interp = pltpu.InterpretParams() if interpret else False
    kernel = functools.partial(
        _rs_gemm_kernel, axis=axis, world=world, m_blk=m_blk, out_dtype=out_dtype
    )
    out, _ws = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_blk, n), out_dtype),
            jax.ShapeDtypeStruct((world, m_blk, n), out_dtype),  # workspace
        ],
        scratch_shapes=[
            pltpu.VMEM((m_blk, k_loc), a_loc.dtype),
            pltpu.VMEM((k_loc, n), b_loc.dtype),
            pltpu.VMEM((m_blk, n), out_dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
        interpret=interp,
    )(a_loc, b_loc)
    return out
