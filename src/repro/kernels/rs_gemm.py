"""Fused GEMM-ReduceScatter kernel — paper Algorithm 3 on the shmem
subsystem (``repro.shmem``).

The paper's push-mode ReduceScatter: as soon as a tile of the producer
GEMM's output is ready, it is one-sided-pushed (putmem_signal) to the rank
that owns that output block; each rank then locally reduces the W partial
tiles that landed in its symmetric workspace after signal_wait.

One kernel per rank plays both roles: per ring step s it computes the
partial block destined for rank (me - s - 1) % W (the Alg. 3 swizzle
order, peers first, own block last), pushes it with a one-sided put whose
recv signal is the arrival notification, and finally reduces its own W
arrived partials. Compute of step s+1 overlaps the DMA of step s.

Backends: ``pltpu`` (real TPU, Pallas body below) and ``emulated``
(host-side symmetric heaps — the same push/signal/reduce protocol
validated on CPU virtual devices; see ``shmem.emulated``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import shmem
from ..shmem import emulated as em


def _rs_gemm_kernel(
    a_ref,  # (m, k_loc) ANY — my A shard (K sharded)
    b_ref,  # (k_loc, n) ANY — my B shard
    o_ref,  # (m_blk, n)  ANY — my reduced output block
    ws_ref,  # (W, m_blk, n) ANY — symmetric landing workspace
    a_vmem,  # (m_blk, k_loc) VMEM
    b_vmem,  # (k_loc, n) VMEM
    p_vmem,  # (m_blk, n) VMEM — partial tile
    local_sem,
    send_sem,
    recv_sem,
    *,
    axis: str,
    world: int,
    m_blk: int,
    out_dtype,
):
    me = lax.axis_index(axis)

    shmem.tpu_backend.barrier_all(axis, world)

    cb = pltpu.make_async_copy(b_ref, b_vmem, local_sem)
    cb.start()
    cb.wait()

    sends = []
    for s in range(world):
        # Alg. 3 swizzle: peers' blocks first, own block last
        blk = lax.rem(me - s - 1 + 2 * world, world)
        ca = pltpu.make_async_copy(
            a_ref.at[pl.ds(blk * m_blk, m_blk), :], a_vmem, local_sem
        )
        ca.start()
        ca.wait()
        p_vmem[...] = jnp.dot(
            a_vmem[...], b_vmem[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)
        if s == world - 1:
            # my own block: local copy into my slot of my workspace
            cl = pltpu.make_async_copy(p_vmem, ws_ref.at[me], local_sem)
            cl.start()
            cl.wait()
        else:
            # one-sided push + arrival signal to the owner (slot = me)
            send = shmem.tpu_backend.putmem_signal_nbi(
                p_vmem, ws_ref.at[me], send_sem, recv_sem, blk, axis=axis
            )
            # the next step's dot overlaps this DMA; drain before reusing
            # p_vmem (single partial buffer — correctness over depth here)
            send.wait_send()
            sends.append(send)

    # signal_wait for all W-1 remote partials, then local reduction
    for send in sends:
        send.wait_recv()
    acc = jnp.zeros((m_blk, o_ref.shape[1]), jnp.float32)
    for r in range(world):
        ct = pltpu.make_async_copy(ws_ref.at[r], p_vmem, local_sem)
        ct.start()
        ct.wait()
        acc = acc + p_vmem[...].astype(jnp.float32)
    p_vmem[...] = acc.astype(out_dtype)
    co = pltpu.make_async_copy(p_vmem, o_ref, local_sem)
    co.start()
    co.wait()


def _rs_gemm_pltpu(a_loc, b_loc, *, axis, world, out_dtype, collective_id):
    m, k_loc = a_loc.shape
    _, n = b_loc.shape
    m_blk = m // world
    kernel = functools.partial(
        _rs_gemm_kernel, axis=axis, world=world, m_blk=m_blk, out_dtype=out_dtype
    )
    out, _ws = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_blk, n), out_dtype),
            jax.ShapeDtypeStruct((world, m_blk, n), out_dtype),  # workspace
        ],
        scratch_shapes=[
            pltpu.VMEM((m_blk, k_loc), a_loc.dtype),
            pltpu.VMEM((k_loc, n), b_loc.dtype),
            pltpu.VMEM((m_blk, n), out_dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
    )(a_loc, b_loc)
    return out


def _rs_gemm_emulated(a_loc, b_loc, *, axis, world, out_dtype, collective_id):
    """Alg. 3 push protocol on the emulated DMA engine: per-step put of
    the partial into the owner's workspace slot ``me`` (own block pushed
    to self at the last step, so all W slots land symmetrically), then
    one signal_wait for W arrivals and the local f32 reduction."""
    me = lax.axis_index(axis)
    m, k_loc = a_loc.shape
    n = b_loc.shape[1]
    m_blk = m // world

    ctx = em.ShmemCtx(axis, world, collective_id)
    ctx.barrier_all()
    for s in range(world):
        # Alg. 3 swizzle: peers' blocks first, own block last (blk == me)
        blk = lax.rem(me - s - 1 + 2 * world, world)
        a_b = lax.dynamic_slice(a_loc, (blk * m_blk, 0), (m_blk, k_loc))
        partial = jnp.dot(
            a_b, b_loc, preferred_element_type=jnp.float32
        ).astype(out_dtype)
        ctx.putmem_signal_nbi(partial, blk, buf="ws", slot=me, sig="recv")

    ctx.signal_wait_until(sig="recv", value=world)
    acc = jnp.zeros((m_blk, n), jnp.float32)
    for r in range(world):
        part = ctx.read_symmetric((m_blk, n), out_dtype, buf="ws", slot=r)
        acc = acc + part.astype(jnp.float32)
    ctx.barrier_all()
    return acc.astype(out_dtype)


def rs_gemm(
    a_loc: jax.Array,  # (m, k_loc) — call inside shard_map, K sharded
    b_loc: jax.Array,  # (k_loc, n)
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 9,
    backend: str | None = None,
) -> jax.Array:
    """Fused overlapped GEMM+ReduceScatter. Returns (m / world, n).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`)."""
    m, _ = a_loc.shape
    assert m % world == 0
    out_dtype = out_dtype or a_loc.dtype
    backend = backend or shmem.default_backend()
    impl = _rs_gemm_pltpu if backend == "pltpu" else _rs_gemm_emulated
    return impl(a_loc, b_loc, axis=axis, world=world, out_dtype=out_dtype,
                collective_id=collective_id)
