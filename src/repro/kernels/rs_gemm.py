"""Fused GEMM-ReduceScatter kernel — paper Algorithm 3, declared over the
shmem tile executor (``repro.shmem.executor``).

The push protocol (partials one-sided-pushed to their owner's symmetric
slot as they retire, signal_wait + local f32 reduction at the end) lives
in the executor; this op contributes only the tile compute — the
per-block dot. Two transports:

  ring      the executor's ``push_rs``: Alg. 3 swizzle order (peers
            first, own block last), compute of step s+1 overlapping the
            DMA of step s.
  one_shot  the executor's ``one_shot_rs`` (low-latency variant): all W
            partials computed first, all puts issued up-front with
            distinct ring offsets — no serial compute/DMA dependency,
            latency-optimal for small blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..shmem import executor

_PROTO = {"ring": "push_rs", "one_shot": "one_shot_rs"}


def rs_gemm(
    a_loc: jax.Array,  # (m, k_loc) — call inside shard_map, K sharded
    b_loc: jax.Array,  # (k_loc, n)
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 9,
    backend: str | None = None,
    transport: str = "ring",
) -> jax.Array:
    """Fused overlapped GEMM+ReduceScatter. Returns (m / world, n).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`). ``transport`` picks the
    push protocol ("ring" = Alg. 3, "one_shot" = all puts up-front)."""
    assert a_loc.shape[0] % world == 0, (a_loc.shape, world)

    def tile(a_blk, b):
        return jnp.dot(a_blk, b, preferred_element_type=jnp.float32)

    return executor.run(
        _PROTO[transport], tile, a_loc, (b_loc,), axis=axis, world=world,
        out_dtype=out_dtype or a_loc.dtype, collective_id=collective_id,
        backend=backend)
