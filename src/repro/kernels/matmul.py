"""Tiled GEMM Pallas kernel with swizzled grid order (paper §3.7).

TPU mapping: BlockSpec tiles staged HBM->VMEM by the Pallas pipeline; the
MXU consumes (bm, bk) x (bk, bn) blocks; accumulation in an f32 VMEM
scratch across the sequential K grid dimension.

The swizzle: when this GEMM consumes an in-flight AllGather (rank/world
set), the M-tile traversal starts at this rank's own chunk and proceeds in
ring-arrival order — ``schedules.ring_ag_order`` — so no tile ever waits on
data that has not arrived (Fig. 7). The swizzle is an index_map transform:
grid position i maps to physical tile ((i + rank * tiles_per_chunk) %
m_tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_tiles: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_tiles - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    out_dtype=jnp.float32,
    rank: int = 0,
    world: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B. A: (M, K), B: (K, N). Shapes must divide the block sizes
    (ops.py pads). ``rank``/``world`` activate the AG-arrival swizzle."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (a.shape, b.shape, (bm, bk, bn))
    m_tiles, k_tiles, n_tiles = m // bm, k // bk, n // bn

    if world > 1:
        assert m_tiles % world == 0, (m_tiles, world)
        per_chunk = m_tiles // world
        offset = rank * per_chunk

        def m_index(i):
            # ring-arrival swizzle: start at own chunk, walk backwards
            # through arrival order (owner r-s has tiles at (r-s)*per_chunk)
            return jax.lax.rem(i + offset, m_tiles)

    else:

        def m_index(i):
            return i

    grid = (m_tiles, n_tiles, k_tiles)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=k_tiles, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (m_index(i), kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (m_index(i), j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
