"""Low-latency one-shot AllGather kernel — paper Algorithm 4 on the
shmem subsystem (``repro.shmem``).

The GPU original combines an NVLink multimem broadcast with the NCCL LL
(flag-in-word) protocol. Neither exists on TPU — and neither is needed:
ICI remote DMAs carry hardware arrival semaphores. What DOES transfer is
the *structure* that makes Alg. 4 fast: every transfer is issued up-front
with no serial ring dependency, so the total latency is one propagation
delay plus the skew, not W-1 hops. Message latency is what matters here
(decode-time AllGather of per-rank partials), not bandwidth.

Each rank one-sided-puts its shard into every peer's output block `me`
(the broadcast_put / multimem_st analogue), then waits for W-1 arrival
signals.

Backends: ``pltpu`` (real TPU, Pallas body below) and ``emulated``
(host-side symmetric heaps; the same all-puts-up-front + signal_wait
structure on CPU virtual devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import shmem
from ..shmem import emulated as em


def _ll_ag_kernel(
    x_ref,  # (m_loc, n) ANY
    o_ref,  # (m_loc*W, n) ANY
    local_sem,
    send_sem,
    recv_sem,
    *,
    axis: str,
    world: int,
    m_loc: int,
):
    me = lax.axis_index(axis)

    shmem.tpu_backend.barrier_all(axis, world)

    # Local copy into my own block.
    lc = pltpu.make_async_copy(x_ref, o_ref.at[pl.ds(me * m_loc, m_loc), :], local_sem)
    lc.start()

    # One-shot: all W-1 puts issued before any wait (Alg. 4 line 11-18
    # structure — no skew accumulation from a serial loop). This is
    # broadcast_put with each DMA kept for the explicit arrival waits.
    sends = []
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        sends.append(
            pltpu.make_async_remote_copy(
                src_ref=x_ref,
                dst_ref=o_ref.at[pl.ds(me * m_loc, m_loc), :],
                send_sem=send_sem,
                recv_sem=recv_sem,
                device_id=(peer,),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
        )
    for s in sends:
        s.start()
    lc.wait()
    # SPMD symmetry: my W-1 incoming messages are my peers' sends with the
    # same shape/semaphore, so waiting my own descriptors consumes exactly
    # the right signal count (send-drain + W-1 arrivals).
    shmem.tpu_backend.quiet(*sends)


def _ll_allgather_pltpu(x, *, axis, world, collective_id):
    m_loc, n = x.shape
    kernel = functools.partial(_ll_ag_kernel, axis=axis, world=world, m_loc=m_loc)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((m_loc * world, n), x.dtype),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
    )(x)


def _ll_allgather_emulated(x, *, axis, world, collective_id):
    """Alg. 4 structure on the emulated DMA engine: broadcast_put my
    shard into every PE's slot ``me`` (self included, so all W slots
    exist symmetrically), one signal_wait for all W arrivals, then
    assemble the gathered output from the W landed slots."""
    m_loc, n = x.shape

    ctx = em.ShmemCtx(axis, world, collective_id)
    ctx.barrier_all()
    ctx.broadcast_put(x, buf="ws", sig="recv")
    ctx.signal_wait_until(sig="recv", value=world)
    out = jnp.zeros((m_loc * world, n), x.dtype)
    for r in range(world):
        shard = ctx.read_symmetric((m_loc, n), x.dtype, buf="ws", slot=r)
        out = lax.dynamic_update_slice(out, shard, (r * m_loc, 0))
    ctx.barrier_all()
    return out


def ll_allgather(
    x: jax.Array,  # (m_loc, n) — call inside shard_map, sharded on dim 0
    *,
    axis: str,
    world: int,
    collective_id: int = 11,
    backend: str | None = None,
) -> jax.Array:
    """One-shot AllGather. Returns (m_loc * world, n).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`)."""
    backend = backend or shmem.default_backend()
    impl = _ll_allgather_pltpu if backend == "pltpu" else _ll_allgather_emulated
    return impl(x, axis=axis, world=world, collective_id=collective_id)
