"""Low-latency one-shot AllGather kernel — paper Algorithm 4, declared
over the shmem tile executor (``repro.shmem.executor``).

The GPU original combines an NVLink multimem broadcast with the NCCL LL
(flag-in-word) protocol; on TPU the remote DMAs carry hardware arrival
semaphores, so what transfers is the *structure* that makes Alg. 4 fast:
every put issued up-front with no serial ring dependency. That structure
is the executor's ``one_shot_ag`` protocol; with no tile compute
(``tile=None``) it IS this kernel.
"""
from __future__ import annotations

import jax

from ..shmem import executor


def ll_allgather(
    x: jax.Array,  # (m_loc, n) — call inside shard_map, sharded on dim 0
    *,
    axis: str,
    world: int,
    collective_id: int = 11,
    backend: str | None = None,
) -> jax.Array:
    """One-shot AllGather. Returns (m_loc * world, n).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`)."""
    return executor.run(
        "one_shot_ag", None, x, (), axis=axis, world=world,
        collective_id=collective_id, backend=backend)
