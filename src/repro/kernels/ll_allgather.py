"""Low-latency one-shot AllGather Pallas kernel — paper Algorithm 4 on TPU.

The GPU original combines an NVLink multimem broadcast with the NCCL LL
(flag-in-word) protocol. Neither exists on TPU — and neither is needed:
ICI remote DMAs carry hardware arrival semaphores. What DOES transfer is
the *structure* that makes Alg. 4 fast: every transfer is issued up-front
with no serial ring dependency, so the total latency is one propagation
delay plus the skew, not W-1 hops. Message latency is what matters here
(decode-time AllGather of per-rank partials), not bandwidth.

Each rank one-sided-puts its shard into every peer's output block `me`
(the broadcast_put / multimem_st analogue), then waits for W-1 arrival
signals. ``hierarchical=True`` splits the put loop into intra-pod peers
first and cross-pod peers second on a 2-level axis pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import _compat


def _ll_ag_kernel(
    x_ref,  # (m_loc, n) ANY
    o_ref,  # (m_loc*W, n) ANY
    local_sem,
    send_sem,
    recv_sem,
    *,
    axis: str,
    world: int,
    m_loc: int,
):
    me = lax.axis_index(axis)

    barrier = pltpu.get_barrier_semaphore()
    for off in range(1, world):
        pltpu.semaphore_signal(
            barrier,
            inc=1,
            device_id=(lax.rem(me + off, world),),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
    pltpu.semaphore_wait(barrier, world - 1)

    # Local copy into my own block.
    lc = pltpu.make_async_copy(x_ref, o_ref.at[pl.ds(me * m_loc, m_loc), :], local_sem)
    lc.start()

    # One-shot: all W-1 puts issued before any wait (Alg. 4 line 11-18
    # structure — no skew accumulation from a serial loop).
    sends = []
    for off in range(1, world):
        peer = lax.rem(me + off, world)
        sends.append(
            pltpu.make_async_remote_copy(
                src_ref=x_ref,
                dst_ref=o_ref.at[pl.ds(me * m_loc, m_loc), :],
                send_sem=send_sem,
                recv_sem=recv_sem,
                device_id=(peer,),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
        )
    for s in sends:
        s.start()
    lc.wait()
    # SPMD symmetry: my W-1 incoming messages are my peers' sends with the
    # same shape/semaphore, so waiting my own descriptors consumes exactly
    # the right signal count (send-drain + W-1 arrivals).
    for s in sends:
        s.wait()


def ll_allgather(
    x: jax.Array,  # (m_loc, n) — call inside shard_map, sharded on dim 0
    *,
    axis: str,
    world: int,
    collective_id: int = 11,
    interpret: bool | None = None,
) -> jax.Array:
    """One-shot AllGather. Returns (m_loc * world, n)."""
    m_loc, n = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret and not _compat.PALLAS_REMOTE_INTERPRET:
        # no remote-DMA emulation in this jax's interpreter: same one-shot
        # structure via the graph-level engine pipeline.
        from ..core import overlap as ov

        return ov.gather_pipeline(x, axis, transport="one_shot")
    interp = pltpu.InterpretParams() if interpret else False
    kernel = functools.partial(_ll_ag_kernel, axis=axis, world=world, m_loc=m_loc)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((m_loc * world, n), x.dtype),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
        interpret=interp,
    )(x)
