"""Pallas TPU kernels (TARGET: pl.pallas_call + BlockSpec VMEM tiling;
validated in interpret mode on CPU against the pure-jnp oracles in ref.py;
ops.py holds the jit'd dispatch wrappers)."""
from . import (
    ag_gemm,
    flash_attention,
    flash_decode,
    grouped_matmul,
    ll_allgather,
    matmul,
    ops,
    ref,
    rs_gemm,
    ssd_scan,
)

__all__ = [
    "ag_gemm", "flash_attention", "flash_decode", "grouped_matmul",
    "ll_allgather", "matmul", "ops", "ref", "rs_gemm", "ssd_scan",
]
