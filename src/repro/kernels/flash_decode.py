"""Flash-decode Pallas kernel: one new token vs. a long KV cache shard.

Bandwidth-bound (the paper's Fig. 15 workload): the kernel's job is to
stream K/V tiles from HBM once at full bandwidth while maintaining the
online softmax. Emits BOTH the un-normalized-combinable output ``o`` and
the log-sum-exp ``lse`` so the *distributed* flash decode
(core/flash_decode.py) can merge partials from sequence-parallel KV shards
with the low-latency AllGather — exactly the paper's FlashDecode+AG.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    bkv: int,
    kv_tiles: int,
):
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (1, d) — one token
    k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (1, bkv)
    valid = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1) < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.exp(s - m_new[:, :1])
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=-1, keepdims=True), l_ref.shape
    )
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ikv == kv_tiles - 1)
    def _done():
        l_fin = l_ref[:, :1]
        o_ref[0, 0] = acc_ref[...] / l_fin
        lse_ref[0, 0, 0] = m_ref[0, 0] + jnp.log(l_fin[0, 0])


def flash_decode(
    q: jax.Array,  # (B, Hq, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    length: jax.Array,  # (B,) int32 valid KV length
    *,
    scale: float | None = None,
    bkv: int = 512,
    interpret: bool = False,
):
    """Returns (o, lse): o (B, Hq, D) f32, lse (B, Hq) f32."""
    b, hq, d = q.shape
    _, hkv, s_len, _ = k.shape
    group = hq // hkv
    bkv = min(bkv, s_len)
    assert s_len % bkv == 0, (s_len, bkv)
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    kv_tiles = s_len // bkv
    grid = (b, hq, kv_tiles)
    kernel = functools.partial(
        _decode_kernel, scale=scale, bkv=bkv, kv_tiles=kv_tiles
    )
    q4 = q[:, :, None, :]  # (B, Hq, 1, D)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, ikv: (bb,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda bb, h, ikv: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ikv: (bb, h // group, ikv, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ikv: (bb, h // group, ikv, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, h, ikv: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, h, ikv: (bb, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(length, q4, k, v)
    return o[:, :, 0, :], lse[:, :, 0]
