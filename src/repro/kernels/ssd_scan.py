"""Mamba2 SSD chunked scan — Pallas TPU kernel.

State-space duality: within a chunk of length C the output is a masked
(C, C) matmul (MXU work); across chunks a (P, S) state is carried in VMEM
scratch through the sequential chunk grid dimension. This is the
TPU-native blocking of SSD: chunk = MXU tile, state = VMEM-resident,
HBM traffic = one pass over x/dt/B/C.

Recurrence (per head):
  S_t = exp(dt_t * a) * S_{t-1} + dt_t * x_t (x) B_t
  y_t = S_t . C_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, C, 1, P)
    dt_ref,  # (1, C, 1)
    a_ref,  # (1,) SMEM
    b_ref,  # (1, C, 1, S)
    c_ref,  # (1, C, 1, S)
    y_ref,  # (1, C, 1, P)
    state_out_ref,  # (1, 1, P, S)
    state_ref,  # VMEM (P, S) f32
    *,
    n_chunks: int,
    chunk: int,
    out_dtype,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x_c = x_ref[0, :, 0, :].astype(jnp.float32)  # (C, P)
    dt_c = dt_ref[0, :, 0].astype(jnp.float32)  # (C,)
    a_h = a_ref[0]
    b_c = b_ref[0, :, 0, :].astype(jnp.float32)  # (C, S)
    c_c = c_ref[0, :, 0, :].astype(jnp.float32)  # (C, S)

    log_decay = dt_c * a_h  # (C,) negative
    cum = jnp.cumsum(log_decay)  # inclusive L_t

    # ---- intra-chunk: y[t] += sum_{u<=t} exp(L_t - L_u) (C_t.B_u) dt_u x_u
    cb = jax.lax.dot_general(
        c_c, b_c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(rows >= cols, cb * decay, 0.0) * dt_c[None, :]
    y = jax.lax.dot_general(
        gate, x_c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)

    # ---- inter-chunk: y[t] += exp(L_t) * C_t . S_prev
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c_c, state_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, S) . (P, S)^T -> (C, P)

    # ---- state update: S <- exp(L_C) S + sum_u exp(L_C - L_u) dt_u x_u (x) B_u
    w = jnp.exp(cum[-1] - cum) * dt_c  # (C,)
    state_ref[...] = jnp.exp(cum[-1]) * state_ref[...] + jax.lax.dot_general(
        x_c, b_c * w[:, None], (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, S)

    y_ref[0, :, 0, :] = y.astype(out_dtype)

    @pl.when(ic == n_chunks - 1)
    def _done():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H)
    a: jax.Array,  # (H,)
    b_mat: jax.Array,  # (B, L, G, S)
    c_mat: jax.Array,  # (B, L, G, S)
    *,
    chunk: int = 128,
    out_dtype=None,
    interpret: bool = False,
):
    """Returns (y, final_state): y (B, L, H, P), state (B, H, P, S) f32."""
    bsz, seqlen, h, p = x.shape
    g, s = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, seqlen)
    assert seqlen % chunk == 0, (seqlen, chunk)
    n_chunks = seqlen // chunk
    out_dtype = out_dtype or x.dtype
    grid = (bsz, h, n_chunks)
    kernel = functools.partial(
        _ssd_kernel, n_chunks=n_chunks, chunk=chunk, out_dtype=out_dtype
    )
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, ic: (bb, ic, hh)),
            pl.BlockSpec((1,), lambda bb, hh, ic: (hh,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, 1, s), lambda bb, hh, ic: (bb, ic, hh // rep, 0)),
            pl.BlockSpec((1, chunk, 1, s), lambda bb, hh, ic: (bb, ic, hh // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, 1, p, s), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, seqlen, h, p), out_dtype),
            jax.ShapeDtypeStruct((bsz, h, p, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, state
