"""Fused AllGather-GEMM Pallas kernel — the paper's Figure 4, on TPU.

One kernel per rank plays BOTH roles of the paper's producer/consumer
pair (on TPU the async-task split is the DMA engines vs. the MXU, not
threadblocks vs. threadblocks):

  producer  — push my current chunk to the right neighbor's symmetric
              workspace with ``putmem_signal`` (remote DMA; the recv
              semaphore is the arrival signal);
  consumer  — ``signal_wait`` for the chunk of step s (= data of rank
              (me - s) % W, the Fig. 7 swizzle), stage it HBM->VMEM, run
              the MXU dot, and write the output strip.

Flow control is the paper's signal-exchange protocol: a credit semaphore
grants the left neighbor permission to overwrite a workspace slot only
after the slot has been consumed (double buffering => 1 initial credit +
one per consumed slot). The DMA of chunk s+1 is in flight while the dot
of chunk s executes — this is the overlap.

Validated on CPU via ``pltpu.InterpretParams()`` under shard_map (the
interpreter emulates cross-device DMAs + semaphores). On real TPU the
same code lowers to Mosaic with ICI remote DMAs.

Scale note: refs are whole-shard (VMEM-resident per step). For production
shapes, wrap the dot in ``pltpu.emit_pipeline`` to tile (bm, bk, bn)
within each chunk; the signal protocol is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import _compat


def _ag_gemm_kernel(
    a_ref,  # (m_loc, k)  ANY — my A shard
    b_ref,  # (k, n_loc)  ANY — my B shard
    o_ref,  # (m_loc*W, n_loc) ANY — my C strip
    ws_ref,  # (2, m_loc, k) ANY — symmetric ring workspace (double buffer);
    #          declared as an extra kernel output so the interpreter and
    #          Mosaic both give it a stable cross-device (symmetric) address
    a_vmem,  # (m_loc, k) VMEM
    b_vmem,  # (k, n_loc) VMEM
    o_vmem,  # (m_loc, n_loc) VMEM
    local_sem,  # DMA
    send_sem,  # DMA
    recv_sem,  # DMA
    cap_sem,  # REGULAR — slot credits granted to my left neighbor
    *,
    axis: str,
    world: int,
    m_loc: int,
    out_dtype,
):
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    # Symmetric-memory handshake: every rank's workspace must exist before
    # any one-sided put lands in it (paper: barrier_all after allocation).
    barrier = pltpu.get_barrier_semaphore()
    for off in range(1, world):
        pltpu.semaphore_signal(
            barrier,
            inc=1,
            device_id=(lax.rem(me + off, world),),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
    pltpu.semaphore_wait(barrier, world - 1)

    # Stage my B shard into VMEM once; copy my A chunk into ring slot 0.
    cb = pltpu.make_async_copy(b_ref, b_vmem, local_sem)
    cb.start()
    c0 = pltpu.make_async_copy(a_ref, ws_ref.at[0], local_sem)
    c0.start()
    cb.wait()
    c0.wait()

    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    pltpu.semaphore_signal(
        cap_sem, inc=1, device_id=(left,), device_id_type=pltpu.DeviceIdType.MESH
    )

    for s in range(world):
        slot = s % 2
        send = None
        if s != world - 1:
            # producer: wait for a free slot at the right neighbor, then
            # putmem_signal my current chunk into their next slot.
            pltpu.semaphore_wait(cap_sem, 1)
            send = pltpu.make_async_remote_copy(
                src_ref=ws_ref.at[slot],
                dst_ref=ws_ref.at[(s + 1) % 2],
                send_sem=send_sem,
                recv_sem=recv_sem,
                device_id=(right,),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
            send.start()

        # consumer: chunk of step s is rank (me - s)'s data. For s>0 its
        # arrival is ordered by recv_sem via the previous step's wait.
        ca = pltpu.make_async_copy(ws_ref.at[slot], a_vmem, local_sem)
        ca.start()
        ca.wait()

        # The MXU dot overlaps the in-flight remote DMA of chunk s+1.
        o_vmem[...] = jnp.dot(
            a_vmem[...], b_vmem[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        co = pltpu.make_async_copy(
            o_vmem, o_ref.at[pl.ds(owner * m_loc, m_loc), :], local_sem
        )
        co.start()
        co.wait()

        if send is not None:
            # wait: my send drained + my incoming chunk (from the left
            # neighbor's symmetric send) has landed in slot (s+1)%2.
            send.wait()
        # Slot fully consumed — BOTH readers are done: the HBM->VMEM copy
        # AND my outgoing remote DMA (send.wait() above). Only now may the
        # left neighbor overwrite it; granting after the vmem copy alone
        # races the in-flight outgoing read (one-sided put corruption).
        # Skip grants that would exceed the W-1 sends the neighbor makes.
        if s < world - 2:
            pltpu.semaphore_signal(
                cap_sem, inc=1, device_id=(left,), device_id_type=pltpu.DeviceIdType.MESH
            )


def ag_gemm(
    a_blk: jax.Array,  # (m_loc, k) — call inside shard_map, sharded on M
    b_loc: jax.Array,  # (k, n_loc) — sharded on N
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 7,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused overlapped AllGather-GEMM. Returns (m_loc * world, n_loc)."""
    m_loc, k = a_blk.shape
    _, n_loc = b_loc.shape
    out_dtype = out_dtype or a_blk.dtype
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret and not _compat.PALLAS_REMOTE_INTERPRET:
        # This jax's Pallas interpreter cannot emulate remote DMAs /
        # signals; validate the same ring schedule through the graph-level
        # engine pipeline instead.
        from ..core import collective_matmul as cm

        return cm.ag_matmul(a_blk, b_loc, axis, mode="ring", out_dtype=out_dtype)
    interp = pltpu.InterpretParams() if interpret else False
    kernel = functools.partial(
        _ag_gemm_kernel,
        axis=axis,
        world=world,
        m_loc=m_loc,
        out_dtype=out_dtype,
    )
    out, _ws = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_loc * world, n_loc), out_dtype),
            jax.ShapeDtypeStruct((2, m_loc, k), a_blk.dtype),  # ring workspace
        ],
        scratch_shapes=[
            pltpu.VMEM((m_loc, k), a_blk.dtype),
            pltpu.VMEM((k, n_loc), b_loc.dtype),
            pltpu.VMEM((m_loc, n_loc), out_dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.REGULAR,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
        interpret=interp,
    )(a_blk, b_loc)
    return out
