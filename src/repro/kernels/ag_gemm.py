"""Fused AllGather-GEMM kernel — the paper's Figure 4, declared over the
shmem tile executor (``repro.shmem.executor``).

The producer/consumer ring, the credit flow control, the double-buffered
symmetric workspace and the barrier handshake all live in the executor's
``ring_ag`` protocol; this op is just its tile compute — the per-chunk
dot whose MXU time overlaps the in-flight remote DMA of the next chunk.
Both backends (pltpu remote DMAs on TPU, the emulated DMA engine on CPU)
come with the protocol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..shmem import executor


def ag_gemm(
    a_blk: jax.Array,  # (m_loc, k) — call inside shard_map, sharded on M
    b_loc: jax.Array,  # (k, n_loc) — sharded on N
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 7,
    backend: str | None = None,
) -> jax.Array:
    """Fused overlapped AllGather-GEMM. Returns (m_loc * world, n_loc).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`)."""

    def tile(a_chunk, b):
        return jnp.dot(a_chunk, b, preferred_element_type=jnp.float32)

    return executor.run(
        "ring_ag", tile, a_blk, (b_loc,), axis=axis, world=world,
        out_dtype=out_dtype or a_blk.dtype, collective_id=collective_id,
        backend=backend)
