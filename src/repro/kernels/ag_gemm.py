"""Fused AllGather-GEMM kernel — the paper's Figure 4, on the shmem
subsystem (``repro.shmem``).

One kernel per rank plays BOTH roles of the paper's producer/consumer
pair (on TPU the async-task split is the DMA engines vs. the MXU, not
threadblocks vs. threadblocks):

  producer  — push my current chunk to the right neighbor's symmetric
              workspace with ``putmem_signal`` (remote DMA; the recv
              semaphore is the arrival signal);
  consumer  — ``signal_wait`` for the chunk of step s (= data of rank
              (me - s) % W, the Fig. 7 swizzle), stage it, run the dot,
              and write the output strip.

Flow control is the paper's signal-exchange protocol: a credit semaphore
grants the left neighbor permission to overwrite a workspace slot only
after the slot has been consumed (double buffering => 1 initial credit +
one per consumed slot). The DMA of chunk s+1 is in flight while the dot
of chunk s executes — this is the overlap.

Backends (``repro.shmem.default_backend``):
  pltpu     real TPU: the Pallas kernel body below, remote DMAs on ICI.
  emulated  CPU / virtual devices: the SAME ring + credit protocol
            executed against host-side symmetric heaps and signal slots
            (``shmem.emulated``) — every put, arrival signal, credit and
            barrier runs with true concurrency semantics, so the kernel
            logic is validated without hardware.

Scale note (pltpu): refs are whole-shard (VMEM-resident per step). For
production shapes, wrap the dot in ``pltpu.emit_pipeline`` to tile
(bm, bk, bn) within each chunk; the signal protocol is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import shmem
from ..shmem import emulated as em


def _ag_gemm_kernel(
    a_ref,  # (m_loc, k)  ANY — my A shard
    b_ref,  # (k, n_loc)  ANY — my B shard
    o_ref,  # (m_loc*W, n_loc) ANY — my C strip
    ws_ref,  # (2, m_loc, k) ANY — symmetric ring workspace (double buffer);
    #          declared as an extra kernel output so the interpreter and
    #          Mosaic both give it a stable cross-device (symmetric) address
    a_vmem,  # (m_loc, k) VMEM
    b_vmem,  # (k, n_loc) VMEM
    o_vmem,  # (m_loc, n_loc) VMEM
    local_sem,  # DMA
    send_sem,  # DMA
    recv_sem,  # DMA
    cap_sem,  # REGULAR — slot credits granted to my left neighbor
    *,
    axis: str,
    world: int,
    m_loc: int,
    out_dtype,
):
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)

    # Symmetric-memory handshake: every rank's workspace must exist before
    # any one-sided put lands in it (paper: barrier_all after allocation).
    shmem.tpu_backend.barrier_all(axis, world)

    # Stage my B shard into VMEM once; copy my A chunk into ring slot 0.
    cb = pltpu.make_async_copy(b_ref, b_vmem, local_sem)
    cb.start()
    c0 = pltpu.make_async_copy(a_ref, ws_ref.at[0], local_sem)
    c0.start()
    cb.wait()
    c0.wait()

    # Initially my right neighbor's slot 1 is free: grant 1 credit.
    shmem.tpu_backend.signal_op(cap_sem, left, axis=axis)

    for s in range(world):
        slot = s % 2
        send = None
        if s != world - 1:
            # producer: wait for a free slot at the right neighbor, then
            # putmem_signal my current chunk into their next slot.
            shmem.tpu_backend.signal_wait_until(cap_sem, 1)
            send = shmem.tpu_backend.putmem_signal_nbi(
                ws_ref.at[slot],
                ws_ref.at[(s + 1) % 2],
                send_sem,
                recv_sem,
                right,
                axis=axis,
            )

        # consumer: chunk of step s is rank (me - s)'s data. For s>0 its
        # arrival is ordered by recv_sem via the previous step's wait.
        ca = pltpu.make_async_copy(ws_ref.at[slot], a_vmem, local_sem)
        ca.start()
        ca.wait()

        # The MXU dot overlaps the in-flight remote DMA of chunk s+1.
        o_vmem[...] = jnp.dot(
            a_vmem[...], b_vmem[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        co = pltpu.make_async_copy(
            o_vmem, o_ref.at[pl.ds(owner * m_loc, m_loc), :], local_sem
        )
        co.start()
        co.wait()

        if send is not None:
            # wait: my send drained + my incoming chunk (from the left
            # neighbor's symmetric send) has landed in slot (s+1)%2.
            send.wait()
        # Slot fully consumed — BOTH readers are done: the HBM->VMEM copy
        # AND my outgoing remote DMA (send.wait() above). Only now may the
        # left neighbor overwrite it; granting after the vmem copy alone
        # races the in-flight outgoing read (one-sided put corruption).
        # Skip grants that would exceed the W-1 sends the neighbor makes.
        if s < world - 2:
            shmem.tpu_backend.signal_op(cap_sem, left, axis=axis)


def _ag_gemm_pltpu(a_blk, b_loc, *, axis, world, out_dtype, collective_id):
    m_loc, k = a_blk.shape
    _, n_loc = b_loc.shape
    kernel = functools.partial(
        _ag_gemm_kernel,
        axis=axis,
        world=world,
        m_loc=m_loc,
        out_dtype=out_dtype,
    )
    out, _ws = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_loc * world, n_loc), out_dtype),
            jax.ShapeDtypeStruct((2, m_loc, k), a_blk.dtype),  # ring workspace
        ],
        scratch_shapes=[
            pltpu.VMEM((m_loc, k), a_blk.dtype),
            pltpu.VMEM((k, n_loc), b_loc.dtype),
            pltpu.VMEM((m_loc, n_loc), out_dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.REGULAR,
        ],
        compiler_params=pltpu.CompilerParams(collective_id=collective_id),
    )(a_blk, b_loc)
    return out


def _ag_gemm_emulated(a_blk, b_loc, *, axis, world, out_dtype, collective_id):
    """The same producer/consumer ring + credit protocol on the emulated
    DMA engine: slot parity, initial credit, grant-after-consume and the
    skip of the final grants mirror the Pallas body line for line."""
    me = lax.axis_index(axis)
    left = lax.rem(me + world - 1, world)
    right = lax.rem(me + 1, world)
    m_loc, k = a_blk.shape
    n_loc = b_loc.shape[1]

    ctx = em.ShmemCtx(axis, world, collective_id)
    ctx.barrier_all()
    ctx.signal_op(left, sig="cap")

    cur = a_blk
    out = jnp.zeros((m_loc * world, n_loc), out_dtype)
    for s in range(world):
        if s != world - 1:
            ctx.signal_wait_until(sig="cap", value=1)
            ctx.putmem_signal_nbi(cur, right, buf="ws", slot=(s + 1) % 2,
                                  sig="recv")
        partial = jnp.dot(
            cur, b_loc, preferred_element_type=jnp.float32
        ).astype(out_dtype)
        owner = lax.rem(me - s + world, world)
        out = lax.dynamic_update_slice(out, partial, (owner * m_loc, 0))
        if s != world - 1:
            cur = ctx.wait_read((m_loc, k), a_blk.dtype, buf="ws",
                                slot=(s + 1) % 2, sig="recv")
            if s < world - 2:
                ctx.signal_op(left, sig="cap")
    ctx.barrier_all()
    return out


def ag_gemm(
    a_blk: jax.Array,  # (m_loc, k) — call inside shard_map, sharded on M
    b_loc: jax.Array,  # (k, n_loc) — sharded on N
    *,
    axis: str,
    world: int,
    out_dtype=None,
    collective_id: int = 7,
    backend: str | None = None,
) -> jax.Array:
    """Fused overlapped AllGather-GEMM. Returns (m_loc * world, n_loc).

    ``backend`` is a shmem backend name ("pltpu" | "emulated"); default
    picks per platform (`shmem.default_backend`)."""
    out_dtype = out_dtype or a_blk.dtype
    backend = backend or shmem.default_backend()
    impl = _ag_gemm_pltpu if backend == "pltpu" else _ag_gemm_emulated
    return impl(a_blk, b_loc, axis=axis, world=world, out_dtype=out_dtype,
                collective_id=collective_id)
