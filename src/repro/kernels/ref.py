"""Pure-jnp oracles for every Pallas kernel (the ref side of the
kernel-vs-ref allclose sweeps). No Pallas, no collectives — just math."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """x: (E, cap, d_in), w: (E, d_in, d_out) -> (E, cap, d_out).

    No operand casts: bf16 inputs feed the dot directly with f32
    accumulation (an .astype(f32) here would materialize an f32 copy of
    every expert weight — gigabytes for large MoEs)."""
    return jnp.einsum(
        "ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if causal:
        lk = k.shape[2]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def flash_attention_chunked(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention scanned over KV chunks — O(Lq * chunk)
    live memory instead of O(Lq * Lk). The production XLA path for long
    sequences (the Pallas kernel is the TPU fast path)."""
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kv_chunk = min(kv_chunk, lk)
    if lk % kv_chunk != 0:
        return flash_attention(q, k, v, causal=causal, scale=scale)
    n_chunks = lk // kv_chunk

    qf = q.astype(jnp.float32) * scale
    ks = jnp.moveaxis(k.reshape(b, hkv, n_chunks, kv_chunk, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, hkv, n_chunks, kv_chunk, d), 2, 0)
    rows = jnp.arange(lq)[:, None]

    @jax.checkpoint  # don't save per-chunk probability residuals — the
    def step(carry, inp):  # backward recomputes each chunk from (q, kc, vc)
        m, l, acc = carry
        idx, kc, vc = inp  # kc: (B, Hkv, C, D)
        kcr = jnp.repeat(kc.astype(jnp.float32), group, axis=1)
        vcr = jnp.repeat(vc.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kcr)
        if causal:
            cols = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
            s = jnp.where(rows + (lk - lq) >= cols, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vcr)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, lq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, lq), jnp.float32)
    a0 = jnp.zeros((b, hq, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(n_chunks), ks, vs))
    return (acc / l[..., None]).astype(q.dtype)


def flash_decode(
    q: jax.Array,  # (B, Hq, D) — one new token
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    scale: float | None = None,
    length: jax.Array | None = None,  # (B,) valid KV length per sequence
):
    """Returns (o, lse): o (B, Hq, D) fp32, lse (B, Hq) fp32.

    lse is the log-sum-exp of the attention logits — the quantity the
    distributed flash-decode combine needs to merge partial results from
    KV shards (paper §4.2 FlashDecode+AG).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if length is not None:
        mask = jnp.arange(s)[None, None, :] < length[:, None, None]
        logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhs,bhsd->bhd", p / l, vv.astype(jnp.float32))
    lse = (m + jnp.log(l))[..., 0]
    return o, lse


def combine_flash_decode(o_parts: jax.Array, lse_parts: jax.Array) -> jax.Array:
    """Merge per-shard partial attention results.

    o_parts: (W, B, H, D) fp32; lse_parts: (W, B, H) fp32 -> (B, H, D).
    """
    m = jnp.max(lse_parts, axis=0, keepdims=True)
    w = jnp.exp(lse_parts - m)  # (W, B, H)
    num = jnp.sum(o_parts * w[..., None], axis=0)
    den = jnp.sum(w, axis=0)
    return num / den[..., None]


def ssd_scan(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — positive step sizes
    a: jax.Array,  # (H,) — negative decay rates (A_log already exp'ed * -1)
    b_mat: jax.Array,  # (B, L, G, S)
    c_mat: jax.Array,  # (B, L, G, S)
    *,
    init_state: jax.Array | None = None,  # (B, H, P, S)
):
    """Sequential reference for the Mamba2 SSD recurrence.

    S_t = exp(dt_t * a) * S_{t-1} + dt_t * (x_t outer B_t)
    y_t = S_t . C_t
    Returns (y, final_state): y (B, L, H, P), state (B, H, P, S).
    """
    bsz, seqlen, h, p = x.shape
    g, s = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(b_mat.astype(jnp.float32), rep, axis=2)  # (B, L, H, S)
    cf = jnp.repeat(c_mat.astype(jnp.float32), rep, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,S), (B,H,S)
        decay = jnp.exp(dtt * a[None, :])  # (B,H)
        state = state * decay[..., None, None] + (
            xt[..., :, None] * bt[..., None, :]
        ) * dtt[..., None, None]
        y = jnp.einsum("bhps,bhs->bhp", state, ct)
        return state, y

    state0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, s), jnp.float32)
    )
    inps = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    final, ys = jax.lax.scan(step, state0, inps)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_scan_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H)
    a: jax.Array,  # (H,)
    b_mat: jax.Array,  # (B, L, G, S)
    c_mat: jax.Array,  # (B, L, G, S)
    *,
    chunk: int = 128,
    init_state: jax.Array | None = None,
):
    """Chunked SSD (same closed form as the Pallas kernel) in pure jnp —
    the production XLA path. The per-timestep reference scan is O(L) deep:
    its backward saves a state residual per TIME STEP (gigabytes at 4k
    context). This version scans per CHUNK with a checkpointed body, so the
    backward saves one state per chunk and recomputes inside.
    """
    bsz, seqlen, h, p = x.shape
    g, s = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, seqlen)
    if seqlen % chunk != 0:
        return ssd_scan(x, dt, a, b_mat, c_mat, init_state=init_state)
    nc = seqlen // chunk

    xf = x.reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = jnp.repeat(b_mat, rep, axis=2).reshape(bsz, nc, chunk, h, s)
    cf = jnp.repeat(c_mat, rep, axis=2).reshape(bsz, nc, chunk, h, s)

    log_decay = dtf * a[None, None, None, :]  # (B, NC, C, H)
    cum = jnp.cumsum(log_decay, axis=2)  # inclusive L_t

    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]
    mask = rows >= cols

    @jax.checkpoint
    def body(state, inp):
        xc, dtc, bc, cc, cumc = inp  # (B, C, H, *)
        xc = xc.astype(jnp.float32)
        # intra-chunk masked matmul: G[t,u] = (c_t.b_u) exp(L_t - L_u) dt_u
        cb = jnp.einsum("bths,buhs->bhtu", cc, bc,
                        preferred_element_type=jnp.float32)
        decay = jnp.exp(
            cumc.transpose(0, 2, 1)[:, :, :, None]
            - cumc.transpose(0, 2, 1)[:, :, None, :]
        )  # (B, H, C, C)
        gate = jnp.where(mask[None, None], cb * decay, 0.0) * \
            dtc.transpose(0, 2, 1)[:, :, None, :]  # * dt_u
        y = jnp.einsum("bhtu,buhp->bthp", gate, xc)
        # inter-chunk from the carried state
        y = y + jnp.exp(cumc)[..., None] * jnp.einsum(
            "bths,bhps->bthp", cc, state, preferred_element_type=jnp.float32)
        # state update: S <- exp(L_C) S + sum_u exp(L_C - L_u) dt_u x_u (x) B_u
        w = jnp.exp(cumc[:, -1:, :] - cumc) * dtc  # (B, C, H)
        new_state = jnp.exp(cumc[:, -1])[..., None, None] * state + jnp.einsum(
            "bthp,bths->bhps", xc, bc * w[..., None],
            preferred_element_type=jnp.float32)
        return new_state, y

    state0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, s), jnp.float32)
    )
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf.astype(jnp.float32), 1, 0),
        jnp.moveaxis(cf.astype(jnp.float32), 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    final, ys = jax.lax.scan(body, state0, xs)  # ys: (NC, B, C, H, P)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, seqlen, h, p)
    return y.astype(x.dtype), final


def ag_gemm(a_shards: jax.Array, b_loc: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused AllGather-GEMM kernel, from a global view:
    a_shards (W, m_loc, K) stacked shards, b_loc (K, n_loc) one rank's B."""
    a_full = a_shards.reshape(-1, a_shards.shape[-1])
    return matmul(a_full, b_loc, out_dtype)


def all_gather(a_shards: jax.Array) -> jax.Array:
    """Oracle for the low-latency AllGather kernel: (W, m, ...) -> concat."""
    return a_shards.reshape((-1,) + a_shards.shape[2:])
