"""Causal GQA flash attention (prefill) — Pallas TPU kernel.

Online-softmax over KV tiles: grid (B, Hq, q_tiles, kv_tiles) with the KV
dimension sequential ("arbitrary"); running max / denominator / f32
accumulator live in VMEM scratch and persist across the KV grid steps.
Causal upper-triangle tiles are skipped entirely (compute AND the pipeline
still fetch — the skip saves MXU work; full block-sparsity would need a
custom index_map, noted in EXPERIMENTS.md §Perf).

GQA: the KV head index_map folds the query-head -> kv-head mapping
(h // group), so no repeat/materialization of K/V ever happens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    bq: int,
    bkv: int,
    kv_tiles: int,
    causal: bool,
    out_dtype,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # last KV tile that intersects the causal band of this q tile
    if causal:
        last_tile = ((iq + 1) * bq - 1) // bkv
        run = ikv <= last_tile
    else:
        last_tile = kv_tiles - 1
        run = ikv == ikv  # True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 128) broadcast lanes
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (bq, 1)
        p = jnp.exp(s - m_new[:, :1])  # (bq, bkv)
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=-1, keepdims=True), l_ref.shape
        )
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ikv == (last_tile if causal else kv_tiles - 1))
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(out_dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 256,
    bkv: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(bq, lq)
    bkv = min(bkv, lk)
    assert lq % bq == 0 and lk % bkv == 0, (lq, lk, bq, bkv)
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    out_dtype = out_dtype or q.dtype
    grid = (b, hq, lq // bq, lk // bkv)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        bq=bq,
        bkv=bkv,
        kv_tiles=lk // bkv,
        causal=causal,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ikv: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, iq, ikv: (bb, h // group, ikv, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, iq, ikv: (bb, h // group, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ikv: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (lane-broadcast)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denominator
            pltpu.VMEM((bq, d), jnp.float32),  # f32 accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
