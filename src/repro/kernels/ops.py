"""Public kernel entry points.

Dispatch policy (production): Pallas on TPU, interpret-mode Pallas for
kernel validation on CPU, and pure-jnp (ref.py math, XLA-fused) as the
default CPU path so that graph-level compilation (dry-run, smoke tests)
sees ordinary HLO. ``force="pallas"`` pins the Pallas path for the
kernel-vs-ref test sweeps.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import flash_decode as _fd
from . import grouped_matmul as _gmm
from . import matmul as _mm
from . import ref as _ref
from . import ssd_scan as _ssd

Force = Optional[Literal["pallas", "ref"]]


def _use_pallas(force: Force) -> bool:
    if force == "pallas":
        return True
    if force == "ref":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul(a, b, *, out_dtype=jnp.float32, bm=256, bk=512, bn=256,
           rank=0, world=1, force: Force = None):
    if not _use_pallas(force):
        return _ref.matmul(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    ap = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    bp = _pad_to(_pad_to(b, bk_, 0), bn_, 1)
    out = _mm.matmul(ap, bp, bm=bm_, bk=bk_, bn=bn_, out_dtype=out_dtype,
                     rank=rank, world=world, interpret=_interpret())
    return out[:m, :n]


def grouped_matmul(x, w, *, out_dtype=jnp.float32, bm=128, bk=512, bn=256,
                   force: Force = None):
    if not _use_pallas(force):
        return _ref.grouped_matmul(x, w, out_dtype)
    e, cap, k = x.shape
    _, _, n = w.shape
    bm_, bk_, bn_ = min(bm, cap), min(bk, k), min(bn, n)
    xp = _pad_to(_pad_to(x, bm_, 1), bk_, 2)
    wp = _pad_to(_pad_to(w, bk_, 1), bn_, 2)
    out = _gmm.grouped_matmul(xp, wp, bm=bm_, bk=bk_, bn=bn_,
                              out_dtype=out_dtype, interpret=_interpret())
    return out[:, :cap, :n]


def flash_attention(q, k, v, *, causal=True, scale=None, bq=256, bkv=256,
                    force: Force = None):
    if not _use_pallas(force):
        if k.shape[2] > 1024:
            # long sequences: chunked online softmax (O(Lq*chunk) memory)
            return _ref.flash_attention_chunked(q, k, v, causal=causal, scale=scale)
        return _ref.flash_attention(q, k, v, causal=causal, scale=scale)
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               bq=bq, bkv=bkv, interpret=_interpret())


def flash_decode(q, k, v, length, *, scale=None, bkv=512, force: Force = None):
    if not _use_pallas(force):
        return _ref.flash_decode(q, k, v, scale=scale, length=length)
    return _fd.flash_decode(q, k, v, length, scale=scale, bkv=bkv,
                            interpret=_interpret())


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk=128, force: Force = None):
    if not _use_pallas(force):
        # chunked closed form: O(L/chunk)-deep scan (the per-timestep
        # reference would save a state residual per step in backward)
        return _ref.ssd_scan_chunked(x, dt, a, b_mat, c_mat, chunk=chunk)
    return _ssd.ssd_scan(x, dt, a, b_mat, c_mat, chunk=chunk,
                         interpret=_interpret())


combine_flash_decode = _ref.combine_flash_decode
