"""`OverlapOp` — declare an overlapped op once, get every lowering derived.

The paper's claim (§2, §3.7) is a *programming model*, not an op zoo: an
overlapped op is a tile-level compute composed with a communication
schedule. This module is that claim as an API. One declaration

    op = declare(OverlapOp(
        name="ag_matmul", kind="ag",
        tile=lambda a_chunk, b: jnp.dot(a_chunk, b,
                                        preferred_element_type=jnp.float32),
        transports=("ring", "bidir", "one_shot"),
        kernel_protocols=(("ring", "ring_ag"), ("one_shot", "one_shot_ag")),
        transpose="matmul_rs",
    ))

derives and registers, from the single ``tile`` function:

  graph lowering   the ``ag_pipeline``/``rs_pipeline`` folds of
                   ``core.overlap`` (lax.ppermute, runs everywhere),
                   including bidir splitting and the sub-chunking knob;
  kernel lowering  the shmem tile executor (``shmem.executor``): the
                   declared protocol wraps ``tile`` in the ring/credit,
                   Alg.-3 push, or one-shot put/signal protocol — remote
                   DMAs on TPU, the emulated DMA engine on CPU;
  backward         the op's dual schedule, via ``jax.vjp`` of ``tile``
                   composed with the transpose pipeline (an AG op's
                   operand gradient rides the dual RS ring and vice
                   versa), routed through the engine's ONE shared
                   custom_vjp — so a kernel forward keeps the graph
                   dual as its backward and grads are bit-identical
                   across backends;
  registration     an ``OverlapSpec`` in the engine registry, which is
                   what ``OverlapPolicy`` resolution, the tuner's
                   candidate enumeration and the parity-test matrix all
                   consume — a declared op shows up in all three with no
                   further wiring.

Contract for ``tile(chunk, *statics)``
--------------------------------------
Pure jax function; the first argument is the tensor that rides the
transport (AG kinds: the gathered operand's per-rank chunk; RS kinds:
one dim-0 block of the local operand), the rest stay rank-resident. It
must be **linear in the riding argument** (every op in the paper is —
the communicated factor of a GEMM enters linearly); statics may enter
arbitrarily. Return the f32 partial; the framework handles output-dtype
casts. Declare ``rowwise=True`` when the tile maps rows to rows
one-to-one (enables bidir halving and the AG sub-chunking knob).

Stateful fold tiles (``fold=FoldTile(...)``)
--------------------------------------------
Ops whose per-chunk compute carries REDUCTION STATE across chunks (ring
attention's online softmax, any chunk-centric running reduction) declare
a :class:`FoldTile` instead of a pure tile — three pure functions, each
taking a leading ``ctx`` dict (the call's non-engine static extras,
``axis`` included):

    init(ctx, chunk, *statics)           -> state pytree (f32)
    fold(ctx, state, chunk, owner, *statics) -> state
    finalize(ctx, state, *statics)       -> output

The graph lowering folds over the engine's AG pipelines; the kernel
lowering binds the executor's carry-passing ``ring_fold`` protocol
(``one_shot`` gathers through ``one_shot_ag`` and replays the fold chain
host-side); the backward is derived with ``jax.vjp`` through the fold
chain (chunks stack-gathered once, cotangents ride the dual RS ring
home). Fold declarations are not linear-in-chunk restricted.

Two-axis (pod x ring) ops declare ``transports=("two_level",)`` and are
called with ``axis=(inner, outer)``; graph lowers through the engine's
``two_level_*_pipeline`` schedules, kernel through the executor's
``two_level_ag`` / ``two_level_rs`` protocols, and the derived backward
rides the two-level dual schedules.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from ..core import overlap as ov
from ..shmem import executor
from ..shmem.executor import FoldTile
from ..shmem.executor import slice_rows as _slice_rows
from ..shmem.executor import update_rows as _update
from . import wire as wirefmt

Array = jax.Array

# Dual kinds: an op's transpose partner must lower through the dual
# schedule (the AG operand-gradient rides an RS ring and vice versa).
# "attn" (fold) ops derive their backward through the fold chain and
# have no transpose partner.
_DUAL_KIND = {"ag": ("rs",), "gather": ("rs",), "rs": ("ag", "gather"),
              "a2a": ("a2a",), "attn": ()}

# collective_id allocation for declared kernel lowerings (the hand-tuned
# kernels in repro.kernels keep their historical ids below 32).
_CIDS = itertools.count(32)


@dataclass(frozen=True)
class OverlapOp:
    """One overlapped op, declared at tile level.

    name              registry identifier (policy / tuner / test key)
    kind              "ag" | "gather" | "rs" | "a2a" | "attn" — which
                      side of the transport the op sits on (what rides:
                      the operand chunks, or the accumulator); "attn" is
                      the stateful-fold kind (requires ``fold``)
    tile              tile compute ``tile(chunk, *statics) -> f32 tile``;
                      None = identity (pure data movement)
    fold              stateful fold tile (:class:`FoldTile`, ctx-first
                      signatures) — mutually exclusive with ``tile``
    transports        engine transports the graph lowering supports
    baseline          monolithic fallback mode name
    default           transport used when an unsupported mode is asked
    kernel_protocols  (transport, executor protocol) pairs: each one
                      becomes a kernel-backend lowering via the shmem
                      tile executor
    transpose         the dual op's registry name, by reference (the
                      derived backward rides the partner's schedule;
                      validated against the registry)
    rowwise           tile maps chunk rows 1:1 to tile rows — enables
                      bidir halving and AG-side sub-chunking
    static_split      optional ``(statics, n) -> [statics_j] | None``:
                      split the statics into n output column groups (RS
                      sub-chunking and RS bidir); None = not splittable
    split_axis        output axis the split groups concatenate on
    differentiable    derive + register the dual-schedule backward
    baseline_fwd      optional explicit monolithic lowering
                      ``(operand, statics, axis, out_dtype) -> out``
                      (derived from ``tile`` when omitted)
    checkpoint_tag    optional ``checkpoint_name`` tag on the output
                      (remat policies key on it)
    wires             wire dtypes the riding chunks may travel as
                      (``("f32",)`` = always as-is; add "int8"/"fp8" to
                      let the policy/tuner pick a scaled 1-byte wire —
                      both lowerings then quantize before every put and
                      dequantize on arrival, accumulating in f32)
    wire_split        required for fold ops that declare low-precision
                      wires: ``wire_split(operand, *statics) -> last-axis
                      section sizes`` of the riding chunk (e.g. ring
                      attention's packed K|V -> ``(d, d)``), so each
                      section quantizes with its own per-row scale
                      (ops.wire.MultiCodec)
    placements        chunk->rank row placements the op's schedule
                      understands (core.schedules.PLACEMENTS); a causal
                      fold op declaring zigzag/striped reads the resolved
                      name from its ``ctx["placement"]`` and maps local
                      rows to global positions accordingly
    """

    name: str
    kind: str
    tile: Optional[Callable] = None
    fold: Optional[FoldTile] = None
    transports: Tuple[str, ...] = ("ring",)
    baseline: str = "none"
    default: str = "ring"
    kernel_protocols: Tuple[Tuple[str, str], ...] = ()
    transpose: Optional[str] = None
    rowwise: bool = False
    static_split: Optional[Callable] = None
    split_axis: int = 1
    differentiable: bool = True
    baseline_fwd: Optional[Callable] = None
    checkpoint_tag: Optional[str] = None
    wires: Tuple[str, ...] = ("f32",)
    wire_split: Optional[Callable] = None
    placements: Tuple[str, ...] = ("contiguous",)

    def __post_init__(self):
        if isinstance(self.kernel_protocols, Mapping):
            object.__setattr__(self, "kernel_protocols",
                               tuple(self.kernel_protocols.items()))
        if self.kind not in _DUAL_KIND:
            raise ValueError(f"{self.name}: unknown kind {self.kind!r}")
        if (self.kind == "attn") != (self.fold is not None):
            raise ValueError(
                f"{self.name}: kind 'attn' and a FoldTile declaration go "
                "together")
        if self.fold is not None and self.tile is not None:
            raise ValueError(f"{self.name}: declare tile OR fold, not both")
        if self.fold is not None and self.baseline_fwd is None \
                and self.baseline not in self.transports:
            # a fold op's monolithic baseline cannot be derived from the
            # chunk-centric declaration (the fold order IS the op)
            raise ValueError(
                f"{self.name}: fold declarations need an explicit "
                "baseline_fwd for their monolithic baseline")
        for t, proto in self.kernel_protocols:
            if proto not in executor.PROTOCOLS:
                raise ValueError(
                    f"{self.name}: unknown executor protocol {proto!r}")
            if proto == "bidir_ring_ag" and not self.rowwise:
                # the protocol tiles each chunk HALF; a non-rowwise tile
                # would silently diverge from the graph lowering, which
                # degrades non-rowwise bidir to ring
                raise ValueError(
                    f"{self.name}: bidir_ring_ag requires rowwise=True")
            if proto == "ring_fold" and self.fold is None:
                raise ValueError(
                    f"{self.name}: ring_fold requires a FoldTile declaration")
            if self.fold is not None and proto not in ("ring_fold",
                                                       "one_shot_ag"):
                # one_shot_ag = gather-then-replay; anything else cannot
                # carry the fold state
                raise ValueError(
                    f"{self.name}: fold ops bind ring_fold or one_shot_ag, "
                    f"not {proto!r}")
            if proto == "two_level_ag" and self.kind not in ("ag", "gather"):
                raise ValueError(
                    f"{self.name}: two_level_ag is an AG-side protocol")
            if proto == "two_level_rs" and self.kind != "rs":
                raise ValueError(
                    f"{self.name}: two_level_rs is an RS-side protocol")
        if self.kind == "a2a" and self.kernel_protocols and self.tile is not None:
            # the graph lowering applies an a2a tile once, post-assembly;
            # the executor protocol applies it per landed block — only
            # the tile=None (pure data movement) case agrees by design
            raise ValueError(
                f"{self.name}: a2a kernel protocols require tile=None")
        if self.fold is not None and tuple(self.wires) != ("f32",) \
                and self.wire_split is None:
            # the fold's riding chunk packs several operands on its last
            # axis (K|V); without a declared section split the per-row
            # codec would share one scale across them
            raise ValueError(
                f"{self.name}: fold declarations with low-precision wires "
                "need a wire_split (last-axis section sizes)")
        if self.wire_split is not None and self.fold is None:
            raise ValueError(
                f"{self.name}: wire_split is a fold-declaration knob")

    def tile_fn(self) -> Callable:
        return self.tile if self.tile is not None else (lambda x: x)

    def fuse(self, other: "OverlapOp", **kwargs) -> "BoundOp":
        """Declare the rs->ag fusion of this declaration (the producer,
        kind "rs") with ``other`` (the consumer, kind "ag") — see the
        module-level :func:`fuse`."""
        return fuse(self, other, **kwargs)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _tile_rows(op: OverlapOp, chunk, statics) -> Tuple[int, Tuple[int, ...]]:
    ts = jax.eval_shape(op.tile_fn(), chunk, *statics)
    return ts.shape[0], tuple(ts.shape[1:])


def _out_dtype(static, operand):
    """Output dtype from the static dict (operand dtype when a raw
    ``overlap.dispatch`` caller omitted it)."""
    return jnp.dtype(static.get("out_dtype") or operand.dtype)


def _axis_world(axis) -> int:
    """World size of one axis name or a compound (inner, outer) tuple."""
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= lax.axis_size(a)
        return n
    return lax.axis_size(axis)


# static keys consumed by the engine itself; everything else is an op
# extra handed to fold declarations as their ``ctx`` (``axis`` included —
# folds key causal masks and rank offsets on it)
_ENGINE_ONLY_KEYS = ("mode", "backend", "chunks", "out_dtype", "wire")


def _wire_codec(static: Mapping):
    """The call's wire codec (None = f32, chunks ride as-is)."""
    return wirefmt.codec(static.get("wire", "f32"))


def _fold_codec(op: "OverlapOp", static: Mapping, operand, statics):
    """The multi-section codec for a fold op's riding chunk (None = f32).
    Sections come from the declaration's ``wire_split`` so each packed
    operand (K and V) quantizes with its own per-row scale."""
    wire = static.get("wire", "f32")
    if wire == "f32" or op.wire_split is None:
        return None
    return wirefmt.multi_codec(wire, op.wire_split(operand, *statics))


def _wrap_fold_packed(bound: FoldTile, codec) -> FoldTile:
    """Executor-level FoldTile whose chunks arrive PACKED (uint8
    payload|scales): unpack-decode to f32 before init/fold see them."""
    return FoldTile(
        init=lambda chunk, *st: bound.init(codec.unpack_decode(chunk), *st),
        fold=lambda state, chunk, owner, *st: bound.fold(
            state, codec.unpack_decode(chunk), owner, *st),
        finalize=bound.finalize,
        live=bound.live)


def _fold_ctx(static: Mapping) -> Dict[str, Any]:
    return {k: v for k, v in static.items() if k not in _ENGINE_ONLY_KEYS}


def _bind_fold(ft: FoldTile, ctx: Dict[str, Any]) -> FoldTile:
    """Close the declaration-level (ctx-first) FoldTile over one call's
    extras, yielding the executor-level (ctx-free) FoldTile."""
    return FoldTile(
        init=lambda chunk, *st: ft.init(ctx, chunk, *st),
        fold=lambda state, chunk, owner, *st: ft.fold(ctx, state, chunk,
                                                      owner, *st),
        finalize=lambda state, *st: ft.finalize(ctx, state, *st),
        live=None if ft.live is None
        else lambda owner, *st: ft.live(ctx, owner, *st))


def _dual_rs(compute_block, axis, codec=None):
    """The dual RS schedule: single-axis ring, or the two-level pipeline
    when the op composes (inner, outer) axes. ``codec`` makes the riding
    accumulator travel in the forward pass's wire dtype (two-level duals
    stay f32, mirroring the forward clamp)."""
    if isinstance(axis, (tuple, list)):
        return ov.two_level_rs_pipeline(compute_block, axis[0], axis[1])
    kw = {} if codec is None else {"encode": codec.encode, "decode": codec.decode}
    return ov.rs_pipeline(compute_block, axis, transport="ring", **kw)


def _dual_ag(operands, fold, init, axis, codec=None):
    """The dual AG schedule (ring / two-level, mirroring :func:`_dual_rs`).
    With a ``codec`` the single riding operand is encoded once and each
    arrival is decoded before the fold sees it."""
    if isinstance(axis, (tuple, list)):
        return ov.two_level_ag_pipeline(operands, fold, init, axis[0], axis[1])
    if codec is not None and len(operands) == 1:
        ride_dtype = operands[0].dtype
        payload, scales = codec.encode(operands[0])

        def fold_w(carry, bufs, s, owner):
            chunk = codec.decode(bufs[0], bufs[1]).astype(ride_dtype)
            return fold(carry, (chunk,), s, owner)

        return ov.ag_pipeline((payload, scales), fold_w, init, axis,
                              transport="ring")
    return ov.ag_pipeline(operands, fold, init, axis, transport="ring")


# ---------------------------------------------------------------------------
# Graph lowering (ag_pipeline / rs_pipeline folds)
# ---------------------------------------------------------------------------


def _ag_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    axis = static["axis"]
    mode = static["mode"]
    out_dtype = _out_dtype(static, operand)
    tile = op.tile_fn()
    codec = _wire_codec(static)
    w = _axis_world(axis)
    m_loc = operand.shape[0]
    tile_m, rest = _tile_rows(op, operand, statics)
    out0 = jnp.zeros((tile_m * w,) + rest, out_dtype)

    # Under a wire dtype the operand rides as (payload, scales) siblings;
    # arrivals decode to f32 before the tile. The scales are per-row, so
    # every row-wise split below (bidir halves, sub-chunks) stays aligned.
    def _chunk(bufs):
        return bufs[0] if codec is None else codec.decode(bufs[0], bufs[1])

    def _riding(x):
        return (x,) if codec is None else codec.encode(x)

    if mode == "two_level":
        inner, outer = axis

        def fold_tl(out, bufs, s, owner):
            t = tile(_chunk(bufs), *statics).astype(out_dtype)
            return _update(out, t, owner * tile_m)

        return ov.two_level_ag_pipeline(_riding(operand), fold_tl, out0,
                                        inner, outer)

    if mode == "bidir" and op.rowwise and m_loc % 2 == 0 and w >= 3:
        h = tile_m // 2

        def fold2(out, bufs, s, owner, direction):
            t = tile(_chunk(bufs), *statics).astype(out_dtype)
            return _update(out, t, owner * tile_m + direction * h)

        return ov.bidir_ag_pipeline(_riding(operand), fold2, out0, axis)
    if mode == "bidir":
        mode = "ring"  # odd chunk or W < 3: bidir degenerates to ring
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"{op.name}: unknown ag mode {mode!r}")

    # Sub-chunk ring: finer pipelining shrinks the first-chunk fill
    # bubble (the communication-tile-size knob of §3.6).
    s_sub = max(1, static.get("chunks", 1)) if op.rowwise else 1
    if m_loc % s_sub != 0 or mode == "one_shot":
        s_sub = 1
    m_sub = m_loc // s_sub
    subs = tuple(_slice_rows(operand, j * m_sub, m_sub) for j in range(s_sub))
    if codec is not None:
        enc = [codec.encode(sj) for sj in subs]
        riding = tuple(p for p, _ in enc) + tuple(sc for _, sc in enc)
    else:
        riding = subs

    def fold(out, bufs, s, owner):
        for j in range(s_sub):
            bj = bufs[j] if codec is None else codec.decode(bufs[j],
                                                            bufs[s_sub + j])
            t = tile(bj, *statics).astype(out_dtype)
            out = _update(out, t, owner * tile_m + j * m_sub)
        return out

    return ov.ag_pipeline(riding, fold, out0, axis, transport=mode)


def _rs_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    axis = static["axis"]
    mode = static["mode"]
    out_dtype = _out_dtype(static, operand)
    tile = op.tile_fn()
    codec = _wire_codec(static)
    # wire hooks for the riding accumulator (quantize before each hop,
    # dequantize + f32-accumulate on arrival)
    wire_kw = ({} if codec is None
               else {"encode": codec.encode, "decode": codec.decode})
    w = _axis_world(axis)
    m = operand.shape[0]
    assert m % w == 0, (m, w)
    m_blk = m // w

    def block(blk):
        return _slice_rows(operand, blk * m_blk, m_blk)

    if mode == "two_level":
        inner, outer = axis

        def compute_tl(blk, s):
            return tile(block(blk), *statics)

        return ov.two_level_rs_pipeline(
            compute_tl, inner, outer).astype(out_dtype)

    if mode == "bidir" and op.static_split is not None and w >= 3:
        halves = op.static_split(statics, 2)
        if halves is not None:
            # split the output columns across BOTH ring directions: two
            # accumulators, half the bytes per link per step.
            def compute2(blk, s, direction):
                return tile(block(blk), *halves[direction])

            acc_f, acc_r = ov.bidir_rs_pipeline(compute2, axis, **wire_kw)
            return jnp.concatenate(
                [acc_f, acc_r], axis=op.split_axis).astype(out_dtype)
    if mode == "bidir":
        mode = "ring"
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"{op.name}: unknown rs mode {mode!r}")

    # Sub-chunked RS ring: the accumulator split into column groups, each
    # riding its own independent ring (§3.6's tile-size knob, RS side).
    s_sub = max(1, static.get("chunks", 1))
    groups = (op.static_split(statics, s_sub)
              if s_sub > 1 and mode == "ring" and op.static_split else None)
    if groups is not None:
        outs = [
            ov.rs_pipeline(
                lambda blk, s, g=g: tile(block(blk), *g), axis,
                transport="ring", **wire_kw)
            for g in groups
        ]
        return jnp.concatenate(outs, axis=op.split_axis).astype(out_dtype)

    def compute(blk, s):
        return tile(block(blk), *statics)

    return ov.rs_pipeline(compute, axis, transport=mode,
                          **wire_kw).astype(out_dtype)


def _a2a_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    codec = _wire_codec(static)
    wire_kw = ({} if codec is None
               else {"encode": codec.encode, "decode": codec.decode})
    out = ov.a2a_pipeline(operand, static["axis"], transport=static["mode"],
                          **wire_kw)
    if op.tile is not None:
        out = op.tile(out, *statics)
    return out.astype(_out_dtype(static, operand))


def _fold_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    """Graph lowering of a stateful fold op: the declaration's fold is
    the engine AG pipeline's carry (ring: one hop per step; one_shot:
    all chunks up-front, folded in ring-distance order)."""
    axis = static["axis"]
    mode = static["mode"]
    ctx = _fold_ctx(static)
    ft = op.fold
    out_dtype = _out_dtype(static, operand)
    codec = _fold_codec(op, static, operand, statics)
    if codec is None:
        state0 = ft.init(ctx, operand, *statics)

        def fold_fn(carry, bufs, s, owner):
            del s
            return ft.fold(ctx, carry, bufs[0], owner, *statics)

        state = ov.ag_pipeline((operand,), fold_fn, state0, axis,
                               transport=mode)
    else:
        # the chunk rides as (payload, scales) siblings; every fold —
        # including step 0's own chunk — consumes the DECODED values, so
        # graph and kernel (packed-workspace) lowerings see identical
        # inputs at every step
        payload, scales = codec.encode(operand)
        state0 = ft.init(ctx, codec.decode(payload, scales), *statics)

        def fold_fn(carry, bufs, s, owner):
            del s
            return ft.fold(ctx, carry, codec.decode(bufs[0], bufs[1]),
                           owner, *statics)

        state = ov.ag_pipeline((payload, scales), fold_fn, state0, axis,
                               transport=mode)
    return ft.finalize(ctx, state, *statics).astype(out_dtype)


def _default_baseline(op: OverlapOp):
    """Monolithic fallback derived from the tile: collective first, then
    the tile per owner chunk (AG kinds) / tile per block then the
    collective (RS kinds) — the "NCCL + compute" analogue."""
    tile = op.tile_fn()

    def ag_baseline(operand, statics, axis, out_dtype):
        w = lax.axis_size(axis)
        full = lax.all_gather(operand, axis, tiled=True)
        m_loc = operand.shape[0]
        tiles = [
            tile(_slice_rows(full, i * m_loc, m_loc), *statics).astype(out_dtype)
            for i in range(w)
        ]
        return jnp.concatenate(tiles, axis=0)

    def rs_baseline(operand, statics, axis, out_dtype):
        w = lax.axis_size(axis)
        m_blk = operand.shape[0] // w
        partial = jnp.concatenate(
            [tile(_slice_rows(operand, i * m_blk, m_blk), *statics)
             for i in range(w)], axis=0)
        return lax.psum_scatter(
            partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)

    return rs_baseline if op.kind == "rs" else ag_baseline


def _make_graph_fwd(op: OverlapOp) -> Callable:
    lower = {"ag": _ag_graph, "gather": _ag_graph, "rs": _rs_graph,
             "a2a": _a2a_graph, "attn": _fold_graph}[op.kind]
    if op.fold is not None:
        # fold baselines need the call's extras (causal flags etc.):
        # they receive the full static dict
        def fwd(static, operand, *statics):
            if static["mode"] == op.baseline:
                return op.baseline_fwd(static, operand, *statics)
            return lower(op, static, operand, *statics)

        return fwd
    baseline = op.baseline_fwd or _default_baseline(op)

    def fwd(static, operand, *statics):
        if static["mode"] == op.baseline and op.kind != "a2a":
            return baseline(operand, statics, static["axis"],
                            _out_dtype(static, operand))
        return lower(op, static, operand, *statics)

    return fwd


# ---------------------------------------------------------------------------
# Kernel lowering (the shmem tile executor)
# ---------------------------------------------------------------------------


def _make_kernel_fwd(op: OverlapOp, cid: int) -> Optional[Callable]:
    if not op.kernel_protocols:
        return None
    protos = dict(op.kernel_protocols)

    if op.fold is not None:

        def kernel_fwd(static, operand, *statics):
            axis = static["axis"]
            w = lax.axis_size(axis)
            out_dtype = _out_dtype(static, operand)
            bound = _bind_fold(op.fold, _fold_ctx(static))
            codec = _fold_codec(op, static, operand, statics)
            ride = operand
            if codec is not None:
                # what rides the executor's workspaces is the PACKED
                # (payload|scales) uint8 buffer; the bound fold unpacks
                # each arrival — including its own chunk — back to f32
                bound = _wrap_fold_packed(bound, codec)
                ride = codec.pack(operand)
            proto = protos[static["mode"]]
            if proto == "ring_fold":
                return executor.run(
                    "ring_fold", bound, ride, statics, axis=axis, world=w,
                    out_dtype=out_dtype, collective_id=cid)
            # one_shot: the executor's low-latency put protocol moves the
            # chunks (pure data movement); the fold chain replays
            # host-side in the same ring-distance order the graph uses
            gathered = executor.run(
                proto, None, ride, (), axis=axis, world=w,
                out_dtype=ride.dtype, collective_id=cid)
            me = lax.axis_index(axis)
            m = ride.shape[0]
            state = bound.init(ride, *statics)
            for s in range(w):
                owner = lax.rem(me - s + w, w)
                chunk = _slice_rows(gathered, owner * m, m)
                state = bound.fold(state, chunk, owner, *statics)
            return bound.finalize(state, *statics).astype(out_dtype)

        return kernel_fwd

    def kernel_fwd(static, operand, *statics):
        axis = static["axis"]
        if isinstance(axis, (tuple, list)):
            inner, outer = axis
            world = (lax.axis_size(inner), lax.axis_size(outer))
        else:
            world = lax.axis_size(axis)
        proto = protos[static["mode"]]
        out_dtype = _out_dtype(static, operand)
        codec = _wire_codec(static)
        if codec is None or proto in executor.TWO_LEVEL_PROTOCOLS:
            return executor.run(
                proto, op.tile, operand, statics, axis=axis, world=world,
                out_dtype=out_dtype, collective_id=cid)
        # Wire lowering: what rides the executor's workspaces is the
        # PACKED (payload|scales) buffer — the protocols move it
        # unmodified, so only the tile boundary changes.
        tile = op.tile_fn()
        from .. import obs

        def _pack(x):
            with obs.phase("pack"):
                return codec.pack(x)

        def _unpack(buf):
            with obs.phase("decode"):
                return codec.unpack_decode(buf)

        if op.kind in ("ag", "gather"):
            # AG side: the riding chunk is packed up-front; the tile
            # unpacks each arrival back to f32 before its compute.
            return executor.run(
                proto,
                lambda buf, *st: tile(_unpack(buf), *st),
                _pack(operand), statics, axis=axis, world=world,
                out_dtype=out_dtype, collective_id=cid)
        if op.kind == "a2a":
            # per-destination blocks packed along the last axis; each
            # landed block is unpacked (tile=None on a2a declarations,
            # so the decode IS the per-block tile)
            return executor.run(
                proto, lambda buf, *st: _unpack(buf),
                _pack(operand), statics, axis=axis, world=world,
                out_dtype=out_dtype, collective_id=cid)
        # RS side: the pushed partial is the packed encoded tile output;
        # the executor decodes each landed partial for the f32 reduction.
        return executor.run(
            proto, lambda blk, *st: _pack(tile(blk, *st)),
            operand, statics, axis=axis, world=world,
            out_dtype=out_dtype, collective_id=cid,
            decode=_unpack)

    return kernel_fwd


# ---------------------------------------------------------------------------
# Backward derivation: the dual schedule over jax.vjp of the tile
# ---------------------------------------------------------------------------


def _make_bwd(op: OverlapOp) -> Optional[Callable]:
    if not op.differentiable:
        return None
    if op.kind == "a2a":
        if op.tile is not None:
            # a post-assembly tile keeps autodiff-through-pipeline; only
            # pure data movement gets the derived self-dual backward.
            return None

        def a2a_bwd(static, res, g):
            # AllToAll is its own transpose as a global linear map (the
            # (rank, block) index swap is symmetric): the cotangent rides
            # the same decomposed a2a back, in the same wire dtype.
            (operand,) = res
            mode = static["mode"]
            if mode not in ("xla",) + op.transports:
                mode = op.default
            codec = _wire_codec(static)
            kw = ({} if codec is None
                  else {"encode": codec.encode, "decode": codec.decode})
            d = ov.a2a_pipeline(g, static["axis"], transport=mode, **kw)
            return (d.astype(operand.dtype),)

        return a2a_bwd
    if op.fold is not None:

        def fold_bwd(static, res, g):
            # jax.vjp THROUGH THE FOLD CHAIN: stack-gather the riding
            # chunks once (one ring of the residuals), differentiate the
            # local replay of init -> fold^W -> finalize, then send every
            # owner's chunk cotangent home on the dual RS ring. Statics
            # (e.g. the resident q) are rank-private: their cotangent is
            # local, no reduction.
            operand, *statics = res
            axis = static["axis"]
            ctx = _fold_ctx(static)
            ft = op.fold
            out_dtype = _out_dtype(static, operand)
            codec = _fold_codec(op, static, operand, statics)
            w = lax.axis_size(axis)
            me = lax.axis_index(axis)
            stacked = ov.stack_gather_pipeline(operand, axis,
                                               transport="ring")

            def local_fn(stk, *st):
                state = ft.init(ctx, lax.index_in_dim(stk, 0, 0, False), *st)
                for s in range(w):
                    owner = lax.rem(me - s + w, w)
                    chunk = lax.dynamic_index_in_dim(stk, owner, 0,
                                                     keepdims=False)
                    if codec is not None:
                        # straight-through: replay the quantized forward
                        # values, but let the cotangent pass as identity
                        # (round() has zero gradient almost everywhere)
                        chunk = chunk + lax.stop_gradient(
                            codec.roundtrip(chunk) - chunk)
                    state = ft.fold(ctx, state, chunk, owner, *st)
                return ft.finalize(ctx, state, *st).astype(out_dtype)

            _, vjp = jax.vjp(local_fn, stacked, *statics)
            grads = vjp(g)
            d_stk = grads[0]  # (W, chunk): my contribution to EVERY owner

            def compute_block(blk, s):
                del s
                return lax.dynamic_index_in_dim(
                    d_stk, blk, 0, keepdims=False).astype(jnp.float32)

            d_chunk = ov.rs_pipeline(
                compute_block, axis, transport="ring").astype(operand.dtype)
            return (d_chunk,) + tuple(
                d.astype(s.dtype) for d, s in zip(grads[1:], statics))

        return fold_bwd
    tile = op.tile_fn()

    def tile_cast(out_dtype, chunk, *statics):
        return tile(chunk, *statics).astype(out_dtype)

    if op.kind in ("ag", "gather"):

        def bwd(static, res, g):
            operand, *statics = res
            axis = static["axis"]
            out_dtype = _out_dtype(static, operand)
            codec = _wire_codec(static)
            tile_m, rest = _tile_rows(op, operand, statics)
            zeros = jnp.zeros(operand.shape, operand.dtype)

            # operand gradient: rides the DUAL RS schedule (the transpose
            # partner's — ring, or two-level for compound-axis ops) —
            # O(1) permute buffers, in the forward pass's wire dtype.
            def compute_block(blk, s):
                g_blk = _slice_rows(g, blk * tile_m, tile_m)
                _, vjp = jax.vjp(
                    lambda xc: tile_cast(out_dtype, xc, *statics), zeros)
                return vjp(g_blk)[0].astype(jnp.float32)

            d_op = _dual_rs(compute_block, axis, codec).astype(operand.dtype)
            if not statics:
                return (d_op,)

            # statics gradients: ring the residual chunk past the static
            # cotangent strips, accumulating in f32.
            def fold(ds, bufs, s, owner):
                g_o = _slice_rows(g, owner * tile_m, tile_m)
                _, vjp = jax.vjp(
                    lambda *st: tile_cast(out_dtype, bufs[0], *st), *statics)
                return tuple(d + gi.astype(jnp.float32)
                             for d, gi in zip(ds, vjp(g_o)))

            ds0 = tuple(jnp.zeros(s.shape, jnp.float32) for s in statics)
            d_statics = _dual_ag((operand,), fold, ds0, axis, codec)
            return (d_op,) + tuple(
                d.astype(s.dtype) for d, s in zip(d_statics, statics))

        return bwd

    def bwd(static, res, g):  # kind == "rs"
        operand, *statics = res
        axis = static["axis"]
        out_dtype = _out_dtype(static, operand)
        codec = _wire_codec(static)
        w = _axis_world(axis)
        m_blk = operand.shape[0] // w

        # ONE dual AG schedule of the cotangent block: each arriving g
        # chunk yields this rank's operand-block gradient (scattered at
        # the owner's rows) AND its statics contribution — both vjps of
        # the tile at the true local primal block.
        def fold(carry, bufs, s, owner):
            d_opnd, ds = carry
            blk_val = _slice_rows(operand, owner * m_blk, m_blk)
            _, vjp = jax.vjp(
                lambda xb, *st: tile_cast(out_dtype, xb, *st),
                blk_val, *statics)
            grads = vjp(bufs[0])
            d_opnd = _update(d_opnd, grads[0].astype(jnp.float32),
                             owner * m_blk)
            ds = tuple(d + gi.astype(jnp.float32)
                       for d, gi in zip(ds, grads[1:]))
            return d_opnd, ds

        init = (jnp.zeros(operand.shape, jnp.float32),
                tuple(jnp.zeros(s.shape, jnp.float32) for s in statics))
        d_opnd, d_statics = _dual_ag((g,), fold, init, axis, codec)
        return (d_opnd.astype(operand.dtype),) + tuple(
            d.astype(s.dtype) for d, s in zip(d_statics, statics))

    return bwd


# ---------------------------------------------------------------------------
# declare() + the bound callable
# ---------------------------------------------------------------------------

_DECLARED: Dict[str, "BoundOp"] = {}


class BoundOp:
    """A declared op, callable with a policy: ``op(x, w, axis=...,
    policy=pcfg.policy)`` or with explicit ``mode=/backend=/chunks=``
    overrides. Runs inside ``shard_map``; routed through the engine's
    shared custom_vjp when the declaration is differentiable."""

    def __init__(self, op: OverlapOp):
        self.decl = op
        self.name = op.name
        self.__doc__ = f"Overlapped op {op.name!r} ({op.kind}): " \
                       f"transports {op.transports}, " \
                       f"kernel {tuple(dict(op.kernel_protocols))}"

    @property
    def spec(self) -> ov.OverlapSpec:
        return ov.get(self.name)

    def __repr__(self):
        return f"<ops.{self.name} kind={self.decl.kind}>"

    def __call__(self, *tensors, axis, policy=None, mode: Optional[str] = None,
                 backend: Optional[str] = None, chunks: Optional[int] = None,
                 wire: Optional[str] = None, placement: Optional[str] = None,
                 out_dtype=None, **extras):
        """``axis`` is one mesh-axis name, or ``(inner, outer)`` for
        two-level (compound-mesh) ops. ``extras`` are op-specific static
        values (hashable — e.g. ring attention's ``causal``/``scale``),
        handed to fold declarations as their ``ctx``.

        Policy resolution is PER SITE: the call threads the tensors'
        shapes into ``policy.resolve``, so a shape-keyed layer rule
        (``OverlapPolicy.with_layer`` / ``tuner.search``) can pin a
        different mode/backend/chunks/wire/placement for the QKV
        projection than for the MLP matmul of the same op name.

        ``placement`` names the chunk->rank owner map (see
        ``core.schedules.PLACEMENTS``); ops that declared non-contiguous
        placements interpret each owner's rows through that map. The
        default ``"contiguous"`` adds nothing to the dispatch statics,
        so existing traces and caches are unchanged."""
        if policy is not None:
            r = policy.resolve(
                self.name, shape=tuple(tuple(t.shape) for t in tensors))
            mode = mode or r.mode
            backend = backend or r.backend
            chunks = r.chunks if chunks is None else chunks
            wire = wire or r.wire
            placement = placement or r.placement
        if isinstance(axis, list):
            axis = tuple(axis)
        mode = ov.resolve_mode(self.name, mode or self.decl.default)
        wire = ov.resolve_wire(self.name, wire or "f32", mode)
        placement = ov.resolve_placement(self.name, placement or "contiguous")
        if placement != "contiguous":
            extras["placement"] = placement
        out_dtype = jnp.dtype(out_dtype or tensors[0].dtype)
        out = ov.dispatch(
            self.name, *tensors, axis=axis, mode=mode,
            chunks=max(1, chunks or 1), backend=backend or "graph",
            wire=wire, out_dtype=out_dtype.name, **extras)
        if self.decl.checkpoint_tag:
            out = checkpoint_name(out, self.decl.checkpoint_tag)
        return out


def declare(op: OverlapOp) -> BoundOp:
    """Register one OverlapOp declaration and return its callable.

    Derives the graph lowering, the kernel lowering (when the declaration
    maps transports to executor protocols), and the dual-schedule
    backward; enters the engine registry — which auto-enrolls the op in
    ``OverlapPolicy`` resolution, the tuner's candidate enumeration and
    the engine parity-test matrix."""
    if op.transpose is not None:
        partner = ov.registry().get(op.transpose)
        if partner is not None and partner.kind not in _DUAL_KIND[op.kind]:
            raise ValueError(
                f"{op.name}: transpose partner {op.transpose!r} has kind "
                f"{partner.kind!r}, not dual to {op.kind!r}")
    cid = next(_CIDS)
    ov.register(
        op.name,
        kind=op.kind,
        transports=op.transports,
        baseline=op.baseline,
        default=op.default,
        fwd=_make_graph_fwd(op),
        bwd=_make_bwd(op),
        kernel_transports=tuple(dict(op.kernel_protocols)),
        kernel_fwd=_make_kernel_fwd(op, cid),
        wires=op.wires,
        placements=getattr(op, "placements", ("contiguous",)),
    )
    bound = BoundOp(op)
    _DECLARED[op.name] = bound
    return bound


def declared() -> Mapping[str, BoundOp]:
    """All ops declared through this front-end (name -> callable)."""
    return dict(_DECLARED)


def get(name: str) -> BoundOp:
    return _DECLARED[name]


# ---------------------------------------------------------------------------
# fuse(): compose an RS declaration into an AG declaration across the
# op boundary (CoCoNet-style rs->ag fusion as a declaration-level feature)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FusedOp:
    """A fused rs->ag boundary declaration, derived by :func:`fuse` from
    two member :class:`OverlapOp` declarations. Carries just enough of
    the :class:`OverlapOp` surface (kind/transports/kernel_protocols/
    default/checkpoint_tag) for :class:`BoundOp` to bind it.

    The fused op's call contract is

        fused(y, *rs_statics, *ag_statics, *mid_tensors,
              axis=..., mid=<static callable>)

    which computes ``ag_tile(mid(reduce_scatter(rs_tile(y-blocks))))``
    all-gathered — i.e. the composition
    ``ag_op(mid(rs_op(y, *rs_statics)), *ag_statics)`` with the boundary
    collective pipelined instead of exposed. ``mid`` is an optional
    rank-local ROW-WISE callable ``mid(reduced, *mid_tensors)`` (residual
    add / norm / activation at the seam); ``mid_tensors`` are ordinary
    differentiable call tensors. ``mid`` itself is a static: pass a
    module-level function so retraces cache.
    """

    name: str
    rs: OverlapOp
    ag: OverlapOp
    transports: Tuple[str, ...] = ("ring", "one_shot")
    baseline: str = "none"
    default: str = "ring"
    kernel_protocols: Tuple[Tuple[str, str], ...] = (
        ("ring", "push_rs_ring_ag"),)
    n_rs_statics: int = 1
    n_ag_statics: int = 1
    checkpoint_tag: Optional[str] = None
    kind: str = "rs_ag"
    rowwise: bool = True
    wires: Tuple[str, ...] = ("f32",)


def _fused_split(fused: FusedOp, rest):
    n_rs, n_ag = fused.n_rs_statics, fused.n_ag_statics
    return (tuple(rest[:n_rs]), tuple(rest[n_rs:n_rs + n_ag]),
            tuple(rest[n_rs + n_ag:]))


def _fused_mid_fn(static):
    mid = static.get("mid")

    def mid_fn(reduced, *mids):
        return mid(reduced, *mids) if mid is not None else reduced

    return mid_fn


def _fused_graph(fused: FusedOp, static, operand, *rest):
    """Graph lowering: chain the engine's rs and ag pipelines through the
    fold API. The boundary is sub-chunked along the reduced block's rows
    by the resolved ``chunks`` knob: chunk c's ag hops depend only on
    chunk c's rs ring, so the consumer's first hops ride while the
    producer's late hops are still reducing — the boundary collective's
    exposed latency disappears from the critical path."""
    axis = static["axis"]
    mode = static["mode"]
    out_dtype = _out_dtype(static, operand)
    rs_statics, ag_statics, mids = _fused_split(fused, rest)
    mid_fn = _fused_mid_fn(static)
    rs_tile = fused.rs.tile_fn()
    ag_tile = fused.ag.tile_fn()
    w = _axis_world(axis)
    m = operand.shape[0]
    assert m % w == 0, (m, w)
    m_blk = m // w

    if mode not in ("ring", "one_shot"):
        raise ValueError(f"{fused.name}: unknown fused mode {mode!r}")
    n_sub = max(1, static.get("chunks", 1))
    if m_blk % n_sub != 0 or mode == "one_shot":
        n_sub = 1
    sub = m_blk // n_sub

    def mid_args(c):
        # row-aligned mid tensors (leading dim == the rank's block) are
        # sliced per boundary chunk; row-broadcast ones (norm scales,
        # scalar eps, ...) pass whole
        if n_sub == 1:
            return mids
        return tuple(_slice_rows(t, c * sub, sub)
                     if t.shape[:1] == (m_blk,) else t for t in mids)

    out = None
    for c in range(n_sub):
        def compute(blk, s, c=c):
            return rs_tile(_slice_rows(operand, blk * m_blk + c * sub, sub),
                           *rs_statics)

        r_c = ov.rs_pipeline(compute, axis, transport=mode).astype(out_dtype)
        h_c = mid_fn(r_c, *mid_args(c))

        def fold(o, bufs, s, owner, c=c):
            t = ag_tile(bufs[0], *ag_statics).astype(out_dtype)
            return _update(o, t, owner * m_blk + c * sub)

        if out is None:
            ts = jax.eval_shape(lambda hh: ag_tile(hh, *ag_statics), h_c)
            out = jnp.zeros((ts.shape[0] * n_sub * w,) + tuple(ts.shape[1:]),
                            out_dtype)
        out = ov.ag_pipeline((h_c,), fold, out, axis, transport=mode)
    return out


def _fused_baseline(fused: FusedOp, static, operand, *rest):
    """Monolithic oracle: the composed unfused pair on XLA collectives
    (psum_scatter, then mid, then all_gather + consumer GEMM)."""
    axis = static["axis"]
    out_dtype = _out_dtype(static, operand)
    rs_statics, ag_statics, mids = _fused_split(fused, rest)
    mid_fn = _fused_mid_fn(static)
    rs_tile = fused.rs.tile_fn()
    ag_tile = fused.ag.tile_fn()
    w = lax.axis_size(axis)
    m_blk = operand.shape[0] // w
    partial = jnp.concatenate(
        [rs_tile(_slice_rows(operand, i * m_blk, m_blk), *rs_statics)
         for i in range(w)], axis=0)
    reduced = lax.psum_scatter(
        partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)
    h = mid_fn(reduced, *mids)
    full = lax.all_gather(h, axis, tiled=True)
    h_loc = h.shape[0]
    return jnp.concatenate(
        [ag_tile(_slice_rows(full, i * h_loc, h_loc),
                 *ag_statics).astype(out_dtype) for i in range(w)], axis=0)


def fuse(rs, ag, *, name: Optional[str] = None,
         transports: Tuple[str, ...] = ("ring", "one_shot"),
         kernel_protocols=(("ring", "push_rs_ring_ag"),),
         n_rs_statics: int = 1, n_ag_statics: int = 1,
         checkpoint_tag: Optional[str] = None) -> "BoundOp":
    """Fuse an RS-kind declaration into an AG-kind declaration across
    the op boundary, deriving a single pipelined declaration.

    ``rs``/``ag`` are member declarations (:class:`OverlapOp` or their
    declared :class:`BoundOp`). The derived op:

    - **graph lowering** chains ``rs_pipeline`` -> ``ag_pipeline``
      through the fold API, sub-chunking the boundary rows by the
      resolved ``chunks`` knob so the consumer's first hops overlap the
      producer's late reductions;
    - **kernel lowering** binds the executor's chained
      ``push_rs_ring_ag`` protocol (per-half workspaces/credits, no
      barrier between the halves);
    - **backward** is derived through the ONE shared custom_vjp as the
      ag->rs transpose of the chain: the members' own dual-schedule
      backwards composed back-to-front around ``jax.vjp`` of the
      boundary ``mid`` — the recompute rides a FIXED graph path, so
      grads are bit-identical across forward backends;
    - **baseline** (mode "none") is the composed unfused pair on XLA
      collectives — the oracle the fused op degrades to under policy.

    Members must be differentiable tile (non-fold) declarations, the
    producer kind "rs", the consumer kind "ag" and rowwise (strips align
    row-wise across boundary sub-chunks).
    """
    rs_decl = rs.decl if isinstance(rs, BoundOp) else rs
    ag_decl = ag.decl if isinstance(ag, BoundOp) else ag
    if rs_decl.kind != "rs":
        raise ValueError(f"fuse: producer must be kind 'rs', got "
                         f"{rs_decl.name!r} ({rs_decl.kind})")
    if ag_decl.kind != "ag":
        raise ValueError(f"fuse: consumer must be kind 'ag', got "
                         f"{ag_decl.name!r} ({ag_decl.kind})")
    if not ag_decl.rowwise:
        raise ValueError(f"fuse: consumer {ag_decl.name!r} must be rowwise "
                         "(boundary strips split along rows)")
    if rs_decl.tile is None or ag_decl.tile is None:
        raise ValueError("fuse: members must declare pure tiles")
    if not (rs_decl.differentiable and ag_decl.differentiable):
        raise ValueError("fuse: members must be differentiable")
    kernel_protocols = tuple(dict(kernel_protocols).items()) \
        if isinstance(kernel_protocols, Mapping) else tuple(kernel_protocols)
    for t, proto in kernel_protocols:
        if proto not in executor.PROTOCOLS:
            raise ValueError(f"fuse: unknown executor protocol {proto!r}")
    fused = FusedOp(
        name=name or f"{rs_decl.name}_{ag_decl.name}",
        rs=rs_decl, ag=ag_decl, transports=tuple(transports),
        kernel_protocols=kernel_protocols, n_rs_statics=n_rs_statics,
        n_ag_statics=n_ag_statics, checkpoint_tag=checkpoint_tag)
    rs_bwd = _make_bwd(rs_decl)
    ag_bwd = _make_bwd(ag_decl)
    protos = dict(kernel_protocols)
    cid = next(_CIDS)

    def fwd(static, operand, *rest):
        if static["mode"] == fused.baseline:
            return _fused_baseline(fused, static, operand, *rest)
        return _fused_graph(fused, static, operand, *rest)

    def kernel_fwd(static, operand, *rest):
        axis = static["axis"]
        w = lax.axis_size(axis)
        rs_statics, ag_statics, mids = _fused_split(fused, rest)
        chain = executor.ChainTile(
            rs=fused.rs.tile, ag=fused.ag.tile, mid=static.get("mid"),
            n_rs=fused.n_rs_statics, n_ag=fused.n_ag_statics)
        return executor.run(
            protos[static["mode"]], chain, operand,
            rs_statics + ag_statics + mids, axis=axis, world=w,
            out_dtype=_out_dtype(static, operand), collective_id=cid)

    def bwd(static, res, g):
        # the ag->rs transpose of the chain: consumer bwd -> mid vjp ->
        # producer bwd, each member riding its own dual schedule. The
        # boundary block is RECOMPUTED on the fixed ring graph path, so
        # the backward never depends on which backend ran the forward —
        # grads are bit-identical across backends by construction.
        operand, *rest = res
        rs_statics, ag_statics, mids = _fused_split(fused, rest)
        axis = static["axis"]
        out_dtype = _out_dtype(static, operand)
        mid_fn = _fused_mid_fn(static)
        rs_tile = fused.rs.tile_fn()
        w = _axis_world(axis)
        m_blk = operand.shape[0] // w

        def compute(blk, s):
            return rs_tile(_slice_rows(operand, blk * m_blk, m_blk),
                           *rs_statics)

        reduced = ov.rs_pipeline(compute, axis,
                                 transport="ring").astype(out_dtype)
        h, mid_vjp = jax.vjp(mid_fn, reduced, *mids)
        member_static = {"axis": axis, "mode": "ring", "chunks": 1,
                         "wire": "f32", "out_dtype": jnp.dtype(out_dtype).name}
        d_h, *d_ag = ag_bwd(member_static, (h,) + ag_statics, g)
        d_reduced, *d_mids = mid_vjp(d_h.astype(h.dtype))
        d_y, *d_rs = rs_bwd(member_static, (operand,) + rs_statics,
                            d_reduced.astype(out_dtype))
        return (d_y,) + tuple(d_rs) + tuple(d_ag) + tuple(d_mids)

    ov.register(
        fused.name,
        kind=fused.kind,
        transports=fused.transports,
        baseline=fused.baseline,
        default=fused.default,
        fwd=fwd,
        bwd=bwd,
        kernel_transports=tuple(protos),
        kernel_fwd=kernel_fwd,
        wires=fused.wires,
    )
    bound = BoundOp(fused)
    _DECLARED[fused.name] = bound
    return bound
