"""`OverlapOp` — declare an overlapped op once, get every lowering derived.

The paper's claim (§2, §3.7) is a *programming model*, not an op zoo: an
overlapped op is a tile-level compute composed with a communication
schedule. This module is that claim as an API. One declaration

    op = declare(OverlapOp(
        name="ag_matmul", kind="ag",
        tile=lambda a_chunk, b: jnp.dot(a_chunk, b,
                                        preferred_element_type=jnp.float32),
        transports=("ring", "bidir", "one_shot"),
        kernel_protocols=(("ring", "ring_ag"), ("one_shot", "one_shot_ag")),
        transpose="matmul_rs",
    ))

derives and registers, from the single ``tile`` function:

  graph lowering   the ``ag_pipeline``/``rs_pipeline`` folds of
                   ``core.overlap`` (lax.ppermute, runs everywhere),
                   including bidir splitting and the sub-chunking knob;
  kernel lowering  the shmem tile executor (``shmem.executor``): the
                   declared protocol wraps ``tile`` in the ring/credit,
                   Alg.-3 push, or one-shot put/signal protocol — remote
                   DMAs on TPU, the emulated DMA engine on CPU;
  backward         the op's dual schedule, via ``jax.vjp`` of ``tile``
                   composed with the transpose pipeline (an AG op's
                   operand gradient rides the dual RS ring and vice
                   versa), routed through the engine's ONE shared
                   custom_vjp — so a kernel forward keeps the graph
                   dual as its backward and grads are bit-identical
                   across backends;
  registration     an ``OverlapSpec`` in the engine registry, which is
                   what ``OverlapPolicy`` resolution, the tuner's
                   candidate enumeration and the parity-test matrix all
                   consume — a declared op shows up in all three with no
                   further wiring.

Contract for ``tile(chunk, *statics)``
--------------------------------------
Pure jax function; the first argument is the tensor that rides the
transport (AG kinds: the gathered operand's per-rank chunk; RS kinds:
one dim-0 block of the local operand), the rest stay rank-resident. It
must be **linear in the riding argument** (every op in the paper is —
the communicated factor of a GEMM enters linearly); statics may enter
arbitrarily. Return the f32 partial; the framework handles output-dtype
casts. Declare ``rowwise=True`` when the tile maps rows to rows
one-to-one (enables bidir halving and the AG sub-chunking knob).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from ..core import overlap as ov
from ..shmem import executor
from ..shmem.executor import slice_rows as _slice_rows
from ..shmem.executor import update_rows as _update

Array = jax.Array

# Dual kinds: an op's transpose partner must lower through the dual
# schedule (the AG operand-gradient rides an RS ring and vice versa).
_DUAL_KIND = {"ag": ("rs",), "gather": ("rs",), "rs": ("ag", "gather"),
              "a2a": ("a2a",)}

# collective_id allocation for declared kernel lowerings (the hand-tuned
# kernels in repro.kernels keep their historical ids below 32).
_CIDS = itertools.count(32)


@dataclass(frozen=True)
class OverlapOp:
    """One overlapped op, declared at tile level.

    name              registry identifier (policy / tuner / test key)
    kind              "ag" | "gather" | "rs" | "a2a" — which side of the
                      transport the op sits on (what rides: the operand
                      chunks, or the accumulator)
    tile              tile compute ``tile(chunk, *statics) -> f32 tile``;
                      None = identity (pure data movement)
    transports        engine transports the graph lowering supports
    baseline          monolithic fallback mode name
    default           transport used when an unsupported mode is asked
    kernel_protocols  (transport, executor protocol) pairs: each one
                      becomes a kernel-backend lowering via the shmem
                      tile executor
    transpose         the dual op's registry name, by reference (the
                      derived backward rides the partner's schedule;
                      validated against the registry)
    rowwise           tile maps chunk rows 1:1 to tile rows — enables
                      bidir halving and AG-side sub-chunking
    static_split      optional ``(statics, n) -> [statics_j] | None``:
                      split the statics into n output column groups (RS
                      sub-chunking and RS bidir); None = not splittable
    split_axis        output axis the split groups concatenate on
    differentiable    derive + register the dual-schedule backward
    baseline_fwd      optional explicit monolithic lowering
                      ``(operand, statics, axis, out_dtype) -> out``
                      (derived from ``tile`` when omitted)
    checkpoint_tag    optional ``checkpoint_name`` tag on the output
                      (remat policies key on it)
    """

    name: str
    kind: str
    tile: Optional[Callable] = None
    transports: Tuple[str, ...] = ("ring",)
    baseline: str = "none"
    default: str = "ring"
    kernel_protocols: Tuple[Tuple[str, str], ...] = ()
    transpose: Optional[str] = None
    rowwise: bool = False
    static_split: Optional[Callable] = None
    split_axis: int = 1
    differentiable: bool = True
    baseline_fwd: Optional[Callable] = None
    checkpoint_tag: Optional[str] = None

    def __post_init__(self):
        if isinstance(self.kernel_protocols, Mapping):
            object.__setattr__(self, "kernel_protocols",
                               tuple(self.kernel_protocols.items()))
        if self.kind not in _DUAL_KIND:
            raise ValueError(f"{self.name}: unknown kind {self.kind!r}")
        for t, proto in self.kernel_protocols:
            if proto not in executor.PROTOCOLS:
                raise ValueError(
                    f"{self.name}: unknown executor protocol {proto!r}")
            if proto == "bidir_ring_ag" and not self.rowwise:
                # the protocol tiles each chunk HALF; a non-rowwise tile
                # would silently diverge from the graph lowering, which
                # degrades non-rowwise bidir to ring
                raise ValueError(
                    f"{self.name}: bidir_ring_ag requires rowwise=True")
        if self.kind == "a2a" and self.kernel_protocols and self.tile is not None:
            # the graph lowering applies an a2a tile once, post-assembly;
            # the executor protocol applies it per landed block — only
            # the tile=None (pure data movement) case agrees by design
            raise ValueError(
                f"{self.name}: a2a kernel protocols require tile=None")

    def tile_fn(self) -> Callable:
        return self.tile if self.tile is not None else (lambda x: x)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _tile_rows(op: OverlapOp, chunk, statics) -> Tuple[int, Tuple[int, ...]]:
    ts = jax.eval_shape(op.tile_fn(), chunk, *statics)
    return ts.shape[0], tuple(ts.shape[1:])


def _out_dtype(static, operand):
    """Output dtype from the static dict (operand dtype when a caller —
    e.g. a legacy string-keyed ``overlap.apply`` — omitted it)."""
    return jnp.dtype(static.get("out_dtype") or operand.dtype)


# ---------------------------------------------------------------------------
# Graph lowering (ag_pipeline / rs_pipeline folds)
# ---------------------------------------------------------------------------


def _ag_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    axis = static["axis"]
    mode = static["mode"]
    out_dtype = _out_dtype(static, operand)
    tile = op.tile_fn()
    w = lax.axis_size(axis)
    m_loc = operand.shape[0]
    tile_m, rest = _tile_rows(op, operand, statics)
    out0 = jnp.zeros((tile_m * w,) + rest, out_dtype)

    if mode == "bidir" and op.rowwise and m_loc % 2 == 0 and w >= 3:
        h = tile_m // 2

        def fold2(out, bufs, s, owner, direction):
            t = tile(bufs[0], *statics).astype(out_dtype)
            return _update(out, t, owner * tile_m + direction * h)

        return ov.bidir_ag_pipeline((operand,), fold2, out0, axis)
    if mode == "bidir":
        mode = "ring"  # odd chunk or W < 3: bidir degenerates to ring
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"{op.name}: unknown ag mode {mode!r}")

    # Sub-chunk ring: finer pipelining shrinks the first-chunk fill
    # bubble (the communication-tile-size knob of §3.6).
    s_sub = max(1, static.get("chunks", 1)) if op.rowwise else 1
    if m_loc % s_sub != 0 or mode == "one_shot":
        s_sub = 1
    m_sub = m_loc // s_sub
    subs = tuple(_slice_rows(operand, j * m_sub, m_sub) for j in range(s_sub))

    def fold(out, bufs, s, owner):
        for j, bj in enumerate(bufs):
            t = tile(bj, *statics).astype(out_dtype)
            out = _update(out, t, owner * tile_m + j * m_sub)
        return out

    return ov.ag_pipeline(subs, fold, out0, axis, transport=mode)


def _rs_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    axis = static["axis"]
    mode = static["mode"]
    out_dtype = _out_dtype(static, operand)
    tile = op.tile_fn()
    w = lax.axis_size(axis)
    m = operand.shape[0]
    assert m % w == 0, (m, w)
    m_blk = m // w

    def block(blk):
        return _slice_rows(operand, blk * m_blk, m_blk)

    if mode == "bidir" and op.static_split is not None and w >= 3:
        halves = op.static_split(statics, 2)
        if halves is not None:
            # split the output columns across BOTH ring directions: two
            # accumulators, half the bytes per link per step.
            def compute2(blk, s, direction):
                return tile(block(blk), *halves[direction])

            acc_f, acc_r = ov.bidir_rs_pipeline(compute2, axis)
            return jnp.concatenate(
                [acc_f, acc_r], axis=op.split_axis).astype(out_dtype)
    if mode == "bidir":
        mode = "ring"
    if mode not in ("ring", "one_shot"):
        raise ValueError(f"{op.name}: unknown rs mode {mode!r}")

    # Sub-chunked RS ring: the accumulator split into column groups, each
    # riding its own independent ring (§3.6's tile-size knob, RS side).
    s_sub = max(1, static.get("chunks", 1))
    groups = (op.static_split(statics, s_sub)
              if s_sub > 1 and mode == "ring" and op.static_split else None)
    if groups is not None:
        outs = [
            ov.rs_pipeline(
                lambda blk, s, g=g: tile(block(blk), *g), axis,
                transport="ring")
            for g in groups
        ]
        return jnp.concatenate(outs, axis=op.split_axis).astype(out_dtype)

    def compute(blk, s):
        return tile(block(blk), *statics)

    return ov.rs_pipeline(compute, axis, transport=mode).astype(out_dtype)


def _a2a_graph(op: OverlapOp, static: Dict[str, Any], operand, *statics):
    out = ov.a2a_pipeline(operand, static["axis"], transport=static["mode"])
    if op.tile is not None:
        out = op.tile(out, *statics)
    return out.astype(_out_dtype(static, operand))


def _default_baseline(op: OverlapOp):
    """Monolithic fallback derived from the tile: collective first, then
    the tile per owner chunk (AG kinds) / tile per block then the
    collective (RS kinds) — the "NCCL + compute" analogue."""
    tile = op.tile_fn()

    def ag_baseline(operand, statics, axis, out_dtype):
        w = lax.axis_size(axis)
        full = lax.all_gather(operand, axis, tiled=True)
        m_loc = operand.shape[0]
        tiles = [
            tile(_slice_rows(full, i * m_loc, m_loc), *statics).astype(out_dtype)
            for i in range(w)
        ]
        return jnp.concatenate(tiles, axis=0)

    def rs_baseline(operand, statics, axis, out_dtype):
        w = lax.axis_size(axis)
        m_blk = operand.shape[0] // w
        partial = jnp.concatenate(
            [tile(_slice_rows(operand, i * m_blk, m_blk), *statics)
             for i in range(w)], axis=0)
        return lax.psum_scatter(
            partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)

    return rs_baseline if op.kind == "rs" else ag_baseline


def _make_graph_fwd(op: OverlapOp) -> Callable:
    lower = {"ag": _ag_graph, "gather": _ag_graph, "rs": _rs_graph,
             "a2a": _a2a_graph}[op.kind]
    baseline = op.baseline_fwd or _default_baseline(op)

    def fwd(static, operand, *statics):
        if static["mode"] == op.baseline and op.kind != "a2a":
            return baseline(operand, statics, static["axis"],
                            _out_dtype(static, operand))
        return lower(op, static, operand, *statics)

    return fwd


# ---------------------------------------------------------------------------
# Kernel lowering (the shmem tile executor)
# ---------------------------------------------------------------------------


def _make_kernel_fwd(op: OverlapOp, cid: int) -> Optional[Callable]:
    if not op.kernel_protocols:
        return None
    protos = dict(op.kernel_protocols)

    def kernel_fwd(static, operand, *statics):
        axis = static["axis"]
        return executor.run(
            protos[static["mode"]], op.tile, operand, statics, axis=axis,
            world=lax.axis_size(axis),
            out_dtype=_out_dtype(static, operand), collective_id=cid)

    return kernel_fwd


# ---------------------------------------------------------------------------
# Backward derivation: the dual schedule over jax.vjp of the tile
# ---------------------------------------------------------------------------


def _make_bwd(op: OverlapOp) -> Optional[Callable]:
    if not op.differentiable:
        return None
    if op.kind == "a2a":
        if op.tile is not None:
            # a post-assembly tile keeps autodiff-through-pipeline; only
            # pure data movement gets the derived self-dual backward.
            return None

        def a2a_bwd(static, res, g):
            # AllToAll is its own transpose as a global linear map (the
            # (rank, block) index swap is symmetric): the cotangent rides
            # the same decomposed a2a back.
            (operand,) = res
            mode = static["mode"]
            if mode not in ("xla",) + op.transports:
                mode = op.default
            d = ov.a2a_pipeline(g, static["axis"], transport=mode)
            return (d.astype(operand.dtype),)

        return a2a_bwd
    tile = op.tile_fn()

    def tile_cast(out_dtype, chunk, *statics):
        return tile(chunk, *statics).astype(out_dtype)

    if op.kind in ("ag", "gather"):

        def bwd(static, res, g):
            operand, *statics = res
            axis = static["axis"]
            out_dtype = _out_dtype(static, operand)
            tile_m, rest = _tile_rows(op, operand, statics)
            zeros = jnp.zeros(operand.shape, operand.dtype)

            # operand gradient: rides the DUAL RS ring (the transpose
            # partner's schedule) — O(1) permute buffers.
            def compute_block(blk, s):
                g_blk = _slice_rows(g, blk * tile_m, tile_m)
                _, vjp = jax.vjp(
                    lambda xc: tile_cast(out_dtype, xc, *statics), zeros)
                return vjp(g_blk)[0].astype(jnp.float32)

            d_op = ov.rs_pipeline(
                compute_block, axis, transport="ring").astype(operand.dtype)
            if not statics:
                return (d_op,)

            # statics gradients: ring the residual chunk past the static
            # cotangent strips, accumulating in f32.
            def fold(ds, bufs, s, owner):
                g_o = _slice_rows(g, owner * tile_m, tile_m)
                _, vjp = jax.vjp(
                    lambda *st: tile_cast(out_dtype, bufs[0], *st), *statics)
                return tuple(d + gi.astype(jnp.float32)
                             for d, gi in zip(ds, vjp(g_o)))

            ds0 = tuple(jnp.zeros(s.shape, jnp.float32) for s in statics)
            d_statics = ov.ag_pipeline((operand,), fold, ds0, axis,
                                       transport="ring")
            return (d_op,) + tuple(
                d.astype(s.dtype) for d, s in zip(d_statics, statics))

        return bwd

    def bwd(static, res, g):  # kind == "rs"
        operand, *statics = res
        axis = static["axis"]
        out_dtype = _out_dtype(static, operand)
        w = lax.axis_size(axis)
        m_blk = operand.shape[0] // w

        # ONE dual AG ring of the cotangent block: each arriving g chunk
        # yields this rank's operand-block gradient (scattered at the
        # owner's rows) AND its statics contribution — both vjps of the
        # tile at the true local primal block.
        def fold(carry, bufs, s, owner):
            d_opnd, ds = carry
            blk_val = _slice_rows(operand, owner * m_blk, m_blk)
            _, vjp = jax.vjp(
                lambda xb, *st: tile_cast(out_dtype, xb, *st),
                blk_val, *statics)
            grads = vjp(bufs[0])
            d_opnd = _update(d_opnd, grads[0].astype(jnp.float32),
                             owner * m_blk)
            ds = tuple(d + gi.astype(jnp.float32)
                       for d, gi in zip(ds, grads[1:]))
            return d_opnd, ds

        init = (jnp.zeros(operand.shape, jnp.float32),
                tuple(jnp.zeros(s.shape, jnp.float32) for s in statics))
        d_opnd, d_statics = ov.ag_pipeline((g,), fold, init, axis,
                                           transport="ring")
        return (d_opnd.astype(operand.dtype),) + tuple(
            d.astype(s.dtype) for d, s in zip(d_statics, statics))

    return bwd


# ---------------------------------------------------------------------------
# declare() + the bound callable
# ---------------------------------------------------------------------------

_DECLARED: Dict[str, "BoundOp"] = {}


class BoundOp:
    """A declared op, callable with a policy: ``op(x, w, axis=...,
    policy=pcfg.policy)`` or with explicit ``mode=/backend=/chunks=``
    overrides. Runs inside ``shard_map``; routed through the engine's
    shared custom_vjp when the declaration is differentiable."""

    def __init__(self, op: OverlapOp):
        self.decl = op
        self.name = op.name
        self.__doc__ = f"Overlapped op {op.name!r} ({op.kind}): " \
                       f"transports {op.transports}, " \
                       f"kernel {tuple(dict(op.kernel_protocols))}"

    @property
    def spec(self) -> ov.OverlapSpec:
        return ov.get(self.name)

    def __repr__(self):
        return f"<ops.{self.name} kind={self.decl.kind}>"

    def __call__(self, *tensors, axis: str, policy=None, mode: Optional[str] = None,
                 backend: Optional[str] = None, chunks: Optional[int] = None,
                 out_dtype=None):
        if policy is not None:
            r = policy.resolve(self.name)
            mode = mode or r.mode
            backend = backend or r.backend
            chunks = r.chunks if chunks is None else chunks
        mode = ov.resolve_mode(self.name, mode or self.decl.default)
        out_dtype = jnp.dtype(out_dtype or tensors[0].dtype)
        out = ov.dispatch(
            self.name, *tensors, axis=axis, mode=mode,
            chunks=max(1, chunks or 1), backend=backend or "graph",
            out_dtype=out_dtype.name)
        if self.decl.checkpoint_tag:
            out = checkpoint_name(out, self.decl.checkpoint_tag)
        return out


def declare(op: OverlapOp) -> BoundOp:
    """Register one OverlapOp declaration and return its callable.

    Derives the graph lowering, the kernel lowering (when the declaration
    maps transports to executor protocols), and the dual-schedule
    backward; enters the engine registry — which auto-enrolls the op in
    ``OverlapPolicy`` resolution, the tuner's candidate enumeration and
    the engine parity-test matrix."""
    if op.transpose is not None:
        partner = ov.registry().get(op.transpose)
        if partner is not None and partner.kind not in _DUAL_KIND[op.kind]:
            raise ValueError(
                f"{op.name}: transpose partner {op.transpose!r} has kind "
                f"{partner.kind!r}, not dual to {op.kind!r}")
    cid = next(_CIDS)
    ov.register(
        op.name,
        kind=op.kind,
        transports=op.transports,
        baseline=op.baseline,
        default=op.default,
        fwd=_make_graph_fwd(op),
        bwd=_make_bwd(op),
        kernel_transports=tuple(dict(op.kernel_protocols)),
        kernel_fwd=_make_kernel_fwd(op, cid),
    )
    bound = BoundOp(op)
    _DECLARED[op.name] = bound
    return bound


def declared() -> Mapping[str, BoundOp]:
    """All ops declared through this front-end (name -> callable)."""
    return dict(_DECLARED)


def get(name: str) -> BoundOp:
    return _DECLARED[name]
