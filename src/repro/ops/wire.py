"""Wire codecs: scaled low-precision block formats for riding chunks.

A *wire dtype* controls how a chunk travels between ranks inside an overlap
schedule. ``f32`` means "as-is" (whatever dtype the operand already has).
``int8`` / ``fp8`` quantize each row (last axis) to a 1-byte payload plus one
f32 scale, cutting ICI bytes to roughly ``1/dtype_bytes`` of the original.

Two representations are used by the lowerings:

* **split** — ``(payload, scales)`` as separate arrays. The graph lowerings
  ride both through the engine pipelines as sibling operands.
* **packed** — a single ``uint8`` buffer of shape ``(..., k + 4)``: the
  payload bitcast to bytes, with the row's f32 scale appended as 4 trailing
  bytes. The kernel lowerings push packed buffers through the executor's
  existing riding-chunk workspaces unchanged.

Accumulation is always f32: ``decode`` returns f32 regardless of the payload
dtype, and reductions add decoded blocks in f32 before the final output cast.
``ef_encode`` implements error feedback for repeated reductions (the residual
of this step's quantization is carried into the next step's input).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .policy import WIRE_DTYPES

Array = jax.Array

SCALE_BYTES = 4  # one f32 scale per row, appended to the packed payload

_QMAX = {"int8": 127.0, "fp8": 448.0}  # float8_e4m3fn max finite = 448


def _payload_dtype(wire: str):
    return jnp.int8 if wire == "int8" else jnp.float8_e4m3fn


def _check(wire: str) -> None:
    if wire not in WIRE_DTYPES:
        raise ValueError(f"unknown wire dtype {wire!r} (valid: {WIRE_DTYPES})")
    if wire == "f32":
        raise ValueError("wire 'f32' has no codec (chunks ride as-is)")


def encode(x: Array, wire: str) -> Tuple[Array, Array]:
    """Per-row symmetric quantization: ``x -> (payload, scales)``.

    ``scales`` has shape ``x.shape[:-1] + (1,)`` in f32. The int8 path is the
    exact formula ``dist/compress.py`` pinned before it moved here.
    """
    _check(wire)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / _QMAX[wire]
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if wire == "int8":
        payload = jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    else:
        payload = jnp.clip(y, -448.0, 448.0).astype(jnp.float8_e4m3fn)
    return payload, scale


def decode(payload: Array, scales: Array) -> Array:
    """Dequantize to f32: ``payload * scales`` (accumulation dtype)."""
    return payload.astype(jnp.float32) * scales.astype(jnp.float32)


def ef_encode(g: Array, ef: Array, wire: str) -> Tuple[Array, Array, Array]:
    """Error-feedback encode: returns ``(payload, scales, new_ef)``.

    The carried residual ``ef`` is added before quantizing; the new residual
    is what this step's quantization lost. Repeated reductions with the
    residual fed back have bounded accumulated bias.
    """
    gf = g.astype(jnp.float32) + ef.astype(jnp.float32)
    payload, scale = encode(gf, wire)
    return payload, scale, gf - decode(payload, scale)


def pack(payload: Array, scales: Array) -> Array:
    """Pack ``(payload, scales)`` into one uint8 buffer of shape (..., k+4)."""
    pb = lax.bitcast_convert_type(payload, jnp.uint8)
    sb = lax.bitcast_convert_type(scales.astype(jnp.float32), jnp.uint8)
    # scales (..., 1) -> bytes (..., 1, 4) -> (..., 4)
    sb = sb.reshape(sb.shape[:-2] + (SCALE_BYTES,))
    return jnp.concatenate([pb, sb], axis=-1)


def unpack(buf: Array, wire: str) -> Tuple[Array, Array]:
    """Invert :func:`pack`: uint8 (..., k+4) -> (payload, scales)."""
    _check(wire)
    k = buf.shape[-1] - SCALE_BYTES
    payload = lax.bitcast_convert_type(buf[..., :k], _payload_dtype(wire))
    sb = buf[..., k:].reshape(buf.shape[:-1] + (1, SCALE_BYTES))
    scales = lax.bitcast_convert_type(sb, jnp.float32)
    return payload, scales


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Bound helpers for one wire dtype (``codec("f32") is None``)."""

    name: str

    def encode(self, x: Array) -> Tuple[Array, Array]:
        return encode(x, self.name)

    def decode(self, payload: Array, scales: Array) -> Array:
        return decode(payload, scales)

    def pack(self, x: Array) -> Array:
        return pack(*encode(x, self.name))

    def unpack_decode(self, buf: Array) -> Array:
        return decode(*unpack(buf, self.name))

    def roundtrip(self, x: Array) -> Array:
        return decode(*encode(x, self.name))


def codec(wire: str) -> Optional[WireCodec]:
    """Codec for ``wire``, or ``None`` for ``"f32"`` (ride as-is)."""
    if wire == "f32":
        return None
    _check(wire)
    return WireCodec(wire)


@dataclasses.dataclass(frozen=True)
class MultiCodec:
    """Multi-operand packed layout: one riding buffer whose last axis
    carries several concatenated sections (e.g. ring attention's K|V
    chunk, sections ``(d, d)``), each quantized per-row with its OWN
    scale — a shared scale across K and V would let the larger-magnitude
    operand swamp the other's resolution.

    The split representation is ``(payload (..., sum k_i), scales
    (..., n))``; the packed one is a single uint8 buffer of shape
    ``(..., sum k_i + 4n)`` with the n f32 scales appended as trailing
    bytes, so the executor's riding-chunk workspaces carry it unchanged.
    """

    name: str
    sections: Tuple[int, ...]

    def _split(self, x: Array):
        out, off = [], 0
        for k in self.sections:
            out.append(x[..., off:off + k])
            off += k
        return out

    def encode(self, x: Array) -> Tuple[Array, Array]:
        parts = [encode(p, self.name) for p in self._split(x)]
        payload = jnp.concatenate([p for p, _ in parts], axis=-1)
        scales = jnp.concatenate([s for _, s in parts], axis=-1)
        return payload, scales

    def decode(self, payload: Array, scales: Array) -> Array:
        out, off = [], 0
        for i, k in enumerate(self.sections):
            out.append(decode(payload[..., off:off + k],
                              scales[..., i:i + 1]))
            off += k
        return jnp.concatenate(out, axis=-1)

    def pack(self, x: Array) -> Array:
        payload, scales = self.encode(x)
        pb = lax.bitcast_convert_type(payload, jnp.uint8)
        sb = lax.bitcast_convert_type(scales.astype(jnp.float32), jnp.uint8)
        # scales (..., n) -> bytes (..., n, 4) -> (..., 4n)
        sb = sb.reshape(sb.shape[:-2] + (len(self.sections) * SCALE_BYTES,))
        return jnp.concatenate([pb, sb], axis=-1)

    def unpack_decode(self, buf: Array) -> Array:
        n = len(self.sections)
        k = buf.shape[-1] - n * SCALE_BYTES
        payload = lax.bitcast_convert_type(buf[..., :k],
                                           _payload_dtype(self.name))
        sb = buf[..., k:].reshape(buf.shape[:-1] + (n, SCALE_BYTES))
        scales = lax.bitcast_convert_type(sb, jnp.float32)
        return self.decode(payload, scales)

    def roundtrip(self, x: Array) -> Array:
        return self.decode(*self.encode(x))

    def packed_cols(self) -> int:
        """Packed-buffer width for one row: payload + scale bytes."""
        return sum(self.sections) + len(self.sections) * SCALE_BYTES


def multi_codec(wire: str, sections) -> Optional[MultiCodec]:
    """Codec for a multi-section riding buffer, or ``None`` for "f32"."""
    if wire == "f32":
        return None
    _check(wire)
    return MultiCodec(wire, tuple(int(s) for s in sections))


def wire_bytes(rows: int, cols: int, wire: str, dtype_bytes: int) -> float:
    """Bytes on the wire for a (rows, cols) chunk — the tuner's bytes term."""
    if wire == "f32":
        return float(rows * cols * dtype_bytes)
    return float(rows * (cols + SCALE_BYTES))
