"""repro.ops — the declarative op-authoring front-end.

This package is the primary user surface for overlapped ops, replacing
the string-keyed ``overlap.apply("ag_matmul", ...)`` plus four parallel
per-op dicts on ``ParallelConfig``:

  ``OverlapOp`` / ``declare``   author a new overlapped op from ONE
      tile-level declaration; the graph lowering (ppermute pipelines),
      the kernel lowering (shmem tile executor protocols), the
      dual-schedule backward and the registry/tuner/test enrollment are
      all derived. See ``authoring`` for the contract.

  ``OverlapPolicy``             the ONE object answering "how should op
      X overlap?" — mode/backend defaults, per-op override maps and the
      chunk knobs, with a single ``resolve(op, hw)`` clamped against the
      live registry. Lives on ``ParallelConfig.overlap`` and is produced
      whole by ``tuner.recommend_overlap_modes``.

  ``ops.fuse`` / ``OverlapOp.fuse``   compose declarations ACROSS op
      boundaries: ``fuse(matmul_rs, ag_matmul)`` derives the single
      pipelined rs->ag declaration ``ops.matmul_rs_ag_matmul`` (graph
      lowering chains the engine pipelines, kernel lowering binds the
      executor's chained ``push_rs_ring_ag`` protocol, backward is the
      members' duals composed ag->rs).

  ``ops.ag_matmul`` / ``ops.matmul_rs`` / ``ops.all_gather``   the
      standard library, declared in ``library`` — call them inside
      ``shard_map`` as ``ops.ag_matmul(x, w, axis="model",
      policy=pcfg.policy)``.

The pre-PR-3 string-keyed surface (``overlap.apply(name, ...)``,
``ParallelConfig.with_modes/with_backends``) is GONE — use
``ops.<name>(...)`` and ``OverlapPolicy.with_modes/with_backends`` (or
the shape-keyed ``OverlapPolicy.with_layer`` / ``tuner.search``) on the
config.
"""
from . import wire
from .authoring import (
    BoundOp,
    FoldTile,
    FusedOp,
    OverlapOp,
    declare,
    declared,
    fuse,
    get,
)
from .library import (
    a2a_ep,
    ag_matmul,
    ag_matmul_2level,
    all_gather,
    flash_decode,
    matmul_rs,
    matmul_rs_2level,
    matmul_rs_ag_matmul,
    reduce_scatter,
    ring_attention,
)
from .policy import (
    DEFAULT_MODES,
    LATENCY_OPS,
    WIRE_DTYPES,
    OverlapPolicy,
    ResolvedOverlap,
    shape_key,
)

__all__ = [
    "BoundOp",
    "FoldTile",
    "FusedOp",
    "OverlapOp",
    "OverlapPolicy",
    "ResolvedOverlap",
    "DEFAULT_MODES",
    "LATENCY_OPS",
    "WIRE_DTYPES",
    "shape_key",
    "wire",
    "a2a_ep",
    "ag_matmul",
    "ag_matmul_2level",
    "all_gather",
    "flash_decode",
    "matmul_rs",
    "matmul_rs_2level",
    "matmul_rs_ag_matmul",
    "reduce_scatter",
    "ring_attention",
    "declare",
    "declared",
    "fuse",
    "get",
]
