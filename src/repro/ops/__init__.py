"""repro.ops — the declarative op-authoring front-end.

This package is the primary user surface for overlapped ops, replacing
the string-keyed ``overlap.apply("ag_matmul", ...)`` plus four parallel
per-op dicts on ``ParallelConfig``:

  ``OverlapOp`` / ``declare``   author a new overlapped op from ONE
      tile-level declaration; the graph lowering (ppermute pipelines),
      the kernel lowering (shmem tile executor protocols), the
      dual-schedule backward and the registry/tuner/test enrollment are
      all derived. See ``authoring`` for the contract.

  ``OverlapPolicy``             the ONE object answering "how should op
      X overlap?" — mode/backend defaults, per-op override maps and the
      chunk knobs, with a single ``resolve(op, hw)`` clamped against the
      live registry. Lives on ``ParallelConfig.overlap`` and is produced
      whole by ``tuner.recommend_overlap_modes``.

  ``ops.ag_matmul`` / ``ops.matmul_rs`` / ``ops.all_gather``   the
      standard library, declared in ``library`` — call them inside
      ``shard_map`` as ``ops.ag_matmul(x, w, axis="model",
      policy=pcfg.policy)``.

Migration from the string-keyed surface (kept as DeprecationWarning
shims): ``overlap.apply(name, ...)`` -> ``ops.<name>(...)``;
``ParallelConfig.with_modes/with_backends`` -> ``pcfg.policy.with_modes``
/ ``OverlapPolicy`` on the config.
"""
from . import wire
from .authoring import BoundOp, FoldTile, OverlapOp, declare, declared, get
from .library import (
    a2a_ep,
    ag_matmul,
    ag_matmul_2level,
    all_gather,
    flash_decode,
    matmul_rs,
    matmul_rs_2level,
    reduce_scatter,
    ring_attention,
)
from .policy import LATENCY_OPS, WIRE_DTYPES, OverlapPolicy, ResolvedOverlap

__all__ = [
    "BoundOp",
    "FoldTile",
    "OverlapOp",
    "OverlapPolicy",
    "ResolvedOverlap",
    "LATENCY_OPS",
    "WIRE_DTYPES",
    "wire",
    "a2a_ep",
    "ag_matmul",
    "ag_matmul_2level",
    "all_gather",
    "flash_decode",
    "matmul_rs",
    "matmul_rs_2level",
    "reduce_scatter",
    "ring_attention",
    "declare",
    "declared",
    "get",
]
