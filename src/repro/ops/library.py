"""The standard overlapped-op library, as `OverlapOp` declarations.

These used to be ~350 lines of hand-written graph folds plus three
hand-rolled kernel protocol loops; each is now one declaration whose
graph lowering, kernel lowering (shmem tile executor) and dual-schedule
backward are derived by ``authoring.declare``. The registry names are
unchanged, so policies, the tuner and the parity tests see the same ops.

Note the ``matmul_rs`` one_shot kernel protocol: the ROADMAP's
"push all partials up-front" rs_gemm variant is the pair
``("one_shot", "one_shot_rs")`` below — the authoring API's whole
cost for a new kernel lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .authoring import FoldTile, OverlapOp, declare, fuse


def _dot_tile(chunk, w):
    return jnp.dot(chunk, w, preferred_element_type=jnp.float32)


def _split_cols(statics, n):
    """Split the weight's output columns into n groups (RS sub-chunking
    and the bidir column halves); None when the columns don't divide."""
    (w,) = statics
    if n < 2 or w.shape[1] % n:
        return None
    n_sub = w.shape[1] // n
    return [
        (lax.dynamic_slice(w, (0, j * n_sub), (w.shape[0], n_sub)),)
        for j in range(n)
    ]


def _ag_matmul_baseline(operand, statics, axis, out_dtype):
    """all_gather(A) @ B with XLA's built-in collective (one big dot)."""
    a_full = lax.all_gather(operand, axis, tiled=True)
    return jnp.dot(a_full, statics[0],
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _matmul_rs_baseline(operand, statics, axis, out_dtype):
    """psum_scatter(A @ B) with XLA's built-in collective."""
    partial = jnp.dot(operand, statics[0], preferred_element_type=jnp.float32)
    return lax.psum_scatter(
        partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)


ag_matmul = declare(OverlapOp(
    name="ag_matmul",
    kind="ag",
    tile=_dot_tile,
    transports=("ring", "bidir", "one_shot"),
    kernel_protocols=(("ring", "ring_ag"), ("bidir", "bidir_ring_ag"),
                      ("one_shot", "one_shot_ag")),
    transpose="matmul_rs",
    rowwise=True,
    baseline_fwd=_ag_matmul_baseline,
    wires=("f32", "int8", "fp8"),
    # remat policy "block_save_ag" keeps gathered activations across the
    # backward instead of re-running the gather ring
    checkpoint_tag="ag_out",
))

matmul_rs = declare(OverlapOp(
    name="matmul_rs",
    kind="rs",
    tile=_dot_tile,
    transports=("ring", "bidir", "one_shot"),
    kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
    transpose="ag_matmul",
    static_split=_split_cols,
    split_axis=1,
    baseline_fwd=_matmul_rs_baseline,
    wires=("f32", "int8", "fp8"),
))

all_gather = declare(OverlapOp(
    name="all_gather",
    kind="gather",
    tile=None,  # identity: pure decomposed data movement
    transports=("ring", "one_shot"),
    kernel_protocols=(("ring", "ring_ag"), ("one_shot", "one_shot_ag")),
    transpose="reduce_scatter",
    rowwise=True,
    wires=("f32", "int8", "fp8"),
))


def _f32_block(block):
    # linear "tile": cast so the ring/push accumulation runs in f32
    return block.astype(jnp.float32)


reduce_scatter = declare(OverlapOp(
    name="reduce_scatter",
    kind="rs",
    tile=_f32_block,
    transports=("ring", "one_shot"),
    kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
    transpose="all_gather",
    wires=("f32", "int8", "fp8"),
))

# EP AllToAll (paper Fig. 16): pure data movement over the leading
# per-destination block dim. The kernel lowering is the executor's
# one-shot a2a push protocol; the derived backward is the SAME a2a on
# the cotangent (AllToAll is its own transpose). The inverse direction
# (combine) reuses this op with transposed block placement — see
# ``core.moe_overlap.a2a_ep_inverse``.
a2a_ep = declare(OverlapOp(
    name="a2a_ep",
    kind="a2a",
    tile=None,
    transports=("one_shot",),
    baseline="xla",
    default="one_shot",
    kernel_protocols=(("one_shot", "one_shot_a2a"),),
    wires=("f32", "int8", "fp8"),
))


def _stack_tile(packed):
    # the LSE-stacking tile: one rank's packed (o, lse) partial becomes a
    # leading-dim-1 strip of the (W, ...) stacked combine input
    return packed[None]


# The distributed flash-decode combine (paper §4.2): a small-message
# stacked AllGather of the packed (o, lse) partials. Binding one_shot_ag
# with the stacking tile IS the kernel lowering; the logsumexp merge
# stays outside (``core.flash_decode``).
flash_decode = declare(OverlapOp(
    name="flash_decode",
    kind="gather",
    tile=_stack_tile,
    transports=("ring", "one_shot"),
    baseline="xla",
    default="one_shot",
    kernel_protocols=(("one_shot", "one_shot_ag"),),
))


# ---------------------------------------------------------------------------
# Ring attention — context parallelism as a STATEFUL FOLD declaration.
# The riding operand is the packed K/V chunk (concatenated on the last
# axis); the resident static is q; the fold state is the blockwise
# online-softmax carry (m, l, acc) in f32. Kernel lowerings: ring ->
# the executor's carry-passing ring_fold protocol; one_shot -> the
# low-latency gather with the fold chain replayed host-side. The
# backward is jax.vjp through the fold chain (authoring derives it).
# ``ctx`` extras: axis (rank offsets for the causal mask), causal, scale,
# and optionally placement (the chunk->rank owner map — zigzag/striped
# equalize per-rank causal work, see ``core.schedules.placement_rows``)
# and with_stats (append the softmax stats (m, l) as two extra output
# channels, for partial-attention merges like CP chunked prefill).
# ---------------------------------------------------------------------------


def _global_positions(placement, world, owner, n):
    """Global sequence positions of ``owner``'s n local rows, as traced
    i32. The jnp twin of ``core.schedules.placement_rows`` (``owner`` may
    be a traced rank index; ``world``/``n`` are static). All placements
    yield strictly increasing positions, so local row order IS position
    order (rope and masks need no per-rank permutation)."""
    idx = jnp.arange(n)
    if placement == "zigzag":
        h = n // 2
        return jnp.where(idx < h, owner * h + idx,
                         (2 * world - 1 - owner) * h + (idx - h))
    if placement == "striped":
        return idx * world + owner
    return owner * n + idx


def _attn_init(ctx, packed, q):
    del ctx, packed
    b, h, s_loc, d = q.shape
    return (
        jnp.full((b, h, s_loc), -1e30, jnp.float32),  # running max
        jnp.zeros((b, h, s_loc), jnp.float32),  # running sum
        jnp.zeros((b, h, s_loc, d), jnp.float32),  # weighted-value acc
    )


def _attn_fold(ctx, state, packed, owner, q):
    b, h, s_loc, d = q.shape
    hkv = packed.shape[1]
    group = h // hkv
    qf = q.astype(jnp.float32) * ctx["scale"]
    buf_k, buf_v = packed[..., :d], packed[..., d:]
    causal = ctx["causal"]
    if causal:
        placement = ctx.get("placement", "contiguous")
        world = lax.axis_size(ctx["axis"])
        me = lax.axis_index(ctx["axis"])
        rows = _global_positions(placement, world, me, s_loc)  # my q pos
        cols = _global_positions(placement, world, owner, packed.shape[2])

    def step(st):
        m, l, acc = st
        kk = jnp.repeat(buf_k.astype(jnp.float32), group, axis=1)
        vv = jnp.repeat(buf_v.astype(jnp.float32), group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        if causal:
            mask = rows[:, None] >= cols[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return m_new, l, acc

    if not causal:
        return step(state)
    # whole-block skip: positions are strictly increasing, so a block is
    # fully masked iff max(rows) < min(cols). Skipping is bitwise equal
    # to folding it (all-masked => p == 0 and alpha == 1 exactly; m is
    # finite because the ring's step 0 is always the own block) but
    # drops the einsums — this is where zigzag/striped turn equalized
    # causal COVERAGE into equalized per-rank COMPUTE.
    return lax.cond(rows[-1] >= cols[0], step, lambda st: st, state)


def _attn_live(ctx, owner, q):
    """The fold's whole-block-skip predicate, exposed so the executor's
    timeline can drop the span of a fully-masked block (``None`` =
    always live for non-causal calls)."""
    if not ctx.get("causal"):
        return None
    placement = ctx.get("placement", "contiguous")
    world = lax.axis_size(ctx["axis"])
    me = lax.axis_index(ctx["axis"])
    rows = _global_positions(placement, world, me, q.shape[2])
    cols = _global_positions(placement, world, owner, q.shape[2])
    return rows[-1] >= cols[0]


def _attn_finalize(ctx, state, q):
    del q
    m, l, acc = state
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    if ctx.get("with_stats"):
        # channel-concat the online-softmax stats: (..., d) -> (..., d+2)
        # with m (running max) and l (sum of exp) trailing, so partial
        # attentions merge downstream (CP prefill's pool-prefix merge).
        # Callers pass out_dtype=f32 to keep them exact through the cast.
        return jnp.concatenate([out, m[..., None], l[..., None]], axis=-1)
    return out


def _attn_baseline(static, packed, q):
    """Monolithic baseline: gather the full K/V, one softmax pass. The
    same owner->row map as the fold path is applied locally, so
    placements survive mode degradation."""
    axis = static["axis"]
    b, h, s_loc, d = q.shape
    group = h // packed.shape[1]
    w = lax.axis_size(axis)
    placement = static.get("placement", "contiguous")
    kvf = jnp.repeat(
        lax.all_gather(packed, axis, axis=2, tiled=True).astype(jnp.float32),
        group, axis=1)
    kf, vf = kvf[..., :d], kvf[..., d:]
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * static["scale"], kf)
    if static["causal"]:
        me = lax.axis_index(axis)
        s_kv = packed.shape[2]
        rows_g = _global_positions(placement, w, me, s_loc)
        cols_g = jnp.concatenate(
            [_global_positions(placement, w, o, s_kv) for o in range(w)])
        mask = rows_g[:, None] >= cols_g[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-30)[..., None], vf)
    if static.get("with_stats"):
        out = jnp.concatenate([out, m[..., None], l[..., None]], axis=-1)
    return out.astype(jnp.dtype(static.get("out_dtype") or q.dtype))


def _attn_wire_split(packed, q):
    """K and V sections of the riding packed chunk, each quantized with
    its own per-row wire scale (K and V magnitudes differ)."""
    d = q.shape[-1]
    return (d, packed.shape[-1] - d)


ring_attention = declare(OverlapOp(
    name="ring_attention",
    kind="attn",
    fold=FoldTile(init=_attn_init, fold=_attn_fold, finalize=_attn_finalize,
                  live=_attn_live),
    transports=("ring", "one_shot"),
    baseline="none",
    default="ring",
    kernel_protocols=(("ring", "ring_fold"), ("one_shot", "one_shot_ag")),
    baseline_fwd=_attn_baseline,
    wires=("f32", "int8", "fp8"),
    wire_split=_attn_wire_split,
    placements=("contiguous", "zigzag", "striped"),
))


# ---------------------------------------------------------------------------
# 2-level (Fig. 10) collective matmuls — compound (pod x ring-in-pod)
# meshes, called with axis=(inner, outer). Graph lowers through the
# engine's two_level_*_pipeline schedules; kernel through the executor's
# two-axis protocols (pod-local one_shot exchange concurrent with the
# inter-pod ring). The derived backward rides the two-level duals.
# ---------------------------------------------------------------------------


def _ag_matmul_2level_baseline(operand, statics, axis, out_dtype):
    """Nested XLA all_gathers (inner then outer: owner-major rows) + dot."""
    inner, outer = axis
    a_full = lax.all_gather(
        lax.all_gather(operand, inner, tiled=True), outer, tiled=True)
    return jnp.dot(a_full, statics[0],
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _matmul_rs_2level_baseline(operand, statics, axis, out_dtype):
    """dot + nested psum_scatters (outer then inner: my linearized block)."""
    inner, outer = axis
    partial = jnp.dot(operand, statics[0], preferred_element_type=jnp.float32)
    p = lax.psum_scatter(partial, outer, scatter_dimension=0, tiled=True)
    return lax.psum_scatter(
        p, inner, scatter_dimension=0, tiled=True).astype(out_dtype)


ag_matmul_2level = declare(OverlapOp(
    name="ag_matmul_2level",
    kind="ag",
    tile=_dot_tile,
    transports=("two_level",),
    default="two_level",
    kernel_protocols=(("two_level", "two_level_ag"),),
    transpose="matmul_rs_2level",
    baseline_fwd=_ag_matmul_2level_baseline,
))

matmul_rs_2level = declare(OverlapOp(
    name="matmul_rs_2level",
    kind="rs",
    tile=_dot_tile,
    transports=("two_level",),
    default="two_level",
    kernel_protocols=(("two_level", "two_level_rs"),),
    transpose="ag_matmul_2level",
    baseline_fwd=_matmul_rs_2level_baseline,
))


# ---------------------------------------------------------------------------
# The fused attention-out -> MLP-in boundary (CoCoNet rs->ag fusion):
# matmul_rs chained into ag_matmul as ONE declaration. Call contract:
#
#   matmul_rs_ag_matmul(y, w_out, w_in, *mid_tensors,
#                       axis=..., policy=..., mid=<rank-local row fn>)
#
# == ag_matmul(mid(matmul_rs(y, w_out)), w_in) with the boundary
# reduce-scatter/all-gather pipelined away instead of exposed. Mode
# "none" (the registered baseline, and the session default via
# ``OverlapPolicy``'s DEFAULT_MODES) degrades to the composed unfused
# pair on XLA collectives — the oracle the parity tests pin against.
# ---------------------------------------------------------------------------

matmul_rs_ag_matmul = fuse(matmul_rs, ag_matmul, checkpoint_tag="boundary_out")
