"""The standard overlapped-op library, as `OverlapOp` declarations.

These used to be ~350 lines of hand-written graph folds plus three
hand-rolled kernel protocol loops; each is now one declaration whose
graph lowering, kernel lowering (shmem tile executor) and dual-schedule
backward are derived by ``authoring.declare``. The registry names are
unchanged, so policies, the tuner and the parity tests see the same ops.

Note the ``matmul_rs`` one_shot kernel protocol: the ROADMAP's
"push all partials up-front" rs_gemm variant is the pair
``("one_shot", "one_shot_rs")`` below — the authoring API's whole
cost for a new kernel lowering.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .authoring import OverlapOp, declare


def _dot_tile(chunk, w):
    return jnp.dot(chunk, w, preferred_element_type=jnp.float32)


def _split_cols(statics, n):
    """Split the weight's output columns into n groups (RS sub-chunking
    and the bidir column halves); None when the columns don't divide."""
    (w,) = statics
    if n < 2 or w.shape[1] % n:
        return None
    n_sub = w.shape[1] // n
    return [
        (lax.dynamic_slice(w, (0, j * n_sub), (w.shape[0], n_sub)),)
        for j in range(n)
    ]


def _ag_matmul_baseline(operand, statics, axis, out_dtype):
    """all_gather(A) @ B with XLA's built-in collective (one big dot)."""
    a_full = lax.all_gather(operand, axis, tiled=True)
    return jnp.dot(a_full, statics[0],
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _matmul_rs_baseline(operand, statics, axis, out_dtype):
    """psum_scatter(A @ B) with XLA's built-in collective."""
    partial = jnp.dot(operand, statics[0], preferred_element_type=jnp.float32)
    return lax.psum_scatter(
        partial, axis, scatter_dimension=0, tiled=True).astype(out_dtype)


ag_matmul = declare(OverlapOp(
    name="ag_matmul",
    kind="ag",
    tile=_dot_tile,
    transports=("ring", "bidir", "one_shot"),
    kernel_protocols=(("ring", "ring_ag"), ("bidir", "bidir_ring_ag"),
                      ("one_shot", "one_shot_ag")),
    transpose="matmul_rs",
    rowwise=True,
    baseline_fwd=_ag_matmul_baseline,
    # remat policy "block_save_ag" keeps gathered activations across the
    # backward instead of re-running the gather ring
    checkpoint_tag="ag_out",
))

matmul_rs = declare(OverlapOp(
    name="matmul_rs",
    kind="rs",
    tile=_dot_tile,
    transports=("ring", "bidir", "one_shot"),
    kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
    transpose="ag_matmul",
    static_split=_split_cols,
    split_axis=1,
    baseline_fwd=_matmul_rs_baseline,
))

all_gather = declare(OverlapOp(
    name="all_gather",
    kind="gather",
    tile=None,  # identity: pure decomposed data movement
    transports=("ring", "one_shot"),
    kernel_protocols=(("ring", "ring_ag"), ("one_shot", "one_shot_ag")),
    transpose="reduce_scatter",
    rowwise=True,
))


def _f32_block(block):
    # linear "tile": cast so the ring/push accumulation runs in f32
    return block.astype(jnp.float32)


reduce_scatter = declare(OverlapOp(
    name="reduce_scatter",
    kind="rs",
    tile=_f32_block,
    transports=("ring", "one_shot"),
    kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
    transpose="all_gather",
))

# EP AllToAll (paper Fig. 16): pure data movement over the leading
# per-destination block dim. The kernel lowering is the executor's
# one-shot a2a push protocol; the derived backward is the SAME a2a on
# the cotangent (AllToAll is its own transpose). The inverse direction
# (combine) reuses this op with transposed block placement — see
# ``core.moe_overlap.a2a_ep_inverse``.
a2a_ep = declare(OverlapOp(
    name="a2a_ep",
    kind="a2a",
    tile=None,
    transports=("one_shot",),
    baseline="xla",
    default="one_shot",
    kernel_protocols=(("one_shot", "one_shot_a2a"),),
))


def _stack_tile(packed):
    # the LSE-stacking tile: one rank's packed (o, lse) partial becomes a
    # leading-dim-1 strip of the (W, ...) stacked combine input
    return packed[None]


# The distributed flash-decode combine (paper §4.2): a small-message
# stacked AllGather of the packed (o, lse) partials. Binding one_shot_ag
# with the stacking tile IS the kernel lowering; the logsumexp merge
# stays outside (``core.flash_decode``).
flash_decode = declare(OverlapOp(
    name="flash_decode",
    kind="gather",
    tile=_stack_tile,
    transports=("ring", "one_shot"),
    baseline="xla",
    default="one_shot",
    kernel_protocols=(("one_shot", "one_shot_ag"),),
))
