"""OverlapPolicy — the ONE object that answers "how should op X overlap?".

It consolidates what used to be four parallel knobs on ``ParallelConfig``
(``overlap_mode`` + ``overlap_modes`` + ``overlap_backend`` +
``overlap_backends``) plus the two chunk counts into a single value with
a single resolution point: :meth:`OverlapPolicy.resolve` clamps the
requested (mode, backend, chunks) against the live engine registry — a
global ``mode="ring"`` resolves to "one_shot" for an op with no ring
transport, ``backend="kernel"`` degrades to "graph" for (op, mode) pairs
without a kernel lowering, and the chunk count is picked by the op's
kind (AG ops sub-chunk the riding operand, RS ops the accumulator's
column groups).

The policy is a frozen, hashable dataclass: it can live on
``ParallelConfig``, be produced whole by ``tuner.recommend_overlap_modes``
and recorded per benchmark row. This module imports no jax — the
registry is consulted lazily — so config modules stay import-light.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Mapping, Tuple

# Ops whose messages are latency-bound regardless of layer shape default
# to the paper's low-latency one-shot kernels (EP dispatch, decode combine).
LATENCY_OPS: Tuple[Tuple[str, str], ...] = (
    ("a2a_ep", "one_shot"),
    ("flash_decode", "one_shot"),
)

# Wire dtypes a riding chunk can travel as: "f32" = as-is (the operand's own
# dtype), "int8"/"fp8" = per-row scaled 1-byte blocks (see ops/wire.py).
WIRE_DTYPES: Tuple[str, ...] = ("f32", "int8", "fp8")


@dataclass(frozen=True)
class ResolvedOverlap:
    """One op's effective lowering: what the engine will actually run."""

    mode: str
    backend: str
    chunks: int
    wire: str = "f32"


def _as_items(value) -> Tuple[Tuple[str, str], ...]:
    if isinstance(value, Mapping):
        return tuple(sorted(value.items()))
    return tuple(value)


@dataclass(frozen=True)
class OverlapPolicy:
    """How overlapped ops lower, session-wide.

    mode       default transport (an engine transport name or an op's
               baseline, e.g. "none")
    backend    default lowering ("graph" = lax.ppermute pipelines,
               "kernel" = the fused shmem kernels)
    modes      per-op transport overrides, keyed by registry op name
    backends   per-op backend overrides
    ag_chunks  sub-chunks per rank for AG-side ops (0 = 1, paper default)
    rs_chunks  accumulator column groups for RS-side ops (0 = 1)
    wire       default wire dtype for riding chunks ("f32" = as-is,
               "int8"/"fp8" = per-row scaled 1-byte blocks)
    wires      per-op wire overrides
    """

    mode: str = "ring"
    backend: str = "graph"
    modes: tuple = LATENCY_OPS
    backends: tuple = ()
    ag_chunks: int = 0
    rs_chunks: int = 0
    wire: str = "f32"
    wires: tuple = ()

    def __post_init__(self):
        # accept dicts for ergonomics; store hashable sorted tuples
        object.__setattr__(self, "modes", _as_items(self.modes))
        object.__setattr__(self, "backends", _as_items(self.backends))
        object.__setattr__(self, "wires", _as_items(self.wires))
        # wire names are a closed set — validate eagerly so a typo fails at
        # config construction, not deep inside a traced lowering
        for w in (self.wire,) + tuple(v for _, v in self.wires):
            if w not in WIRE_DTYPES:
                raise ValueError(
                    f"unknown wire dtype {w!r} (valid: {WIRE_DTYPES})")

    # -- resolution ----------------------------------------------------
    def _requested(self, table, default: str, op: str) -> str:
        for name, value in table:
            if name == op:
                return value
        return default

    def mode_for(self, op: str) -> str:
        """Effective transport for registry op ``op`` (override if
        present, else the session default, clamped by the registry)."""
        from ..core import overlap  # lazy: keep this module import-light

        return overlap.resolve_mode(op, self._requested(self.modes, self.mode, op))

    def backend_for(self, op: str) -> str:
        """Effective lowering backend for ``op``, clamped to the
        registry's kernel-capable (op, mode) pairs."""
        from ..core import overlap

        return overlap.resolve_backend(
            op, self._requested(self.backends, self.backend, op),
            self.mode_for(op))

    def chunks_for(self, op: str) -> int:
        """Sub-chunk count for ``op``, by its registry kind (AG ops ride
        finer operand chunks; RS ops split the accumulator's columns)."""
        from ..core import overlap

        kind = overlap.get(op).kind
        return max(1, self.rs_chunks if kind == "rs" else self.ag_chunks)

    def wire_for(self, op: str) -> str:
        """Effective wire dtype for ``op``, clamped to the registry's
        wire-capable ops and transports (baselines ride f32)."""
        from ..core import overlap

        return overlap.resolve_wire(
            op, self._requested(self.wires, self.wire, op), self.mode_for(op))

    def resolve(self, op: str, hw=None) -> ResolvedOverlap:
        """The op's effective (mode, backend, chunks).

        ``hw`` optionally names the target platform's
        :class:`repro.hw.HardwareSpec`: on a spec without ICI links the
        kernel backend has no remote-DMA engine to drive, so it degrades
        to graph (the emulated backend stays reachable by requesting
        ``backend="kernel"`` per call, as the parity tests do)."""
        backend = self.backend_for(op)
        if hw is not None and getattr(hw, "ici_links", 0) == 0:
            backend = "graph"
        return ResolvedOverlap(
            self.mode_for(op), backend, self.chunks_for(op),
            self.wire_for(op))

    # -- functional updates -------------------------------------------
    def with_modes(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op transport overrides merged in."""
        merged = dict(self.modes)
        merged.update(per_op)
        return dataclasses.replace(self, modes=tuple(sorted(merged.items())))

    def with_backends(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op backend overrides merged in."""
        merged = dict(self.backends)
        merged.update(per_op)
        return dataclasses.replace(self, backends=tuple(sorted(merged.items())))

    def with_wires(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op wire-dtype overrides merged in."""
        merged = dict(self.wires)
        merged.update(per_op)
        return dataclasses.replace(self, wires=tuple(sorted(merged.items())))

    def describe(self, op: str) -> str:
        """Compact 'mode/backend[/xN][/wire]' string (benchmark + log rows)."""
        r = self.resolve(op)
        sub = f"/x{r.chunks}" if r.chunks > 1 else ""
        wire = f"/{r.wire}" if r.wire != "f32" else ""
        return f"{r.mode}/{r.backend}{sub}{wire}"
