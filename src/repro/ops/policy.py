"""OverlapPolicy — the ONE object that answers "how should op X overlap?".

It consolidates what used to be four parallel knobs on ``ParallelConfig``
(``overlap_mode`` + ``overlap_modes`` + ``overlap_backend`` +
``overlap_backends``) plus the two chunk counts into a single value with
a single resolution point: :meth:`OverlapPolicy.resolve` clamps the
requested (mode, backend, chunks) against the live engine registry — a
global ``mode="ring"`` resolves to "one_shot" for an op with no ring
transport, ``backend="kernel"`` degrades to "graph" for (op, mode) pairs
without a kernel lowering, and the chunk count is picked by the op's
kind (AG ops sub-chunk the riding operand, RS ops the accumulator's
column groups).

Resolution is PER SITE, not just per op name: ``layers`` holds
shape-keyed rules (one per ``(op, layer shape)``, produced by hand via
:meth:`OverlapPolicy.with_layer` or searched by ``tuner.search``), and
``resolve(op, hw, shape=...)`` applies the matching rule's overrides on
top of the per-op resolution — so the QKV projection and the MLP matmul
of the same op name can lower differently. A searched policy serializes
with :meth:`to_json` / :meth:`from_json` so it can be committed.

The policy is a frozen, hashable dataclass: it can live on
``ParallelConfig``, be produced whole by ``tuner.recommend_overlap_modes``
and recorded per benchmark row. This module imports no jax — the
registry is consulted lazily — so config modules stay import-light.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Mapping, Optional, Tuple

# Ops whose messages are latency-bound regardless of layer shape default
# to the paper's low-latency one-shot kernels (EP dispatch, decode combine).
LATENCY_OPS: Tuple[Tuple[str, str], ...] = (
    ("a2a_ep", "one_shot"),
    ("flash_decode", "one_shot"),
)

# Wire dtypes a riding chunk can travel as: "f32" = as-is (the operand's own
# dtype), "int8"/"fp8" = per-row scaled 1-byte blocks (see ops/wire.py).
WIRE_DTYPES: Tuple[str, ...] = ("f32", "int8", "fp8")

# Chunk->rank row placements a causal context-parallel op can run under
# (core.schedules.PLACEMENTS; "contiguous" = owner-major blocks). The import
# is allowed here because core.schedules is pure Python (no jax).
from ..core.schedules import PLACEMENTS  # noqa: E402

# Session defaults for the per-op mode table: the latency-bound ops plus
# the fused boundary op, which is opt-in — "none" keeps the transformer
# block on the composed unfused pair (the oracle) until a policy or a
# tuner.search rule turns the fusion on.
DEFAULT_MODES: Tuple[Tuple[str, str], ...] = LATENCY_OPS + (
    ("matmul_rs_ag_matmul", "none"),
)

# Per-layer override knobs a shape-keyed rule may carry.
LAYER_KEYS: Tuple[str, ...] = ("mode", "backend", "chunks", "wire", "placement")


def shape_key(shape) -> Tuple[int, ...]:
    """Canonical layer-shape key: nested int iterables flatten to one
    flat int tuple, so ``((m, k), (k, n))`` (a call site's operand
    shapes) and ``(m, k, k, n)`` (a tuner search key) address the same
    rule."""
    if isinstance(shape, int):
        return (shape,)
    flat = []
    for s in shape:
        flat.extend(shape_key(s))
    return tuple(flat)


@dataclass(frozen=True)
class ResolvedOverlap:
    """One op's effective lowering: what the engine will actually run."""

    mode: str
    backend: str
    chunks: int
    wire: str = "f32"
    placement: str = "contiguous"


def _as_items(value) -> Tuple[Tuple[str, str], ...]:
    if isinstance(value, Mapping):
        return tuple(sorted(value.items()))
    return tuple(value)


def _canon_layers(layers) -> tuple:
    """Canonicalize shape-keyed rules: keys become ``(op, flat shape
    tuple)``, overrides become sorted item tuples restricted to
    ``LAYER_KEYS``; entries sort by key so equal rule sets hash equal."""
    if isinstance(layers, Mapping):
        layers = layers.items()
    canon = {}
    for key, overrides in layers:
        op, shape = key
        ov = dict(_as_items(overrides))
        bad = set(ov) - set(LAYER_KEYS)
        if bad:
            raise ValueError(
                f"layer rule for {op!r} has unknown keys {sorted(bad)} "
                f"(valid: {LAYER_KEYS})")
        canon[(op, shape_key(shape))] = tuple(sorted(ov.items()))
    return tuple(sorted(canon.items()))


@dataclass(frozen=True)
class OverlapPolicy:
    """How overlapped ops lower, session-wide.

    mode       default transport (an engine transport name or an op's
               baseline, e.g. "none")
    backend    default lowering ("graph" = lax.ppermute pipelines,
               "kernel" = the fused shmem kernels)
    modes      per-op transport overrides, keyed by registry op name
    backends   per-op backend overrides
    ag_chunks  sub-chunks per rank for AG-side ops (0 = 1, paper default)
    rs_chunks  accumulator column groups for RS-side ops (0 = 1)
    wire       default wire dtype for riding chunks ("f32" = as-is,
               "int8"/"fp8" = per-row scaled 1-byte blocks)
    wires      per-op wire overrides
    placement  default chunk->rank row placement for causal context-
               parallel ops ("contiguous" = owner-major blocks; "zigzag"/
               "striped" = the balanced causal maps, see core.schedules)
    placements per-op placement overrides
    layers     shape-keyed per-site rules: ((op, shape_key), overrides)
               entries where overrides is a sorted item tuple over
               ``LAYER_KEYS`` — applied by ``resolve(op, shape=...)``
               on top of the per-op resolution
    """

    mode: str = "ring"
    backend: str = "graph"
    modes: tuple = DEFAULT_MODES
    backends: tuple = ()
    ag_chunks: int = 0
    rs_chunks: int = 0
    wire: str = "f32"
    wires: tuple = ()
    placement: str = "contiguous"
    placements: tuple = ()
    layers: tuple = ()

    def __post_init__(self):
        # accept dicts for ergonomics; store hashable sorted tuples
        object.__setattr__(self, "modes", _as_items(self.modes))
        object.__setattr__(self, "backends", _as_items(self.backends))
        object.__setattr__(self, "wires", _as_items(self.wires))
        object.__setattr__(self, "placements", _as_items(self.placements))
        object.__setattr__(self, "layers", _canon_layers(self.layers))
        # wire / placement names are closed sets — validate eagerly so a
        # typo fails at config construction, not deep inside a lowering
        layer_wires = tuple(dict(ov).get("wire", "f32")
                            for _, ov in self.layers)
        for w in (self.wire,) + tuple(v for _, v in self.wires) + layer_wires:
            if w not in WIRE_DTYPES:
                raise ValueError(
                    f"unknown wire dtype {w!r} (valid: {WIRE_DTYPES})")
        layer_plc = tuple(dict(ov).get("placement", "contiguous")
                          for _, ov in self.layers)
        for p in ((self.placement,)
                  + tuple(v for _, v in self.placements) + layer_plc):
            if p not in PLACEMENTS:
                raise ValueError(
                    f"unknown placement {p!r} (valid: {PLACEMENTS})")

    # -- resolution ----------------------------------------------------
    def _requested(self, table, default: str, op: str) -> str:
        for name, value in table:
            if name == op:
                return value
        return default

    def mode_for(self, op: str) -> str:
        """Effective transport for registry op ``op`` (override if
        present, else the session default, clamped by the registry)."""
        from ..core import overlap  # lazy: keep this module import-light

        return overlap.resolve_mode(op, self._requested(self.modes, self.mode, op))

    def backend_for(self, op: str) -> str:
        """Effective lowering backend for ``op``, clamped to the
        registry's kernel-capable (op, mode) pairs."""
        from ..core import overlap

        return overlap.resolve_backend(
            op, self._requested(self.backends, self.backend, op),
            self.mode_for(op))

    def chunks_for(self, op: str) -> int:
        """Sub-chunk count for ``op``, by its registry kind (AG ops ride
        finer operand chunks; RS ops split the accumulator's columns)."""
        from ..core import overlap

        kind = overlap.get(op).kind
        return max(1, self.rs_chunks if kind == "rs" else self.ag_chunks)

    def wire_for(self, op: str) -> str:
        """Effective wire dtype for ``op``, clamped to the registry's
        wire-capable ops and transports (baselines ride f32)."""
        from ..core import overlap

        return overlap.resolve_wire(
            op, self._requested(self.wires, self.wire, op), self.mode_for(op))

    def placement_for(self, op: str) -> str:
        """Effective row placement for ``op``, clamped to the registry's
        placement-capable ops (everything else stays contiguous)."""
        from ..core import overlap

        return overlap.resolve_placement(
            op, self._requested(self.placements, self.placement, op))

    def layer_for(self, op: str, shape) -> Optional[Mapping[str, object]]:
        """The shape-keyed rule matching ``(op, shape)``, or None. The
        shape canonicalizes through :func:`shape_key`, so a call site's
        operand-shape tuple and a tuner search key address one rule."""
        if shape is None:
            return None
        key = (op, shape_key(shape))
        for k, overrides in self.layers:
            if k == key:
                return dict(overrides)
        return None

    def resolve(self, op: str, hw=None, shape=None) -> ResolvedOverlap:
        """The op's effective (mode, backend, chunks, wire) at one site.

        ``hw`` optionally names the target platform's
        :class:`repro.hw.HardwareSpec`: on a spec without ICI links the
        kernel backend has no remote-DMA engine to drive, so it degrades
        to graph (the emulated backend stays reachable by requesting
        ``backend="kernel"`` per call, as the parity tests do).

        ``shape`` optionally keys a per-site layer rule (see
        :meth:`with_layer` / ``tuner.search``): matching overrides are
        applied on top of the per-op resolution, then re-clamped against
        the registry so a searched rule can never request an unsupported
        (mode, backend, wire) triple."""
        from ..core import overlap

        mode = self.mode_for(op)
        backend = self.backend_for(op)
        chunks = self.chunks_for(op)
        wire = self.wire_for(op)
        placement = self.placement_for(op)
        rule = self.layer_for(op, shape)
        if rule is not None:
            mode = overlap.resolve_mode(op, rule.get("mode", mode))
            backend = overlap.resolve_backend(
                op, rule.get("backend", backend), mode)
            chunks = max(1, int(rule.get("chunks", chunks)))
            wire = overlap.resolve_wire(op, rule.get("wire", wire), mode)
            placement = overlap.resolve_placement(
                op, rule.get("placement", placement))
        if hw is not None and getattr(hw, "ici_links", 0) == 0:
            backend = "graph"
        return ResolvedOverlap(mode, backend, chunks, wire, placement)

    # -- functional updates -------------------------------------------
    def with_modes(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op transport overrides merged in."""
        merged = dict(self.modes)
        merged.update(per_op)
        return dataclasses.replace(self, modes=tuple(sorted(merged.items())))

    def with_backends(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op backend overrides merged in."""
        merged = dict(self.backends)
        merged.update(per_op)
        return dataclasses.replace(self, backends=tuple(sorted(merged.items())))

    def with_wires(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op wire-dtype overrides merged in."""
        merged = dict(self.wires)
        merged.update(per_op)
        return dataclasses.replace(self, wires=tuple(sorted(merged.items())))

    def with_placements(self, **per_op: str) -> "OverlapPolicy":
        """A copy with per-op row-placement overrides merged in."""
        merged = dict(self.placements)
        merged.update(per_op)
        return dataclasses.replace(
            self, placements=tuple(sorted(merged.items())))

    def with_layer(self, op: str, shape, **overrides) -> "OverlapPolicy":
        """A copy with one shape-keyed rule merged in: ``resolve(op,
        shape=shape)`` will apply ``overrides`` (any of ``mode``,
        ``backend``, ``chunks``, ``wire``) at that site only."""
        merged = dict(self.layers)
        merged[(op, shape_key(shape))] = tuple(sorted(overrides.items()))
        return dataclasses.replace(self, layers=tuple(merged.items()))

    def describe(self, op: str, shape=None) -> str:
        """Compact 'mode/backend[/xN][/wire][/placement]' string
        (benchmark + log rows)."""
        r = self.resolve(op, shape=shape)
        sub = f"/x{r.chunks}" if r.chunks > 1 else ""
        wire = f"/{r.wire}" if r.wire != "f32" else ""
        plc = f"/{r.placement}" if r.placement != "contiguous" else ""
        return f"{r.mode}/{r.backend}{sub}{wire}{plc}"

    # -- serialization -------------------------------------------------
    def to_json(self) -> str:
        """JSON text for this policy (searched policies get committed;
        :meth:`from_json` round-trips)."""
        data = {
            "mode": self.mode,
            "backend": self.backend,
            "modes": [list(kv) for kv in self.modes],
            "backends": [list(kv) for kv in self.backends],
            "ag_chunks": self.ag_chunks,
            "rs_chunks": self.rs_chunks,
            "wire": self.wire,
            "wires": [list(kv) for kv in self.wires],
            "placement": self.placement,
            "placements": [list(kv) for kv in self.placements],
            "layers": [
                {"op": op, "shape": list(shp), "overrides": dict(ov)}
                for (op, shp), ov in self.layers
            ],
        }
        return json.dumps(data, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, data) -> "OverlapPolicy":
        """Rebuild a policy from :meth:`to_json` output (text or the
        parsed dict)."""
        if isinstance(data, str):
            data = json.loads(data)
        layers = tuple(
            ((e["op"], tuple(e["shape"])),
             tuple(sorted(e.get("overrides", {}).items())))
            for e in data.get("layers", ()))
        return cls(
            mode=data.get("mode", "ring"),
            backend=data.get("backend", "graph"),
            modes=tuple((k, v) for k, v in data.get("modes", DEFAULT_MODES)),
            backends=tuple((k, v) for k, v in data.get("backends", ())),
            ag_chunks=int(data.get("ag_chunks", 0)),
            rs_chunks=int(data.get("rs_chunks", 0)),
            wire=data.get("wire", "f32"),
            wires=tuple((k, v) for k, v in data.get("wires", ())),
            placement=data.get("placement", "contiguous"),
            placements=tuple((k, v) for k, v in data.get("placements", ())),
            layers=layers,
        )
