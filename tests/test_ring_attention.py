"""Ring attention (context parallelism) vs. full-attention oracle."""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.ring_attention import ring_attention
    from repro.kernels import ref

    W = 8
    mesh = jax.make_mesh((W,), ("cp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    B, H, HKV, S, D = 2, 4, 2, 64 * W, 16
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)

    for causal in (True, False):
        f = jax.jit(jax.shard_map(
            functools.partial(ring_attention, axis="cp", causal=causal),
            mesh=mesh,
            in_specs=(P(None, None, "cp", None),) * 3,
            out_specs=P(None, None, "cp", None), check_vma=False))
        got = np.asarray(f(q, k, v))
        want = np.asarray(ref.flash_attention(q, k, v, causal=causal))
        err = np.abs(got - want).max()
        assert err < 2e-5, (causal, err)

    # gradients flow through the ring (long-context TRAINING enabler)
    def loss(q, k, v):
        return jnp.sum(jnp.square(ring_attention(q, k, v, "cp", causal=True)))
    g = jax.jit(jax.shard_map(jax.grad(loss, argnums=(0, 1, 2)), mesh=mesh,
        in_specs=(P(None, None, "cp", None),) * 3,
        out_specs=(P(None, None, "cp", None),) * 3, check_vma=False))(q, k, v)
    for gi in g:
        arr = np.asarray(gi)
        assert np.isfinite(arr).all() and np.abs(arr).max() > 0
    print("OK")
""")


def test_ring_attention_matches_full():
    out = run_devices(SCRIPT, devices=8)
    assert "OK" in out
