"""Ring attention (context parallelism) vs. full-attention oracle.

The op is now a ``repro.ops`` stateful-fold declaration: forward parity
and the derived jax.vjp-through-the-fold-chain backward are checked
against an INDEPENDENT oracle gradient path (full-softmax attention on
gathered K/V differentiated directly, no dispatch/custom_vjp), on both
lowering backends — ``kernel`` runs the executor's carry-passing
``ring_fold`` protocol on the emulated DMA engine. The policy-threaded
model call site (``blocks.attention_cp``) rides the same check.
"""
import textwrap

import pytest

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.configs.base import ParallelConfig
    from repro.core.ring_attention import ring_attention
    from repro.kernels import ref
    from repro.models import blocks

    W = 8
    mesh = jax.make_mesh((W,), ("cp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    B, H, HKV, S, D = 2, 4, 2, 64 * W, 16
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    SPECS3 = (P(None, None, "cp", None),) * 3
    scale = 1.0 / float(np.sqrt(D))

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    for causal in (True, False):
        f = sh(functools.partial(ring_attention, axis="cp", causal=causal),
               SPECS3, P(None, None, "cp", None))
        got = np.asarray(f(q, k, v))
        want = np.asarray(ref.flash_attention(q, k, v, causal=causal))
        err = np.abs(got - want).max()
        assert err < 2e-5, (causal, err)

    # the policy-threaded model call site resolves transport AND backend
    # from the overlap policy (kernel = the executor ring_fold protocol)
    pcfg = ParallelConfig(
        tp=1, overlap=ops.OverlapPolicy(mode="ring", backend="kernel"))
    assert pcfg.policy.resolve("ring_attention").backend == "kernel"
    f = sh(lambda q_, k_, v_: blocks.attention_cp(pcfg, q_, k_, v_,
                                                  axis="cp"),
           SPECS3, P(None, None, "cp", None))
    err = np.abs(np.asarray(f(q, k, v))
                 - np.asarray(ref.flash_attention(q, k, v, causal=True))).max()
    assert err < 2e-5, ("attention_cp/kernel", err)

    # gradients: the derived fold-chain backward vs an INDEPENDENT
    # oracle path (full-softmax on gathered K/V, differentiated through
    # — same psum'd loss, no dispatch), then bit-equality across
    # backends (the kernel forward keeps the graph dual)
    def oracle_local(q_, k_, v_, causal):
        group = q_.shape[1] // k_.shape[1]
        kf = jnp.repeat(lax.all_gather(k_, "cp", axis=2, tiled=True)
                        .astype(jnp.float32), group, 1)
        vf = jnp.repeat(lax.all_gather(v_, "cp", axis=2, tiled=True)
                        .astype(jnp.float32), group, 1)
        logits = jnp.einsum("bhqd,bhkd->bhqk",
                            q_.astype(jnp.float32) * scale, kf)
        if causal:
            me = lax.axis_index("cp")
            rows = me * q_.shape[2] + jnp.arange(q_.shape[2])
            mask = rows[:, None] >= jnp.arange(kf.shape[2])[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q_.dtype)

    def grads_of(fn):
        def loss(q_, k_, v_):
            out = fn(q_, k_, v_)
            return lax.psum(jnp.sum(out * out), "cp")
        return [np.asarray(t) for t in
                sh(jax.grad(loss, argnums=(0, 1, 2)), SPECS3, SPECS3)(q, k, v)]

    for causal in (True, False):
        gr = grads_of(functools.partial(ring_attention, axis="cp",
                                        causal=causal))
        gk = grads_of(functools.partial(ring_attention, axis="cp",
                                        causal=causal, backend="kernel"))
        go = grads_of(functools.partial(oracle_local, causal=causal))
        for a, b, c in zip(gr, gk, go):
            assert np.array_equal(a, b), ("backend grads differ", causal)
            assert np.isfinite(a).all() and np.abs(a).max() > 0
            assert np.abs(a - c).max() < 2e-3, (causal, np.abs(a - c).max())
    print("OK")
""")


def test_ring_attention_matches_full():
    out = run_devices(SCRIPT, devices=8, timeout=1200)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Placement axis: zigzag/striped owner maps vs TWO references — the
# in-shard_map oracle (full softmax on gathered K/V with the same
# python-level position tables) and the natural-order dense attention
# permuted into the placement layout. Grads must be bit-identical across
# lowering backends under the same fixed cotangent.
# ---------------------------------------------------------------------------

PLACEMENT_SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.ring_attention import ring_attention
    from repro.core import schedules as sched
    from repro.kernels import ref

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("cp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    B, H, HKV, S, D = 2, 4, 2, 16 * W, 16
    s_loc = S // W
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    SPECS3 = (P(None, None, "cp", None),) * 3
    scale = 1.0 / float(np.sqrt(D))

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    def perm(placement):
        out = []
        for r in range(W):
            out.extend(sched.placement_rows(placement, W, r, s_loc))
        return np.array(out)  # rank-major shard layout -> global position

    def oracle_local(q_, k_, v_, causal, placement):
        group = q_.shape[1] // k_.shape[1]
        kf = jnp.repeat(lax.all_gather(k_, "cp", axis=2, tiled=True)
                        .astype(jnp.float32), group, 1)
        vf = jnp.repeat(lax.all_gather(v_, "cp", axis=2, tiled=True)
                        .astype(jnp.float32), group, 1)
        logits = jnp.einsum("bhqd,bhkd->bhqk",
                            q_.astype(jnp.float32) * scale, kf)
        if causal:
            table = jnp.asarray(np.stack(
                [sched.placement_rows(placement, W, r, s_loc)
                 for r in range(W)]))
            rows = table[lax.axis_index("cp")]
            cols = table.reshape(-1)
            mask = rows[:, None] >= cols[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q_.dtype)

    for placement in ("zigzag", "striped"):
        pm = perm(placement)
        qp, kp, vp = q[:, :, pm], k[:, :, pm], v[:, :, pm]
        for causal in (True, False):
            # natural-order dense attention, permuted into the layout
            dense = np.asarray(
                ref.flash_attention(q, k, v, causal=causal))[:, :, pm]
            for backend in ("graph", "kernel"):
                f = sh(functools.partial(ring_attention, axis="cp",
                                         causal=causal, backend=backend,
                                         placement=placement),
                       SPECS3, P(None, None, "cp", None))
                got = np.asarray(f(qp, kp, vp))
                err = np.abs(got - dense).max()
                assert err < 2e-5, (placement, causal, backend, err)
            g = sh(functools.partial(oracle_local, causal=causal,
                                     placement=placement),
                   SPECS3, P(None, None, "cp", None))
            err = np.abs(np.asarray(g(qp, kp, vp)) - dense).max()
            assert err < 2e-5, ("oracle", placement, causal, err)

    def grads_of(fn, qp, kp, vp):
        def loss(q_, k_, v_):
            out = fn(q_, k_, v_)
            return lax.psum(jnp.sum(out * out), "cp")
        return [np.asarray(t) for t in
                sh(jax.grad(loss, argnums=(0, 1, 2)),
                   SPECS3, SPECS3)(qp, kp, vp)]

    for placement in ("zigzag", "striped"):
        pm = perm(placement)
        qp, kp, vp = q[:, :, pm], k[:, :, pm], v[:, :, pm]
        for causal in (True, False):
            gg = grads_of(functools.partial(
                ring_attention, axis="cp", causal=causal,
                placement=placement), qp, kp, vp)
            gk = grads_of(functools.partial(
                ring_attention, axis="cp", causal=causal,
                placement=placement, backend="kernel"), qp, kp, vp)
            go = grads_of(functools.partial(
                oracle_local, causal=causal, placement=placement),
                qp, kp, vp)
            for a, b, c in zip(gg, gk, go):
                assert np.array_equal(a, b), \
                    ("backend grads differ", placement, causal)
                assert np.isfinite(a).all() and np.abs(a).max() > 0
                err = np.abs(a - c).max()
                assert err < 2e-3, (placement, causal, err)
    print("OK")
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_ring_attention_placements_match_oracle(world):
    out = run_devices(PLACEMENT_SCRIPT.replace("__WORLD__", str(world)),
                      devices=world, timeout=1200)
    assert "OK" in out
