"""Traced causal load-balance pin: zigzag placement shrinks the per-PE
``tile_compute`` span spread >= 2x vs contiguous (world 8, kernel
backend on the emulated shmem engine), without regressing measured
``overlap_eff``.

Under the contiguous owner map, rank r's causal ring fold computes only
r+1 of the W K/V blocks (the rest are fully masked and skipped by the
fold's whole-block guard) — rank 0 sits idle for W-1 of W steps while
rank W-1 computes every block. Zigzag gives every rank one early + one
late half-chunk, so no (rank, owner) block is ever fully masked and
every PE computes all W steps: the per-PE compute-span sums equalize.
"""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import obs
    from repro.core.ring_attention import ring_attention

    obs.enable()
    W = 8
    mesh = jax.make_mesh((W,), ("cp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    B, H, HKV, D = 2, 4, 2, 32
    S_LOC = 256  # a block's fold must dwarf callback/dispatch overhead
    S = S_LOC * W
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, HKV, S, D), jnp.float32)
    SPECS3 = (P(None, None, "cp", None),) * 3

    def measure(placement, iters=3):
        f = jax.jit(jax.shard_map(
            functools.partial(ring_attention, axis="cp", causal=True,
                              mode="ring", backend="kernel",
                              placement=placement),
            mesh=mesh, in_specs=SPECS3, out_specs=P(None, None, "cp", None),
            check_vma=False))
        jax.block_until_ready(f(q, k, v))  # compile + warm
        obs.clear()
        for _ in range(iters):
            jax.block_until_ready(f(q, k, v))
        ev = obs.events(clear=True)
        per_pe = {p: 0.0 for p in range(W)}
        for e in ev:
            if e.kind == "tile_compute":
                per_pe[e.pe] += e.t1 - e.t0
        s = obs.metrics.summarize(ev)
        spans = [per_pe[p] for p in range(W)]
        # normalized spread: (max - min) / mean — the placements do
        # different TOTAL span time by design (contiguous skips 28 of 64
        # blocks), so only the relative imbalance is comparable
        spread = (max(spans) - min(spans)) * W / sum(spans)
        return spread, s.overlap_efficiency, spans

    spread_c, eff_c, spans_c = measure("contiguous")
    spread_z, eff_z, spans_z = measure("zigzag")
    print("contig spread %.3f eff %.3f spans %s"
          % (spread_c, eff_c, ["%.3f" % x for x in spans_c]))
    print("zigzag spread %.3f eff %.3f spans %s"
          % (spread_z, eff_z, ["%.3f" % x for x in spans_z]))
    # structural: contiguous rank 0 computes 1 of 8 blocks, rank 7 all 8
    # -> spread ~ the full wall; zigzag computes 8 equal-work steps on
    # every rank -> spread is scheduler noise only
    assert spread_c >= 2.0 * spread_z, (spread_c, spread_z)
    # balance must not cost overlap: measured efficiency no worse
    # (small slack for run-to-run noise on shared CPU runners)
    assert eff_z >= eff_c - 0.1, (eff_z, eff_c)
    print("OK")
""")


def test_zigzag_halves_compute_span_spread():
    out = run_devices(SCRIPT, devices=8, timeout=1200)
    assert "OK" in out
