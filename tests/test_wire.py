"""Wire-dtype axis tests (quantized riding chunks).

1. Codec pins: ``ops.wire.encode`` int8 is bit-identical to the legacy
   ``dist/compress.py`` per-row formula (which now delegates to it), and
   pack/unpack round-trips the split representation exactly.
2. Policy/config validation: unknown wire dtypes raise eagerly with the
   valid set in the message; the explicit-policy-vs-legacy-fields
   conflict covers ``overlap_wire`` in both argument orders; resolution
   clamps wires off baseline modes, two_level and non-wire-capable ops.
3. Graph-vs-kernel and quantized-vs-f32 parity for every wire-capable
   (op, transport) at worlds 2/4/8. Documented tolerances (relative
   error vs the f32 graph baseline): int8 <= 5e-2, fp8 <= 1e-1 — the
   empirical errors on randn inputs are ~5x under these.
4. Backward: with a linear loss (constant cotangent) the int8-wire
   grads are bit-identical across graph/kernel forwards (the shared
   custom_vjp keeps ONE dual schedule), and close to the f32 grads.
5. Error feedback: repeated int8 reductions WITH feedback beat the
   same reductions without (satellite of ``pod_allreduce_int8``).
6. Tuner: the analytic models enumerate mode x chunks x wire and pick
   int8 only where the ICI-bytes term binds.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_devices


# ---------------------------------------------------------------------------
# 1. codec pins (single device, in-process)
# ---------------------------------------------------------------------------


def test_codec_pins_legacy_formula_and_roundtrip():
    import jax.numpy as jnp

    from repro.dist import compress
    from repro.ops import wire

    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(16, 33) * 3.0, jnp.float32)

    # the exact legacy dist/compress.py recipe, inlined as the reference
    gf = np.asarray(g, np.float32)
    scale_ref = np.maximum(np.abs(gf).max(axis=-1, keepdims=True) / 127.0,
                           1e-12)
    q_ref = np.clip(np.round(gf / scale_ref), -127.0, 127.0).astype(np.int8)

    q, s = wire.encode(g, "int8")
    assert np.array_equal(np.asarray(q), q_ref)
    assert np.array_equal(np.asarray(s), scale_ref.astype(np.float32))
    # compress.quantize_int8 IS the shared codec now — pin the equality
    q2, s2 = compress.quantize_int8(g)
    assert np.array_equal(np.asarray(q2), np.asarray(q))
    assert np.array_equal(np.asarray(s2), np.asarray(s))
    assert np.array_equal(np.asarray(compress.dequantize_int8(q, s)),
                          np.asarray(wire.decode(q, s)))

    # pack/unpack is an exact round-trip of the split representation
    for w in ("int8", "fp8"):
        p, sc = wire.encode(g, w)
        buf = wire.pack(p, sc)
        assert buf.dtype == jnp.uint8
        assert buf.shape == (16, 33 + wire.SCALE_BYTES)
        p2, sc2 = wire.unpack(buf, w)
        assert np.array_equal(np.asarray(p2), np.asarray(p))
        assert np.array_equal(np.asarray(sc2), np.asarray(sc))
        c = wire.codec(w)
        assert np.array_equal(np.asarray(c.unpack_decode(buf)),
                              np.asarray(wire.decode(p, sc)))

    assert wire.codec("f32") is None
    with pytest.raises(ValueError, match="int4"):
        wire.codec("int4")
    # bytes model: 1-byte payload + one f32 scale per row
    assert wire.wire_bytes(8, 32, "f32", 4) == 8 * 32 * 4
    assert wire.wire_bytes(8, 32, "int8", 4) == 8 * (32 + 4)
    assert wire.wire_bytes(8, 32, "fp8", 2) == 8 * (32 + 4)


# ---------------------------------------------------------------------------
# 2. policy / config validation and resolution
# ---------------------------------------------------------------------------


def test_policy_wire_validation_and_resolution():
    from repro import ops

    with pytest.raises(ValueError, match=r"int4.*valid.*f32"):
        ops.OverlapPolicy(wire="int4")
    with pytest.raises(ValueError, match=r"int4.*valid"):
        ops.OverlapPolicy(wires={"ag_matmul": "int4"})

    pol = ops.OverlapPolicy(mode="ring", wire="int8")
    assert pol.resolve("ag_matmul").wire == "int8"
    assert pol.resolve("matmul_rs").wire == "int8"
    # baseline mode rides XLA collectives — no riding chunks to quantize
    assert ops.OverlapPolicy(mode="none", wire="int8") \
        .resolve("ag_matmul").wire == "f32"
    # non-wire-capable ops clamp to f32 under a global int8 default
    assert pol.resolve("flash_decode").wire == "f32"
    assert pol.resolve("ag_matmul_2level").wire == "f32"
    # per-op override beats the global default
    pol2 = ops.OverlapPolicy(mode="ring", wires={"matmul_rs": "fp8"})
    assert pol2.resolve("matmul_rs").wire == "fp8"
    assert pol2.resolve("ag_matmul").wire == "f32"
    assert "fp8" in pol2.describe("matmul_rs")


def test_parallel_config_wire_field_and_conflict():
    from repro import ops
    from repro.configs.base import ParallelConfig

    with pytest.raises(ValueError, match=r"int4.*valid"):
        ParallelConfig(tp=4, overlap_wire="int4")
    cfg = ParallelConfig(tp=4, overlap_mode="ring", overlap_wire="int8")
    assert cfg.policy.resolve("ag_matmul").wire == "int8"

    # explicit policy + non-default legacy wire field = two sources of
    # truth -> ValueError, BOTH argument orders (PR 4 pattern)
    pol = ops.OverlapPolicy(mode="ring", wire="int8")
    with pytest.raises(ValueError, match="overlap_wire"):
        ParallelConfig(tp=4, overlap=pol, overlap_wire="int8")
    with pytest.raises(ValueError, match="overlap_wire"):
        ParallelConfig(tp=4, overlap_wire="int8", overlap=pol)
    # a policy carrying the wire is the one source of truth — fine
    assert ParallelConfig(tp=4, overlap=pol) \
        .policy.resolve("ag_matmul").wire == "int8"


def test_registry_wire_capability():
    from repro.core import overlap as ov

    for op in ("ag_matmul", "matmul_rs", "all_gather", "reduce_scatter",
               "a2a_ep", "ring_attention"):
        assert ov.wires_for(op) == ("f32", "int8", "fp8"), op
    for op in ("flash_decode", "ag_matmul_2level"):
        assert ov.wires_for(op) == ("f32",), op
    with pytest.raises(ValueError, match="int4"):
        ov.resolve_wire("ag_matmul", "int4")
    assert ov.resolve_wire("ag_matmul", "int8", "ring") == "int8"
    assert ov.resolve_wire("ag_matmul", "int8", "none") == "f32"
    assert ov.resolve_wire("flash_decode", "int8", "one_shot") == "f32"
    # fold ops ride a multi-section packed chunk (K|V): wire-capable too
    assert ov.resolve_wire("ring_attention", "int8", "ring") == "int8"


# ---------------------------------------------------------------------------
# 3. quantized parity: graph vs kernel vs f32 baseline, worlds 2/4/8
# ---------------------------------------------------------------------------

# documented tolerances (relative error vs the f32 graph baseline)
_TOL = {"int8": 5e-2, "fp8": 1e-1}

PARITY = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.core import moe_overlap as mo

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    TOL = {"int8": 5e-2, "fp8": 1e-1}

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_rep=False))

    def rel(a, b):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        return np.abs(a - b).max() / max(1e-9, np.abs(b).max())

    M, K, N = 8 * W, 16, 4 * W
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    Wt = jnp.asarray(rng.randn(K, N), jnp.float32)

    def check(tag, got, ref, wire):
        e = rel(got, ref)
        assert e <= TOL[wire], f"{tag}: rel_err={e:.4f} > {TOL[wire]}"

    # ---- ag_matmul: riding A-chunks quantized ----
    AG = ((P("tp", None), P(None, "tp")), P(None, "tp"))
    ref = sh(functools.partial(ops.ag_matmul, axis="tp", mode="ring",
                               out_dtype=jnp.float32), *AG)(A, Wt)
    for mode in ("ring", "bidir", "one_shot"):
        for backend in ("graph", "kernel"):
            for wire in ("int8", "fp8"):
                if wire == "fp8" and mode != "ring":
                    continue  # fp8 pinned on one transport per op
                f = sh(functools.partial(ops.ag_matmul, axis="tp", mode=mode,
                                         backend=backend, wire=wire,
                                         out_dtype=jnp.float32), *AG)
                check(f"ag_matmul/{mode}/{backend}/{wire}", f(A, Wt), ref, wire)

    # ---- matmul_rs: riding partial accumulators quantized ----
    RS = ((P(None, "tp"), P("tp", None)), P("tp", None))
    ref = sh(functools.partial(ops.matmul_rs, axis="tp", mode="ring",
                               out_dtype=jnp.float32), *RS)(A, Wt)
    for mode in ("ring", "bidir", "one_shot"):
        for backend in ("graph", "kernel"):
            f = sh(functools.partial(ops.matmul_rs, axis="tp", mode=mode,
                                     backend=backend, wire="int8",
                                     out_dtype=jnp.float32), *RS)
            check(f"matmul_rs/{mode}/{backend}/int8", f(A, Wt), ref, "int8")

    # ---- stand-alone collectives ----
    X = jnp.asarray(rng.randn(4 * W, 8), jnp.float32)
    C = (P("tp", None), P(None, None))
    ref = sh(functools.partial(ops.all_gather, axis="tp", mode="ring"),
             *C)(X)
    for backend in ("graph", "kernel"):
        f = sh(functools.partial(ops.all_gather, axis="tp", mode="ring",
                                 backend=backend, wire="int8"), *C)
        check(f"all_gather/ring/{backend}/int8", f(X), ref, "int8")

    Y = jnp.asarray(rng.randn(4 * W, 8), jnp.float32)
    C = (P(None, None), P("tp", None))
    ref = sh(functools.partial(ops.reduce_scatter, axis="tp", mode="ring"),
             *C)(Y)
    for mode in ("ring", "one_shot"):
        for backend in ("graph", "kernel"):
            f = sh(functools.partial(ops.reduce_scatter, axis="tp", mode=mode,
                                     backend=backend, wire="int8"), *C)
            check(f"reduce_scatter/{mode}/{backend}/int8", f(Y), ref, "int8")

    # ---- a2a_ep: riding token slabs quantized ----
    E, cap, d = 2 * W, 4, 16
    Xd = jnp.asarray(rng.randn(W * E, cap, d), jnp.float32)
    C = (P("tp", None, None), P("tp", None, None))
    ref = sh(functools.partial(mo.a2a_ep, axis="tp", mode="one_shot"),
             *C)(Xd)
    for backend in ("graph", "kernel"):
        f = sh(functools.partial(mo.a2a_ep, axis="tp", mode="one_shot",
                                 backend=backend, wire="int8"), *C)
        check(f"a2a_ep/one_shot/{backend}/int8", f(Xd), ref, "int8")

    # ---- ring_attention: riding packed K|V chunk, per-section scales ----
    B, H, HKV, D = 2, 4, 2, 16
    S = 8 * W
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    kv = jnp.asarray(rng.randn(B, HKV, S, 2 * D), jnp.float32)
    AT = ((P(None, None, "tp", None), P(None, None, "tp", None)),
          P(None, None, "tp", None))
    attn = functools.partial(ops.ring_attention, axis="tp", causal=True,
                             scale=float(1.0 / np.sqrt(D)),
                             out_dtype=jnp.float32)
    ref = sh(functools.partial(attn, mode="ring"), *AT)(kv, q)
    for mode in ("ring", "one_shot"):
        for backend in ("graph", "kernel"):
            for wire in ("int8", "fp8"):
                if wire == "fp8" and mode != "ring":
                    continue
                f = sh(functools.partial(attn, mode=mode, backend=backend,
                                         wire=wire), *AT)
                check(f"ring_attention/{mode}/{backend}/{wire}",
                      f(kv, q), ref, wire)

    print("OK")
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_quantized_parity_all_wire_ops(world):
    out = run_devices(PARITY.replace("__WORLD__", str(world)), devices=world)
    assert "OK" in out


# ---------------------------------------------------------------------------
# 4. backward under a quantized wire
# ---------------------------------------------------------------------------

GRADS = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)

    M, K, N = 8 * W, 16, 4 * W
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    Wt = jnp.asarray(rng.randn(K, N), jnp.float32)

    for op, in_specs in (
        (ops.ag_matmul, (P("tp", None), P(None, "tp"))),
        (ops.matmul_rs, (P(None, "tp"), P("tp", None))),
    ):
        def make_grad(backend, wire):
            def f(a, w):
                # linear loss -> constant cotangent: the dual schedule's
                # output is bit-identical across forward backends
                out = op(a, w, axis="tp", mode="ring", backend=backend,
                         wire=wire, out_dtype=jnp.float32)
                return lax.psum(jnp.sum(out), "tp")
            return jax.jit(jax.shard_map(
                jax.grad(f, argnums=(0, 1)), mesh=mesh,
                in_specs=in_specs, out_specs=in_specs, check_rep=False))

        g_f32 = make_grad("graph", "f32")(A, Wt)
        g_g = make_grad("graph", "int8")(A, Wt)
        g_k = make_grad("kernel", "int8")(A, Wt)
        for gg, gk, gf in zip(g_g, g_k, g_f32):
            gg, gk, gf = map(np.asarray, (gg, gk, gf))
            assert np.all(np.isfinite(gg))
            # ONE dual schedule: kernel fwd keeps the graph dual
            assert np.array_equal(gg, gk), op
            # duals ride the same wire -> close to f32 grads
            err = np.abs(gg - gf).max() / max(1e-9, np.abs(gf).max())
            assert err <= 5e-2, f"{op}: grad rel_err={err:.4f}"
    print("OK")
""")


def test_quantized_wire_grads_bit_identical_across_backends():
    out = run_devices(GRADS.replace("__WORLD__", "4"), devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# 5. error feedback beats no feedback over repeated reductions
# ---------------------------------------------------------------------------

EF = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist import compress

    W = 4
    mesh = jax.make_mesh((W,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    G = jnp.asarray(rng.randn(W, 8, 64) * 0.1, jnp.float32)
    true = np.asarray(G, np.float64).sum(axis=0)

    step = jax.jit(jax.shard_map(
        functools.partial(compress.pod_allreduce_int8, axis="pod"),
        mesh=mesh, in_specs=(P("pod", None, None), P("pod", None, None)),
        out_specs=(P("pod", None, None), P("pod", None, None)),
        check_rep=False))

    def run(feedback, steps=8):
        ef = jnp.zeros_like(G)
        acc = np.zeros_like(true)
        for _ in range(steps):
            total, new_ef = step(G, ef)
            if feedback:
                ef = new_ef
            acc += np.asarray(total[0], np.float64)
        return np.abs(acc / steps - true).mean()

    err_with, err_without = run(True), run(False)
    # with feedback the residual is re-injected next step, so the TIME-
    # AVERAGED sum converges; without it the same bias repeats every step
    assert err_with < err_without * 0.5, (err_with, err_without)
    print("OK", err_with, err_without)
""")


def test_error_feedback_convergence():
    out = run_devices(EF, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# 6. tuner enumerates the wire axis
# ---------------------------------------------------------------------------


def test_tuner_wire_axis():
    from repro.core import tuner

    # f32 operands, tiny per-chunk compute: ICI bytes bind -> int8 wins
    comm = tuner.analytic_ag_matmul(1024, 4096, 256, 8, dtype_bytes=4)
    assert comm.wire == "int8"
    # big n_loc: MXU time dominates, codec passes make int8 a loss
    comp = tuner.analytic_ag_matmul(1024, 4096, 16384, 8, dtype_bytes=4)
    assert comp.wire == "f32"

    # matmul_rs rides an f32 accumulator, so even bf16 problems compress
    rs_comm = tuner.analytic_matmul_rs(8192, 256, 4096, 8)
    assert rs_comm.wire == "int8"
    rs_comp = tuner.analytic_matmul_rs(8192, 8192, 4096, 8)
    assert rs_comp.wire == "f32"

    # recommend_overlap_modes lands wire picks as per-op policy entries
    pol = tuner.recommend_overlap_modes(8192, 4096, 2048, 8)
    assert pol.resolve("matmul_rs").wire == "int8"
    assert pol.resolve("a2a_ep").wire == "f32"  # no analytic pick -> f32
