"""repro.obs: trace-event schema, metrics reduction, stall watchdog,
reset-in-flight guard, and disabled-mode bit-identity / overhead."""
import threading
import time

import numpy as np
import pytest

from conftest import run_devices


# ---------------------------------------------------------------------------
# Trace-event schema on a real 4-PE ring_ag run (subprocess: needs devices)
# ---------------------------------------------------------------------------


_RING_TRACE_SCRIPT = r"""
import functools, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import obs
from repro.core.collective_matmul import make_sharded
from repro.ops import ag_matmul

W = jax.device_count()
assert W == 4, W
mesh = jax.make_mesh((W,), ("tp",))
M, K, N = 8 * W, 16, 4 * W
x = jax.random.normal(jax.random.key(0), (M, K), jnp.float32)
w = jax.random.normal(jax.random.key(1), (K, N), jnp.float32)
specs = ((P("tp", None), P(None, "tp")), P(None, "tp"))

def build():
    return make_sharded(
        functools.partial(ag_matmul, axis="tp", mode="ring",
                          backend="kernel", out_dtype=jnp.float32),
        mesh, *specs)

# run 1: tracing disabled (reference output)
y_ref = np.asarray(build()(x, w))
assert not obs.events(), "no events may be recorded while disabled"

# run 2: tracing enabled (fresh build -> fresh trace with spans)
obs.enable()
y_traced = np.asarray(build()(x, w))
events = obs.events(clear=True)
obs.disable()

# run 3: disabled again — bit-identity with run 1
y_after = np.asarray(build()(x, w))
assert (y_ref == y_traced).all(), "tracing perturbed the result"
assert (y_ref == y_after).all(), "disable() did not restore the seed program"

# schema: every event field well-formed
per_pe = {}
for ev in events:
    assert 0 <= ev.pe < W, ev
    assert ev.t1 >= ev.t0 >= 0.0, ev
    assert ev.bytes >= 0, ev
    per_pe.setdefault(ev.pe, []).append(ev)
assert sorted(per_pe) == list(range(W)), sorted(per_pe)

counts = {}
for ev in events:
    counts[ev.kind] = counts.get(ev.kind, 0) + 1
# ring protocol, per PE: W-1 puts, W-1 credit waits, W-1 arrival waits,
# W tile computes, 2 barriers
assert counts["put"] == W * (W - 1), counts
assert counts["credit_wait"] == W * (W - 1), counts
assert counts["arrival_wait"] == W * (W - 1), counts
assert counts["tile_compute"] == W * W, counts
assert counts["barrier"] == 2 * W, counts
# every put has a matching arrival wait on the receiving side
assert counts["put"] == counts["arrival_wait"], counts
# wire bytes: each put ships one (M/W, K) f32 chunk
chunk_bytes = (M // W) * K * 4
put_bytes = sum(ev.bytes for ev in events if ev.kind == "put")
assert put_bytes == W * (W - 1) * chunk_bytes, (put_bytes, chunk_bytes)

s = obs.metrics.summarize(events, op="ag_matmul", mode="ring",
                          backend="kernel")
assert 0.0 < s.overlap_efficiency <= 1.0, s
assert s.n_pes == W and s.wire_bytes == put_bytes, s
assert s.labels["op"] == "ag_matmul", s.labels

# chrome-trace export round-trips
doc = obs.trace.chrome_trace(events)
xev = [r for r in doc["traceEvents"] if r.get("ph") == "X"]
assert len(xev) == len(events), (len(xev), len(events))
assert {r["tid"] for r in xev} == set(range(W))
json.dumps(doc)  # serializable
print("RING_TRACE_OK")
"""


def test_ring_ag_trace_schema_and_bit_identity():
    out = run_devices(_RING_TRACE_SCRIPT, devices=4)
    assert "RING_TRACE_OK" in out


# ---------------------------------------------------------------------------
# Metrics pinned on a hand-built synthetic timeline (no devices needed)
# ---------------------------------------------------------------------------


def test_overlap_efficiency_synthetic():
    from repro import obs

    # 2 PEs, wall = 10s. PE0: 6s compute + 2s arrival stall; PE1: 5s
    # compute + 4s credit stall. exposed = mean(2, 4) = 3 -> eff = 0.7.
    ev = [
        obs.TraceEvent(0, 7, "tile_compute", "s0", 0, 0.0, 6.0),
        obs.TraceEvent(0, 7, "arrival_wait", "recv", 0, 6.0, 8.0),
        obs.TraceEvent(0, 7, "put", "ws->pe1", 1024, 8.0, 10.0),
        obs.TraceEvent(1, 7, "credit_wait", "cap", 0, 0.0, 4.0),
        obs.TraceEvent(1, 7, "tile_compute", "s0", 0, 4.0, 9.0),
        obs.TraceEvent(1, 7, "put", "ws->pe0", 1024, 9.0, 10.0),
    ]
    s = obs.metrics.summarize(ev, op="synthetic")
    assert s.wall == pytest.approx(10.0)
    assert s.compute_busy == pytest.approx(5.5)     # mean(6, 5)
    assert s.exposed_comm == pytest.approx(3.0)     # mean(2, 4)
    assert s.stall_frac == pytest.approx(0.3)
    assert s.overlap_efficiency == pytest.approx(0.7)
    assert s.wire_bytes == 2048
    assert s.n_pes == 2 and s.n_events == 6
    assert s.per_pe[0]["stall"] == pytest.approx(2.0)
    assert s.per_pe[1]["stall"] == pytest.approx(4.0)


def test_mid_stream_barriers_count_as_exposed():
    from repro import obs

    # One PE, one kernel instance (cid 7): entry barrier, a mid-stream
    # flush, an exit barrier. Only the FIRST is the launch rendezvous
    # (the separate `barrier` bucket); the later two are rendezvous the
    # schedule put in the middle of the work — exposed comm (this is
    # what makes the fused rs->ag chain read better than the
    # back-to-back pair: it drops the mid-chain flushes).
    ev = [
        obs.TraceEvent(0, 7, "barrier", "b", 0, 0.0, 1.0),   # launch
        obs.TraceEvent(0, 7, "tile_compute", "s0", 0, 1.0, 5.0),
        obs.TraceEvent(0, 7, "barrier", "b", 0, 5.0, 7.0),   # flush
        obs.TraceEvent(0, 7, "tile_compute", "s1", 0, 7.0, 9.0),
        obs.TraceEvent(0, 7, "barrier", "b", 0, 9.0, 10.0),  # flush
    ]
    s = obs.metrics.summarize(ev)
    assert s.barrier == pytest.approx(1.0)
    assert s.exposed_comm == pytest.approx(3.0)
    assert s.stall_frac == pytest.approx(0.3)
    assert s.overlap_efficiency == pytest.approx(0.7)
    # a second kernel instance gets its own launch rendezvous
    ev2 = ev + [obs.TraceEvent(0, 8, "barrier", "b", 0, 10.0, 12.0)]
    s2 = obs.metrics.summarize(ev2)
    assert s2.barrier == pytest.approx(3.0)
    assert s2.exposed_comm == pytest.approx(3.0)
    # unsorted input: the launch barrier is the EARLIEST, not the first
    # in list order
    s3 = obs.metrics.summarize(list(reversed(ev)))
    assert s3.barrier == pytest.approx(1.0)
    assert s3.exposed_comm == pytest.approx(3.0)


def test_summarize_empty_trace_raises():
    from repro import obs

    with pytest.raises(ValueError, match="empty trace"):
        obs.metrics.summarize([])


def test_split_by_cid():
    from repro import obs

    ev = [obs.TraceEvent(0, 1, "put", "a", 1, 0.0, 1.0),
          obs.TraceEvent(0, 2, "put", "b", 1, 0.0, 1.0),
          obs.TraceEvent(1, 1, "read", "a", 1, 0.0, 1.0)]
    groups = obs.metrics.split_by_cid(ev)
    assert sorted(groups) == [1, 2]
    assert len(groups[1]) == 2 and len(groups[2]) == 1


# ---------------------------------------------------------------------------
# Stall watchdog: timeout resolved at wait time + report content
# ---------------------------------------------------------------------------


def test_watchdog_report_on_timeout(monkeypatch):
    from repro import obs
    from repro.shmem import emulated as em

    # satellite 1: the timeout is read PER WAIT — this setenv takes
    # effect without any reimport (at import time the default was 60s,
    # so this test hanging <1s proves wait-time resolution)
    monkeypatch.setenv("REPRO_SHMEM_TIMEOUT", "0.2")
    key = (9301, 1)
    obs.enable()
    try:
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError) as ei:
            em._host_wait(key, "recv", np.int32(0), np.int32(2), np.int32(1))
        elapsed = time.perf_counter() - t0
        assert elapsed < 5.0, f"timeout not resolved at wait time ({elapsed})"
        msg = str(ei.value)
        assert "timed out" in msg
        assert "shmem watchdog" in msg
        assert "pe 2: wait on 'recv' want=1 have=0" in msg
    finally:
        obs.disable()
        em.reset(key[0])


def test_watchdog_reports_other_waiters(monkeypatch):
    from repro.shmem import emulated as em

    # per-wait timeout resolution lets the two waits use different
    # budgets: the blocker outlives the probing wait, so the probe's
    # watchdog report captures it in the waiter table
    monkeypatch.setenv("REPRO_SHMEM_TIMEOUT", "30")
    key = (9302, 1)
    try:
        blocker = threading.Thread(
            target=lambda: em._host_wait(key, "cap", np.int32(0), np.int32(0),
                                         np.int32(3)),
            daemon=True)
        blocker.start()
        w = em._world(key)
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with w.cond:
                if 0 in w.waiters:
                    break
            time.sleep(0.005)
        monkeypatch.setenv("REPRO_SHMEM_TIMEOUT", "0.2")
        with pytest.raises(RuntimeError) as ei:
            em._host_wait(key, "recv", np.int32(0), np.int32(1), np.int32(1))
        # the report names BOTH blocked PEs (credit waiter + this one)
        msg = str(ei.value)
        assert "pe 0: wait on 'cap' want=3 have=0" in msg
        assert "pe 1: wait on 'recv' want=1 have=0" in msg
        # release the blocker (grant its 3 credits) and clean up
        em._host_signal(key, "cap", np.int32(0), np.int32(0), np.int32(3),
                        np.int32(1))
        blocker.join(timeout=5.0)
        assert not blocker.is_alive()
    finally:
        em.reset(key[0])


# ---------------------------------------------------------------------------
# reset() guard: refuses to drop state under a blocked PE (satellite 2)
# ---------------------------------------------------------------------------


def test_reset_refuses_while_wait_in_flight(monkeypatch):
    from repro.shmem import emulated as em

    monkeypatch.setenv("REPRO_SHMEM_TIMEOUT", "30")
    key = (9303, 1)
    done = threading.Event()

    def blocked_wait():
        em._host_wait(key, "recv", np.int32(0), np.int32(0), np.int32(1))
        done.set()

    t = threading.Thread(target=blocked_wait, daemon=True)
    t.start()
    w = em._world(key)
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline:
        with w.cond:
            if 0 in w.waiters:
                break
        time.sleep(0.005)
    with pytest.raises(RuntimeError, match="wait in flight"):
        em.reset(key[0])
    # the error names the live waiter via the watchdog table
    with pytest.raises(RuntimeError, match="pe 0: wait on 'recv'"):
        em.reset(key[0])
    # release the waiter; reset then succeeds
    em._host_signal(key, "recv", np.int32(0), np.int32(0), np.int32(1),
                    np.int32(1))
    assert done.wait(timeout=5.0)
    t.join(timeout=5.0)
    em.reset(key[0])
    with em._worlds_lock:
        assert key not in em._worlds


def test_reset_drops_trace_buffers():
    from repro import obs
    from repro.shmem import emulated as em

    key = (9304, 1)
    obs.enable()
    try:
        em._host_signal(key, "s", np.int32(0), np.int32(0), np.int32(1),
                        np.int32(0))
        assert any(ev.cid == key[0] for ev in obs.events())
        em.reset(key[0])
        assert not any(ev.cid == key[0] for ev in obs.events())
    finally:
        obs.disable()
        em.reset(key[0])


# ---------------------------------------------------------------------------
# Disabled mode: no events, no measurable overhead on the host-op path
# ---------------------------------------------------------------------------


def test_disabled_records_nothing_and_is_cheap():
    from repro import obs
    from repro.shmem import emulated as em

    assert not obs.enabled()
    key = (9305, 1)
    try:
        n = 2000
        t0 = time.perf_counter()
        for i in range(n):
            em._host_signal(key, "s", np.int32(0), np.int32(0), np.int32(1),
                            np.int32(0))
        per_call = (time.perf_counter() - t0) / n
        assert not obs.events(), "disabled tracing recorded events"
        # generous absolute bound: the gate is one bool check per call
        assert per_call < 1e-3, f"{per_call * 1e6:.1f}us per host op"
    finally:
        em.reset(key[0])


def test_capacity_bounds_ring_buffer():
    from repro import obs
    from repro.shmem import emulated as em

    key = (9306, 1)
    obs.enable(capacity=16)
    try:
        for _ in range(100):
            em._host_signal(key, "s", np.int32(0), np.int32(0), np.int32(1),
                            np.int32(0))
        mine = [ev for ev in obs.events() if ev.cid == key[0]]
        assert len(mine) == 16, len(mine)
    finally:
        obs.disable()
        obs.enable()  # restore default capacity for later tests
        obs.disable()
        em.reset(key[0])


# ---------------------------------------------------------------------------
# Chrome-trace export shape
# ---------------------------------------------------------------------------


def test_chrome_trace_metadata_and_units():
    from repro import obs

    ev = [obs.TraceEvent(2, 5, "tile_compute", "s0", 0, 1.0, 1.001),
          obs.TraceEvent(2, 5, "put", "ws->pe0", 64, 1.001, 1.002)]
    doc = obs.trace.chrome_trace(ev)
    meta = [r for r in doc["traceEvents"] if r["ph"] == "M"]
    names = {(r["name"], r.get("tid")) for r in meta}
    assert ("process_name", None) in names
    assert ("thread_name", 2) in names
    xs = [r for r in doc["traceEvents"] if r["ph"] == "X"]
    assert xs[0]["ts"] == pytest.approx(0.0)          # normalized to start
    assert xs[0]["dur"] == pytest.approx(1000.0)      # 1ms in us
    assert xs[1]["args"]["bytes"] == 64


def test_trace_save_writes_file(tmp_path):
    from repro import obs

    path = tmp_path / "t.json"
    ev = [obs.TraceEvent(0, 1, "put", "ws", 4, 0.0, 1.0)]
    n = obs.trace.save(str(path), ev)
    assert n == 1
    import json

    doc = json.loads(path.read_text())
    assert doc["traceEvents"]
