"""Optimizer unit tests (reference AdamW equivalence, momentum mode,
moment dtypes, LR schedule)."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.train import optimizer as opt


def _ref_adamw(p, g, m, v, step, tcfg, clip=1.0):
    b1, b2 = tcfg.beta1, tcfg.beta2
    lr = float(opt.lr_schedule(tcfg, jnp.int32(step)))
    g = g * clip
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    p = p - lr * (mh / (np.sqrt(vh) + tcfg.eps) + tcfg.weight_decay * p)
    return p, m, v


def test_adamw_matches_reference():
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=100,
                       grad_clip=1e9)
    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(4, 8).reshape(4, 8), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(4, 8), jnp.float32)}
    st = opt.init_opt_state(p)
    new_p, new_st, lr = opt.adamw_update(p, g, st, tcfg, grad_norm=jnp.float32(1.0))
    want_p, want_m, want_v = _ref_adamw(
        np.asarray(p["w"]), np.asarray(g["w"]),
        np.zeros((4, 8), np.float32), np.zeros((4, 8), np.float32), 1, tcfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want_p, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_st.mu["w"]), want_m, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_st.nu["w"]), want_v, atol=1e-6)


def test_stacked_scan_update_matches_flat():
    """The per-layer scanned update must equal the direct elementwise one."""
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=100)
    rng = np.random.RandomState(1)
    stacked = {"w": jnp.asarray(rng.randn(5, 16), jnp.float32)}  # (L, packed)
    flat = {"w": stacked["w"][2:3]}  # one layer, still 2D but L=1 -> direct
    g_st = {"w": jnp.asarray(rng.randn(5, 16), jnp.float32)}
    st = opt.init_opt_state(stacked)
    new_st_p, _, _ = opt.adamw_update(stacked, g_st, st, tcfg,
                                      grad_norm=jnp.float32(1.0))
    st1 = opt.init_opt_state(flat)
    new_fl_p, _, _ = opt.adamw_update(flat, {"w": g_st["w"][2:3]}, st1, tcfg,
                                      grad_norm=jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(new_st_p["w"][2]),
                               np.asarray(new_fl_p["w"][0]), atol=1e-6)


def test_momentum_mode():
    tcfg = TrainConfig(optimizer="momentum", learning_rate=1e-2, warmup_steps=1,
                       total_steps=100, grad_clip=1e9, weight_decay=0.0, beta1=0.9)
    p = {"w": jnp.ones((3, 4), jnp.float32)}
    g = {"w": jnp.full((3, 4), 0.5, jnp.float32)}
    st = opt.init_opt_state(p, kind="momentum")
    assert st.nu["w"].shape == (1,)  # placeholder, no second moment
    new_p, new_st, lr = opt.adamw_update(p, g, st, tcfg, grad_norm=jnp.float32(1.0))
    # m = 0.9*0 + g = 0.5 ; p -= lr * m
    np.testing.assert_allclose(np.asarray(new_st.mu["w"]), 0.5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - float(lr) * 0.5,
                               atol=1e-6)


def test_grad_clipping():
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=100,
                       grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((2,), jnp.float32)}
    g = {"w": jnp.asarray([3.0, 4.0], jnp.float32)}  # norm 5 -> scaled by 1/5
    st = opt.init_opt_state(p)
    new_p, new_st, _ = opt.adamw_update(p, g, st, tcfg, grad_norm=jnp.float32(5.0))
    np.testing.assert_allclose(np.asarray(new_st.mu["w"]),
                               0.1 * np.asarray([0.6, 0.8]), atol=1e-5)


def test_bf16_moments():
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt.init_opt_state(p, jnp.bfloat16)
    assert st.mu["w"].dtype == jnp.bfloat16
    tcfg = TrainConfig(warmup_steps=1, total_steps=10)
    new_p, new_st, _ = opt.adamw_update(p, {"w": jnp.ones((4,), jnp.bfloat16)},
                                        st, tcfg, grad_norm=jnp.float32(1.0))
    assert new_st.mu["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


def test_skip_gate_freezes_state():
    """ok=False must be a full no-op (params, moments, AND step count) —
    the donation-safe NaN/fault guard."""
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=100)
    p = {"w": jnp.ones((3, 4), jnp.float32)}
    g = {"w": jnp.full((3, 4), jnp.nan, jnp.float32)}
    st = opt.init_opt_state(p)
    new_p, new_st, _ = opt.adamw_update(p, g, st, tcfg,
                                        grad_norm=jnp.float32(jnp.nan),
                                        ok=jnp.bool_(False))
    np.testing.assert_array_equal(np.asarray(new_p["w"]), np.asarray(p["w"]))
    np.testing.assert_array_equal(np.asarray(new_st.mu["w"]),
                                  np.asarray(st.mu["w"]))
    np.testing.assert_array_equal(np.asarray(new_st.nu["w"]),
                                  np.asarray(st.nu["w"]))
    assert int(new_st.step) == 0
    # and ok=True behaves exactly like the default
    g2 = {"w": jnp.full((3, 4), 0.5, jnp.float32)}
    a_p, a_st, _ = opt.adamw_update(p, g2, st, tcfg, grad_norm=jnp.float32(1.0),
                                    ok=jnp.bool_(True))
    b_p, b_st, _ = opt.adamw_update(p, g2, st, tcfg, grad_norm=jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(a_p["w"]), np.asarray(b_p["w"]))
    assert int(a_st.step) == int(b_st.step) == 1


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=110)
    lrs = [float(opt.lr_schedule(tcfg, jnp.int32(s))) for s in (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6  # floor at 10%
