"""End-to-end behaviour tests for the paper's system.

The paper's claim structure is performance/overlap-structural, so the
system tests assert:
 1. the overlapped implementations are numerically EXACT vs. baselines
    (test_collectives.py, test_train_integration.py — multi-device),
 2. every assigned architecture trains/decodes (test_arch_smoke.py),
 3. here: training on learnable synthetic data actually reduces loss, and
    the dry-run machinery produces coherent roofline reports.
"""
import os
import sys
sys.path.insert(0, os.path.dirname(__file__))


from conftest import run_devices


TRAIN_LEARNS = """
import argparse, shutil
import numpy as np
from repro.launch.train import run

shutil.rmtree("/tmp/repro_sys_ckpt", ignore_errors=True)
ns = argparse.Namespace(
    arch="granite-3-2b", reduced=True, dp=2, tp=2, pods=1, steps=40,
    batch=8, seq=32, lr=3e-3, overlap="ring", remat="block",
    dtype="float32", no_fsdp=False, fresh=True,
    ckpt_dir="/tmp/repro_sys_ckpt", ckpt_every=0, log_every=100)
losses = run(ns)
first = np.mean(losses[:5])
last = np.mean(losses[-5:])
assert last < first - 0.1, (first, last)
print("OK", first, last)
"""


def test_training_reduces_loss():
    out = run_devices(TRAIN_LEARNS, devices=4, timeout=1200)
    assert "OK" in out


def test_dryrun_cell_produces_report(tmp_path):
    """One full dry-run cell in a 512-device subprocess: lower + compile +
    memory/cost analysis + roofline JSON."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
rep = run_cell("granite-3-2b", "decode_32k", multi_pod=False,
               out_dir={str(tmp_path)!r}, force=True)
assert rep["skipped"] is False
assert rep["fits_hbm"] in (True, False)
assert rep["t_compute"] > 0 and rep["t_memory"] > 0
assert rep["dominant"] in ("compute", "memory", "collective")
print("OK")
"""
    out = run_devices(script, devices=512, timeout=1800)
    assert "OK" in out


def test_shape_skip_policy():
    from repro.configs import SHAPES, shape_applicable

    assert shape_applicable("ssm", SHAPES["long_500k"])
    assert shape_applicable("hybrid", SHAPES["long_500k"])
    assert not shape_applicable("dense", SHAPES["long_500k"])
    assert not shape_applicable("moe", SHAPES["long_500k"])
    for fam in ("dense", "moe", "ssm", "hybrid", "vlm", "whisper"):
        assert shape_applicable(fam, SHAPES["train_4k"])
