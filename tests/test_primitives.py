"""Direct tests of the paper's Table-1 primitive API (core/primitives.py)
inside Pallas kernels under the cross-device interpreter."""
import textwrap

import pytest

from conftest import run_devices
from repro import _compat

SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.core import primitives as prim

    W = 4
    mesh = jax.make_mesh((W,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))

    # ---- putmem_signal + signal-ordered read: ring rotate by one ----
    def rotate_kernel(x_ref, o_ref, send_sem, recv_sem):
        me = lax.axis_index("x")
        prim.barrier_all("x", W)
        peer = lax.rem(me + 1, W)
        copy = prim.putmem_signal_nbi(x_ref, o_ref, send_sem, recv_sem, peer)
        prim.quiet(copy)   # send drained + my incoming arrived

    def rotate(x):
        return pl.pallas_call(
            rotate_kernel,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
            compiler_params=pltpu.CompilerParams(collective_id=3),
            interpret=pltpu.InterpretParams())(x)

    x = jnp.arange(W * 8, dtype=jnp.float32).reshape(W, 8)
    f = jax.jit(jax.shard_map(rotate, mesh=mesh, in_specs=P("x", None),
                              out_specs=P("x", None), check_vma=False))
    got = np.asarray(f(x))
    want = np.roll(np.asarray(x), 1, axis=0)  # rank r's data lands at r+1
    assert np.abs(got - want).max() == 0, got

    # ---- broadcast_put (multimem_st analogue): all ranks see rank data ----
    def bcast_kernel(x_ref, o_ref, send_sem, recv_sem, local_sem):
        me = lax.axis_index("x")
        prim.barrier_all("x", W)
        lc = pltpu.make_async_copy(x_ref, o_ref, local_sem)
        lc.start()
        prim.broadcast_put(x_ref, o_ref, send_sem, recv_sem, "x", W)
        lc.wait()
        # wait for W-1 arrivals (symmetric senders)
        for _ in range(W - 1):
            pltpu.make_async_copy(x_ref, o_ref, recv_sem).wait()

    # NOTE: every rank overwrites o_ref with ITS x — last writer wins per
    # slot; with identical payloads this asserts delivery, not ordering.
    xx = jnp.ones((W, 8), jnp.float32) * 7.0
    def bcast(x):
        return pl.pallas_call(
            bcast_kernel,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA],
            compiler_params=pltpu.CompilerParams(collective_id=4),
            interpret=pltpu.InterpretParams())(x)
    g = jax.jit(jax.shard_map(bcast, mesh=mesh, in_specs=P("x", None),
                              out_specs=P("x", None), check_vma=False))
    got = np.asarray(g(xx))
    assert np.all(got == 7.0), got

    # ---- my_pe / n_pes linearization over 2 axes ----
    mesh2 = jax.make_mesh((2, 2), ("a", "b"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    def pe(x):
        return (prim.my_pe(("a", "b")) + prim.n_pes(("a", "b")) * 0 + x[0] * 0
                ).reshape(1)
    h = jax.jit(jax.shard_map(pe, mesh=mesh2, in_specs=P(("a", "b")),
                              out_specs=P(("a", "b")), check_vma=False))
    ids = np.asarray(h(jnp.zeros((4,), jnp.int32)))
    assert sorted(ids.tolist()) == [0, 1, 2, 3], ids

    # consume_token is a no-op passthrough (Pallas refs are effect-ordered)
    t = prim.consume_token(jnp.ones(3), token=None)
    assert np.all(np.asarray(t) == 1.0)
    print("OK")
""")


@pytest.mark.skipif(
    not _compat.PALLAS_REMOTE_INTERPRET,
    reason="this jax's Pallas interpreter cannot emulate remote DMA signals "
           "(no pltpu.InterpretParams); kernel-level primitives need real "
           "TPU or a newer jax",
)
def test_table1_primitives():
    out = run_devices(SCRIPT, devices=4)
    assert "OK" in out
