"""Direct tests of the paper's Table-1 primitive API on the shmem
subsystem's emulated-DMA backend (no hardware, no skip: the emulated
backend implements the kernel-level primitive set on host-side
symmetric heaps — see repro/shmem/emulated.py)."""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core import primitives as prim
    from repro.shmem import emulated as em

    W = 4
    mesh = jax.make_mesh((W,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    # ---- putmem_signal + signal-ordered read: ring rotate by one ----
    def rotate(x):
        ctx = em.ShmemCtx("x", W, cid=3)
        me = lax.axis_index("x")
        ctx.barrier_all()
        peer = lax.rem(me + 1, W)
        ctx.putmem_signal_nbi(x, peer, sig="recv")
        out = ctx.wait_read(x.shape, x.dtype, sig="recv")
        ctx.barrier_all()
        return out

    x = jnp.arange(W * 8, dtype=jnp.float32).reshape(W, 8)
    got = np.asarray(sh(rotate, P("x", None), P("x", None))(x))
    want = np.roll(np.asarray(x), 1, axis=0)  # rank r's data lands at r+1
    assert np.abs(got - want).max() == 0, got

    # ---- broadcast_put (multimem_st analogue): all ranks see all data ----
    def bcast(x):
        ctx = em.ShmemCtx("x", W, cid=4)
        ctx.barrier_all()
        ctx.broadcast_put(x, sig="recv")
        ctx.signal_wait_until(sig="recv", value=W)  # W arrivals (self incl.)
        out = jnp.zeros((W * x.shape[0],) + x.shape[1:], x.dtype)
        for r in range(W):
            s = ctx.read_symmetric(x.shape, x.dtype, slot=r)
            out = lax.dynamic_update_slice(out, s, (r * x.shape[0], 0))
        ctx.barrier_all()
        return out

    xx = jnp.ones((W, 8), jnp.float32) * 7.0
    got = np.asarray(sh(bcast, P("x", None), P(None, None))(xx))
    assert np.all(got == 7.0), got

    # ---- notify / wait aliases (signal_op / signal_wait_until) ----
    def handshake(x):
        ctx = em.ShmemCtx("x", W, cid=5)
        me = lax.axis_index("x")
        ctx.barrier_all()
        ctx.notify(lax.rem(me + 1, W), sig="hs", inc=2)
        ctx.wait(sig="hs", value=2)
        ctx.barrier_all()
        return x

    np.asarray(sh(handshake, P("x", None), P("x", None))(x))

    # ---- my_pe / n_pes linearization over 2 axes ----
    mesh2 = jax.make_mesh((2, 2), ("a", "b"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    def pe(x):
        return (prim.my_pe(("a", "b")) + prim.n_pes(("a", "b")) * 0 + x[0] * 0
                ).reshape(1)
    h = jax.jit(jax.shard_map(pe, mesh=mesh2, in_specs=P(("a", "b")),
                              out_specs=P(("a", "b")), check_vma=False))
    ids = np.asarray(h(jnp.zeros((4,), jnp.int32)))
    assert sorted(ids.tolist()) == [0, 1, 2, 3], ids

    # consume_token is a no-op passthrough (ordering comes from Pallas ref
    # effects on TPU / the emulated token chain on CPU)
    t = prim.consume_token(jnp.ones(3), token=None)
    assert np.all(np.asarray(t) == 1.0)
    print("OK")
""")


def test_table1_primitives():
    out = run_devices(SCRIPT, devices=4)
    assert "OK" in out
