"""Training integration on 4 virtual devices (subprocess):
- 2x2 mesh train step produces same loss as 1x1 (parallelism invariance)
- overlapped modes give the same training trajectory as baseline
- the fused attention->MLP boundary (policy opt-in) matches the unfused
  oracle in loss and parameter grads
- checkpoint restart reproduces the loss stream
- gradient compression (int8 + error feedback) approximates the true sum
"""
import textwrap

from conftest import run_devices

PARALLEL_INVARIANCE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import build_model

    cfg = reduced(ARCHS["granite-3-2b"])
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab_size, (4, 32)),
                         jnp.int32)

    losses = {}
    for name, (dp, tp, mode) in {
        "1x1": (1, 1, "none"),
        "2x2ring": (2, 2, "ring"),
        "2x2oneshot": (2, 2, "one_shot"),
        "4x1": (4, 1, "none"),
        "1x4": (1, 4, "ring"),
    }.items():
        pcfg = ParallelConfig(dp=dp, tp=tp, fsdp=dp > 1, overlap_mode=mode,
                              compute_dtype="float32", param_dtype="float32")
        mesh = jax.make_mesh((dp, tp), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        model = build_model(cfg, pcfg)
        params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
        f = jax.jit(jax.shard_map(
            lambda p, t, l: model.loss_local(p, t, l, None), mesh=mesh,
            in_specs=(pspecs, P("data", None), P("data", None)),
            out_specs=P(), check_vma=False))
        losses[name] = float(f(params, tokens, tokens))

    base = losses["1x1"]
    for k, v in losses.items():
        # NOTE: inits differ per mesh layout (per-rank RNG); losses are all
        # near ln(V) but NOT identical — so assert the band, and assert the
        # sharded overlap modes agree with each other exactly.
        assert np.isfinite(v), k
        assert abs(v - base) < 1.0, (k, v, base)
    assert abs(losses["2x2ring"] - losses["2x2oneshot"]) < 1e-4
    print("OK", losses)
""")


def test_parallelism_invariance():
    out = run_devices(PARALLEL_INVARIANCE, devices=4)
    assert "OK" in out


OVERLAP_EXACT = textwrap.dedent("""
    # Same mesh + same params: overlapped collectives must match the XLA
    # baseline bit-for-bit in f32 (same math, different schedule).
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import build_model

    cfg = reduced(ARCHS["zamba2-2.7b"])
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 32)),
                         jnp.int32)
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    vals = {}
    params0 = None
    for mode in ("none", "ring", "bidir", "one_shot"):
        pcfg = ParallelConfig(dp=2, tp=2, fsdp=True, overlap_mode=mode,
                              compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg, pcfg)
        params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
        f = jax.jit(jax.shard_map(
            lambda p, t, l: model.loss_local(p, t, l, None), mesh=mesh,
            in_specs=(pspecs, P("data", None), P("data", None)),
            out_specs=P(), check_vma=False))
        vals[mode] = float(f(params, tokens, tokens))
    base = vals["none"]
    for k, v in vals.items():
        assert abs(v - base) < 5e-5, (k, v, base)
    print("OK", vals)
""")


def test_overlap_modes_match_baseline_exactly():
    out = run_devices(OVERLAP_EXACT, devices=4)
    assert "OK" in out


FUSED_BOUNDARY = textwrap.dedent("""
    # The dense block's attention->MLP boundary routed through the fused
    # matmul_rs_ag_matmul declaration (policy opt-in; graph backend, the
    # model default) must match the unfused oracle in loss AND parameter
    # grads. The residual algebra (one concatenated GEMM+RS closing both
    # residual branches) reassociates f32 sums, so the tolerance is
    # accumulation rounding, not exact equality.
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import blocks, build_model

    cfg = reduced(ARCHS["granite-3-2b"])
    tokens = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 32)),
        jnp.int32)
    mesh = jax.make_mesh((1, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def run(policy):
        pcfg = ParallelConfig(dp=1, tp=4, fsdp=False, overlap=policy,
                              compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg, pcfg)
        params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
        f = jax.jit(jax.shard_map(
            jax.value_and_grad(
                lambda p, t, l: model.loss_local(p, t, l, None)),
            mesh=mesh, in_specs=(pspecs, P("data", None), P("data", None)),
            out_specs=(P(), pspecs), check_vma=False))
        loss, grads = f(params, tokens, tokens)
        return float(loss), jax.tree.leaves(grads)

    base_pol = ops.OverlapPolicy(mode="ring")
    fused_pol = base_pol.with_modes(matmul_rs_ag_matmul="ring")
    # the routing gate: fused only when the policy opts the op in
    assert blocks.boundary_fused(ParallelConfig(tp=4, overlap=fused_pol))
    assert not blocks.boundary_fused(ParallelConfig(tp=4, overlap=base_pol))

    l0, g0 = run(base_pol)
    l1, g1 = run(fused_pol)
    # boundary sub-chunking (the chunks knob splits the reduced block's
    # rows) rides the same call path and stays equivalent
    l2, g2 = run(ops.OverlapPolicy(mode="ring", ag_chunks=2).with_modes(
        matmul_rs_ag_matmul="ring"))
    assert np.isfinite(l0)
    for lx in (l1, l2):
        assert abs(lx - l0) < 5e-5, (l0, l1, l2)
    for gx in (g1, g2):
        for a, b in zip(g0, gx):
            a, b = np.asarray(a), np.asarray(b)
            rel = np.abs(a - b).max() / max(1.0, np.abs(a).max())
            assert rel < 1e-4, rel
    print("OK fused boundary", l0, l1, l2)
""")


def test_fused_boundary_block_matches_unfused_oracle():
    out = run_devices(FUSED_BOUNDARY, devices=4, timeout=1200)
    assert "OK" in out


RESTART = textwrap.dedent("""
    import sys, numpy as np
    from repro.launch.train import run
    import argparse

    def args(steps, fresh):
        ns = argparse.Namespace(
            arch="granite-3-2b", reduced=True, dp=2, tp=2, pods=1, steps=steps,
            batch=4, seq=32, lr=1e-3, overlap="ring", remat="block",
            dtype="float32", no_fsdp=False, fresh=fresh,
            ckpt_dir="/tmp/repro_test_ckpt", ckpt_every=4, log_every=100)
        return ns

    import shutil
    shutil.rmtree("/tmp/repro_test_ckpt", ignore_errors=True)
    full = run(args(10, fresh=True))           # steps 0..9
    part = run(args(10, fresh=False))          # resumes at 10 -> no new steps
    assert part == []
    shutil.rmtree("/tmp/repro_test_ckpt", ignore_errors=True)
    a = run(args(6, fresh=True))               # 0..5 (final ckpt at 6)
    b = run(args(10, fresh=False))             # resumes at 6: 6..9
    merged = a + b                             # the full 0..9 stream
    assert len(merged) == len(full) == 10, (len(a), len(b), len(full))
    # XLA:CPU multi-device collectives are not bitwise-deterministic
    # across executions (reduction arrival order); assert the restart
    # semantics (step alignment + same trajectory), not bit equality.
    assert np.allclose(merged, full, atol=5e-2), (merged, full)
    print("OK")
""")


def test_checkpoint_restart_reproduces_stream():
    out = run_devices(RESTART, devices=4, timeout=1200)
    assert "OK" in out


COMPRESSION = textwrap.dedent("""
    import functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist import compress

    mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(4, 256), jnp.float32)  # per-pod gradients

    def step(gl, ef):
        return compress.pod_allreduce_int8(gl, ef, "pod")

    f = jax.jit(jax.shard_map(step, mesh=mesh,
        in_specs=(P("pod", None), P("pod", None)), out_specs=(P("pod", None), P("pod", None)),
        check_vma=False))
    ef = jnp.zeros_like(g)
    got, ef1 = f(g, ef)
    want = np.asarray(g).reshape(4, 1, 256).sum(0)
    got_np = np.asarray(got).reshape(4, 1, 256)
    # every pod holds (approximately) the same sum
    for r in range(4):
        rel = np.abs(got_np[r] - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.05, rel
    # error feedback: quantization residual is recorded, bounded by 1 LSB
    scales = np.abs(np.asarray(g)).max(axis=1, keepdims=True) / 127.0
    assert np.all(np.abs(np.asarray(ef1)) <= scales * 0.51 + 1e-6)
    print("OK")
""")


def test_int8_gradient_compression():
    out = run_devices(COMPRESSION, devices=4)
    assert "OK" in out
