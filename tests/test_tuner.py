"""Autotuner tests: analytic model sanity + the paper's whole-step
empirical protocol (§3.8)."""
import jax.numpy as jnp

from repro.core import tuner
from repro import hw


def test_analytic_ag_prefers_overlap_when_compute_bound():
    # huge n_loc -> dot dominates -> any overlapped mode beats "none"
    choice = tuner.analytic_ag_matmul(4096, 8192, 8192, world=16)
    assert choice.mode != "none"
    assert choice.t_total < choice.t_comm + choice.t_compute


def test_analytic_ag_small_message_prefers_one_shot():
    # tiny per-step compute, tiny message: latency regime
    choice = tuner.analytic_ag_matmul(8, 256, 64, world=16)
    assert choice.mode in ("one_shot", "bidir")


def test_analytic_rs_overlap_wins_when_balanced():
    c = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16)
    # comm-heavy regime: a ring transport (uni- or bidirectional) beats
    # the serialized baseline and the bandwidth-hungry one_shot
    assert c.mode in ("ring", "bidir")
    assert c.t_total <= c.t_compute + c.t_comm + 1e-9


def test_analytic_candidates_come_from_registry():
    from repro.core import overlap

    # every transport the registry declares is considered (plus baseline)
    assert set(overlap.transports_for("ag_matmul", include_baseline=True)) == {
        "none", "ring", "bidir", "one_shot"}
    assert set(overlap.transports_for("matmul_rs", include_baseline=True)) == {
        "none", "ring", "bidir", "one_shot"}
    # an op-restricted candidate list narrows the search
    only_ring = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16,
                                         candidates=("ring",))
    assert only_ring.mode == "ring"


def test_recommend_overlap_modes_returns_policy():
    from repro import ops
    from repro.core import overlap

    rec = tuner.recommend_overlap_modes(4096, 8192, 8192, world=16)
    # the recommendation IS an OverlapPolicy — consumable by
    # ParallelConfig.overlap / repro.ops calls with no dict re-packing
    assert isinstance(rec, ops.OverlapPolicy)
    assert rec.mode_for("ag_matmul") in overlap.transports_for(
        "ag_matmul", include_baseline=True)
    assert rec.mode_for("matmul_rs") in overlap.transports_for(
        "matmul_rs", include_baseline=True)
    assert rec.resolve("ag_matmul").chunks >= 1
    assert rec.resolve("matmul_rs").chunks >= 1
    assert rec.backend in overlap.BACKENDS
    # CPU test host: the emulated-DMA kernel backend is a correctness
    # vehicle, not a fast path — the tuner must recommend graph here
    assert rec.resolve("ag_matmul").backend == "graph"
    # latency-bound ops keep their one-shot defaults in the policy map
    assert rec.mode_for("a2a_ep") == "one_shot"
    assert rec.mode_for("flash_decode") == "one_shot"
    # the carry-passing / compound-mesh ops enumerate too: ring attention
    # follows the AG regime pick (clamped to its transports) and the
    # 2-level ops resolve to their single two_level transport
    assert rec.mode_for("ring_attention") in overlap.transports_for(
        "ring_attention")
    assert rec.mode_for("ag_matmul_2level") == "two_level"
    assert rec.mode_for("matmul_rs_2level") == "two_level"


def test_recommend_backend_enumerates_registry():
    from repro.core import overlap

    # EVERY registry op exposes both backends to the tuner — the last
    # fwd-less engine entries (ring attention, the 2-level compound-mesh
    # ops) gained kernel lowerings via the carry-passing / two-axis
    # executor protocols, so there is no graph-only tail left
    for name in overlap.registry():
        assert overlap.backends_for(name) == ("graph", "kernel"), name
    # the newly kernel-capable bindings, by name
    assert overlap.get("ring_attention").kernel_transports == (
        "ring", "one_shot")
    assert overlap.get("ag_matmul_2level").kernel_transports == ("two_level",)
    assert overlap.get("matmul_rs_2level").kernel_transports == ("two_level",)


def test_analytic_rs_enumerates_sub_chunks():
    # n divisible by 4: the ring candidate set includes rs_chunks in
    # {1,2,4}; whatever wins must be one of them
    c = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16, max_sub=4,
                                 candidates=("ring",))
    assert c.mode == "ring"
    assert c.chunks_per_rank in (1, 2, 4)


def test_analytic_respects_link_bandwidth():
    slow = hw.HardwareSpec("slow", 197e12, 819e9, 1e9, 1, 16 << 30, 128 << 20)
    fast = hw.HardwareSpec("fast", 197e12, 819e9, 400e9, 4, 16 << 30, 128 << 20)
    c_slow = tuner.analytic_ag_matmul(1024, 4096, 4096, 16, spec=slow)
    c_fast = tuner.analytic_ag_matmul(1024, 4096, 4096, 16, spec=fast)
    assert c_slow.t_total > c_fast.t_total


def test_empirical_tuner_whole_step_protocol():
    """The tuner times the whole wrapped step, resets between configs, and
    picks the global argmin."""
    calls = {"reset": 0}

    def make_step(cfg):
        import time

        def step():
            # coarse 60ms granularity: robust to single-core scheduling noise
            time.sleep(0.06 * cfg)
            return jnp.zeros(())

        return step

    def reset():
        calls["reset"] += 1

    res = tuner.tune(make_step, [3, 1, 2], reset=reset, warmup=1, iters=2)
    assert res.config == 1
    # reset after every execution (warmup + iters per config)
    assert calls["reset"] == 3 * (1 + 2)
    assert set(res.all_timings) == {"1", "2", "3"}


def test_tune_default_reset_clears_emulated_shmem_state():
    """On CPU the tuner's default reset is ``shmem.emulated.reset``:
    stale symmetric-heap / signal-slot state left by a kernel-backend
    candidate cannot leak into (skew or deadlock) the next timed one."""
    from repro.shmem import emulated as em

    assert tuner.default_reset() is em.reset  # CPU test host

    def make_step(cfg):
        return lambda: jnp.zeros(())

    # simulate an aborted kernel candidate's leftover world state
    em._worlds[(999, 12345)] = em._World()
    tuner.tune(make_step, [1, 2], warmup=0, iters=1)  # reset="auto"
    assert (999, 12345) not in em._worlds, "default reset did not run"

    # an explicit reset=None disables the between-candidates cleanup
    em._worlds[(998, 12345)] = em._World()
    try:
        tuner.tune(make_step, [1], reset=None, warmup=0, iters=1)
        assert (998, 12345) in em._worlds
    finally:
        em.reset()


def test_tune_record_stalls_attaches_summary_per_config():
    """``tune(record_stalls=True)`` traces each candidate's timed
    iterations and reduces them into a per-config Summary in
    ``TuneResult.stalls`` — drained BEFORE the between-iteration reset
    (which drops worlds AND trace buffers)."""
    import numpy as np

    from repro import obs
    from repro.shmem import emulated as em

    def make_step(cfg):
        key = (7700 + cfg, 0)

        def step():
            # host-side shmem traffic stands in for a kernel candidate:
            # the pre-satisfied wait records a stall span, the signal
            # records the wire-side event
            em._host_signal(key, "recv", np.int32(0), np.int32(0),
                            np.int32(1), np.int32(1))
            em._host_wait(key, "recv", np.int32(0), np.int32(0),
                          np.int32(1))
            return jnp.zeros(())

        return step

    assert not obs.enabled()
    res = tuner.tune(make_step, [1, 2], warmup=1, iters=2,
                     record_stalls=True)
    assert not obs.enabled(), "tune must restore the prior tracing state"
    assert set(res.stalls) == {"1", "2"}
    for cfg_repr, s in res.stalls.items():
        assert s.n_events > 0
        assert 0.0 <= s.overlap_efficiency <= 1.0
        assert s.labels["config"] == cfg_repr

    # record_stalls off (the default): no tracing, no stalls
    res2 = tuner.tune(make_step, [1], warmup=0, iters=1)
    assert res2.stalls == {}
