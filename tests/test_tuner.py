"""Autotuner tests: analytic model sanity + the paper's whole-step
empirical protocol (§3.8)."""
import jax.numpy as jnp

from repro.core import tuner
from repro import hw


def test_analytic_ag_prefers_overlap_when_compute_bound():
    # huge n_loc -> dot dominates -> any overlapped mode beats "none"
    choice = tuner.analytic_ag_matmul(4096, 8192, 8192, world=16)
    assert choice.mode != "none"
    assert choice.t_total < choice.t_comm + choice.t_compute


def test_analytic_ag_small_message_prefers_one_shot():
    # tiny per-step compute, tiny message: latency regime
    choice = tuner.analytic_ag_matmul(8, 256, 64, world=16)
    assert choice.mode in ("one_shot", "bidir")


def test_analytic_rs_overlap_wins_when_balanced():
    c = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16)
    # comm-heavy regime: a ring transport (uni- or bidirectional) beats
    # the serialized baseline and the bandwidth-hungry one_shot
    assert c.mode in ("ring", "bidir")
    assert c.t_total <= c.t_compute + c.t_comm + 1e-9


def test_analytic_candidates_come_from_registry():
    from repro.core import overlap

    # every transport the registry declares is considered (plus baseline)
    assert set(overlap.transports_for("ag_matmul", include_baseline=True)) == {
        "none", "ring", "bidir", "one_shot"}
    assert set(overlap.transports_for("matmul_rs", include_baseline=True)) == {
        "none", "ring", "bidir", "one_shot"}
    # an op-restricted candidate list narrows the search
    only_ring = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16,
                                         candidates=("ring",))
    assert only_ring.mode == "ring"


def test_recommend_overlap_modes_returns_policy():
    from repro import ops
    from repro.core import overlap

    rec = tuner.recommend_overlap_modes(4096, 8192, 8192, world=16)
    # the recommendation IS an OverlapPolicy — consumable by
    # ParallelConfig.overlap / repro.ops calls with no dict re-packing
    assert isinstance(rec, ops.OverlapPolicy)
    assert rec.mode_for("ag_matmul") in overlap.transports_for(
        "ag_matmul", include_baseline=True)
    assert rec.mode_for("matmul_rs") in overlap.transports_for(
        "matmul_rs", include_baseline=True)
    assert rec.resolve("ag_matmul").chunks >= 1
    assert rec.resolve("matmul_rs").chunks >= 1
    assert rec.backend in overlap.BACKENDS
    # CPU test host: the emulated-DMA kernel backend is a correctness
    # vehicle, not a fast path — the tuner must recommend graph here
    assert rec.resolve("ag_matmul").backend == "graph"
    # latency-bound ops keep their one-shot defaults in the policy map
    assert rec.mode_for("a2a_ep") == "one_shot"
    assert rec.mode_for("flash_decode") == "one_shot"
    # the carry-passing / compound-mesh ops enumerate too: ring attention
    # follows the AG regime pick (clamped to its transports) and the
    # 2-level ops resolve to their single two_level transport
    assert rec.mode_for("ring_attention") in overlap.transports_for(
        "ring_attention")
    assert rec.mode_for("ag_matmul_2level") == "two_level"
    assert rec.mode_for("matmul_rs_2level") == "two_level"


def test_analytic_ring_attention_placement():
    # causal at world >= 4: the balanced owner map halves the lockstep
    # critical path (contiguous charges the last rank ~the dense block
    # count), so zigzag is the interior optimum — not a tie broken by
    # enumeration order
    for world in (4, 8):
        ch = tuner.analytic_ring_attention(256, 64, world, causal=True)
        assert ch.placement == "zigzag", ch
        contig = tuner.analytic_ring_attention(
            256, 64, world, causal=True, placements=("contiguous",))
        assert ch.t_total < contig.t_total
    # the charged fractions themselves: contiguous -> ~1 - 1/(2W),
    # zigzag/striped -> ~1/2, and zigzag <= striped (no +1/(2*s_loc) tail)
    fc = tuner.causal_flop_fraction("contiguous", 8, 256)
    fz = tuner.causal_flop_fraction("zigzag", 8, 256)
    fs = tuner.causal_flop_fraction("striped", 8, 256)
    assert abs(fc - (1 - 1 / 16)) < 1e-2
    assert abs(fz - 0.5) < 1e-2 and fz <= fs < fc
    # non-causal: placements are FLOP-identical -> contiguous is kept
    # (strict-< selection) and forcing zigzag changes nothing
    nc = tuner.analytic_ring_attention(256, 64, 8, causal=False)
    assert nc.placement == "contiguous"
    ncz = tuner.analytic_ring_attention(256, 64, 8, causal=False,
                                        placements=("zigzag",))
    assert nc.t_total == ncz.t_total
    # recommend_overlap_modes lands the pick as a policy placement entry,
    # clamped off ops that never declared placements
    rec = tuner.recommend_overlap_modes(4096, 8192, 8192, world=16)
    assert rec.resolve("ring_attention").placement == "zigzag"
    assert rec.resolve("ag_matmul").placement == "contiguous"


def test_recommend_backend_enumerates_registry():
    from repro.core import overlap

    # EVERY registry op exposes both backends to the tuner — the last
    # fwd-less engine entries (ring attention, the 2-level compound-mesh
    # ops) gained kernel lowerings via the carry-passing / two-axis
    # executor protocols, so there is no graph-only tail left
    for name in overlap.registry():
        assert overlap.backends_for(name) == ("graph", "kernel"), name
    # the newly kernel-capable bindings, by name
    assert overlap.get("ring_attention").kernel_transports == (
        "ring", "one_shot")
    assert overlap.get("ag_matmul_2level").kernel_transports == ("two_level",)
    assert overlap.get("matmul_rs_2level").kernel_transports == ("two_level",)


def test_analytic_rs_enumerates_sub_chunks():
    # n divisible by 4: the ring candidate set includes rs_chunks in
    # {1,2,4}; whatever wins must be one of them
    c = tuner.analytic_matmul_rs(4096, 2048, 8192, world=16, max_sub=4,
                                 candidates=("ring",))
    assert c.mode == "ring"
    assert c.chunks_per_rank in (1, 2, 4)


def test_analytic_respects_link_bandwidth():
    slow = hw.HardwareSpec("slow", 197e12, 819e9, 1e9, 1, 16 << 30, 128 << 20)
    fast = hw.HardwareSpec("fast", 197e12, 819e9, 400e9, 4, 16 << 30, 128 << 20)
    c_slow = tuner.analytic_ag_matmul(1024, 4096, 4096, 16, spec=slow)
    c_fast = tuner.analytic_ag_matmul(1024, 4096, 4096, 16, spec=fast)
    assert c_slow.t_total > c_fast.t_total


def test_empirical_tuner_whole_step_protocol():
    """The tuner times the whole wrapped step, resets between configs, and
    picks the global argmin."""
    calls = {"reset": 0}

    def make_step(cfg):
        import time

        def step():
            # coarse 60ms granularity: robust to single-core scheduling noise
            time.sleep(0.06 * cfg)
            return jnp.zeros(())

        return step

    def reset():
        calls["reset"] += 1

    res = tuner.tune(make_step, [3, 1, 2], reset=reset, warmup=1, iters=2)
    assert res.config == 1
    # reset after every execution (warmup + iters per config)
    assert calls["reset"] == 3 * (1 + 2)
    assert set(res.all_timings) == {"1", "2", "3"}


def test_tune_default_reset_clears_emulated_shmem_state():
    """On CPU the tuner's default reset is ``shmem.emulated.reset``:
    stale symmetric-heap / signal-slot state left by a kernel-backend
    candidate cannot leak into (skew or deadlock) the next timed one."""
    from repro.shmem import emulated as em

    assert tuner.default_reset() is em.reset  # CPU test host

    def make_step(cfg):
        return lambda: jnp.zeros(())

    # simulate an aborted kernel candidate's leftover world state
    em._worlds[(999, 12345)] = em._World()
    tuner.tune(make_step, [1, 2], warmup=0, iters=1)  # reset="auto"
    assert (999, 12345) not in em._worlds, "default reset did not run"

    # an explicit reset=None disables the between-candidates cleanup
    em._worlds[(998, 12345)] = em._World()
    try:
        tuner.tune(make_step, [1], reset=None, warmup=0, iters=1)
        assert (998, 12345) in em._worlds
    finally:
        em.reset()


def test_tune_record_stalls_attaches_summary_per_config():
    """``tune(record_stalls=True)`` traces each candidate's timed
    iterations and reduces them into a per-config Summary in
    ``TuneResult.stalls`` — drained BEFORE the between-iteration reset
    (which drops worlds AND trace buffers)."""
    import numpy as np

    from repro import obs
    from repro.shmem import emulated as em

    def make_step(cfg):
        key = (7700 + cfg, 0)

        def step():
            # host-side shmem traffic stands in for a kernel candidate:
            # the pre-satisfied wait records a stall span, the signal
            # records the wire-side event
            em._host_signal(key, "recv", np.int32(0), np.int32(0),
                            np.int32(1), np.int32(1))
            em._host_wait(key, "recv", np.int32(0), np.int32(0),
                          np.int32(1))
            return jnp.zeros(())

        return step

    assert not obs.enabled()
    res = tuner.tune(make_step, [1, 2], warmup=1, iters=2,
                     record_stalls=True)
    assert not obs.enabled(), "tune must restore the prior tracing state"
    assert set(res.stalls) == {"1", "2"}
    for cfg_repr, s in res.stalls.items():
        assert s.n_events > 0
        assert 0.0 <= s.overlap_efficiency <= 1.0
        assert s.labels["config"] == cfg_repr

    # record_stalls off (the default): no tracing, no stalls
    res2 = tuner.tune(make_step, [1], warmup=0, iters=1)
    assert res2.stalls == {}

def test_search_candidates_come_from_registry():
    from repro.core import overlap

    grid = tuner.search_candidates("ag_matmul", chunks=(1, 2))
    modes = {m for m, _, _, _ in grid}
    assert modes == set(overlap.transports_for("ag_matmul",
                                               include_baseline=True))
    # the chunk axis only where the transport pipelines; baseline and
    # one_shot stay x1
    assert all(n == 1 for m, _, n, _ in grid if m in ("none", "one_shot"))
    assert any(n == 2 for m, _, n, _ in grid if m == "ring")
    # pairs the registry would clamp away never appear
    assert all(overlap.resolve_backend("ag_matmul", b, m) == b
               for m, b, _, _ in grid)
    assert all(overlap.resolve_wire("ag_matmul", w, m) == w
               for m, _, _, w in grid)
    # the fused boundary declaration enrolls automatically
    fused = tuner.search_candidates("matmul_rs_ag_matmul", chunks=(1, 2))
    assert {m for m, _, _, _ in fused} == {"none", "ring", "one_shot"}
    assert ("ring", "kernel", 2, "f32") in fused


def test_search_caches_per_op_shape_world_hw(tmp_path):
    """The PR-9 acceptance contract: a second identical ``search``
    performs ZERO new timings (``SEARCH_TIMINGS`` pinned); the cache
    round-trips through JSON; the searched policy round-trips through
    JSON and resolves per layer shape."""
    from repro import ops
    from repro.core import overlap

    tuner.clear_search_cache()

    def make_step(shape, resolved):
        assert isinstance(resolved, ops.ResolvedOverlap)
        return lambda: jnp.zeros(())

    shapes = [((64, 128), (128, 256)), ((64, 256), (256, 64))]
    n_grid = len(tuner.search_candidates("ag_matmul"))
    t0 = tuner.SEARCH_TIMINGS
    pol = tuner.search(make_step, "ag_matmul", shapes, world=4,
                       reset=None, warmup=0, iters=1)
    n_first = tuner.SEARCH_TIMINGS - t0
    assert n_first == 2 * n_grid  # one timed iter per candidate per shape
    assert isinstance(pol, ops.OverlapPolicy)
    for shp in shapes:
        r = pol.resolve("ag_matmul", shape=shp)
        assert r.mode in overlap.transports_for("ag_matmul",
                                                include_baseline=True)
        assert r.chunks >= 1

    # second identical search: served from cache, ZERO new timings
    pol2 = tuner.search(make_step, "ag_matmul", shapes, world=4,
                        reset=None, warmup=0, iters=1)
    assert tuner.SEARCH_TIMINGS - t0 == n_first, "cache miss on identical key"
    assert pol2 == pol

    # a different world is a different site: times again
    tuner.search(make_step, "ag_matmul", shapes[:1], world=8,
                 reset=None, warmup=0, iters=1)
    assert tuner.SEARCH_TIMINGS - t0 == n_first + n_grid

    # cache JSON round-trip: reload, then zero new timings again
    path = tmp_path / "search_cache.json"
    tuner.save_search_cache(path)
    tuner.clear_search_cache()
    assert tuner.load_search_cache(path) == 3  # 2 shapes@w4 + 1 shape@w8
    t1 = tuner.SEARCH_TIMINGS
    pol3 = tuner.search(make_step, "ag_matmul", shapes, world=4,
                        reset=None, warmup=0, iters=1)
    assert tuner.SEARCH_TIMINGS == t1, "loaded cache did not serve"
    assert pol3 == pol

    # the searched policy itself ships as JSON and still resolves
    back = ops.OverlapPolicy.from_json(pol.to_json())
    assert back == pol
    assert back.resolve("ag_matmul", shape=shapes[0]) == \
        pol.resolve("ag_matmul", shape=shapes[0])
    tuner.clear_search_cache()
