"""PagedKVCache allocator unit tests (pure host NumPy — no jax)."""
import numpy as np
import pytest

from repro.serve.kvcache import PagedKVCache


def test_geometry_and_scratch_page():
    kv = PagedKVCache(batch=4, max_len=33, page_size=8)
    assert kv.pages_per_slot == 5          # ceil(33 / 8)
    # dense-equivalent default: every slot can hold max_len, + scratch
    assert kv.num_pages == 1 + 4 * 5
    # page 0 (scratch) is never on the free list
    assert 0 not in kv._free[0]
    assert kv.free_pages(0) == kv.num_pages - 1


def test_alloc_is_all_or_nothing():
    kv = PagedKVCache(batch=2, max_len=24, page_size=8, num_pages=4)
    # 3 usable pages; a 4-page request must fail without touching state
    before = kv.free_pages(0)
    assert not kv.alloc(0, 25)
    assert kv.free_pages(0) == before
    assert kv.alloc(0, 24)                 # 3 pages fit
    assert kv.free_pages(0) == 0
    # occupied slot cannot be re-allocated
    assert not kv.can_alloc(0, 1)


def test_free_returns_pages_and_zeros_table():
    kv = PagedKVCache(batch=2, max_len=32, page_size=8)
    assert kv.alloc(0, 20)                 # 3 pages
    row = kv.table[0].copy()
    assert row[:3].min() > 0               # real pages, never scratch
    assert (row[3:] == 0).all()            # unallocated entries -> scratch
    kv.lens[0] = 17
    kv.free(0)
    assert (kv.table[0] == 0).all()        # successor can't reach old KV
    assert kv.lens[0] == 0
    assert kv.free_pages(0) == kv.num_pages - 1


def test_free_list_reuse_is_lifo():
    kv = PagedKVCache(batch=2, max_len=32, page_size=8)
    assert kv.alloc(0, 16)
    first = list(kv.table[0][:2])
    kv.free(0)
    assert kv.alloc(1, 16)
    # freed pages are reused first, in the same order
    assert list(kv.table[1][:2]) == first


def test_per_shard_free_lists_are_isolated():
    kv = PagedKVCache(batch=4, max_len=16, page_size=8, num_pages=3,
                      dp_shards=2)
    # slots 0,1 -> shard 0; slots 2,3 -> shard 1; 2 usable pages each
    assert kv.shard(1) == 0 and kv.shard(2) == 1
    assert kv.alloc(0, 16)                 # exhausts shard 0
    assert not kv.can_alloc(1, 8)          # shard 0 empty...
    assert kv.can_alloc(2, 16)             # ...but shard 1 untouched
    assert kv.alloc(2, 16)
    assert kv.occupancy() == 1.0
    kv.free(0)
    assert kv.occupancy() == 0.5


def test_pages_needed_ceil_and_min_one():
    kv = PagedKVCache(batch=1, max_len=32, page_size=8)
    assert kv.pages_needed(0) == 1         # even empty requests hold a page
    assert kv.pages_needed(8) == 1
    assert kv.pages_needed(9) == 2


def test_pool_too_small_raises():
    with pytest.raises(ValueError):
        PagedKVCache(batch=1, max_len=64, page_size=8, num_pages=4)


def test_allocated_pages_are_disjoint():
    kv = PagedKVCache(batch=4, max_len=16, page_size=8)
    used = []
    for slot in range(4):
        assert kv.alloc(slot, 16)
        used.extend(kv.table[slot][:2])
    assert len(set(used)) == len(used)     # no page belongs to two slots
    assert 0 not in used
    assert np.all(np.asarray(used) < kv.num_pages)
