"""Property tests for the swizzle schedules (paper Fig. 7/8/10)."""
import os
import sys
sys.path.insert(0, os.path.dirname(__file__))

import proptest as pt
from repro.core import schedules as S


@pt.given(examples=30, world=pt.integers(1, 64))
def test_ring_ag_valid(world):
    assert S.validate_ring_ag(world)


@pt.given(examples=30, world=pt.integers(1, 64))
def test_ring_rs_valid(world):
    assert S.validate_ring_rs(world)


@pt.given(examples=30, world=pt.integers(2, 32), rank=pt.integers(0, 31))
def test_one_shot_order_is_permutation(world, rank):
    rank = rank % world
    assert S.is_permutation(S.one_shot_ag_order(world, rank), world)
    # local chunk first — zero-latency start (Alg. 4 property)
    assert S.one_shot_ag_order(world, rank)[0] == rank


@pt.given(examples=30, world=pt.integers(3, 32), rank=pt.integers(0, 31))
def test_bidir_covers_all_half_chunks(world, rank):
    rank = rank % world
    pairs = S.bidir_ag_order(world, rank)
    fwd = [p[0] for p in pairs]
    bwd = [p[1] for p in pairs]
    # every chunk's top half and bottom half each visited exactly once
    assert S.is_permutation(fwd, world)
    assert S.is_permutation(bwd, world)
    assert fwd[0] == rank and bwd[0] == rank


@pt.given(examples=20, no=pt.integers(2, 4), ni=pt.integers(2, 16),
          orank=pt.integers(0, 3), irank=pt.integers(0, 15))
def test_hierarchical_rs_regions(no, ni, orank, irank):
    orank, irank = orank % no, irank % ni
    steps = S.hierarchical_rs_schedule(no, ni, orank, irank)
    regions = [s.region for s in steps]
    assert S.is_permutation(regions, no)
    # Fig. 10: own pod LAST (its inter-pod transfer does not exist)
    assert regions[-1] == orank
    for s in steps:
        assert S.is_permutation(list(s.inner_order), ni)


@pt.given(examples=20, no=pt.integers(2, 4), ni=pt.integers(2, 16),
          orank=pt.integers(0, 3), irank=pt.integers(0, 15))
def test_hierarchical_ag_regions(no, ni, orank, irank):
    orank, irank = orank % no, irank % ni
    steps = S.hierarchical_ag_schedule(no, ni, orank, irank)
    regions = [s.region for s in steps]
    assert S.is_permutation(regions, no)
    # own pod FIRST — compute starts on local data while peer pods stream
    assert regions[0] == orank


@pt.given(examples=40, world=pt.integers(1, 16),
          s_loc=pt.sampled_from([2, 4, 8, 16]),
          placement=pt.sampled_from(["contiguous", "zigzag", "striped"]))
def test_placement_owner_map_valid(world, s_loc, placement):
    assert S.validate_placement(placement, world, s_loc)
    # every global row owned exactly once, local order == position order
    seen = []
    for r in range(world):
        rows = S.placement_rows(placement, world, r, s_loc)
        assert all(a < b for a, b in zip(rows, rows[1:]))
        seen.extend(rows)
    assert sorted(seen) == list(range(world * s_loc))
    # causal coverage exact: the per-rank pair shares tile the triangle
    s_tot = world * s_loc
    assert sum(S.causal_pairs(placement, world, r, s_loc)
               for r in range(world)) == s_tot * (s_tot + 1) // 2


@pt.given(examples=20, world=pt.integers(2, 16),
          s_loc=pt.sampled_from([4, 8, 16]))
def test_placement_balance(world, s_loc):
    # zigzag equalizes causal work EXACTLY (one early + one late
    # half-chunk per rank); striped is near-balanced with an O(W/S)
    # tail; contiguous concentrates it on the last rank (-> 2 at large
    # world)
    assert abs(S.causal_imbalance("zigzag", world, s_loc) - 1.0) < 1e-9
    striped = S.causal_imbalance("striped", world, s_loc)
    contig = S.causal_imbalance("contiguous", world, s_loc)
    assert striped <= 1.3
    if world >= 4:
        assert contig > 1.5 > striped


@pt.given(examples=15, m=pt.sampled_from([4, 8, 16]), n=pt.integers(1, 6),
          world=pt.sampled_from([2, 4]), rank=pt.integers(0, 3))
def test_swizzled_grid_order(m, n, world, rank):
    rank = rank % world
    order = S.swizzled_grid_order(m, n, rank, world)
    assert len(order) == m * n
    assert len(set(order)) == m * n  # visits every tile once
    # first tile belongs to this rank's own chunk
    first_m = order[0][0]
    per = m // world
    assert rank * per <= first_m < (rank + 1) * per
